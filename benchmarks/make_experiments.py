"""Assemble EXPERIMENTS.md sections SSDry-run and SSRoofline from the
dry-run result JSONs.  Run after the sweeps:

    PYTHONPATH=src python benchmarks/make_experiments.py > /tmp/tables.md
"""
import json
import pathlib

HERE = pathlib.Path(__file__).resolve().parent
DRY = HERE / "results" / "dryrun"

ARCH_ORDER = [
    "llama-3.2-vision-11b", "zamba2-7b", "whisper-medium", "qwen2-1.5b",
    "minicpm-2b", "smollm-135m", "qwen2.5-3b", "mamba2-2.7b", "dbrx-132b",
    "grok-1-314b",
]
CELLS = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
EDM = ["edm-fish1_normo", "edm-subject6", "edm-subject11"]


def load(arch, cell, mesh, opt=False):
    suffix = "__opt" if opt else ""
    for p in DRY.glob(f"{arch}__{cell}*__{mesh}{suffix}.json"):
        if not opt and p.name.endswith("__opt.json"):
            continue
        return json.loads(p.read_text())
    return None


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def improvement_hint(r):
    rl = r["roofline"]
    bn = rl["bottleneck"]
    if bn == "memory":
        return "cut materialized activation slabs (chunked attention / fused kernels)"
    if bn == "collective":
        kinds = rl["coll_by_kind"]
        top = max(kinds, key=kinds.get)
        return f"reduce {top} traffic (sharding layout / compression)"
    return "already compute-bound: raise MFU via larger per-chip tiles"


def table(mesh):
    rows = [
        "| arch | cell | t_comp (s) | t_mem (s) | t_coll (s) | bottleneck | "
        "roofline frac | peak GiB/dev | model/HLO flops | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER + EDM:
        cells = CELLS if not arch.startswith("edm-") else [""]
        for cell in cells:
            r = load(arch, cell, mesh)
            if r is None:
                continue
            if "skipped" in r:
                rows.append(f"| {arch} | {cell} | — | — | — | SKIP | — | — | — | {r['skipped']} |")
                continue
            rl = r["roofline"]
            ratio = r.get("useful_flops_ratio", 0.0)
            rows.append(
                f"| {r['arch']} | {r['cell']} | {rl['t_compute_s']:.4f} | "
                f"{rl['t_memory_s']:.4f} | {rl['t_collective_s']:.4f} | "
                f"{rl['bottleneck']} | {rl['roofline_fraction']:.3f} | "
                f"{fmt_bytes(r['memory']['peak_bytes_per_device'])} | "
                f"{ratio:.3f} | {improvement_hint(r)} |"
            )
    return "\n".join(rows)


def opt_table(mesh):
    """Baseline vs beyond-paper-optimized, per cell (step-time = max term)."""
    rows = [
        "| arch | cell | baseline step (s) | optimized step (s) | speedup | "
        "peak GiB base→opt | bottleneck base→opt |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for cell in CELLS:
            b = load(arch, cell, mesh)
            o = load(arch, cell, mesh, opt=True)
            if not b or not o or "skipped" in b or "skipped" in o:
                continue
            tb = max(b["roofline"][k] for k in ("t_compute_s", "t_memory_s", "t_collective_s"))
            to = max(o["roofline"][k] for k in ("t_compute_s", "t_memory_s", "t_collective_s"))
            rows.append(
                f"| {arch} | {cell} | {tb:.4f} | {to:.4f} | "
                f"**{tb / max(to, 1e-9):.1f}×** | "
                f"{fmt_bytes(b['memory']['peak_bytes_per_device'])}→"
                f"{fmt_bytes(o['memory']['peak_bytes_per_device'])} | "
                f"{b['roofline']['bottleneck']}→{o['roofline']['bottleneck']} |"
            )
    return "\n".join(rows)


def main():
    print("## Single-pod mesh 16x16 (256 chips) — baseline\n")
    print(table("16x16"))
    print("\n## Multi-pod mesh 2x16x16 (512 chips) — baseline\n")
    print(table("2x16x16"))
    print("\n## Baseline vs beyond-paper optimized (16x16)\n")
    print(opt_table("16x16"))


if __name__ == "__main__":
    main()
