"""Hillclimb driver: lower a cell with a named variant and print the
roofline delta vs the recorded baseline.  Results land in
benchmarks/results/hillclimb/ and the narrative in EXPERIMENTS.md SSPerf.

  PYTHONPATH=src python -m benchmarks.hillclimb --target minicpm-prefill --variant chunked_attn
  PYTHONPATH=src python -m benchmarks.hillclimb --target edm --variant unroll
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "hillclimb"

# variant name -> (cfg overrides, policy overrides)
LM_VARIANTS = {
    "baseline": ({}, {}),
    "chunked_attn": ({"attn_impl": "chunked", "attn_chunk": 1024}, {}),
    "chunked_attn_512": ({"attn_impl": "chunked", "attn_chunk": 512}, {}),
    "chunked_attn_2048": ({"attn_impl": "chunked", "attn_chunk": 2048}, {}),
    "last_logits": ({"prefill_last_only": True}, {}),
    "chunked+last_logits": (
        {"attn_impl": "chunked", "attn_chunk": 1024, "prefill_last_only": True}, {}),
    "dp_only": ({}, {"dp_only": True, "fsdp": False}),
    "chunked+dp_only": (
        {"attn_impl": "chunked", "attn_chunk": 1024},
        {"dp_only": True, "fsdp": False}),
    "chunked+dp_only+last": (
        {"attn_impl": "chunked", "attn_chunk": 1024, "prefill_last_only": True},
        {"dp_only": True, "fsdp": False}),
    "fsdp_off": ({}, {"fsdp": False}),
    "dp_only+fsdp": ({}, {"dp_only": True, "fsdp": True}),
    "chunked+dp_only+fsdp": (
        {"attn_impl": "chunked", "attn_chunk": 1024},
        {"dp_only": True, "fsdp": True}),
    "seq_shard": ({"attn_seq_shard": True}, {}),
    "chunked+seq_shard": (
        {"attn_impl": "chunked", "attn_chunk": 1024, "attn_seq_shard": True}, {}),
    "chunked+last+seq_shard": (
        {"attn_impl": "chunked", "attn_chunk": 1024, "prefill_last_only": True,
         "attn_seq_shard": True}, {}),
    "chunked2k+last+seq_shard": (
        {"attn_impl": "chunked", "attn_chunk": 2048, "prefill_last_only": True,
         "attn_seq_shard": True}, {}),
    "chunked4k+last+seq_shard": (
        {"attn_impl": "chunked", "attn_chunk": 4096, "prefill_last_only": True,
         "attn_seq_shard": True}, {}),
    "chunked8k+last+seq_shard": (
        {"attn_impl": "chunked", "attn_chunk": 8192, "prefill_last_only": True,
         "attn_seq_shard": True}, {}),
}

TARGETS = {
    "minicpm-prefill": ("minicpm-2b", "prefill_32k"),
    "minicpm-train": ("minicpm-2b", "train_4k"),
    "whisper-train": ("whisper-medium", "train_4k"),
    "smollm-train": ("smollm-135m", "train_4k"),
    "mamba2-train": ("mamba2-2.7b", "train_4k"),
    "grok-train": ("grok-1-314b", "train_4k"),
}

EDM_VARIANTS = {
    "baseline": {},
    "unroll": {"knn_impl": "unroll"},
    "rebuild": {"knn_impl": "rebuild"},
    "bf16_dist": {"dist_dtype": "bfloat16"},
    "unroll+bf16": {"knn_impl": "unroll", "dist_dtype": "bfloat16"},
    "rebuild+bf16": {"knn_impl": "rebuild", "dist_dtype": "bfloat16"},
    "lib4": {"lib_block": 4},
    "unroll+lib4": {"knn_impl": "unroll", "lib_block": 4},
    "rebuild+lib4": {"knn_impl": "rebuild", "lib_block": 4},
    "rebuild+lib4+tb4096": {"knn_impl": "rebuild", "lib_block": 4, "target_block": 4096},
    "unroll+lib2": {"knn_impl": "unroll", "lib_block": 2},
    "unroll+lib1": {"knn_impl": "unroll", "lib_block": 1},
    "blocked4+lib4": {"knn_impl": "blocked:4", "lib_block": 4},
    "blocked5+lib2": {"knn_impl": "blocked:5", "lib_block": 2},
    "blocked4+lib2": {"knn_impl": "blocked:4", "lib_block": 2},
}


TC_VARIANTS = {"bf16_moments": {"moment_dtype": "bfloat16"}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)

    if args.target == "edm":
        from repro.configs.edm_datasets import SUBJECT11
        from repro.launch.edm_dryrun import lower_edm_cell

        cfg = dataclasses.replace(SUBJECT11.edm, **EDM_VARIANTS[args.variant])
        res = lower_edm_cell("subject11", multi_pod=args.multi_pod, cfg=cfg)
        res["variant"] = args.variant
    else:
        from repro.configs import get_config
        from repro.launch.dryrun import lower_cell

        arch, cell = TARGETS[args.target]
        parts = args.variant.split("&")
        cfg_kw, pol_kw = LM_VARIANTS[parts[0]]
        cfg = dataclasses.replace(get_config(arch), **cfg_kw)
        if len(parts) > 1:
            import repro.launch.dryrun as DR

            tc_kw = TC_VARIANTS[parts[1]]
            orig = DR.train_config_for
            DR.train_config_for = lambda a: dataclasses.replace(orig(a), **tc_kw)
        res = lower_cell(arch, cell, multi_pod=args.multi_pod, cfg=cfg,
                         policy_kw=pol_kw, variant=args.variant)

    out = RESULTS / f"{args.target}__{args.variant}.json"
    out.write_text(json.dumps(res, indent=2))
    rl = res["roofline"]
    print(
        f"{args.target} / {args.variant}: "
        f"t_comp={rl['t_compute_s']:.4f} t_mem={rl['t_memory_s']:.4f} "
        f"t_coll={rl['t_collective_s']:.4f} bottleneck={rl['bottleneck']} "
        f"frac={rl['roofline_fraction']:.4f} "
        f"peak={res['memory']['peak_bytes_per_device']/2**30:.1f}GiB"
    )


if __name__ == "__main__":
    main()
