"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Scales are laptop-sized
(this container is 1 CPU core); every benchmark also reports the derived
quantity the paper's figure plots (speedup, scaling exponent, fraction),
and the complexity-model extrapolation to the paper's own dataset sizes.

  Table II  -> naive (cppEDM Alg.1) vs improved (mpEDM Alg.2) causal map
  Fig 3     -> strong scaling over fake-device worker counts (subprocess)
  Fig 6     -> runtime vs number of series N
  Fig 7     -> runtime vs series length L
  Fig 8     -> CCM phase breakdown: kNN tables vs lookup
  Fig 9     -> multi-E table construction: cumulative-E scan vs per-E
               rebuild (the TPU analogue of the paper's GPU-vs-CPU kernel)
  roofline  -> summary of the dry-run table (benchmarks/results/dryrun)

Regression gate: ``python benchmarks/run.py --check phase2 knn
significance`` reruns the named benches with their JSON output
redirected to benchmarks/results/fresh/ (CI uploads these as
artifacts), compares the gated timings against the COMMITTED repo-root
BENCH_*.json baselines, and exits nonzero on any >1.5x slowdown.
``--check knn`` additionally runs the knn-gate: streaming table builds
must stay at-or-below the slab historical baseline at EVERY benched Lc
on both engines (the contract that justified deleting the slab path).
Refresh a baseline by running the bench WITHOUT --check (writes the
repo-root JSON) and committing it.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import (  # noqa: E402
    EDMConfig,
    all_futures,
    ccm_block,
    ccm_matrix,
    ccm_pair_naive,
    knn_table_single_E,
    knn_tables_dense,
    lag_matrix,
    simplex_batch,
)
from repro.data.synthetic import dummy_brain  # noqa: E402

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
REPO = pathlib.Path(__file__).resolve().parents[1]
# Where benches write their BENCH_*.json: the repo root by default
# (committed baselines), benchmarks/results/fresh/ under --check.
BENCH_DIR = REPO


def _write_bench(name: str, out: dict) -> None:
    BENCH_DIR.mkdir(parents=True, exist_ok=True)
    (BENCH_DIR / name).write_text(json.dumps(out, indent=2))


def _time(fn, *args, reps=3) -> float:
    """median wall time (s) with block_until_ready."""
    fn(*args)  # warmup/compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")


# ---------------------------------------------------------------- Table II
def table2_speedup():
    """Improved Alg.2 vs naive Alg.1 full causal map."""
    N, L = 24, 400
    cfg = EDMConfig(E_max=8)
    ts = jnp.asarray(dummy_brain(N, L))
    _, optE = simplex_batch(ts, cfg)
    ts_fut = all_futures(ts, cfg)

    t_improved = _time(lambda: jax.block_until_ready(ccm_matrix(ts, optE, cfg)))
    # naive cost = N^2 single-pair cross maps (measure one, multiply)
    E_med = int(np.median(np.asarray(optE)))
    t_pair = _time(lambda: ccm_pair_naive(ts[0], ts_fut[1], E_med, cfg), reps=5)
    t_naive = t_pair * N * N
    row("table2_improved_ccm", t_improved, f"N={N};L={L}")
    row("table2_naive_ccm_extrap", t_naive, f"pair={t_pair*1e6:.0f}us x N^2")
    row("table2_speedup", t_improved, f"speedup={t_naive / t_improved:.1f}x")
    # complexity-model speedup at the paper's Fish1_Normo scale
    for name, (Np, Lp_) in {"fish1": (53053, 1450), "subject11": (101729, 8528)}.items():
        E = 20
        naive = Np * Np * Lp_ * Lp_ * E
        improved = Np * Lp_ * Lp_ * E + Np * Np * Lp_ * E  # cumulative-E: E not E^2
        row(f"table2_model_{name}", 0.0, f"algorithmic_speedup={naive / improved:.0f}x")


# ------------------------------------------------------------------- Fig 3
def fig3_strong_scaling():
    """Pipeline wall time vs fake-device worker count (subprocess per point)."""
    N, L = 32, 300
    code = """
import time, numpy as np
import jax
from repro.core.pipeline import run_causal_inference
from repro.core.types import EDMConfig
from repro.data.synthetic import dummy_brain
ts = dummy_brain({N}, {L})
cfg = EDMConfig(E_max=5, lib_block=2)
run_causal_inference(ts[:4], cfg)  # warm compile caches
t0 = time.perf_counter()
run_causal_inference(ts, cfg)
print("TIME", time.perf_counter() - t0)
""".format(N=N, L=L)
    # NOTE: fake devices time-share ONE physical core, so wall time cannot
    # drop; what this measures is the SPMD partitioning OVERHEAD of the
    # worker decomposition (paper Fig 3's linearity comes from the same
    # zero-communication structure, whose overhead we bound here).
    base = None
    for w in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={w}"
        env["PYTHONPATH"] = str(pathlib.Path(__file__).resolve().parents[1] / "src")
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, env=env, timeout=900)
        t = float([l for l in r.stdout.splitlines() if l.startswith("TIME")][0].split()[1])
        base = base or t
        row(f"fig3_workers_{w}", t, f"spmd_overhead={100 * (t - base) / base:.0f}%")


# ------------------------------------------------------------------- Fig 6/7
def fig6_scaling_N():
    L, cfg = 300, EDMConfig(E_max=5)
    times = {}
    for N in (8, 16, 32):
        ts = jnp.asarray(dummy_brain(N, L, seed=N))
        _, optE = simplex_batch(ts, cfg)
        times[N] = _time(lambda ts=ts, optE=optE: ccm_matrix(ts, optE, cfg))
        row(f"fig6_N{N}", times[N], f"L={L}")
    expo = np.polyfit(np.log(list(times)), np.log(list(times.values())), 1)[0]
    row("fig6_scaling_exponent", 0.0, f"O(N^{expo:.2f})_model_<=2")


def fig7_scaling_L():
    N, cfg = 12, EDMConfig(E_max=5)
    times = {}
    for L in (200, 400, 800):
        ts = jnp.asarray(dummy_brain(N, L, seed=L))
        _, optE = simplex_batch(ts, cfg)
        times[L] = _time(lambda ts=ts, optE=optE: ccm_matrix(ts, optE, cfg))
        row(f"fig7_L{L}", times[L], f"N={N}")
    expo = np.polyfit(np.log(list(times)), np.log(list(times.values())), 1)[0]
    row("fig7_scaling_exponent", 0.0, f"O(L^{expo:.2f})_model_<=2")


# ------------------------------------------------------------------- Fig 8
def fig8_breakdown():
    """CCM phase split: kNN table construction vs lookup (paper Fig 8)."""
    N, L = 32, 500
    cfg = EDMConfig(E_max=8)
    ts = jnp.asarray(dummy_brain(N, L))
    _, optE = simplex_batch(ts, cfg)
    ts_fut = all_futures(ts, cfg)
    Lp = cfg.n_points(L)
    V = lag_matrix(ts[0], cfg.E_max, cfg.tau, Lp)

    from repro.core.knn import (
        knn_tables_all_E_streaming,
        resolve_stream_tile,
        simplex_forecast,
        tables_with_weights,
    )

    tile = resolve_stream_tile(Lp, cfg, profile="host")
    build = jax.jit(
        lambda V: knn_tables_all_E_streaming(V, V, cfg.k_max, True, tile)
    )
    t_knn = _time(lambda: build(V))
    idx, sqd = build(V)
    idx, w = tables_with_weights(idx, sqd)

    def lookup_all():
        e = optE - 1
        return jax.vmap(lambda yf, ee: simplex_forecast(idx[ee], w[ee], yf))(
            ts_fut, e
        )

    t_lookup = _time(jax.jit(lookup_all))
    total = t_knn + t_lookup
    row("fig8_knn_per_series", t_knn, f"{100 * t_knn / total:.0f}%_of_ccm")
    row("fig8_lookup_per_series", t_lookup, f"{100 * t_lookup / total:.0f}%_of_ccm;N={N}")


# ------------------------------------------------------------------- Fig 9
def fig9_multiE_kernel():
    """Cumulative-E scan vs per-E rebuild — the beyond-paper algorithmic
    win on the paper's own hot spot (analogue of its GPU-kernel speedup)."""
    L, E_max = 800, 20
    cfg = EDMConfig(E_max=E_max)
    x = jnp.asarray(dummy_brain(1, L)[0])
    Lp = cfg.n_points(L)
    V = lag_matrix(x, E_max, cfg.tau, Lp)

    t_cum = _time(
        jax.jit(lambda V: knn_tables_dense(V, V, E_max + 1, False)), V
    )

    @jax.jit
    def per_E_rebuild(V):
        return [
            knn_table_single_E(V, V, E, E_max + 1, False, matmul_form=True)
            for E in range(1, E_max + 1)
        ]

    t_reb = _time(per_E_rebuild, V)
    row("fig9_cumulative_multiE", t_cum, f"L={L};E_max={E_max}")
    row("fig9_per_E_rebuild", t_reb, f"speedup={t_reb / t_cum:.1f}x")


def fig9b_knn_impl_variants():
    """Measured wall time of the kNN table-construction variants (SSPerf
    HC3): paper-faithful per-E rebuild vs cumulative-E scan/unroll/blocked.
    Primary evidence for the HC3 variant ordering (XLA cost_analysis cannot
    attribute scan bodies, so these are real timings)."""
    L, cfg = 2000, EDMConfig(E_max=20)
    x = jnp.asarray(dummy_brain(1, L)[0])
    V = lag_matrix(x, cfg.E_max, cfg.tau, cfg.n_points(L))
    times = {}
    for impl in ("rebuild", "scan", "unroll", "blocked:4", "blocked:2"):
        f = jax.jit(
            lambda V, impl=impl: knn_tables_dense(V, V, cfg.k_max, True, impl=impl)
        )
        times[impl] = _time(lambda: f(V))
    base = times["rebuild"]
    for impl, t in times.items():
        row(
            f"fig9b_knn_{impl.replace(':', '')}", t,
            f"vs_paper_faithful_rebuild={base / t:.2f}x",
        )


# ------------------------------------------------------- phase-2 engine bench
def phase2_engine_bench(N=128, L=1000, E_max=20, engine="reference", tile=32):
    """Phase-2 wall clock + host memory: seed path (all-E tables, dense
    host map, synchronous drain) vs optE-bucketed tables + double-buffered
    streaming (DESIGN.md SS3/SS6) vs the 2D target-tiled decomposition
    (DESIGN.md SS7: tables once per chunk + column tiles, NO dense host
    map), through the real pipeline loops including the TileWriter.
    Records engine name, bucket count, tile geometry, and per-variant
    host-allocation peaks (tracemalloc) + process peak RSS to
    BENCH_phase2.json so trajectories stay comparable across backends.
    """
    import resource
    import tempfile
    import tracemalloc

    import jax.numpy as jnp

    from repro.core import make_bucket_plan, make_tile_plans
    from repro.core.pipeline import (
        make_ccm_chunk_fn,
        make_ccm_chunk_fn_bucketed,
        make_ccm_tables_fn_bucketed,
        make_ccm_tile_fn_bucketed,
        _pad_rows,
    )
    from repro.data.store import TileWriter
    from repro.runtime.stream import ChunkStreamer

    mesh = jax.make_mesh((len(jax.devices()),), ("workers",))
    base = dict(E_max=E_max, engine=engine, lib_block=8)
    cfg_seed = EDMConfig(**base, bucketed=False, stream_depth=1)
    cfg_new = EDMConfig(**base, bucketed=True, stream_depth=2)
    cfg_tiled = EDMConfig(**base, bucketed=True, stream_depth=2, target_tile=tile)
    chunk = mesh.size * cfg_seed.lib_block

    ts = jnp.asarray(dummy_brain(N, L, seed=42))
    _, optE = simplex_batch(ts, cfg_new)
    optE_np = np.asarray(optE)
    plan, order = make_bucket_plan(optE_np)
    ts_fut = all_futures(ts, cfg_new)
    ts_np = np.asarray(ts)

    def run_loop(chunk_fn, args_of_rows, unsort, depth, out_dir):
        # seed-shaped loop: full-width row blocks into a DENSE host map
        writer = TileWriter(out_dir, N)
        rho = np.zeros((N, N), np.float32)

        def drain(tag, rows_dev):
            row0, valid = tag
            rows_np = unsort(rows_dev)[:valid]
            rho[row0 : row0 + valid] = rows_np
            writer.write_block(row0, rows_np)

        t0 = time.perf_counter()
        with ChunkStreamer(drain, depth=depth) as s:
            for row0 in range(0, N, chunk):
                valid = min(chunk, N - row0)
                rows = _pad_rows(ts_np[row0 : row0 + chunk], chunk)
                s.submit((row0, valid), chunk_fn(*args_of_rows(rows)))
        return time.perf_counter() - t0, rho

    inv = np.argsort(order)
    ts_fut_sorted = ts_fut[jnp.asarray(order)]  # hoisted, as in the pipeline
    ts_fut_sorted_np = np.asarray(ts_fut_sorted)
    tile_plans = make_tile_plans(plan, tile)
    tables_fn = make_ccm_tables_fn_bucketed(mesh, cfg_tiled, plan)
    tile_fn_for = make_ccm_tile_fn_bucketed(mesh, cfg_tiled)

    def run_loop_tiled(out_dir):
        # DESIGN SS7 loop: tables once per chunk, targets in column tiles,
        # blocks stream to the TileWriter — no dense (N, N) host array;
        # the map is assembled into a memmap afterwards (counted in time).
        writer = TileWriter(out_dir, N)
        writer.ensure_col_order(order)

        def drain(tag, block):
            row0, col0, valid = tag
            writer.write_tile(row0, col0, block[:valid])

        t0 = time.perf_counter()
        with ChunkStreamer(drain, depth=cfg_tiled.stream_depth) as s:
            for row0 in range(0, N, chunk):
                valid = min(chunk, N - row0)
                rows = _pad_rows(ts_np[row0 : row0 + chunk], chunk)
                idx, w = tables_fn(jnp.asarray(rows))
                for c0, seg_plan in tile_plans:
                    fut_tile = jnp.asarray(ts_fut_sorted_np[c0 : c0 + tile])
                    s.submit(
                        (row0, c0, valid), tile_fn_for(seg_plan)(idx, w, fut_tile)
                    )
        rho = writer.assemble(mmap_path=writer.dir / "causal_map" / "data.npy")
        return time.perf_counter() - t0, rho  # rho is a disk-backed memmap

    variants = {
        "seed_all_e_sync": (
            make_ccm_chunk_fn(mesh, cfg_seed),
            lambda rows: (jnp.asarray(rows), ts_fut, optE),
            lambda r: r,
            1,
        ),
        "bucketed_double_buffered": (
            make_ccm_chunk_fn_bucketed(mesh, cfg_new, plan),
            lambda rows: (jnp.asarray(rows), ts_fut_sorted),
            lambda r: r[:, inv],
            2,
        ),
    }
    times, rhos, host_peaks = {}, {}, {}
    for name, (fn, args_of_rows, unsort, depth) in variants.items():
        # warm the compile cache so we time steady-state phase 2
        jax.block_until_ready(fn(*args_of_rows(_pad_rows(ts_np[:chunk], chunk))))
        tracemalloc.start()
        with tempfile.TemporaryDirectory() as d:
            times[name], rhos[name] = run_loop(fn, args_of_rows, unsort, depth, d)
        host_peaks[name] = tracemalloc.get_traced_memory()[1]
        tracemalloc.stop()
        row(f"phase2_{name}", times[name], f"N={N};L={L};E_max={E_max}")

    # warm the tiled fns (tables + every distinct tile signature)
    idx_w, w_w = tables_fn(jnp.asarray(_pad_rows(ts_np[:chunk], chunk)))
    for c0, seg_plan in tile_plans:
        jax.block_until_ready(
            tile_fn_for(seg_plan)(
                idx_w, w_w, jnp.asarray(ts_fut_sorted_np[c0 : c0 + tile])
            )
        )
    tracemalloc.start()
    with tempfile.TemporaryDirectory() as d:
        times["bucketed_tiled"], rho_mm = run_loop_tiled(d)
        # peak captured BEFORE the dense comparison copy below — the copy
        # exists only so err_tiled can be computed after the tempdir (and
        # the memmap's backing file) are gone; it is not part of the
        # tiled path's own memory profile
        host_peaks["bucketed_tiled"] = tracemalloc.get_traced_memory()[1]
        tracemalloc.stop()
        rhos["bucketed_tiled"] = np.array(rho_mm)
    row(
        "phase2_bucketed_tiled", times["bucketed_tiled"],
        f"N={N};L={L};tile={tile};n_col_tiles={len(tile_plans)}",
    )

    err = float(
        np.abs(rhos["seed_all_e_sync"] - rhos["bucketed_double_buffered"]).max()
    )
    err_tiled = float(
        np.abs(rhos["bucketed_double_buffered"] - rhos["bucketed_tiled"]).max()
    )
    speedup = times["seed_all_e_sync"] / times["bucketed_double_buffered"]
    ru_maxrss_kb = int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss)
    row("phase2_speedup", 0.0, f"speedup={speedup:.2f}x;max_drho={err:.1e}")
    row(
        "phase2_tiled_host_peak", 0.0,
        f"host_peak_MiB={host_peaks['bucketed_tiled'] / 2**20:.1f};"
        f"dense_MiB={host_peaks['seed_all_e_sync'] / 2**20:.1f};"
        f"tiled_drho={err_tiled:.1e}",
    )

    out = {
        "bench": "phase2_engine",
        "workload": {"N": N, "L": L, "E_max": E_max},
        "engine": engine,
        "n_buckets": len(plan.buckets),
        "buckets": list(plan.buckets),
        "devices": mesh.size,
        "tile": {
            "target_tile": tile,
            "n_col_tiles": len(tile_plans),
            "n_tile_signatures": len({sp for _, sp in tile_plans}),
            "chunk_rows": chunk,
        },
        "seed_path": {
            "bucketed": False, "stream_depth": 1,
            "phase2_s": times["seed_all_e_sync"],
            "host_peak_bytes": host_peaks["seed_all_e_sync"],
        },
        "new_path": {
            "bucketed": True, "stream_depth": 2,
            "phase2_s": times["bucketed_double_buffered"],
            "host_peak_bytes": host_peaks["bucketed_double_buffered"],
        },
        "tiled_path": {
            "bucketed": True, "stream_depth": 2, "target_tile": tile,
            "phase2_s": times["bucketed_tiled"],
            "host_peak_bytes": host_peaks["bucketed_tiled"],
        },
        "ru_maxrss_kb": ru_maxrss_kb,
        "speedup": speedup,
        "max_abs_drho": err,
        "max_abs_drho_tiled": err_tiled,
    }
    _write_bench("BENCH_phase2.json", out)
    return out


# ----------------------------------------------------- kNN selection bench
def _slab_bytes(Lq: int, Lc: int) -> int:
    """Distance working set of the RETIRED slab layout: the full (Lq, Lc)
    f32 distance matrix plus its i32 candidate-id plane.  Lives only here
    — src/ no longer has a slab path — as the historical yardstick the
    streaming flat-memory column is plotted against."""
    return Lq * Lc * (4 + 4)


def _slab_knn_pallas(Vq, Vc, k, exclude_self, block_q=128):
    """Compact copy of the retired slab Pallas kernel (VMEM-resident
    (block_q, Lc) distance slab accumulated across E, k-pass top-k per E).

    Deleted from src/ by the streaming+merge-network rework; kept ONLY
    here so the knn bench's historical reference column times the layout
    each engine actually used before, on the same machine as the fresh
    streaming numbers the knn-gate compares against."""
    import functools

    from jax.experimental import pallas as pl

    from repro.core.knn import _acc_sq
    from repro.kernels.knn_topk import knn_topk as ktk

    E_max, Lc = Vq.shape[0], Vc.shape[1]
    Lc_pad = pl.cdiv(Lc, 128) * 128
    Vc_p = jnp.pad(Vc, ((0, 0), (0, Lc_pad - Lc)))

    def kernel(vq_ref, vc_ref, idx_ref, dist_ref, *, bq, row0):
        col_ids = jax.lax.broadcasted_iota(jnp.int32, (bq, Lc_pad), 1)
        invalid = col_ids >= Lc
        if exclude_self:
            row_ids = row0 + pl.program_id(0) * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, Lc_pad), 0
            )
            invalid = invalid | (col_ids == row_ids)
        D = jnp.zeros((bq, Lc_pad), jnp.float32)
        for e in range(E_max):
            D = _acc_sq(D, vq_ref[e, :], vc_ref[e, :], jnp.float32)
            Dm = jnp.where(invalid, ktk._BIG, D)
            idxs, dists = ktk._kpass_select(Dm, col_ids, k, Lc_pad)
            idx_ref[e] = idxs
            dist_ref[e] = dists

    def call_split(Vq_p, row0, rows_pad, bq):
        return pl.pallas_call(
            functools.partial(kernel, bq=bq, row0=row0),
            grid=(rows_pad // bq,),
            in_specs=[
                pl.BlockSpec((E_max, bq), lambda i: (0, i)),
                pl.BlockSpec((E_max, Lc_pad), lambda i: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((E_max, bq, k), lambda i: (0, i, 0)),
                pl.BlockSpec((E_max, bq, k), lambda i: (0, i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((E_max, rows_pad, k), jnp.int32),
                jax.ShapeDtypeStruct((E_max, rows_pad, k), jnp.float32),
            ],
            interpret=True,
        )(Vq_p, Vc_p)

    return ktk._over_query_splits(Vq, block_q, call_split)


def knn_selection_bench(Lc_sweep=(1000, 2000, 4000, 16000), Lq=128, N=128,
                        L_ref=1000, Lc_ref_extra=(64000,)):
    """BENCH_knn.json (DESIGN.md SS8): streaming kNN table construction
    (bitonic partial-merge network, one-shot calibrated tile) vs the
    RETIRED dense slab layout, for a FIXED 128-row query block against
    candidate libraries of growing length Lc, both engines.  The
    reference engine additionally sweeps ``Lc_ref_extra`` (paper-scale
    libraries the interpret-mode kernel would take too long on).

    Records, per engine and per Lc: the calibrated tile width, build
    wall time for both layouts (the slab column is a benchmark-local
    copy — :func:`_slab_knn_pallas` / the dense-oracle jnp builder —
    kept one last time as the historical reference), and the PEAK
    DISTANCE WORKING SET each needs — the slab grows linearly in Lc,
    streaming stays FLAT.  The ``--check knn`` knn-gate asserts
    stream_s <= slab_s at every benched Lc on both engines (streaming
    wins everywhere — the reason the slab could be deleted), plus the
    usual wall-time drift gate against the committed baseline.
    Bit-identity streaming-vs-dense-oracle is spot-checked on the
    cheapest cell (the full sweep lives in tests/test_knn_streaming.py).
    """
    from repro.core import knn
    from repro.engine import get_engine
    from repro.kernels.knn_topk.knn_topk import stream_vmem_bytes

    E_max, k = 20, 21
    out = {
        "bench": "knn_selection",
        "E_max": E_max,
        "k": k,
        "Lq": Lq,
        "merge": "bitonic_partial_merge_network",
        "tile_budget_bytes": knn.KNN_TILE_BUDGET_BYTES,
        "tile_budget_bytes_host": knn.KNN_TILE_BUDGET_BYTES_HOST,
        "engines": {},
        "phase1": {},
    }
    max_Lc = max(list(Lc_sweep) + list(Lc_ref_extra))
    pair = dummy_brain(2, max_Lc + E_max + 1, seed=3)
    checked = False
    for engine in ("reference", "pallas-interpret"):
        eng = get_engine(engine)
        cfg = EDMConfig(E_max=E_max, engine=engine)  # knn_tile_c=0: calibrated
        sweep = list(Lc_sweep)
        if engine == "reference":
            sweep += list(Lc_ref_extra)
        rows_d = {}
        for Lc in sweep:
            tile = eng.knn_selection_tile(Lc, cfg)  # per-engine profile
            Vq = lag_matrix(jnp.asarray(pair[0]), E_max, 1, Lq)
            Vc = lag_matrix(jnp.asarray(pair[1]), E_max, 1, Lc)
            f_stream = jax.jit(
                lambda Vq, Vc, c=cfg: eng.knn_tables(
                    Vq, Vc, k, exclude_self=False, cfg=c
                )
            )
            if engine == "reference":
                f_slab = jax.jit(
                    lambda Vq, Vc: knn_tables_dense(Vq, Vc, k, False)
                )
            else:
                f_slab = jax.jit(
                    lambda Vq, Vc: _slab_knn_pallas(Vq, Vc, k, False)
                )
            # interleave the two layouts' reps: the shared-runner clock
            # drifts on the seconds scale, which a paired A/B absorbs
            reps = 5 if Lc <= 4000 else 3
            jax.block_until_ready(f_stream(Vq, Vc))
            jax.block_until_ready(f_slab(Vq, Vc))
            obs = {"stream": [], "slab": []}
            for _ in range(reps):
                for name, f in (("stream", f_stream), ("slab", f_slab)):
                    t0 = time.perf_counter()
                    jax.block_until_ready(f(Vq, Vc))
                    obs[name].append(time.perf_counter() - t0)
            t_stream = float(np.median(obs["stream"]))
            t_slab = float(np.median(obs["slab"]))
            if not checked:  # bit-identity spot check on the cheapest cell
                a, b = f_slab(Vq, Vc), f_stream(Vq, Vc)
                assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
                assert np.array_equal(np.asarray(a[1]), np.asarray(b[1]))
                checked = True
            # peak distance working set: the slab materializes (Lq, Lc);
            # streaming holds one tile + doubled merge buffers + running
            # tables (jnp path) or the per-program VMEM budget (pallas
            # path) — both INDEPENDENT of Lc
            eff_tile = min(tile, -(-Lc // 8) * 8)
            if engine == "reference":
                ws_stream = knn.streaming_bytes(Lq, k, eff_tile, E_max)
            else:
                ws_stream = stream_vmem_bytes(E_max, k, Lq, eff_tile)
            rows_d[str(Lc)] = {
                "Lc": Lc,
                "tile_c": tile,
                "stream_s": t_stream,
                "slab_s": t_slab,
                "slab_working_set_bytes": _slab_bytes(Lq, Lc),
                "stream_working_set_bytes": ws_stream,
            }
            row(
                f"knn_{engine}_Lc{Lc}", t_stream,
                f"slab_s={t_slab:.3f};tile_c={tile};slab_MiB="
                f"{_slab_bytes(Lq, Lc) / 2**20:.2f};"
                f"stream_MiB={ws_stream / 2**20:.2f}",
            )
        out["engines"][engine] = rows_d

    # ---- phase-1 wall clock at the reference workload -----------------
    # auto (knn_tile_c=0, one-shot calibration) vs a deliberately narrow
    # forced tile: the no-regression guard that calibration picks a tile
    # at least as good as any hand-forced one.
    ts = jnp.asarray(dummy_brain(N, L_ref, seed=1))
    forced = 512
    times = {}
    for name, cfg in {
        "auto": EDMConfig(E_max=E_max),
        "forced_tile": EDMConfig(E_max=E_max, knn_tile_c=forced),
    }.items():
        times[name] = _time(lambda c=cfg: simplex_batch(ts, c))
    out["phase1"] = {
        "workload": {"N": N, "L": L_ref},
        "auto_s": times["auto"],
        "auto_tile_c": knn.resolve_stream_tile(
            EDMConfig(E_max=E_max).n_points(L_ref), EDMConfig(E_max=E_max),
            profile="host",
        ),
        "forced_tile_s": times["forced_tile"],
        "forced_tile_c": forced,
        "auto_vs_forced": times["auto"] / times["forced_tile"],
    }
    row(
        "knn_phase1_ref", times["auto"],
        f"forced_tile_s={times['forced_tile']:.3f};"
        f"auto_vs_forced={times['auto'] / times['forced_tile']:.2f}x",
    )
    _write_bench("BENCH_knn.json", out)
    return out


# ------------------------------------------------- significance bench (SS9)
def significance_bench(N=128, L=1000, E_max=20, rows=8, n_sizes=6):
    """BENCH_significance.json (DESIGN.md SS9): ONE-sweep prefix-snapshot
    convergence table build vs the old-style per-size rebuild at the
    128x1000 reference workload.

    Times the convergence-table construction for one ``rows``-row library
    chunk (the pipeline's dispatch unit) with the REAL bucket set from
    phase 1 and a paper-style grid of ``n_sizes`` nested library sizes:
    the rebuild sweeps sum(lib_sizes) candidate columns, the one-sweep
    snapshot only max(lib_sizes) — the measured speedup should track
    that ratio.  Chunk times are extrapolated to the full N-row workload
    (both variants scale linearly in rows).
    """
    from repro.core import knn, lag_matrix, make_bucket_plan
    from repro.inference import subsample_permutation

    cfg = EDMConfig(E_max=E_max)
    ts = jnp.asarray(dummy_brain(N, L, seed=5))
    _, optE = simplex_batch(ts, cfg)
    plan, _ = make_bucket_plan(np.asarray(optE))
    Lp = cfg.n_points(L)
    kb = plan.buckets[-1] + 1
    lib_sizes = tuple(
        int(s) for s in np.linspace(max(kb + 1, Lp // 8), Lp, n_sizes)
    )
    perm = subsample_permutation(jax.random.PRNGKey(0), Lp)
    tile = knn.calibrate_knn_tile(
        Lp, E_max=E_max, k=kb,
        budget_bytes=knn.KNN_TILE_BUDGET_BYTES_HOST,
        tile_max=knn.KNN_TILE_MAX_HOST,
    )
    rows_j = ts[:rows]

    def build(fn):
        def per_row(x):
            V = lag_matrix(x, cfg.E_max, cfg.tau, Lp)
            return fn(
                V, V, kb, cfg.exclude_self, plan.buckets, lib_sizes, tile,
                jnp.float32, perm,
            )

        return jax.jit(jax.vmap(per_row))

    one_sweep = build(knn.knn_tables_prefix_streaming)
    rebuild = build(knn.knn_tables_prefix_rebuild)
    t_one = _time(lambda: one_sweep(rows_j), reps=1)
    t_reb = _time(lambda: rebuild(rows_j), reps=1)

    # identical tables is part of the contract the bench compares under
    a, b = one_sweep(rows_j), rebuild(rows_j)
    assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
    assert np.array_equal(np.asarray(a[1]), np.asarray(b[1]))

    speedup = t_reb / t_one
    row("significance_one_sweep_chunk", t_one,
        f"N={N};L={L};rows={rows};S={n_sizes}")
    row("significance_rebuild_chunk", t_reb, f"speedup={speedup:.2f}x")
    out = {
        "bench": "significance_convergence_build",
        "workload": {"N": N, "L": L, "E_max": E_max, "Lp": Lp},
        "rows_timed": rows,
        "lib_sizes": list(lib_sizes),
        "n_buckets": len(plan.buckets),
        "k": kb,
        "tile_c": tile,
        "one_sweep_chunk_s": t_one,
        "rebuild_chunk_s": t_reb,
        "one_sweep_full_N_s": t_one * N / rows,
        "rebuild_full_N_s": t_reb * N / rows,
        "speedup": speedup,
        "candidate_cols_ratio": sum(lib_sizes) / lib_sizes[-1],
    }
    _write_bench("BENCH_significance.json", out)
    return out


# ------------------------------------------------------------------ roofline
def roofline_summary():
    d = RESULTS / "dryrun"
    if not d.exists():
        return
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if "skipped" in r:
            row(f"roofline_{r['arch']}_{r['cell']}_{r.get('mesh')}", 0.0, "SKIP")
            continue
        rl = r["roofline"]
        row(
            f"roofline_{r['arch']}_{r['cell']}_{r['mesh']}",
            rl["t_compute_s"] + 0.0,
            f"bottleneck={rl['bottleneck']};frac={rl['roofline_fraction']:.3f};"
            f"mem_GiB={r['memory']['peak_bytes_per_device'] / 2**30:.1f}",
        )


# ------------------------------------------------- paper-shape scaling
# Prior bench ceiling (fig6/fig7 topped out at 128 series x 1000 steps);
# the scale sweep below grows N*L 100x+ past it (DESIGN.md SS14).
PRIOR_CEILING_NL = 128 * 1000
SCALE_CELLS = ((512, 1000), (2048, 2048), (16384, 4096))


def scale_bench():
    """Synthetic scaling sweep toward the paper shape -> BENCH_scale.json
    (DESIGN.md SS14).

    Per cell (N series x L steps): time the per-series streaming kNN
    table build (the phase-1/phase-2 workhorse), the SHARDED build +
    device-side collective merge at several candidate-shard counts, and
    the merge alone device-vs-host — asserting sharded == unsharded
    BYTE-identity (idx and f32 dists) at every cell, the SS14 contract.
    N enters the recorded geometry and the extrapolations (per-series
    costs are N-independent after the mpEDM rework — DESIGN.md SS2), so
    the same harness runs unchanged at the paper's 100k-neuron scale on
    a real cluster; locally the largest cell is 16384 x 4096 = 524x the
    prior 128x1000 bench ceiling.

    EDM_SCALE_SMOKE=1 (CI's scale-smoke job, 2 spoofed devices): only
    the smallest cell and shard set — the identity gate without the
    wall-clock bill.
    """
    from repro.core import knn
    from repro.core.pipeline import (
        default_mesh,
        knn_tables_library_sharded,
        knn_tables_library_sharded_sim,
    )

    smoke = os.environ.get("EDM_SCALE_SMOKE") == "1"
    cells = SCALE_CELLS[:1] if smoke else SCALE_CELLS
    shard_counts = (2,) if smoke else (2, 4)
    W = len(jax.devices())
    mesh = default_mesh()
    out: dict = {
        "prior_ceiling_NL": PRIOR_CEILING_NL,
        "devices": W,
        "smoke": smoke,
        "cells": {},
    }
    for N, L in cells:
        cfg = EDMConfig(E_max=20)
        k = cfg.k_max
        # One representative series' lag matrix: per-series table cost is
        # N-independent, so one timed build extrapolates the whole brain.
        series = jnp.asarray(dummy_brain(1, L, seed=N)[0])
        Lp = cfg.n_points(L)
        V = lag_matrix(series, cfg.E_max, cfg.tau, Lp)
        tile_c = knn.resolve_stream_tile(Lp, cfg)
        reps = 1 if N * L > 10 * PRIOR_CEILING_NL else 3

        t_build = _time(
            lambda: knn.knn_tables_all_E_streaming(
                V, V, k, exclude_self=True, tile_c=tile_c
            ),
            reps=reps,
        )
        ref_i, ref_d = jax.block_until_ready(
            knn.knn_tables_all_E_streaming(V, V, k, exclude_self=True,
                                           tile_c=tile_c)
        )

        sharded: dict = {}
        # Real-mesh collective when this process has >1 device (CI's
        # scale-smoke spoofs 2); simulated shards cover the other counts.
        if W > 1:
            mi, md = jax.block_until_ready(
                knn_tables_library_sharded(V, V, k, cfg, exclude_self=True,
                                           mesh=mesh)
            )
            np.testing.assert_array_equal(np.asarray(mi), np.asarray(ref_i))
            np.testing.assert_array_equal(np.asarray(md), np.asarray(ref_d))
            t_mesh = _time(
                lambda: knn_tables_library_sharded(V, V, k, cfg,
                                                   exclude_self=True,
                                                   mesh=mesh),
                reps=reps,
            )
            sharded[f"mesh{W}"] = {"build_merge_s": t_mesh,
                                   "identical": True, "collective": True}
        for S in shard_counts:
            si, sd = jax.block_until_ready(
                knn_tables_library_sharded_sim(V, V, k, cfg,
                                               exclude_self=True, shards=S)
            )
            np.testing.assert_array_equal(np.asarray(si), np.asarray(ref_i))
            np.testing.assert_array_equal(np.asarray(sd), np.asarray(ref_d))
            t_sim = _time(
                lambda S=S: knn_tables_library_sharded_sim(
                    V, V, k, cfg, exclude_self=True, shards=S
                ),
                reps=reps,
            )
            sharded[f"sim{S}"] = {"build_merge_s": t_sim,
                                  "identical": True, "collective": False}

        # Merge-only, device tree vs host lexsort (+ the host round-trip
        # the SS14 bugfix removed): per-shard tables built once, reduced
        # both ways.
        S = shard_counts[-1]
        shard = -(-Lp // S)
        parts = [
            jax.block_until_ready(knn.knn_tables_all_E_streaming(
                V, V[:, s * shard : min((s + 1) * shard, Lp)],
                min(k, shard, Lp - s * shard), exclude_self=True,
                tile_c=tile_c, col_offset=s * shard,
                col_hi=min((s + 1) * shard, Lp),
            ))
            for s in range(S)
        ]
        idx_p = [p[0] for p in parts]
        d_p = [p[1] for p in parts]
        t_merge_dev = _time(lambda: knn.merge_topk_tree(idx_p, d_p, k),
                            reps=max(reps, 3))
        t0 = time.perf_counter()
        knn.merge_shard_tables([np.asarray(i) for i in idx_p],
                               [np.asarray(d) for d in d_p], k=k)
        t_merge_host = time.perf_counter() - t0

        cell = {
            "N": N, "L": L, "Lp": Lp, "E_max": cfg.E_max, "k": k,
            "NL": N * L, "ceiling_ratio": N * L / PRIOR_CEILING_NL,
            "tile_c": tile_c,
            "streaming_bytes": knn.streaming_bytes(
                Lp, k, tile_c, cfg.E_max),
            "knn_build_s": t_build,
            "sharded": sharded,
            "merge_device_s": t_merge_dev,
            "merge_host_s": t_merge_host,
            # Whole-brain extrapolations (per-series costs x N; the flat
            # worker grid divides them by the device count).
            "phase1_tables_extrapolated_s": t_build * N,
            "phase1_tables_per_512_workers_s": t_build * N / 512,
        }
        out["cells"][f"{N}x{L}"] = cell
        row(f"scale_{N}x{L}_knn_build", t_build,
            f"Lp={Lp};tile={tile_c};NL={N * L}"
            f";ceiling_x={cell['ceiling_ratio']:.0f}")
        for sk, sv in sharded.items():
            row(f"scale_{N}x{L}_sharded_{sk}", sv["build_merge_s"],
                "identical=True")
        row(f"scale_{N}x{L}_merge", t_merge_dev,
            f"host={t_merge_host * 1e6:.0f}us;"
            f"device_vs_host={t_merge_host / max(t_merge_dev, 1e-9):.1f}x")

    # Paper-shape model: per-series build scales as E_max * Lp^2 (the
    # streaming distance sweep); calibrate the constant on the largest
    # measured cell and project the paper's two headline datasets.
    big = out["cells"][f"{cells[-1][0]}x{cells[-1][1]}"]
    c0 = big["knn_build_s"] / (big["E_max"] * big["Lp"] ** 2)
    for name, (Np, Lraw) in {"fish1_normo": (53053, 1450),
                             "subject11": (101729, 8528)}.items():
        Lpp = Lraw - (20 - 1) - 1
        t_series = c0 * 20 * Lpp ** 2
        out[f"model_{name}"] = {
            "N": Np, "L": Lraw,
            "phase1_tables_s_1core": t_series * Np,
            "phase1_tables_s_512_workers": t_series * Np / 512,
        }
        row(f"scale_model_{name}", t_series * Np / 512,
            "per_512_workers_extrapolated")
    _write_bench("BENCH_scale.json", out)


BENCHES = {
    "table2": table2_speedup,
    "fig6": fig6_scaling_N,
    "fig7": fig7_scaling_L,
    "fig8": fig8_breakdown,
    "fig9": fig9_multiE_kernel,
    "fig9b": fig9b_knn_impl_variants,
    "fig3": fig3_strong_scaling,
    "phase2": phase2_engine_bench,
    "knn": knn_selection_bench,
    "significance": significance_bench,
    "roofline": roofline_summary,
    "scale": scale_bench,
}


# --------------------------------------------- bench regression gate (CI)
#: bench name -> (baseline JSON, gated timing fields as key paths).
#: Gated fields are WALL TIMES ONLY — derived ratios (speedups) divide
#: out machine speed and working-set bytes are deterministic, so a
#: straight fresh/baseline ratio on the timings is the regression signal.
GATES: dict[str, tuple[str, list[tuple[str, ...]]]] = {
    "phase2": (
        "BENCH_phase2.json",
        [("seed_path", "phase2_s"), ("new_path", "phase2_s"),
         ("tiled_path", "phase2_s")],
    ),
    "knn": (
        "BENCH_knn.json",
        [("phase1", "auto_s"),
         ("engines", "reference", "64000", "stream_s"),
         ("engines", "pallas-interpret", "16000", "stream_s")],
    ),
    "significance": (
        "BENCH_significance.json",
        [("one_sweep_chunk_s",), ("rebuild_chunk_s",)],
    ),
}
# Absolute wall-time gate (the committed contract).  Baselines are only
# meaningful for the machine class they were measured on: promote a
# bench-gate run's uploaded fresh JSONs to the committed baselines the
# first time the gate runs on a new runner class, rather than comparing
# a CI runner against a workstation.  BENCH_GATE_LIMIT overrides the
# ratio for machines with known constant offsets.
SLOWDOWN_LIMIT = float(os.environ.get("BENCH_GATE_LIMIT", "1.5"))
# knn-gate margin: streaming must stay at-or-below the slab baseline at
# EVERY benched Lc on both engines; the margin absorbs shared-runner
# timer noise on the cells where the two layouts are genuinely tied
# (single-tile small-Lc cells degenerate to the same computation).
KNN_STREAM_MARGIN = float(os.environ.get("KNN_STREAM_MARGIN", "1.15"))


def _dig(d: dict, path: tuple[str, ...]) -> float:
    for k in path:
        d = d[k]
    return float(d)


def _knn_stream_gate(base: dict, fresh: dict, floor: dict,
                     summary: list | None = None) -> bool:
    """The knn-gate (DESIGN.md SS8): fresh streaming build time must beat
    the slab baseline at every benched Lc on both engines — both the
    slab timed fresh in the same run (same-machine, noise-free yardstick)
    and the committed recorded baseline (drift contract, with the usual
    SLOWDOWN_LIMIT machine allowance).  Retry passes keep the BEST
    streaming observation per cell via ``floor``."""
    ok = True
    for engine, rows in fresh.get("engines", {}).items():
        for lc, r in sorted(rows.items(), key=lambda kv: int(kv[0])):
            key = f"BENCH_knn.json:knn-gate.{engine}.Lc{lc}"
            f = min(float(r["stream_s"]), floor.get(key, float("inf")))
            floor[key] = f
            slab_fresh = float(r["slab_s"])
            slab_base = float(
                base.get("engines", {}).get(engine, {}).get(lc, {}).get(
                    "slab_s", slab_fresh
                )
            )
            limit = max(
                slab_fresh * KNN_STREAM_MARGIN, slab_base * SLOWDOWN_LIMIT
            )
            verdict = "OK" if f <= limit else "STREAM_SLOWER_THAN_SLAB"
            ok = ok and verdict == "OK"
            if summary is not None:
                summary.append({
                    "gate": key, "bench": "knn", "kind": "knn-stream",
                    "fresh_s": f, "slab_fresh_s": slab_fresh,
                    "slab_base_s": slab_base, "limit_s": limit,
                    "verdict": verdict,
                })
            print(
                f"gate,{key},stream={f:.3f}s;slab_fresh={slab_fresh:.3f}s;"
                f"slab_base={slab_base:.3f}s;{verdict}"
            )
    return ok


def check_regressions(names: list[str], floor: dict | None = None,
                      summary: list | None = None) -> list[str]:
    """Compare fresh BENCH_DIR timings against committed repo-root
    baselines; print one verdict row per gated field and return the
    bench names with violations (>SLOWDOWN_LIMIT x).  ``floor`` carries
    the best fresh timing seen so far per field across retry passes —
    shared-runner wall clocks are noisy, so a field only regresses if
    its BEST observation is slow.  ``summary`` (when given) collects one
    machine-readable entry per gate row for CHECK_summary.json."""
    bad: list[str] = []
    floor = {} if floor is None else floor
    for name in names:
        if name not in GATES:
            continue
        fname, fields = GATES[name]
        base_f, fresh_f = REPO / fname, BENCH_DIR / fname
        if not base_f.exists():
            print(f"gate,{fname},SKIP_no_committed_baseline")
            continue
        base = json.loads(base_f.read_text())
        fresh = json.loads(fresh_f.read_text())
        for path in fields:
            key = f"{fname}:{'.'.join(path)}"
            b = _dig(base, path)
            f = min(_dig(fresh, path), floor.get(key, float("inf")))
            floor[key] = f
            ratio = f / b if b > 0 else float("inf")
            verdict = "OK" if ratio <= SLOWDOWN_LIMIT else "REGRESSION"
            if verdict != "OK" and name not in bad:
                bad.append(name)
            if summary is not None:
                summary.append({
                    "gate": key, "bench": name, "kind": "drift",
                    "base_s": b, "fresh_s": f, "ratio": ratio,
                    "verdict": verdict,
                })
            print(
                f"gate,{key},"
                f"base={b:.3f}s;fresh={f:.3f}s;ratio={ratio:.2f}x;{verdict}"
            )
        if name == "knn" and not _knn_stream_gate(base, fresh, floor,
                                                 summary):
            if name not in bad:
                bad.append(name)
    return bad


def main() -> None:
    global BENCH_DIR
    args = sys.argv[1:]
    check = "--check" in args
    bad_flags = [a for a in args if a.startswith("--") and a != "--check"]
    if bad_flags:
        # A typo'd --check must fail loudly, not silently skip the gate.
        sys.exit(f"unknown option(s) {bad_flags}; the only flag is --check")
    names = [a for a in args if not a.startswith("--")] or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        sys.exit(f"unknown bench(es) {unknown}; available: {list(BENCHES)}")
    if check:
        gated = [n for n in names if n in GATES]
        if not gated:
            sys.exit(f"--check needs at least one gated bench: {list(GATES)}")
        BENCH_DIR = RESULTS / "fresh"  # keep committed baselines untouched
        # Clear THIS run's gated artifacts up front: a stale fresh JSON
        # from an aborted earlier run must never shadow the bench we are
        # about to (re)run — the gate would silently compare old numbers.
        for name in gated:
            stale = BENCH_DIR / GATES[name][0]
            if stale.exists():
                stale.unlink()
        (BENCH_DIR / "CHECK_summary.json").unlink(missing_ok=True)
    print("name,us_per_call,derived")
    for name in names:
        BENCHES[name]()
    if check:
        floor: dict = {}
        summary: list = []
        bad = check_regressions(names, floor, summary)
        if bad:
            # One retry of only the offending benches: transient runner
            # noise clears (best-of-2 per field), real regressions persist.
            print(f"gate,retry,rerunning_{'+'.join(bad)}_once")
            for name in bad:
                BENCHES[name]()
            summary = [e for e in summary if e["bench"] not in bad]
            bad = check_regressions(bad, floor, summary)
        # Machine-readable per-bench delta summary, uploaded with the
        # fresh JSONs so a regression (or a promotable speedup) can be
        # triaged from the artifact alone.
        (BENCH_DIR / "CHECK_summary.json").write_text(json.dumps({
            "slowdown_limit": SLOWDOWN_LIMIT,
            "knn_stream_margin": KNN_STREAM_MARGIN,
            "benches": names,
            "gates": summary,
            "failed": bad,
            "passed": not bad,
        }, indent=1))
        if bad:
            sys.exit(
                f"bench regression gate FAILED: {bad} slower than "
                f"{SLOWDOWN_LIMIT}x baseline (see gate rows above; refresh "
                "baselines by rerunning without --check and committing the "
                "repo-root BENCH_*.json)"
            )
        print(f"gate,all,within_{SLOWDOWN_LIMIT}x_of_baselines")


if __name__ == "__main__":
    main()
