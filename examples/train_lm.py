"""Train a ~100M-class LM (smollm-135m family) for a few hundred steps with
the full production stack: sharded state, deterministic data stream,
async checkpointing, resilient step loop.

    PYTHONPATH=src python examples/train_lm.py --steps 300

Runs the REDUCED (smoke) config by default so 300 steps finish on CPU;
pass --full for the real 135M config (slow on CPU, the intended target is
the pod mesh via launch/train.py --production-mesh).
"""
import argparse
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    sys.argv = [
        "train",
        "--arch", "smollm-135m",
        *([] if args.full else ["--smoke"]),
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "256",
        "--ckpt-dir", tempfile.mkdtemp(prefix="train_lm_ckpt_"),
        "--save-every", "100",
        "--log-every", "20",
    ]
    from repro.launch.train import main as train_main

    train_main()


if __name__ == "__main__":
    main()
