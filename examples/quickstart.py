"""Quickstart: CCM causal inference on the classic two-species system.

    PYTHONPATH=src python examples/quickstart.py

Generates Sugihara's coupled logistic maps (x drives y), runs the full
pipeline (simplex projection -> optimal E -> cross mapping), and prints the
causal verdict.  ~10 s on CPU.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.pipeline import run_causal_inference
from repro.core.types import EDMConfig
from repro.data.synthetic import coupled_logistic


def main():
    x, y = coupled_logistic(1000, beta_xy=0.0, beta_yx=0.1, seed=3)
    ts = np.stack([x, y])
    out = run_causal_inference(ts, EDMConfig(E_max=8))
    print(f"optimal embedding dims: x={out.optE[0]}, y={out.optE[1]}")
    # rho[i, j]: skill of predicting series j from library i's manifold;
    # high rho[y, x] means x's influence is recoverable from M_y => x -> y.
    print(f"rho(x-hat | M_y) = {out.rho[1, 0]:.3f}   (x causes y)")
    print(f"rho(y-hat | M_x) = {out.rho[0, 1]:.3f}   (y causes x)")
    verdict = "x -> y" if out.rho[1, 0] > out.rho[0, 1] else "y -> x"
    print(f"CCM verdict: {verdict}  (ground truth: x -> y)")


if __name__ == "__main__":
    main()
