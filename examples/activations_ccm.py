"""Causal inference of NETWORK dynamics at single-neuron resolution — the
paper's technique applied to an artificial neural network.

    PYTHONPATH=src python examples/activations_ccm.py

Trains a small LM for a few steps while recording the activation time
series of individual hidden units ("neurons"), then runs the distributed
CCM pipeline on those series to produce a causal map across layers —
exactly the paper's workflow with the zebrafish brain swapped for an ANN.
This closes the loop between the two halves of the framework: the LM
runtime produces the recordings, the EDM core analyses them
(DESIGN.md SS5 Arch-applicability).
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core.pipeline import run_causal_inference
from repro.core.types import EDMConfig
from repro.data.pipeline import TokenStream
from repro.launch.steps import TrainState, make_train_step
from repro.models import transformer as T


def record_neurons(params, cfg, batch, n_per_layer=8):
    """Activation time series: residual-stream units across the sequence
    axis (time = token position, like the paper's 2 Hz frames)."""
    x = T._embed(params["embed"], cfg, batch["tokens"])
    traces = []
    def body(h, lp):
        h2, _, _ = T._dense_block_fwd(lp, cfg, h)
        return h2, h2[0, :, :n_per_layer]  # (S, n) units of example 0
    _, acts = jax.lax.scan(body, x, params["blocks"])
    # (layers, S, n) -> (layers * n, S)
    L_, S, n = acts.shape
    return np.asarray(acts.transpose(0, 2, 1).reshape(L_ * n, S), np.float32)


def main():
    cfg = get_config("smollm-135m", smoke=True)
    tc = TrainConfig(lr=2e-3, warmup_steps=5, total_steps=40, remat=False)
    state = TrainState.create(cfg, tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tc))
    stream = TokenStream(cfg.vocab_size, 2, 512, seed=0)

    print("[1/3] training a small LM for 40 steps...")
    for i in range(40):
        state, m = step(state, stream.batch_at(i))
    print(f"      final loss {float(m['loss']):.3f}")

    print("[2/3] recording per-neuron activation time series (S=512)...")
    ts = np.array(record_neurons(state.params, cfg, stream.batch_at(99)))
    ts += 1e-3 * np.random.default_rng(0).standard_normal(ts.shape).astype(np.float32)
    keep = ts.std(axis=1) > 1e-4  # active neurons only, like the paper
    ts = (ts[keep] - ts[keep].mean(1, keepdims=True)) / ts[keep].std(1, keepdims=True)
    print(f"      {ts.shape[0]} active neurons x {ts.shape[1]} time steps")

    print("[3/3] CCM causal map across neurons...")
    out = run_causal_inference(ts, EDMConfig(E_max=6))
    rho = out.rho
    np.fill_diagonal(rho, 0)
    n_layers_units = rho.shape[0]
    strongest = np.unravel_index(np.argmax(rho), rho.shape)
    print(f"      mean |rho| = {np.abs(rho).mean():.3f}; "
          f"strongest causal link: neuron {strongest[1]} -> neuron {strongest[0]} "
          f"(rho={rho[strongest]:.3f})")
    # within-layer links should on average beat cross-layer-distant links
    print("      causal map computed — the paper's pipeline, ANN edition.")


if __name__ == "__main__":
    main()
