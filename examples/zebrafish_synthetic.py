"""End-to-end driver — the paper's workflow at laptop scale.

    PYTHONPATH=src python examples/zebrafish_synthetic.py [--neurons 48]

1. Generate a synthetic 'zebrafish brain': a sparse directed network of
   coupled nonlinear (logistic) neurons with known ground-truth adjacency
   — the stand-in for the SPIM light-sheet recordings of Table I.
2. Store it in the zarr-lite dataset format (the HDF5 replacement).
3. Run the full distributed causal-inference pipeline (simplex projection
   -> per-neuron optimal embedding -> all-to-all CCM), streaming row
   blocks to disk with resume support (kill it mid-run and re-invoke:
   it continues from the last completed block).
4. Score the inferred causal map against the ground-truth network (AUC),
   reproducing the paper's scientific claim (Fig. 10 E/F) in miniature.
"""
import argparse
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.pipeline import run_causal_inference
from repro.core.types import EDMConfig
from repro.data import store
from repro.data.synthetic import logistic_network


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--neurons", type=int, default=48)
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out = args.out or tempfile.mkdtemp(prefix="zebrafish_")
    print(f"[1/4] generating {args.neurons}-neuron synthetic brain "
          f"({args.steps} steps @ 2 Hz equivalent)")
    ts, adj = logistic_network(
        args.neurons, args.steps, density=0.12, strength=0.3, seed=7
    )
    store.save_dataset(pathlib.Path(out) / "recording", ts,
                       {"species": "synthetic zebrafish", "hz": 2})

    print(f"[2/4] running causal inference pipeline -> {out}")
    t0 = time.time()
    result = run_causal_inference(
        ts, EDMConfig(E_max=8), out_dir=str(pathlib.Path(out) / "causal_map"),
        progress=True,
    )
    dt = time.time() - t0
    n = args.neurons
    print(f"[3/4] {n}x{n} causal map in {dt:.1f}s "
          f"({n * n / dt:.0f} cross-maps/s); mean optimal E = {result.optE.mean():.1f}")

    # score: does rho separate true edges from non-edges?
    rho = result.rho.T  # rho[dst, src] -> edge src->dst
    mask = ~np.eye(n, dtype=bool)
    pos, neg = rho[adj], rho[(~adj) & mask]
    order = np.concatenate([pos, neg]).argsort().argsort()
    auc = (order[: len(pos)].mean() + 1 - (len(pos) + 1) / 2) / len(neg)
    print(f"[4/4] edge-recovery AUC = {auc:.3f} "
          f"(true-edge mean rho {pos.mean():.3f} vs non-edge {neg.mean():.3f})")


if __name__ == "__main__":
    main()
