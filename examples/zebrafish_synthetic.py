"""End-to-end driver — the paper's workflow at laptop scale.

    PYTHONPATH=src python examples/zebrafish_synthetic.py [--neurons 48]

1. Generate a synthetic 'zebrafish brain': a sparse directed network of
   coupled nonlinear (logistic) neurons with known ground-truth adjacency
   — the stand-in for the SPIM light-sheet recordings of Table I.
2. Store it in the zarr-lite dataset format (the HDF5 replacement).
3. Run the full distributed causal-inference pipeline (simplex projection
   -> per-neuron optimal embedding -> all-to-all CCM), streaming row
   blocks to disk with resume support (kill it mid-run and re-invoke:
   it continues from the last completed block).
4. Score the inferred causal map against the ground-truth network (AUC),
   reproducing the paper's scientific claim (Fig. 10 E/F) in miniature.
5. Turn the raw rho map into a SIGNIFICANCE-MASKED causal graph
   (DESIGN.md SS9): one-sweep convergence CCM, phase-randomized
   surrogate nulls, and a BH-FDR edge mask — the statistically
   defensible version of step 4's threshold-free ranking — and score
   the surviving edges against the ground truth.
"""
import argparse
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.pipeline import run_causal_inference
from repro.core.types import EDMConfig
from repro.data import store
from repro.data.synthetic import logistic_network
from repro.inference import SignificanceConfig, run_significance


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--neurons", type=int, default=48)
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--out", default=None)
    ap.add_argument("--surrogates", type=int, default=99)
    ap.add_argument("--fdr", type=float, default=0.1)
    args = ap.parse_args()

    out = args.out or tempfile.mkdtemp(prefix="zebrafish_")
    print(f"[1/4] generating {args.neurons}-neuron synthetic brain "
          f"({args.steps} steps @ 2 Hz equivalent)")
    ts, adj = logistic_network(
        args.neurons, args.steps, density=0.12, strength=0.3, seed=7
    )
    store.save_dataset(pathlib.Path(out) / "recording", ts,
                       {"species": "synthetic zebrafish", "hz": 2})

    print(f"[2/5] running causal inference pipeline -> {out}")
    cfg = EDMConfig(E_max=8)
    t0 = time.time()
    result = run_causal_inference(
        ts, cfg, out_dir=str(pathlib.Path(out) / "causal_map"),
        progress=True,
    )
    dt = time.time() - t0
    n = args.neurons
    print(f"[3/5] {n}x{n} causal map in {dt:.1f}s "
          f"({n * n / dt:.0f} cross-maps/s); mean optimal E = {result.optE.mean():.1f}")

    # score: does rho separate true edges from non-edges?
    rho = result.rho.T  # rho[dst, src] -> edge src->dst
    mask = ~np.eye(n, dtype=bool)
    pos, neg = rho[adj], rho[(~adj) & mask]
    order = np.concatenate([pos, neg]).argsort().argsort()
    auc = (order[: len(pos)].mean() + 1 - (len(pos) + 1) / 2) / len(neg)
    print(f"[4/5] edge-recovery AUC = {auc:.3f} "
          f"(true-edge mean rho {pos.mean():.3f} vs non-edge {neg.mean():.3f})")

    # significance-masked causal graph: convergence CCM + surrogate nulls
    # + BH-FDR (DESIGN.md SS9) — the defensible cut of the rho ranking.
    Lp = cfg.n_points(ts.shape[1])
    # keep the grid ascending/distinct for any --steps: fixed small sizes
    # strictly below the near-full top size
    lib_sizes = tuple(
        s for s in (40, 100, 250) if s < Lp - 20
    ) + (Lp - 20,)
    sig = SignificanceConfig(
        lib_sizes=lib_sizes, n_surrogates=args.surrogates, alpha=args.fdr,
        surrogate="phase", seed=7,
    )
    t0 = time.time()
    graph = run_significance(
        ts, np.asarray(result.optE), np.asarray(result.rho),
        cfg, sig, out_dir=out, progress=False,
    )
    e = graph.edges
    hits = adj[e["src"], e["dst"]]
    n_true = int(adj.sum())
    prec = hits.mean() if len(e) else float("nan")
    print(f"[5/5] significance-masked graph in {time.time() - t0:.1f}s: "
          f"{len(e)} edges at FDR {args.fdr} "
          f"({args.surrogates} phase surrogates, p* = {graph.p_threshold:.4g}); "
          f"precision {prec:.2f}, recall {hits.sum() / n_true:.2f} "
          f"vs {n_true} true edges -> {out}/edges")


if __name__ == "__main__":
    main()
