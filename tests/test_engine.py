"""Execution-engine layer: registry, backend agreement, optE bucketing,
and the double-buffered chunk stream (DESIGN.md SS3/SS5)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro import engine as engines
from repro.core import (
    EDMConfig,
    ccm_block_bucketed,
    ccm_library_row_bucketed,
    ccm_matrix,
    all_futures,
    knn,
    make_bucket_plan,
    simplex_batch,
)
from repro.data.synthetic import dummy_brain


# ---------------------------------------------------------------- registry
def test_registry_has_at_least_three_backends():
    names = engines.available_engines()
    assert len(names) >= 3
    for required in ("reference", "pallas-interpret", "pallas-compiled"):
        assert required in names
        assert engines.get_engine(required).name == required


def test_unknown_engine_raises():
    with pytest.raises(KeyError, match="unknown engine"):
        engines.get_engine("nope")


def test_register_custom_backend():
    class Custom(engines.ReferenceEngine):
        name = "custom-test"

    engines.register(Custom())
    try:
        assert "custom-test" in engines.available_engines()
        assert isinstance(engines.get_engine("custom-test"), Custom)
    finally:
        engines._REGISTRY.pop("custom-test", None)


def test_use_kernels_deprecation_shim():
    with pytest.warns(DeprecationWarning, match="use_kernels is deprecated"):
        cfg = EDMConfig(use_kernels=True)
    assert cfg.engine == "pallas-compiled"
    with pytest.warns(DeprecationWarning):
        cfg = EDMConfig(use_kernels=False)
    assert cfg.engine == "reference"


# ---------------------------------------------------- oracle check harness
@pytest.mark.parametrize("name", ["reference", "pallas-interpret", "pallas-compiled"])
def test_engine_ops_vs_oracle(name):
    from repro.engine.check import check_engine

    errs = check_engine(name, E_max=5, Lq=96, Lc=96, seed=1)
    assert set(errs) == {
        "knn_tables", "knn_tables_bucketed", "knn_tables_prefix", "ccm_lookup",
    }


def test_all_engines_agree_on_synthetic_32x400():
    """Acceptance: every registered backend reproduces the reference causal
    map on a 32x400 synthetic dataset to <= 1e-4 max |drho|."""
    cfg_ref = EDMConfig(E_max=5, engine="reference")
    ts = jnp.asarray(dummy_brain(32, 400, seed=11))
    _, optE = simplex_batch(ts, cfg_ref)
    rho_ref = np.asarray(ccm_matrix(ts, optE, cfg_ref))
    for name in engines.available_engines():
        cfg = EDMConfig(E_max=5, engine=name)
        rho = np.asarray(ccm_matrix(ts, optE, cfg))
        err = np.abs(rho - rho_ref).max()
        assert err <= 1e-4, f"engine {name}: max |drho| {err}"


# ---------------------------------------------------------------- bucketing
def test_bucket_plan_groups_targets():
    optE = np.asarray([3, 1, 3, 7, 1, 1], np.int32)
    plan, order = make_bucket_plan(optE)
    assert plan.buckets == (1, 3, 7)
    assert plan.counts == (3, 2, 1)
    assert plan.offsets == (0, 3, 5)
    assert plan.n_targets == 6
    np.testing.assert_array_equal(optE[order], np.sort(optE))
    # stable: within-bucket original order preserved
    np.testing.assert_array_equal(order, [1, 4, 5, 0, 2, 3])


def test_bucketed_tables_match_all_E_rows():
    rng = np.random.default_rng(2)
    V = jnp.asarray(rng.standard_normal((8, 140)), jnp.float32)
    buckets = (2, 5, 8)
    idx_b, sqd_b = knn.knn_tables_bucketed_dense(V, V, 9, True, buckets)
    idx_a, sqd_a = knn.knn_tables_dense(V, V, 9, True, impl="unroll")
    assert idx_b.shape == (3, 140, 9)
    for b, E in enumerate(buckets):
        np.testing.assert_array_equal(np.asarray(idx_b[b]), np.asarray(idx_a[E - 1]))
        np.testing.assert_allclose(
            np.asarray(sqd_b[b]), np.asarray(sqd_a[E - 1]), rtol=1e-6, atol=1e-8
        )


def test_bucketed_rebuild_impl_matches_all_E_rebuild():
    """cfg.knn_impl='rebuild' must reach the bucketed builder too (matmul
    -form distances per bucket), matching knn_tables_dense's rebuild rows."""
    rng = np.random.default_rng(4)
    V = jnp.asarray(rng.standard_normal((8, 120)), jnp.float32)
    buckets = (3, 6)
    idx_b, sqd_b = knn.knn_tables_bucketed_dense(V, V, 7, True, buckets, impl="rebuild")
    idx_a, sqd_a = knn.knn_tables_dense(V, V, 7, True, impl="rebuild")
    for b, E in enumerate(buckets):
        np.testing.assert_array_equal(np.asarray(idx_b[b]), np.asarray(idx_a[E - 1]))
        np.testing.assert_allclose(
            np.asarray(sqd_b[b]), np.asarray(sqd_a[E - 1]), rtol=1e-6, atol=1e-8
        )


def test_bucketed_ccm_equals_all_E_and_counts_table_rows():
    """Acceptance: bucketed phase 2 == all-E path (<= 1e-5) while building
    kNN tables only for the distinct optE values (counted)."""
    cfg = EDMConfig(E_max=7)
    # L=311 gives this test a unique trace shape so the trace-time table
    # counters below actually fire (jit caches earlier shapes).
    ts = jnp.asarray(dummy_brain(12, 311, seed=7))
    _, optE = simplex_batch(ts, cfg)
    optE_np = np.asarray(optE)
    n_buckets = len(np.unique(optE_np))
    assert n_buckets < cfg.E_max  # workload actually exercises the saving

    knn.reset_table_counters()
    rho_b = np.asarray(ccm_matrix(ts, optE, cfg))
    assert knn.TABLE_ROWS_BUILT["bucketed"] == n_buckets  # one vmap trace
    assert knn.TABLE_ROWS_BUILT["all_E"] == 0

    knn.reset_table_counters()
    rho_a = np.asarray(ccm_matrix(ts, optE, EDMConfig(E_max=7, bucketed=False)))
    assert knn.TABLE_ROWS_BUILT["bucketed"] == 0
    assert knn.TABLE_ROWS_BUILT["all_E"] == cfg.E_max

    np.testing.assert_allclose(rho_b, rho_a, rtol=0, atol=1e-5)


def test_bucketed_row_handles_target_block_chunking():
    """Segment chunking (target_block < bucket size) must not change rho."""
    cfg_small = EDMConfig(E_max=4, target_block=3)
    cfg_big = EDMConfig(E_max=4, target_block=4096)
    ts = jnp.asarray(dummy_brain(10, 260, seed=3))
    _, optE = simplex_batch(ts, cfg_big)
    plan, order = make_bucket_plan(np.asarray(optE))
    ts_fut = all_futures(ts, cfg_big)[jnp.asarray(order)]
    a = ccm_block_bucketed(ts, ts_fut, cfg_small, plan)
    b = ccm_block_bucketed(ts, ts_fut, cfg_big, plan)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ccm_lookup_kernel_crosschecks_simplex_forecast():
    """kernels/ccm_lookup (wired in via the pallas engines) == batched
    knn.simplex_forecast on one shared table."""
    from repro.kernels.ccm_lookup.ops import ccm_lookup

    rng = np.random.default_rng(5)
    V = jnp.asarray(rng.standard_normal((5, 120)), jnp.float32)
    idx, sqd = knn.knn_tables_dense(V, V, 6, True)
    idx, w = knn.tables_with_weights(idx, sqd)
    Y = jnp.asarray(rng.standard_normal((9, 120)), jnp.float32)
    got = np.asarray(ccm_lookup(idx[3], w[3], Y, block_b=4, block_t=64))
    want = np.asarray(
        jnp.stack([knn.simplex_forecast(idx[3], w[3], y) for y in Y])
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_bucketed_row_un_jitted_counts_rows():
    """Direct (un-jitted) bucketed row: table rows built == len(buckets)."""
    cfg = EDMConfig(E_max=6)
    ts = jnp.asarray(dummy_brain(6, 205, seed=9))
    optE = np.asarray([2, 2, 4, 4, 4, 1], np.int32)
    plan, order = make_bucket_plan(optE)
    ts_fut = all_futures(ts, cfg)[jnp.asarray(order)]
    knn.reset_table_counters()
    row = ccm_library_row_bucketed(ts[0], ts_fut, cfg, plan)
    assert row.shape == (6,)
    assert knn.TABLE_ROWS_BUILT["bucketed"] == len(plan.buckets) == 3


# ---------------------------------------------------------- chunk streaming
def test_chunk_streamer_orders_and_bounds_inflight():
    from repro.runtime.stream import ChunkStreamer

    drained = []
    s = ChunkStreamer(lambda tag, v: drained.append((tag, int(v))), depth=2)
    for i in range(5):
        s.submit(i, np.asarray(i * 10))
        assert len(s) <= 2
    s.flush()
    assert drained == [(i, i * 10) for i in range(5)]


def test_chunk_streamer_discards_on_error():
    from repro.runtime.stream import ChunkStreamer

    drained = []
    with pytest.raises(RuntimeError):
        with ChunkStreamer(lambda t, v: drained.append(t), depth=3) as s:
            s.submit(0, np.asarray(0))
            raise RuntimeError("boom")
    assert drained == []  # stale chunks not flushed on failure


def test_pipeline_stream_depths_agree(tmp_path):
    """depth=1 (sync legacy) and depth=3 produce bit-identical maps and
    resume manifests."""
    from repro.core.pipeline import run_causal_inference

    ts = dummy_brain(9, 220, seed=13)
    outs = {}
    for depth in (1, 3):
        out = run_causal_inference(
            ts,
            EDMConfig(E_max=4, lib_block=2, stream_depth=depth),
            out_dir=str(tmp_path / f"d{depth}"),
        )
        outs[depth] = out.rho
    np.testing.assert_array_equal(outs[1], outs[3])
