"""Multi-process elastic fleet (DESIGN.md SS10): real worker processes
over one shared store must produce BYTE-identical artifacts to the
in-process driver — including when a worker is SIGKILLed mid-run and
relaunched.

The full-scale elastic smoke (64x500, 4 workers, kill + relaunch) is the
CI fleet job: set CI_FLEET_SMOKE=1 to run it; plain tier-1 runs the
small 2-worker variant only.
"""
import json
import os
import pathlib
import shutil
import signal
import time

import numpy as np
import pytest

from repro.core.types import EDMConfig
from repro.data import store
from repro.inference import SignificanceConfig
from repro.launch import edm_fleet
from repro.runtime import autotune, telemetry

ARTIFACTS = ("causal_map", "rho_conv", "rho_trend", "pvals", "edges")


def _baseline(tmp_path, ts, cfg, sig):
    """Fresh single-process W=1 run (the classic driver path)."""
    from repro.core.pipeline import run_causal_inference
    from repro.inference import run_significance

    out = tmp_path / "base"
    res = run_causal_inference(ts, cfg, out_dir=str(out))
    run_significance(
        ts, np.asarray(res.optE), np.asarray(res.rho), cfg, sig,
        out_dir=str(out),
    )
    return out


def _assert_byte_identical(fleet_out, base_out):
    for art in ARTIFACTS:
        a = np.load(fleet_out / art / "data.npy")
        b = np.load(base_out / art / "data.npy")
        assert a.dtype == b.dtype and a.shape == b.shape, art
        assert a.tobytes() == b.tobytes(), f"{art} differs from W=1 run"


def _spawn_fleet(out, n, ttl=None):
    return {f"w{i}": edm_fleet.spawn_worker(out, f"w{i}", ttl=ttl)
            for i in range(n)}


def _wait(procs, timeout=900):
    t0 = time.time()
    for wid, p in procs.items():
        left = timeout - (time.time() - t0)
        assert left > 0, "fleet timed out"
        assert p.wait(timeout=left) == 0, f"worker {wid} failed"


def _init(tmp_path, ts, cfg, sig, synthetic):
    out = tmp_path / "fleet"
    store.save_dataset(out / "dataset", ts, {"synthetic": synthetic})
    edm_fleet.init_fleet(out, out / "dataset", cfg, sig)
    return out


def _assert_telemetry_and_status(out, worker_ids):
    """DESIGN.md SS11 acceptance: every worker's JSONL is schema-valid
    and holds a span for ALL five pipeline stages (the barrier wait IS
    the record), and fleet status agrees with the artifacts."""
    span_stages: dict[str, set] = {}
    for stem, rec in telemetry.iter_store_records(out):
        assert telemetry.validate(rec) == [], (stem, rec)
        if rec["kind"] == "span":
            span_stages.setdefault(stem, set()).add(rec["stage"])
    assert set(worker_ids) <= set(span_stages), \
        f"missing telemetry files: {worker_ids} vs {sorted(span_stages)}"
    for wid in worker_ids:
        for stage in telemetry.PIPELINE_STAGES:
            assert stage in span_stages[wid], f"{wid} missing {stage} span"

    st = edm_fleet.fleet_status(out)
    assert st["complete"], st
    for name, c in st["coverage"].items():
        assert c["pct"] == 100.0, (name, c)
    for kind, s in st["stages"].items():
        assert s["done"] == s["total"] and not s["poisoned"], (kind, s)
        assert s["leases"] == [], (kind, s)
    assert st["telemetry"]["violations"] == 0
    assert edm_fleet.render_status(st).count("COMPLETE") == 1
    return st


def test_fleet_two_workers_byte_identical(tmp_path):
    """W=2 subprocess fleet == fresh in-process W=1 run, byte for byte
    (causal_map, rho_conv, rho_trend, pvals, edges)."""
    from repro.data.synthetic import dummy_brain

    ts = dummy_brain(16, 250, seed=0)
    cfg = EDMConfig(E_max=4, lib_block=4, target_tile=6)
    sig = SignificanceConfig(lib_sizes=(40, 80), n_surrogates=6, seed=0)
    base = _baseline(tmp_path, ts, cfg, sig)
    out = _init(tmp_path, ts, cfg, sig, "16x250")
    _wait(_spawn_fleet(out, 2))
    _assert_byte_identical(out, base)
    _assert_telemetry_and_status(out, ["w0", "w1"])
    # the recorded timings are enough to autotune the next run
    tuned = autotune.recommend(out)
    assert tuned is not None, "fleet run recorded no tunable telemetry"
    autotune.write_tuned(out, tuned)
    assert autotune.load_tuned(out)["recommend"] == tuned["recommend"]


@pytest.mark.skipif(
    not os.environ.get("CI_FLEET_SMOKE"),
    reason="full-scale elastic fleet smoke (64x500, 4 workers, SIGKILL + "
    "relaunch); run with CI_FLEET_SMOKE=1 — the CI fleet job does",
)
def test_fleet_kill_one_worker_relaunch_byte_identical(tmp_path):
    """The acceptance scenario: 4 workers on a 64x500 significance
    workload, one SIGKILLed mid-run and relaunched under the same id;
    assembled artifacts must equal a fresh W=1 run byte for byte."""
    from repro.data.synthetic import dummy_brain

    # CI pins the store to a known path (CI_FLEET_STORE) so follow-up
    # workflow steps can run `edm_fleet status` and upload telemetry/.
    ci_store = os.environ.get("CI_FLEET_STORE")
    if ci_store:
        base_dir = pathlib.Path(ci_store)
        shutil.rmtree(base_dir, ignore_errors=True)
        base_dir.mkdir(parents=True)
    else:
        base_dir = tmp_path

    ts = dummy_brain(64, 500, seed=0)
    cfg = EDMConfig(E_max=6, lib_block=4, target_tile=16)
    sig = SignificanceConfig(lib_sizes=(60, 120, 240), n_surrogates=20,
                             seed=0)
    base = _baseline(tmp_path, ts, cfg, sig)
    out = _init(base_dir, ts, cfg, sig, "64x500")

    procs = _spawn_fleet(out, 4)
    # wait until phase 2 is visibly underway (some tile durable), then
    # SIGKILL one worker mid-run
    deadline = time.time() + 600
    while not list(pathlib.Path(out).glob("tile_*.npy")) and not list(
        pathlib.Path(out).glob("rows_*.npy")
    ):
        assert time.time() < deadline, "fleet made no phase-2 progress"
        assert all(p.poll() is None for p in procs.values()), \
            "a worker died before the kill"
        time.sleep(0.2)
    victim = procs.pop("w0")
    os.kill(victim.pid, signal.SIGKILL)
    assert victim.wait() != 0
    # relaunch under the SAME id: its leases are reclaimed instantly
    procs["w0"] = edm_fleet.spawn_worker(out, "w0")
    _wait(procs)

    _assert_byte_identical(out, base)
    # the killed worker's leases never linger as queue state
    leases = list((out / "queue").glob("*.lease"))
    assert leases == [], f"stale leases after completion: {leases}"
    meta = json.loads((out / "causal_map" / "meta.json").read_text())
    assert meta.get("fleet") is True

    # telemetry schema + status acceptance: all four workers (including
    # the relaunched w0, whose JSONL survived the SIGKILL via the
    # crash-safe rewrite) recorded every stage; status reports complete
    st = _assert_telemetry_and_status(out, ["w0", "w1", "w2", "w3"])
    assert len(st["telemetry"]["workers"]) >= 4
    # and the run left enough recorded timing to write tuned.json
    tuned = autotune.recommend(out)
    assert tuned is not None
    p = autotune.write_tuned(out, tuned)
    assert p.exists() and autotune.load_tuned(out) is not None
