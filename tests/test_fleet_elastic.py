"""Multi-process elastic fleet (DESIGN.md SS10): real worker processes
over one shared store must produce BYTE-identical artifacts to the
in-process driver — including when a worker is SIGKILLed mid-run and
relaunched.

The full-scale elastic smoke (64x500, 4 workers, kill + relaunch) is the
CI fleet job: set CI_FLEET_SMOKE=1 to run it; plain tier-1 runs the
small 2-worker variant only.
"""
import json
import os
import pathlib
import signal
import time

import numpy as np
import pytest

from repro.core.types import EDMConfig
from repro.data import store
from repro.inference import SignificanceConfig
from repro.launch import edm_fleet

ARTIFACTS = ("causal_map", "rho_conv", "rho_trend", "pvals", "edges")


def _baseline(tmp_path, ts, cfg, sig):
    """Fresh single-process W=1 run (the classic driver path)."""
    from repro.core.pipeline import run_causal_inference
    from repro.inference import run_significance

    out = tmp_path / "base"
    res = run_causal_inference(ts, cfg, out_dir=str(out))
    run_significance(
        ts, np.asarray(res.optE), np.asarray(res.rho), cfg, sig,
        out_dir=str(out),
    )
    return out


def _assert_byte_identical(fleet_out, base_out):
    for art in ARTIFACTS:
        a = np.load(fleet_out / art / "data.npy")
        b = np.load(base_out / art / "data.npy")
        assert a.dtype == b.dtype and a.shape == b.shape, art
        assert a.tobytes() == b.tobytes(), f"{art} differs from W=1 run"


def _spawn_fleet(out, n, ttl=None):
    return {f"w{i}": edm_fleet.spawn_worker(out, f"w{i}", ttl=ttl)
            for i in range(n)}


def _wait(procs, timeout=900):
    t0 = time.time()
    for wid, p in procs.items():
        left = timeout - (time.time() - t0)
        assert left > 0, "fleet timed out"
        assert p.wait(timeout=left) == 0, f"worker {wid} failed"


def _init(tmp_path, ts, cfg, sig, synthetic):
    out = tmp_path / "fleet"
    store.save_dataset(out / "dataset", ts, {"synthetic": synthetic})
    edm_fleet.init_fleet(out, out / "dataset", cfg, sig)
    return out


def test_fleet_two_workers_byte_identical(tmp_path):
    """W=2 subprocess fleet == fresh in-process W=1 run, byte for byte
    (causal_map, rho_conv, rho_trend, pvals, edges)."""
    from repro.data.synthetic import dummy_brain

    ts = dummy_brain(16, 250, seed=0)
    cfg = EDMConfig(E_max=4, lib_block=4, target_tile=6)
    sig = SignificanceConfig(lib_sizes=(40, 80), n_surrogates=6, seed=0)
    base = _baseline(tmp_path, ts, cfg, sig)
    out = _init(tmp_path, ts, cfg, sig, "16x250")
    _wait(_spawn_fleet(out, 2))
    _assert_byte_identical(out, base)


@pytest.mark.skipif(
    not os.environ.get("CI_FLEET_SMOKE"),
    reason="full-scale elastic fleet smoke (64x500, 4 workers, SIGKILL + "
    "relaunch); run with CI_FLEET_SMOKE=1 — the CI fleet job does",
)
def test_fleet_kill_one_worker_relaunch_byte_identical(tmp_path):
    """The acceptance scenario: 4 workers on a 64x500 significance
    workload, one SIGKILLed mid-run and relaunched under the same id;
    assembled artifacts must equal a fresh W=1 run byte for byte."""
    from repro.data.synthetic import dummy_brain

    ts = dummy_brain(64, 500, seed=0)
    cfg = EDMConfig(E_max=6, lib_block=4, target_tile=16)
    sig = SignificanceConfig(lib_sizes=(60, 120, 240), n_surrogates=20,
                             seed=0)
    base = _baseline(tmp_path, ts, cfg, sig)
    out = _init(tmp_path, ts, cfg, sig, "64x500")

    procs = _spawn_fleet(out, 4)
    # wait until phase 2 is visibly underway (some tile durable), then
    # SIGKILL one worker mid-run
    deadline = time.time() + 600
    while not list(pathlib.Path(out).glob("tile_*.npy")) and not list(
        pathlib.Path(out).glob("rows_*.npy")
    ):
        assert time.time() < deadline, "fleet made no phase-2 progress"
        assert all(p.poll() is None for p in procs.values()), \
            "a worker died before the kill"
        time.sleep(0.2)
    victim = procs.pop("w0")
    os.kill(victim.pid, signal.SIGKILL)
    assert victim.wait() != 0
    # relaunch under the SAME id: its leases are reclaimed instantly
    procs["w0"] = edm_fleet.spawn_worker(out, "w0")
    _wait(procs)

    _assert_byte_identical(out, base)
    # the killed worker's leases never linger as queue state
    leases = list((out / "queue").glob("*.lease"))
    assert leases == [], f"stale leases after completion: {leases}"
    meta = json.loads((out / "causal_map" / "meta.json").read_text())
    assert meta.get("fleet") is True
