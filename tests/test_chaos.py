"""Chaos harness (DESIGN.md SS12): seeded randomized schedules of worker
kills, injected crash/error/delay faults (runtime/faultpoints.py), and
post-hoc store corruption — every schedule must converge to byte-identical
causal_map / rho_conv / rho_trend / pvals / edges with a clean
`edm_fleet fsck`, and every corruption must be detected, healed, and
recomputed identically by one more fleet pass.

Tier-1 replays a few seeds; the CI ``chaos-smoke`` job (CI_CHAOS=1) runs
the full 20-seed battery of the acceptance criteria.  All schedules are
pure functions of their seed — a failure reproduces from the seed alone.
"""
import json
import os
import random
import signal
import time

import numpy as np
import pytest

from repro.core.types import EDMConfig
from repro.data import store
from repro.inference import SignificanceConfig
from repro.launch import edm_fleet
from repro.runtime import integrity

ARTIFACTS = ("causal_map", "rho_conv", "rho_trend", "pvals", "edges")
CFG = EDMConfig(E_max=4, lib_block=4, target_tile=6)
SIG = SignificanceConfig(lib_sizes=(40, 80), n_surrogates=6, seed=0)
N_SCHEDULES = 20 if os.environ.get("CI_CHAOS") else 3
SCHEDULE_TIMEOUT = 600.0
MAX_RESTARTS = 6

#: one armed process generation each — crash arms die once and the
#: relaunched (unarmed) worker finishes; error/delay arms are absorbed
#: in-process by the bounded-retry / TTL machinery.
FAULT_ARMS = (
    "tile_pre_rename:crash@{k}",
    "tile_pre_fsync:crash@{k}",
    "manifest_pre_rename:crash@{k}",
    "done_pre_mark:crash@1",
    "done_pre_rename:crash@1",
    "unit_post_compute:crash@1",
    "lease_pre_steal:crash@1",
    "unit_pre_compute:error@1",
    "chunk_pre:error@{k}",
    "chunk_pre:delay=0.2",
)
CORRUPTIONS = ("none", "bitflip", "truncate", "delete")


def make_schedule(seed: int) -> dict:
    rng = random.Random(seed)
    n_workers = rng.randint(1, 3)
    workers = []
    for i in range(n_workers):
        arm = None
        if rng.random() < 0.7:
            arm = rng.choice(FAULT_ARMS).format(k=rng.randint(1, 4))
        workers.append({"id": f"c{i}", "fault": arm})
    return {
        "seed": seed,
        "workers": workers,
        # one external SIGKILL of a random live worker, paper-style
        "kill_after_s": rng.uniform(2.0, 8.0) if rng.random() < 0.5 else None,
        "kill_idx": rng.randrange(n_workers),
        "corruption": rng.choice(CORRUPTIONS),
    }


@pytest.fixture(scope="module")
def jax_cache(tmp_path_factory):
    """One persistent compile cache for every schedule's workers — all
    but the first process hit the disk cache (the fleet's answer to the
    paper's GPU-init straggler tail, SSIV-B2)."""
    return str(tmp_path_factory.mktemp("jax_cache"))


@pytest.fixture(scope="module")
def baseline(tmp_path_factory):
    """The W=1 in-process ground truth every schedule must reproduce."""
    from repro.core.pipeline import run_causal_inference
    from repro.inference import run_significance

    root = tmp_path_factory.mktemp("baseline")
    ts = np.random.default_rng(42).standard_normal((16, 250)).astype(np.float32)
    store.save_dataset(root / "dataset", ts, {"synthetic": "16x250"})
    out = root / "out"
    res = run_causal_inference(ts, CFG, out_dir=str(out))
    run_significance(ts, np.asarray(res.optE), np.asarray(res.rho), CFG, SIG,
                     out_dir=str(out))
    return {
        "dataset": root / "dataset",
        "bytes": {n: (out / n / "data.npy").read_bytes() for n in ARTIFACTS},
    }


def _spawn(out, wid, jax_cache, fault=None):
    env = dict(os.environ, JAX_COMPILATION_CACHE_DIR=jax_cache,
               JAX_PLATFORMS="cpu")
    env.pop("EDM_FAULTS", None)
    env.pop("EDM_TELEMETRY", None)  # default-on JSONL: the loss-window
    # bound below is asserted against the recorded telemetry
    if fault is not None:
        env["EDM_FAULTS"] = fault
    return edm_fleet.spawn_worker(out, wid, env=env)


def _drive_fleet(out, schedule, jax_cache):
    """Run one schedule's fleet to convergence: spawn armed workers,
    apply the external kill, relaunch every dead worker (unarmed — the
    armed generation crashed exactly once) until the store completes."""
    procs, restarts = {}, {}
    for w in schedule["workers"]:
        procs[w["id"]] = _spawn(out, w["id"], jax_cache, fault=w["fault"])
        restarts[w["id"]] = 0
    kill_at = (None if schedule["kill_after_s"] is None
               else time.time() + schedule["kill_after_s"])
    kill_wid = schedule["workers"][schedule["kill_idx"]]["id"]
    deadline = time.time() + SCHEDULE_TIMEOUT
    try:
        while True:
            if time.time() > deadline:
                raise TimeoutError(
                    f"schedule {schedule['seed']} did not converge: "
                    f"{json.dumps(edm_fleet.fleet_status(out)['stages'])}"
                )
            if kill_at is not None and time.time() >= kill_at:
                kill_at = None
                if procs[kill_wid].poll() is None:
                    procs[kill_wid].send_signal(signal.SIGKILL)
            poison = list((out / "queue").glob("*.poison"))
            if poison:
                raise AssertionError(
                    f"unit poisoned under schedule {schedule['seed']}: "
                    + poison[0].read_text()
                )
            # Relaunch crashed workers FIRST, then re-poll for the
            # all-dead checks below: a stale snapshot here once spawned a
            # second same-id worker next to the relaunched one, and two
            # live processes sharing a worker id (which the fleet's
            # one-process-per-id contract forbids) last-writer-win
            # clobbered each other's manifest shard.
            for wid, p in procs.items():
                rc = p.poll()
                if rc is None or rc == 0:
                    continue
                if restarts[wid] >= MAX_RESTARTS:
                    raise AssertionError(
                        f"worker {wid} burned {MAX_RESTARTS} restarts "
                        f"(schedule {schedule['seed']}, last rc {rc})"
                    )
                restarts[wid] += 1
                procs[wid] = _spawn(out, wid, jax_cache)  # unarmed relaunch
            if all(p.poll() is not None for p in procs.values()):
                if edm_fleet.fleet_status(out)["complete"]:
                    return
                # every proc exited 0 yet the store is incomplete (a
                # worker raced a stage it could not finish): respawn one
                wid = schedule["workers"][0]["id"]
                if restarts[wid] >= MAX_RESTARTS:
                    raise AssertionError(
                        f"store incomplete after {MAX_RESTARTS} respawns "
                        f"of {wid} (schedule {schedule['seed']})"
                    )
                restarts[wid] += 1
                procs[wid] = _spawn(out, wid, jax_cache)
            time.sleep(0.5)
    finally:
        for p in procs.values():
            if p.poll() is None:
                p.kill()
        for p in procs.values():
            p.wait(timeout=30)


def _corrupt(out, kind, rng):
    """Post-hoc damage in a random tiled artifact dir; returns the path."""
    d = rng.choice([out, out / "pvals", out / "rho_conv"])
    tiles = sorted(d.glob("tile_*.npy"))
    f = tiles[rng.randrange(len(tiles))]
    if kind == "bitflip":
        raw = bytearray(f.read_bytes())
        raw[rng.randrange(len(raw))] ^= 1 << rng.randrange(8)
        f.write_bytes(bytes(raw))
    elif kind == "truncate":
        f.write_bytes(f.read_bytes()[: rng.randrange(8, 64)])
    else:  # delete
        f.unlink()
    return f


def _assert_matches(out, baseline):
    for name in ARTIFACTS:
        got = (out / name / "data.npy").read_bytes()
        assert got == baseline["bytes"][name], (
            f"{name} differs from the W=1 baseline"
        )


def _assert_done_markers_covered(out):
    """Every durable ``queue/*.done`` marker names its writer; that
    worker's telemetry JSONL must contain the matching done counter
    (mark_done's flush-before-marker ordering makes this an invariant,
    not a best effort)."""
    from repro.runtime import telemetry

    for marker in sorted((out / "queue").glob("*.done")):
        uid = marker.name[: -len(".done")]
        writer = json.loads(marker.read_text())["worker"]
        recs = telemetry.read_jsonl(telemetry.worker_jsonl(out, writer))
        assert any(
            r.get("kind") == "counter" and r.get("name") == "done"
            and r.get("attrs", {}).get("uid") == uid
            for r in recs
        ), f"done marker {uid} has no durable done record from {writer}"


@pytest.mark.parametrize("seed", range(N_SCHEDULES))
def test_chaos_schedule_converges_byte_identical(
    baseline, jax_cache, tmp_path, seed
):
    schedule = make_schedule(seed)
    out = tmp_path / "fleet"
    edm_fleet.init_fleet(out, baseline["dataset"], CFG, SIG)
    _drive_fleet(out, schedule, jax_cache)

    # 1. converged bytes == the W=1 in-process ground truth
    _assert_matches(out, baseline)
    # 2. the surviving store verifies clean, crash residue and all
    rep = integrity.fsck_store(out)
    assert rep["clean"], json.dumps(rep, indent=1)
    # 2b. telemetry loss-window bound: mark_done flushes the unit's done
    # record BEFORE the durable marker lands, so — even across injected
    # SIGKILLs — every surviving done marker implies its writer's
    # telemetry for that unit survived too (DESIGN.md SS13)
    _assert_done_markers_covered(out)

    # 3. post-hoc corruption: detect -> heal -> one pass -> identical
    if schedule["corruption"] != "none":
        rng = random.Random(schedule["seed"] ^ 0xC0FFEE)
        f = _corrupt(out, schedule["corruption"], rng)
        rep = integrity.fsck_store(out, heal=True)
        assert not rep["clean"], f"fsck missed {schedule['corruption']} of {f}"
        assert "refused" not in rep["healed"]
        assert integrity.fsck_store(out)["clean"]
        edm_fleet.FleetWorker(out, "wheal", progress=False).run()
        _assert_matches(out, baseline)
        assert integrity.fsck_store(out)["clean"]


def test_faultpoint_spec_parsing():
    from repro.runtime import faultpoints

    arms = faultpoints.parse_spec("tile_pre_rename:crash@3, chunk_pre:delay=0.5")
    assert arms["tile_pre_rename"] == ("crash", 0.0, 3)
    assert arms["chunk_pre"] == ("delay", 0.5, 0)
    with pytest.raises(faultpoints.FaultSpecError):
        faultpoints.parse_spec("p:explode")
    with pytest.raises(faultpoints.FaultSpecError):
        faultpoints.parse_spec("p:crash@0")
    with pytest.raises(faultpoints.FaultSpecError):
        faultpoints.parse_spec("p:delay")


def test_faultpoint_error_and_nth_hit_semantics():
    from repro.runtime import faultpoints

    faultpoints.configure("p:error@3")
    try:
        faultpoints.fire("p")
        faultpoints.fire("p")
        faultpoints.fire("other")  # unarmed points never fire
        with pytest.raises(faultpoints.InjectedFault, match="hit 3"):
            faultpoints.fire("p")
        faultpoints.fire("p")  # @n is one-shot: hit 4 passes
    finally:
        faultpoints.configure(None)
