"""EDM extensions: S-Map nonlinearity test + time-delayed CCM."""
import jax.numpy as jnp
import numpy as np

from repro.core.extensions import ccm_lagged, smap_theta_sweep
from repro.core.types import EDMConfig


def test_smap_detects_nonlinearity(coupled_pair):
    """Logistic-map dynamics are state-dependent: rho(theta>0) must beat
    the global linear model rho(0) (Sugihara 1994)."""
    cfg = EDMConfig(E_max=6)
    x = jnp.asarray(coupled_pair[0])
    rhos = np.asarray(smap_theta_sweep(x, 2, cfg))
    assert rhos.max() > rhos[0] + 0.02, rhos
    assert np.argmax(rhos) > 0


def test_smap_linear_system_flat_theta():
    """An AR(1) (linear) series shows no S-Map gain from locality."""
    rng = np.random.default_rng(0)
    x = np.zeros(600, np.float32)
    for t in range(1, 600):
        x[t] = 0.8 * x[t - 1] + 0.1 * rng.standard_normal()
    cfg = EDMConfig(E_max=6)
    rhos = np.asarray(smap_theta_sweep(jnp.asarray(x), 2, cfg))
    assert rhos.max() <= rhos[0] + 0.05, rhos


def test_lagged_ccm_prefers_nonpositive_lag(coupled_pair):
    """x drives y: estimating x from M_y peaks at lag <= 0 (cause precedes
    effect — Ye et al. 2015, the paper's adjacency criterion)."""
    cfg = EDMConfig(E_max=6)
    x, y = jnp.asarray(coupled_pair[0]), jnp.asarray(coupled_pair[1])
    lags = (-4, -3, -2, -1, 0, 1, 2, 3, 4)
    rhos = np.asarray(ccm_lagged(y, x, 3, cfg, lags))  # library = M_y
    assert lags[int(np.argmax(rhos))] <= 0, dict(zip(lags, np.round(rhos, 3)))
