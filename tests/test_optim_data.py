"""Optimizers, schedules, gradient compression, data pipeline, store."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import Prefetcher, TokenStream
from repro.data import store
from repro.optim import adafactor, adamw, grad_compress
from repro.optim.schedule import make_schedule


def _quadratic_losses(opt_mod, steps=60, lr=0.1):
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}
    target = {"w": jnp.asarray([0.5, 0.5]), "b": jnp.asarray(-0.5)}
    state = opt_mod.init(params)

    def loss_fn(p):
        return sum(
            jnp.sum((a - b) ** 2) for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(target))
        )

    losses = []
    for _ in range(steps):
        g = jax.grad(loss_fn)(params)
        params, state = opt_mod.update(g, state, params, lr)
        losses.append(float(loss_fn(params)))
    return losses


def test_adamw_converges():
    losses = _quadratic_losses(adamw)
    assert losses[-1] < 1e-2 * losses[0]


def test_adafactor_converges():
    losses = _quadratic_losses(adafactor)
    assert losses[-1] < 0.1 * losses[0]


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules_warmup_and_shape():
    for kind in ("cosine", "wsd", "constant"):
        s = make_schedule(kind, 1.0, warmup=10, total=100)
        assert float(s(0)) < 0.11
        assert float(s(10)) == pytest.approx(1.0, rel=1e-5)
        assert float(s(99)) <= 1.0
    cos = make_schedule("cosine", 1.0, 10, 100)
    assert float(cos(99)) < 0.01


# -------------------------------------------------------- grad compression
# (hypothesis-based roundtrip bound: tests/test_properties.py)
def test_error_feedback_converges_on_quadratic():
    """int8 + error feedback must still drive a quadratic to ~0."""
    w = jnp.asarray([4.0, -3.0, 2.0, 5.0])
    err = jnp.zeros_like(w)
    lr = 0.05
    for _ in range(400):
        g = 2 * w  # grad of |w|^2
        q, scale, err = grad_compress.compress_residual(g, err)
        w = w - lr * grad_compress.dequantize(q, scale)
    assert float(jnp.abs(w).max()) < 1e-2


# -------------------------------------------------------------- data layer
def test_token_stream_deterministic():
    a = TokenStream(1000, 4, 16, seed=7).batch_at(5)
    b = TokenStream(1000, 4, 16, seed=7).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = TokenStream(1000, 4, 16, seed=8).batch_at(5)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_prefetcher_preserves_order():
    stream = TokenStream(100, 2, 8, seed=0)
    pf = Prefetcher(stream, n_steps=5)
    got = [np.asarray(b["tokens"]) for b in pf]
    assert len(got) == 5
    for i, g in enumerate(got):
        np.testing.assert_array_equal(g, stream.batch_at(i)["tokens"])


def test_store_roundtrip(tmp_path):
    ts = np.random.default_rng(0).standard_normal((8, 32)).astype(np.float32)
    store.save_dataset(tmp_path / "ds", ts, {"name": "t"})
    loaded = store.load_dataset(tmp_path / "ds")
    np.testing.assert_array_equal(np.asarray(loaded), ts)


def test_row_block_writer_coverage(tmp_path):
    w = store.RowBlockWriter(tmp_path / "w", N=10)
    w.write_block(0, np.ones((4, 10), np.float32))
    w.write_block(7, np.ones((3, 10), np.float32))
    assert w.next_uncovered() == 4
    w.write_block(4, np.ones((3, 10), np.float32))
    assert w.next_uncovered() is None
    assert w.assemble().sum() == 100
