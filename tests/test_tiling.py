"""Target-tiled phase-2 CCM (DESIGN.md SS7) + the PR bugfix sweep:

  * tiled vs untiled rho bit-identical across tile sizes (including ones
    that don't divide N) and both bucketed/all-E table layouts;
  * no dense (N, N) host allocation when phase 2 streams to a store;
  * TileWriter 2D manifest: coverage, elastic/fragmented chunk_plan,
    col_order persistence, assemble (dense and memmap);
  * simplex_weights tied-neighbour (d1 ~ 0) handling — dead-neuron
    datasets produce a finite causal map end-to-end;
  * k_override / k <= Lp validation.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    BucketPlan,
    EDMConfig,
    ccm_matrix,
    ccm_row_tables,
    ccm_row_tables_bucketed,
    make_bucket_plan,
    make_tile_plans,
    simplex_batch,
    simplex_weights,
)
from repro.data.store import RowBlockWriter, TileWriter
from repro.data.synthetic import dummy_brain


# ------------------------------------------------------- tiled bit-identity
@pytest.mark.parametrize("bucketed", [True, False])
def test_tiled_matrix_bit_identical_across_tile_sizes(bucketed):
    """Acceptance: tiling the target axis must not change a single bit,
    for dividing and non-dividing tile widths, in both table layouts."""
    N = 14
    ts = jnp.asarray(dummy_brain(N, 250, seed=21))
    cfg0 = EDMConfig(E_max=5, bucketed=bucketed)
    _, optE = simplex_batch(ts, cfg0)
    base = np.asarray(ccm_matrix(ts, optE, cfg0))
    for tile in (3, 5, N, 4 * N):  # 5 and 3 do not divide N=14
        cfg = EDMConfig(E_max=5, bucketed=bucketed, target_tile=tile)
        tiled = np.asarray(ccm_matrix(ts, optE, cfg))
        np.testing.assert_array_equal(tiled, base, err_msg=f"tile={tile}")


@pytest.mark.parametrize("bucketed", [True, False])
def test_tiled_pipeline_bit_identical_with_store(tmp_path, bucketed):
    """Full pipeline: tiled + streamed-to-store == untiled in-memory."""
    from repro.core.pipeline import run_causal_inference

    ts = dummy_brain(13, 230, seed=3)
    base = run_causal_inference(ts, EDMConfig(E_max=4, lib_block=3, bucketed=bucketed))
    cfg = EDMConfig(E_max=4, lib_block=3, bucketed=bucketed, target_tile=5)
    out = run_causal_inference(ts, cfg, out_dir=str(tmp_path / f"b{bucketed}"))
    assert isinstance(out.rho, np.memmap)  # disk-backed, not a host array
    np.testing.assert_array_equal(np.asarray(out.rho), np.asarray(base.rho))


def test_make_tile_plans_cover_and_bounded_signatures():
    optE = np.asarray([2] * 5 + [4] * 9 + [7] * 3, np.int32)
    plan, _ = make_bucket_plan(optE)
    plans = make_tile_plans(plan, 4)
    # tiles cover [0, N) in order
    assert [c0 for c0, _ in plans] == [0, 4, 8, 12, 16]
    assert all(sum(c for _, c in sp) in (4, 1) for _, sp in plans)
    assert sum(sum(c for _, c in sp) for _, sp in plans) == plan.n_targets
    # boundary tile straddles buckets 0 and 1
    assert plans[1][1] == ((0, 1), (1, 3))
    # distinct jit signatures stay small (~2 x len(buckets))
    assert len({sp for _, sp in plans}) <= 2 * len(plan.buckets)
    with pytest.raises(ValueError, match="tile"):
        make_tile_plans(plan, 0)


def test_phase2_no_dense_host_alloc_with_store(tmp_path, monkeypatch):
    """Acceptance: with an output store, phase 2 must never allocate the
    dense (N, N) host map — np.zeros is guarded for the whole run."""
    from repro.core.pipeline import run_causal_inference

    N = 24
    ts = dummy_brain(N, 220, seed=1)
    real_zeros = np.zeros

    def guarded(shape, *args, **kwargs):
        if tuple(np.atleast_1d(shape)) == (N, N):
            raise AssertionError("dense NxN host allocation in streaming mode")
        return real_zeros(shape, *args, **kwargs)

    monkeypatch.setattr(np, "zeros", guarded)
    out = run_causal_inference(
        ts, EDMConfig(E_max=4, lib_block=4, target_tile=8),
        out_dir=str(tmp_path / "rho"),
    )
    monkeypatch.undo()
    base = run_causal_inference(ts, EDMConfig(E_max=4, lib_block=4))
    np.testing.assert_array_equal(np.asarray(out.rho), np.asarray(base.rho))


# ---------------------------------------------------------- TileWriter (2D)
def test_tile_writer_2d_manifest_roundtrip(tmp_path):
    N = 9
    rho = np.arange(N * N, dtype=np.float32).reshape(N, N)
    w = TileWriter(tmp_path / "w", N)
    w.write_tile(0, 0, rho[:4, :5])
    w.write_tile(0, 5, rho[:4, 5:])
    w.write_block(4, rho[4:])  # legacy full-width block interoperates
    assert w.covered().all()
    np.testing.assert_array_equal(w.assemble(), rho)
    # a fresh writer over the same dir sees the same state (resume)
    w2 = TileWriter(tmp_path / "w", N)
    assert w2.chunk_plan(4) == []
    np.testing.assert_array_equal(w2.assemble(), rho)
    # memmap assembly is identical and lands at the requested path
    mm = w2.assemble(mmap_path=tmp_path / "w" / "causal_map" / "data.npy")
    assert isinstance(mm, np.memmap)
    np.testing.assert_array_equal(np.asarray(mm), rho)


def test_tile_writer_partial_columns_not_covered(tmp_path):
    w = TileWriter(tmp_path / "w", 6)
    w.write_tile(0, 0, np.ones((6, 4), np.float32))
    assert not w.covered().any()  # cols 4..5 missing on every row
    w.write_tile(0, 4, np.ones((3, 2), np.float32))
    cov = w.covered()
    np.testing.assert_array_equal(cov, [True] * 3 + [False] * 3)


def test_tile_writer_col_order_persisted_and_checked(tmp_path):
    N = 8
    rng = np.random.default_rng(0)
    rho = rng.standard_normal((N, N)).astype(np.float32)
    order = rng.permutation(N)
    w = TileWriter(tmp_path / "w", N)
    w.ensure_col_order(order)
    rho_sorted = rho[:, order]  # tiles are written in on-disk (sorted) order
    w.write_tile(0, 0, rho_sorted[:, :5])
    w.write_tile(0, 5, rho_sorted[:, 5:])
    np.testing.assert_array_equal(w.assemble(), rho)  # permutation undone
    # resume with the same order is fine; a different one must refuse
    TileWriter(tmp_path / "w", N).ensure_col_order(order)
    with pytest.raises(ValueError, match="column-order mismatch"):
        TileWriter(tmp_path / "w", N).ensure_col_order(np.roll(order, 1))


# --------------------------------------------- chunk_plan fragmentation fix
def test_chunk_plan_skips_covered_islands(tmp_path):
    """Elastic resume can leave covered islands mid-range; planned spans
    must be trimmed to uncovered runs, not re-span covered rows."""
    w = RowBlockWriter(tmp_path / "w", 20)
    w.write_block(6, np.zeros((4, 20), np.float32))  # island: rows 6..9
    assert w.chunk_plan(8) == [(0, 6), (10, 8), (18, 2)]
    # the old behaviour would have produced [(0, 8), ...] — recomputing
    # (and rewriting) covered rows 6..7 inside the first span
    w.write_block(0, np.zeros((6, 20), np.float32))
    assert w.chunk_plan(8) == [(10, 8), (18, 2)]
    w.write_block(10, np.zeros((10, 20), np.float32))
    assert w.chunk_plan(8) == []


# ------------------------------------- degenerate-distance simplex weights
def test_simplex_weights_uniform_over_tied_neighbours():
    """d1 == 0 (duplicate points): cppEDM weights the tied neighbours
    uniformly; the exponential form would underflow to a delta."""
    sqd = jnp.asarray([[0.0, 0.0, 0.0, 4.0, 9.0]])
    w = np.asarray(simplex_weights(sqd, 5))
    np.testing.assert_allclose(w[0], [1 / 3, 1 / 3, 1 / 3, 0.0, 0.0], atol=1e-6)
    # k_valid masks ties beyond the valid neighbour count too
    w2 = np.asarray(simplex_weights(sqd, 2))
    np.testing.assert_allclose(w2[0], [0.5, 0.5, 0.0, 0.0, 0.0], atol=1e-6)
    # regular rows are untouched by the tie branch
    sqd_reg = jnp.asarray([[1.0, 4.0, 9.0]])
    w3 = np.asarray(simplex_weights(sqd_reg, 3))
    assert w3[0, 0] > w3[0, 1] > w3[0, 2] > 0
    np.testing.assert_allclose(w3.sum(), 1.0, rtol=1e-6)
    # scale invariance: a tiny-amplitude row (d1 > 0 but << any absolute
    # eps) must weight exactly like its rescaled counterpart — the tie
    # branch only fires on EXACT zeros, never on small-but-real distances
    w4 = np.asarray(simplex_weights(jnp.asarray(sqd_reg) * 1e-20, 3))
    np.testing.assert_allclose(w4, w3, rtol=1e-5)


@pytest.mark.parametrize("target_tile", [0, 4])
def test_dead_and_duplicate_neurons_finite_causal_map(target_tile):
    """End-to-end: constant (dead) and duplicated series must yield a
    finite causal map — no NaN/Inf reaches pearson."""
    rng = np.random.default_rng(7)
    ts = dummy_brain(8, 240, seed=7).copy()
    ts[0] = 0.0                    # dead neuron: all distances are 0
    ts[1] = 3.14                   # dead at a nonzero level
    ts[3] = ts[2]                  # exact duplicate pair
    ts = jnp.asarray(ts)
    cfg = EDMConfig(E_max=4, target_tile=target_tile)
    rhos, optE = simplex_batch(ts, cfg)
    assert np.isfinite(np.asarray(rhos)).all()
    rho = np.asarray(ccm_matrix(ts, optE, cfg))
    assert np.isfinite(rho).all()
    # dead neurons are unpredictable: 0 skill by the pearson convention
    assert rho[0, 0] == 0.0 and rho[1, 1] == 0.0


# ------------------------------------------------------- k validation fixes
def test_k_override_zero_rejected():
    with pytest.raises(ValueError, match="k_override"):
        EDMConfig(k_override=0)
    with pytest.raises(ValueError, match="k_override"):
        EDMConfig(k_override=-3)
    assert EDMConfig(k_override=5).k_max == 5
    assert EDMConfig(E_max=7).k_max == 8  # None -> tracks E_max


def test_k_exceeding_library_points_raises_clear_error():
    """Short series with large optE/k must fail with a diagnosable error,
    not crash inside lax.top_k."""
    x = jnp.asarray(np.linspace(0, 1, 16), jnp.float32)
    with pytest.raises(ValueError, match="library points"):
        ccm_row_tables(x, EDMConfig(E_max=8))  # Lp=8 < k_max=9
    with pytest.raises(ValueError, match="library points"):
        ccm_row_tables_bucketed(
            x, EDMConfig(E_max=8), BucketPlan(buckets=(8,), counts=(1,))
        )
    with pytest.raises(ValueError, match="library points"):
        ccm_row_tables(x, EDMConfig(E_max=3, k_override=500))
    # k_override=1 is explicit and honoured (the old `or` idiom could not
    # distinguish unset from small-but-set)
    idx, w = ccm_row_tables_bucketed(
        x, EDMConfig(E_max=3, k_override=1), BucketPlan(buckets=(2,), counts=(1,))
    )
    assert idx.shape[-1] == 1
