"""runtime/platform.py (DESIGN.md SS14): execution tiers, XLA-flag
merging, and the env-driven multi-host mesh contract.

The pieces that must run BEFORE a jax backend exists (flag latching,
jax.distributed.initialize) are exercised in subprocesses; the pure
spec/parsing logic runs in-process.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.runtime import platform

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str, extra_env: dict | None = None):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env.update(extra_env or {})
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)], capture_output=True,
        text=True, env=env, timeout=600, cwd=REPO,
    )


def test_tier_registry():
    """Every tier names a registered engine; the gpu tier carries the
    tuned async-collective/latency-hiding flag set SS14 relies on."""
    from repro import engine

    assert platform.available_tiers() == ("cpu", "gpu", "tpu")
    for name in platform.available_tiers():
        engine.get_engine(platform.default_engine(name))  # must resolve
    gpu = platform.TIERS["gpu"]
    assert any("latency_hiding" in f for f in gpu.xla_flags)
    assert any("async_collectives" in f for f in gpu.xla_flags)
    assert platform.default_engine("cpu") == "reference"
    assert platform.default_engine("gpu") == "pallas-compiled"
    with pytest.raises(KeyError, match="unknown platform tier"):
        platform.apply_platform("cuda")


def test_distributed_spec_from_env():
    """The EDM_* contract: unset -> None; complete -> parsed spec;
    partial or out-of-range -> a refusal (a guessed rank would deadlock
    the whole mesh)."""
    assert platform.distributed_spec_from_env({}) is None
    spec = platform.distributed_spec_from_env({
        "EDM_COORDINATOR": "head:1234",
        "EDM_NUM_PROCESSES": "8",
        "EDM_PROCESS_ID": "3",
        "EDM_LOCAL_DEVICE_IDS": "0,1",
    })
    assert spec == {
        "coordinator": "head:1234",
        "num_processes": 8,
        "process_id": 3,
        "local_device_ids": (0, 1),
    }
    with pytest.raises(ValueError, match="missing"):
        platform.distributed_spec_from_env({"EDM_COORDINATOR": "head:1"})
    with pytest.raises(ValueError, match="outside world size"):
        platform.distributed_spec_from_env({
            "EDM_COORDINATOR": "head:1",
            "EDM_NUM_PROCESSES": "2",
            "EDM_PROCESS_ID": "2",
        })


def test_apply_platform_after_backend_warns():
    """The suite's jax backend is already live, so a tier application
    here must WARN that flags cannot latch (rather than silently doing
    nothing)."""
    import jax

    jax.devices()  # ensure the backend is up
    with pytest.warns(RuntimeWarning, match="NOT take effect"):
        platform.apply_platform("cpu")


def test_apply_platform_latches_flags_and_devices():
    """Fresh process: cpu tier + device spoof land in XLA_FLAGS before
    backend init, the backend sees the spoofed device count, and
    describe() reports tier + census."""
    r = _run_sub("""
        from repro.runtime import platform
        rec = platform.apply_platform("cpu", cpu_devices=3)
        assert rec["tier"] == "cpu" and rec["engine"] == "reference"
        import os
        assert "--xla_force_host_platform_device_count=3" in \\
            os.environ["XLA_FLAGS"]
        import jax
        assert len(jax.devices()) == 3, jax.devices()
        d = platform.describe()
        assert d["tier"]["tier"] == "cpu"
        assert d["devices"]["global"] == 3
        print("latch OK")
    """)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "latch OK" in r.stdout


def test_init_distributed_single_process_mesh():
    """jax.distributed.initialize via the EDM_* env (1-process world on
    a local coordinator): the mesh forms, init is idempotent, a
    conflicting re-init refuses, and the SS14 sharded builder runs on
    the resulting global device view bit-identically."""
    r = _run_sub("""
        import socket
        s = socket.socket(); s.bind(("localhost", 0))
        port = s.getsockname()[1]; s.close()
        import os
        os.environ["EDM_COORDINATOR"] = f"localhost:{port}"
        os.environ["EDM_NUM_PROCESSES"] = "1"
        os.environ["EDM_PROCESS_ID"] = "0"
        from repro.runtime import platform
        platform.apply_platform("cpu", cpu_devices=2)
        info = platform.init_distributed()
        assert info["num_processes"] == 1 and info["process_id"] == 0
        assert platform.init_distributed() == info  # idempotent
        try:
            platform.init_distributed({"coordinator": "x:1",
                                       "num_processes": 2, "process_id": 1})
        except RuntimeError as e:
            assert "already initialized" in str(e)
        else:
            raise AssertionError("conflicting re-init must refuse")
        import jax, numpy as np, jax.numpy as jnp
        assert jax.process_count() == 1 and len(jax.devices()) == 2
        from repro.core import EDMConfig, knn
        from repro.core.pipeline import knn_tables_library_sharded
        rng = np.random.default_rng(7)
        Vq = jnp.asarray(rng.standard_normal((4, 90)), jnp.float32)
        cfg = EDMConfig(E_max=4)
        mi, md = knn_tables_library_sharded(Vq, Vq, 5, cfg, exclude_self=True)
        i0, d0 = knn.knn_tables_all_E_streaming(Vq, Vq, 5, True, tile_c=32)
        np.testing.assert_array_equal(np.asarray(mi), np.asarray(i0))
        np.testing.assert_array_equal(np.asarray(md), np.asarray(d0))
        print("distributed mesh OK")
    """)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "distributed mesh OK" in r.stdout


def test_fleet_spec_platform_opt_in(tmp_path):
    """fleet.json records the platform tier + distributed opt-in, and a
    worker process applies them from the spec before its first jax touch
    (apply_spec_platform in a fresh interpreter)."""
    import numpy as np

    from repro.core.types import EDMConfig
    from repro.data import store
    from repro.launch import edm_fleet

    ds = tmp_path / "dataset"
    store.save_dataset(ds, np.random.default_rng(0)
                       .standard_normal((8, 60)).astype(np.float32), {})
    out = tmp_path / "fleet"
    spec = edm_fleet.init_fleet(out, ds, EDMConfig(E_max=3),
                                platform="cpu", distributed=False)
    assert spec["platform"] == "cpu"
    assert spec["distributed"] is False
    raw = json.loads((out / "fleet.json").read_text())
    assert raw["platform"] == "cpu"
    r = _run_sub(f"""
        from repro.launch import edm_fleet
        from repro.runtime import platform
        edm_fleet.apply_spec_platform({str(out)!r})
        rec = platform.current()
        assert rec is not None and rec["tier"] == "cpu", rec
        print("spec opt-in OK")
    """)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "spec opt-in OK" in r.stdout
