"""Causal-significance subsystem tests (DESIGN.md SS9).

Covers: prefix-snapshot kNN tables (one-sweep vs per-size rebuild,
bit-identical, both engines, plus a candidate-mask oracle), surrogate
null models (spectrum preservation, determinism), BH-FDR against the
scipy oracle, the deprecated ccm_convergence wrapper, the hardened
pearson, and the end-to-end significance pipeline (coupled-logistic
edge survives FDR, decoupled pair does not; streaming store matches the
in-memory path bit-for-bit and resumes).
"""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import knn
from repro.core.stats import pearson
from repro.core.types import EDMConfig


# ------------------------------------------------- prefix-snapshot tables
@pytest.fixture(scope="module")
def lag_pair():
    rng = np.random.default_rng(0)
    Vq = jnp.asarray(rng.standard_normal((6, 120)), jnp.float32)
    perm = jnp.asarray(rng.permutation(120).astype(np.int32))
    return Vq, perm


@pytest.mark.parametrize("engine", ["reference", "pallas-interpret"])
@pytest.mark.parametrize("tile_c", [13, 64])
@pytest.mark.parametrize("permuted", [False, True])
def test_prefix_snapshot_bit_identity(lag_pair, engine, tile_c, permuted):
    """Engine-op prefix tables == old-style per-size rebuild, bit for bit
    (indices AND float32 distances), under dividing and non-dividing
    tiles, natural and permuted candidate order, on both engines (the
    reference engine runs the ONE-sweep snapshot builder, the Pallas
    engines the base-class per-size fallback)."""
    from repro.engine import get_engine

    Vq, perm = lag_pair
    col_ids = perm if permuted else None
    cfg = EDMConfig(E_max=6, engine=engine, knn_tile_c=tile_c)
    buckets, lib_sizes, k = (1, 3, 6), (25, 60, 120), 7
    got = get_engine(engine).knn_tables_prefix(
        Vq, Vq, k, buckets=buckets, lib_sizes=lib_sizes,
        exclude_self=True, cfg=cfg, col_ids=col_ids,
    )
    want = knn.knn_tables_prefix_rebuild(
        Vq, Vq, k, True, buckets, lib_sizes, tile_c, col_ids=col_ids
    )
    assert got[0].shape == (3, 3, 120, 7)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


def test_prefix_matches_candidate_mask_oracle(lag_pair):
    """Each snapshot equals an independent single-E build restricted to
    the prefix subset via candidate_mask (tie-free gaussian data, so the
    permuted-order tie rule cannot differ from the natural one)."""
    Vq, perm = lag_pair
    lib_sizes = (25, 60, 120)
    idx, sqd = knn.knn_tables_prefix_streaming(
        Vq, Vq, 7, True, (3,), lib_sizes, 13, col_ids=perm
    )
    perm_np = np.asarray(perm)
    for s, Ls in enumerate(lib_sizes):
        member = np.zeros(120, bool)
        member[perm_np[:Ls]] = True
        oi, od = knn.knn_table_single_E(
            Vq, Vq, 3, 7, True, candidate_mask=jnp.asarray(member)
        )
        np.testing.assert_array_equal(np.asarray(idx[s, 0]), np.asarray(oi))
        np.testing.assert_array_equal(np.asarray(sqd[s, 0]), np.asarray(od))


def test_prefix_full_size_row_equals_bucketed_tables(lag_pair):
    """The last snapshot of a natural-order full-length prefix IS the
    plain bucketed table set."""
    Vq, _ = lag_pair
    pi, pd = knn.knn_tables_prefix_streaming(
        Vq, Vq, 7, True, (1, 3, 6), (30, 120), 64
    )
    bi, bd = knn.knn_tables_bucketed_dense(Vq, Vq, 7, True, (1, 3, 6))
    np.testing.assert_array_equal(np.asarray(pi[-1]), np.asarray(bi))
    np.testing.assert_array_equal(np.asarray(pd[-1]), np.asarray(bd))


def test_prefix_validation_errors(lag_pair):
    Vq, _ = lag_pair
    with pytest.raises(ValueError, match="ascending"):
        knn.knn_tables_prefix_streaming(Vq, Vq, 7, True, (3,), (60, 25), 64)
    with pytest.raises(ValueError, match="exceeds candidate count"):
        knn.knn_tables_prefix_streaming(Vq, Vq, 7, True, (3,), (25, 300), 64)
    with pytest.raises(ValueError, match="too small"):
        knn.knn_tables_prefix_streaming(Vq, Vq, 7, True, (3,), (7, 120), 64)
    with pytest.raises(ValueError, match="buckets"):
        knn.knn_tables_prefix_streaming(Vq, Vq, 7, True, (6, 3), (25,), 64)


# ------------------------------------------------------------- surrogates
def test_shuffle_surrogates_preserve_values():
    from repro.inference import random_shuffle

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(200), jnp.float32)
    s = np.asarray(random_shuffle(jax.random.PRNGKey(0), x, 5))
    assert s.shape == (5, 200)
    for row in s:
        np.testing.assert_allclose(np.sort(row), np.sort(np.asarray(x)))
    assert not np.array_equal(s[0], s[1])  # distinct draws


def test_phase_surrogates_preserve_spectrum():
    """FFT phase randomization: power spectrum (and hence mean and
    autocovariance) preserved, series itself changed."""
    from repro.inference import phase_randomized

    rng = np.random.default_rng(2)
    for L in (200, 201):  # even L exercises the Nyquist-bin branch
        x = np.cumsum(rng.standard_normal(L)).astype(np.float32)
        s = np.asarray(phase_randomized(jax.random.PRNGKey(3), jnp.asarray(x), 4))
        P0 = np.abs(np.fft.rfft(x)) ** 2
        P1 = np.abs(np.fft.rfft(s, axis=-1)) ** 2
        np.testing.assert_allclose(P1, np.broadcast_to(P0, P1.shape), rtol=2e-3)
        np.testing.assert_allclose(s.mean(axis=-1), x.mean(), rtol=1e-3)
        assert np.abs(s - x).max() > 0.1  # actually randomized


def test_surrogate_futures_deterministic_per_series_id():
    """The fold_in(key, series_id) derivation makes the draw independent
    of tile composition: the same series under the same id yields the
    same futures whether batched alone or with others."""
    from repro.inference import surrogate_futures

    rng = np.random.default_rng(3)
    cfg = EDMConfig(E_max=4)
    ts = jnp.asarray(rng.standard_normal((3, 100)), jnp.float32)
    key = jax.random.PRNGKey(9)
    ids = jnp.asarray([5, 2, 7], jnp.int32)
    full = np.asarray(surrogate_futures(key, ts, ids, n=4, kind="phase", cfg=cfg))
    solo = np.asarray(
        surrogate_futures(key, ts[1:2], ids[1:2], n=4, kind="phase", cfg=cfg)
    )
    np.testing.assert_array_equal(full.reshape(3, 4, -1)[1], solo.reshape(4, -1))
    # different id -> different draw
    other = np.asarray(
        surrogate_futures(
            key, ts[1:2], jnp.asarray([8], jnp.int32), n=4, kind="phase", cfg=cfg
        )
    )
    assert not np.array_equal(solo, other)


# ----------------------------------------------------------------- BH-FDR
def test_bh_adjust_matches_scipy_oracle():
    sp = pytest.importorskip("scipy.stats")
    from repro.inference import bh_adjust

    rng = np.random.default_rng(4)
    for n in (1, 7, 100, 1000):
        p = rng.uniform(size=n)
        p[: n // 3] **= 4  # some small p-values
        np.testing.assert_allclose(
            bh_adjust(p), sp.false_discovery_control(p, method="bh"),
            rtol=1e-12,
        )


def test_bh_threshold_consistent_with_adjust():
    from repro.inference import bh_adjust, bh_threshold

    rng = np.random.default_rng(5)
    p = rng.uniform(size=500) ** 2
    for alpha in (0.01, 0.05, 0.2):
        thr, n = bh_threshold(p, alpha)
        assert n == 500
        np.testing.assert_array_equal(p <= thr, bh_adjust(p) <= alpha)


def test_bh_threshold_discrete_matches_dense():
    """The streaming per-value-count BH pass == the sorted-scan BH pass
    on the expanded array, for discrete empirical p-values (with ties)."""
    from repro.inference import bh_threshold, bh_threshold_discrete

    rng = np.random.default_rng(6)
    m = 19
    for _ in range(5):
        counts = rng.integers(0, 40, size=m + 1)
        p = np.repeat(np.arange(1, m + 2) / (m + 1), counts)
        for alpha in (0.01, 0.05, 0.3):
            thr_d, n_d = bh_threshold_discrete(counts, m, alpha)
            thr, n = bh_threshold(p, alpha)
            assert n_d == n
            assert thr_d == pytest.approx(thr, abs=1e-12)


# ---------------------------------------------------------------- pearson
def test_pearson_degenerate_and_overflow_finite():
    """Constant (dead-neuron) and variance-overflow series must yield
    rho = 0, never NaN/Inf, so significance masks stay finite."""
    rng = np.random.default_rng(7)
    b = jnp.asarray(rng.standard_normal(300), jnp.float32)
    const = jnp.zeros(300)
    big = jnp.asarray((rng.standard_normal(300) * 1e20), jnp.float32)
    cases = [
        pearson(const, b), pearson(b, const), pearson(const, const),
        pearson(jnp.full((300,), 7.5), b),
        pearson(big, big), pearson(big, b),
    ]
    out = np.asarray(jnp.stack(cases))
    assert np.isfinite(out).all(), out
    assert out[0] == out[1] == out[2] == 0.0
    # sane values unaffected
    assert float(pearson(b, b)) == pytest.approx(1.0, abs=1e-5)


# ------------------------------------------- deprecated wrapper + stats
def test_ccm_convergence_deprecated_wrapper(coupled_pair):
    """Same signature, now routed through the batched prefix path: warns,
    matches ccm_convergence_pair exactly, still shows convergence."""
    from repro.core import ccm_convergence
    from repro.inference import ccm_convergence_pair

    cfg = EDMConfig(E_max=4)
    x, y = jnp.asarray(coupled_pair[0]), jnp.asarray(coupled_pair[1])
    key = jax.random.PRNGKey(0)
    with pytest.warns(DeprecationWarning):
        rhos = np.asarray(ccm_convergence(y, x, 3, (40, 150, 700), cfg, key))
    direct = np.asarray(ccm_convergence_pair(y, x, 3, (40, 150, 700), cfg, key))
    np.testing.assert_array_equal(rhos, direct)
    assert rhos.shape == (3,)
    assert rhos[-1] > rhos[0]


def test_convergence_stats_known_curves():
    from repro.inference import convergence_stats

    curves = jnp.asarray(
        [[0.1, 0.5, 0.3], [0.2, 0.4, 0.3], [0.3, 0.3, 0.3], [0.4, 0.2, 0.3]]
    )  # (S=4, 3 pairs): increasing / decreasing / flat
    drho, trend = (np.asarray(v) for v in convergence_stats(curves))
    np.testing.assert_allclose(drho, [0.3, 0.3, 0.0], atol=1e-7)
    np.testing.assert_allclose(trend, [1.0, -1.0, 0.0], atol=1e-7)


# ----------------------------------------------------------- end to end
@pytest.fixture(scope="module")
def sig_system():
    """4 series: x drives y (true edge x->y); a, b independent."""
    from repro.core.pipeline import run_causal_inference
    from repro.data.synthetic import coupled_logistic

    x, y = coupled_logistic(600, beta_xy=0.0, beta_yx=0.12, seed=3)
    a, b = coupled_logistic(600, beta_xy=0.0, beta_yx=0.0, seed=12)
    ts = np.stack([x, y, a, b])
    cfg = EDMConfig(E_max=5)
    res = run_causal_inference(ts, cfg)
    return ts, cfg, res


def test_significance_end_to_end_fdr(sig_system):
    """The true coupled-logistic edge survives BH-FDR; the decoupled pair
    produces no edge in either direction."""
    from repro.inference import SignificanceConfig, run_significance

    ts, cfg, res = sig_system
    sig = SignificanceConfig(
        lib_sizes=(60, 150, 300, 570), n_surrogates=299, alpha=0.05, seed=0
    )
    out = run_significance(ts, res.optE, np.asarray(res.rho), cfg, sig)
    assert out.n_tests == 12  # diagonal excluded
    assert np.isfinite(out.pvals).all()
    assert np.isfinite(out.drho).all() and np.isfinite(out.trend).all()
    edges = {(int(e["src"]), int(e["dst"])) for e in out.edges}
    assert (0, 1) in edges, (edges, out.pvals)  # x -> y survives
    for pair in [(2, 3), (3, 2)]:  # decoupled pair: nothing
        assert pair not in edges
    # self-prediction converges: diagonal trend is maximal for the
    # chaotic series (strictly increasing rho with library size)
    assert out.trend[1, 1] == pytest.approx(1.0)


def test_significance_store_matches_memory_and_resumes(sig_system, tmp_path):
    """Streaming-store run == in-memory run bit-for-bit (non-dividing
    column tiles, multiple chunks); a rerun over the complete store
    resumes via the recount path and reproduces the same edges."""
    import json

    from repro.inference import SignificanceConfig, run_significance

    ts, _, res = sig_system
    cfg = EDMConfig(E_max=5, lib_block=2, target_tile=3)
    sig = SignificanceConfig(
        lib_sizes=(60, 300, 570), n_surrogates=39, alpha=0.2, seed=1
    )
    rho = np.asarray(res.rho)
    mem = run_significance(ts, res.optE, rho, cfg, sig)
    disk = run_significance(
        ts, res.optE, rho, cfg, sig, out_dir=str(tmp_path)
    )
    for a in ("rho_conv", "rho_trend", "pvals"):
        assert (tmp_path / a / "data.npy").exists()
        assert (tmp_path / a / "meta.json").exists()
    emeta = json.loads((tmp_path / "edges" / "meta.json").read_text())
    assert emeta["n_edges"] == len(disk.edges)
    assert emeta["seed"] == 1
    np.testing.assert_array_equal(np.asarray(disk.pvals), mem.pvals)
    np.testing.assert_array_equal(np.asarray(disk.drho), mem.drho)
    np.testing.assert_array_equal(np.asarray(disk.trend), mem.trend)
    np.testing.assert_array_equal(disk.edges, mem.edges)

    # resume over the complete store: nothing recomputed, same outputs
    again = run_significance(
        ts, res.optE, rho, cfg, sig, out_dir=str(tmp_path)
    )
    assert again.p_threshold == mem.p_threshold
    np.testing.assert_array_equal(np.asarray(again.pvals), mem.pvals)
    np.testing.assert_array_equal(again.edges, mem.edges)


def test_significance_seed_reproducibility(sig_system):
    from repro.inference import SignificanceConfig, run_significance

    ts, cfg, res = sig_system
    rho = np.asarray(res.rho)
    outs = [
        run_significance(
            ts, res.optE, rho, cfg,
            SignificanceConfig(lib_sizes=(60, 570), n_surrogates=9, seed=s),
        )
        for s in (0, 0, 1)
    ]
    np.testing.assert_array_equal(outs[0].pvals, outs[1].pvals)
    np.testing.assert_array_equal(np.asarray(outs[0].drho), outs[1].drho)
    assert not np.array_equal(outs[0].pvals, outs[2].pvals)
