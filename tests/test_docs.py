"""Documentation front door (PR 10): the docs must not rot.

Three invariants, all enforced against the REAL artifacts:

* every public module in core/, runtime/, inference/, engine/ names its
  DESIGN.md section in the module docstring, and the section exists;
* every CLI invocation shown in README.md / docs/OPERATIONS.md parses
  against the real argparse parsers (launch.edm_run.build_parser /
  launch.edm_fleet.build_parser), and every bench name shown exists in
  benchmarks/run.py's BENCHES registry;
* every `SSn` design reference in README/ROADMAP/OPERATIONS/docstrings
  resolves to an actual `## SSn` header in DESIGN.md.
"""
from __future__ import annotations

import ast
import pathlib
import re
import shlex

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
DESIGN = (REPO / "DESIGN.md").read_text()
DESIGN_SECTIONS = {int(m) for m in re.findall(r"^## SS(\d+)", DESIGN, re.M)}

PUBLIC_PACKAGES = ("core", "runtime", "inference", "engine")


def _public_modules():
    for pkg in PUBLIC_PACKAGES:
        for p in sorted((REPO / "src" / "repro" / pkg).glob("*.py")):
            yield p


def test_design_sections_contiguous():
    """Headers are `## SSn` for n = 1..max with no gaps — a renumbering
    that orphans cross-references cannot land silently."""
    assert DESIGN_SECTIONS == set(range(1, max(DESIGN_SECTIONS) + 1))
    assert max(DESIGN_SECTIONS) >= 14


@pytest.mark.parametrize("path", list(_public_modules()),
                         ids=lambda p: f"{p.parent.name}/{p.name}")
def test_module_docstring_names_design_section(path):
    ds = ast.get_docstring(ast.parse(path.read_text()))
    assert ds, f"{path} has no module docstring"
    refs = re.findall(r"DESIGN\.md SS(\d+)", ds)
    assert refs, f"{path} docstring names no DESIGN.md section"
    for n in refs:
        assert int(n) in DESIGN_SECTIONS, f"{path} cites missing SS{n}"


# --------------------------------------------------------------- SS refs

DOC_FILES = ("README.md", "ROADMAP.md", "DESIGN.md", "docs/OPERATIONS.md")


@pytest.mark.parametrize("doc", DOC_FILES)
def test_doc_ss_references_resolve(doc):
    """Any `SSn` (digits — paper sections use roman numerals) in the
    prose docs must be a real DESIGN.md header."""
    text = (REPO / doc).read_text()
    for n in re.findall(r"\bSS(\d+)\b", text):
        assert int(n) in DESIGN_SECTIONS, f"{doc} cites missing SS{n}"


def test_module_docstring_ss_references_resolve():
    for path in _public_modules():
        ds = ast.get_docstring(ast.parse(path.read_text())) or ""
        for n in re.findall(r"\bSS(\d+)\b", ds):
            assert int(n) in DESIGN_SECTIONS, f"{path} cites missing SS{n}"


# ------------------------------------------------------------- CLI tours


def _console_commands(text: str):
    """Commands from ``` fenced blocks: join backslash continuations,
    keep `$ `-prompted lines, split env-var prefixes off."""
    for block in re.findall(r"```(?:console|bash|sh)?\n(.*?)```", text,
                            re.S):
        joined = block.replace("\\\n", " ")
        for line in joined.splitlines():
            line = line.strip()
            if not line.startswith("$ "):
                continue
            toks = shlex.split(line[2:])
            while toks and re.fullmatch(r"[A-Z_][A-Z0-9_]*=.*", toks[0]):
                toks.pop(0)
            if toks:
                yield toks


def _bench_names():
    src = (REPO / "benchmarks" / "run.py").read_text()
    block = re.search(r"^BENCHES = \{\n(.*?)^\}", src, re.S | re.M).group(1)
    return set(re.findall(r'"([a-z0-9]+)":', block))


def _doc_cli_invocations():
    out = []
    for doc in ("README.md", "docs/OPERATIONS.md"):
        for toks in _console_commands((REPO / doc).read_text()):
            out.append((doc, toks))
    return out


def test_readme_and_runbook_cli_lines_parse():
    """Every edm_run / edm_fleet invocation in the docs parses against
    the real parser; every bench name shown exists in BENCHES.  At least
    one of each must be present — the tour cannot silently vanish."""
    from repro.launch import edm_fleet, edm_run

    parsers = {"repro.launch.edm_run": edm_run.build_parser(),
               "repro.launch.edm_fleet": edm_fleet.build_parser()}
    benches = _bench_names()
    seen = {"repro.launch.edm_run": 0, "repro.launch.edm_fleet": 0,
            "bench": 0}
    for doc, toks in _doc_cli_invocations():
        if toks[0] == "python" and toks[1:2] == ["-m"] and \
                toks[2] in parsers:
            try:
                parsers[toks[2]].parse_args(toks[3:])
            except SystemExit:
                pytest.fail(f"{doc}: `{' '.join(toks)}` does not parse "
                            f"against the real {toks[2]} parser")
            seen[toks[2]] += 1
        elif toks[0] == "python" and toks[1:2] == ["benchmarks/run.py"]:
            names = [t for t in toks[2:] if not t.startswith("-")]
            for name in names:
                assert name in benches, \
                    f"{doc}: bench `{name}` not in BENCHES ({sorted(benches)})"
            seen["bench"] += 1
    assert seen["repro.launch.edm_run"] >= 2, "README lost the edm_run tour"
    assert seen["repro.launch.edm_fleet"] >= 4, \
        "README lost the edm_fleet tour"
    assert seen["bench"] >= 1, "docs lost the benchmark tour"


def test_readme_architecture_map_paths_exist():
    """The README architecture-map module paths must exist on disk."""
    text = (REPO / "README.md").read_text()
    table = re.search(r"\| layer \| modules \|.*?\n\n", text, re.S).group(0)
    for mod in re.findall(r"`((?:core|engine|kernels|inference|runtime|"
                          r"data|launch)/[a-z_./]+)`", table):
        target = REPO / "src" / "repro" / mod
        assert target.exists(), f"README architecture map: {mod} missing"


def test_operations_runbook_exists_and_covers_recovery():
    text = (REPO / "docs" / "OPERATIONS.md").read_text()
    for needle in ("--watch", "--heal", "fingerprint", "poison",
                   "EDM_COORDINATOR", "EDM_NUM_PROCESSES",
                   "EDM_PROCESS_ID"):
        assert needle in text, f"runbook lost its {needle} section"
