"""Store integrity battery (DESIGN.md SS12): checksum primitives, the
fingerprint stamp/verify contract, and the fsck scan/heal cycle over a
real fleet store — truncated tile, bit-flipped tile, orphaned tile,
stale-fingerprint resume — each detected, reported in --json, and (where
healable) recomputed to byte-identical output by one fleet pass."""
import json
import pathlib
import shutil

import numpy as np
import pytest

from repro.core.types import EDMConfig
from repro.data import store
from repro.inference import SignificanceConfig
from repro.launch import edm_fleet
from repro.runtime import integrity

ARTIFACTS = ("causal_map", "rho_conv", "rho_trend", "pvals", "edges")
CFG = EDMConfig(E_max=4, lib_block=4, target_tile=6)
SIG = SignificanceConfig(lib_sizes=(40, 80), n_surrogates=6, seed=0)


# ----------------------------------------------------------- primitives
def test_checksum_primitives(tmp_path):
    data = b"the store is the ground truth"
    assert integrity.checksum_bytes(data) == integrity.checksum_bytes(data)
    assert integrity.checksum_bytes(data) != integrity.checksum_bytes(data + b"!")
    f = tmp_path / "blob"
    f.write_bytes(data)
    assert integrity.checksum_file(f) == integrity.checksum_bytes(data)
    a = np.arange(20, dtype=np.float32).reshape(4, 5)
    # slab streaming must equal the one-shot hash
    assert integrity.checksum_ndarray(a) == \
        integrity.checksum_ndarray(a, rows_per_step=1) == \
        integrity.Crc32().update(a.tobytes()).hex
    # a memmap view hashes the same as the in-memory array
    np.save(tmp_path / "a.npy", a)
    mm = np.load(tmp_path / "a.npy", mmap_mode="r")
    assert integrity.checksum_ndarray(mm) == integrity.checksum_ndarray(a)


def test_atomic_save_records_matching_crc(tmp_path):
    a = np.random.default_rng(3).standard_normal((7, 9)).astype(np.float32)
    stats = store.atomic_save_npy(tmp_path / "a.npy", a)
    # the crc accumulated during the write equals a post-hoc file hash
    assert stats["crc32"] == integrity.checksum_file(tmp_path / "a.npy")


def test_sidecar_verify_and_load(tmp_path):
    a = np.ones((3, 3), np.float32)
    store.save_npy_checksummed(tmp_path / "a.npy", a)
    assert integrity.verify_file(tmp_path / "a.npy") == "ok"
    np.testing.assert_array_equal(
        integrity.load_npy_verified(tmp_path / "a.npy"), a)
    raw = bytearray((tmp_path / "a.npy").read_bytes())
    raw[-1] ^= 0xFF
    (tmp_path / "a.npy").write_bytes(bytes(raw))
    assert integrity.verify_file(tmp_path / "a.npy") == "corrupt"
    with pytest.raises(integrity.IntegrityError, match="checksum"):
        integrity.load_npy_verified(tmp_path / "a.npy")
    (tmp_path / "a.npy.crc32").unlink()
    assert integrity.verify_file(tmp_path / "a.npy") == "unverified"
    assert integrity.verify_file(tmp_path / "missing.npy") == "missing"


def test_manifest_self_checksum_roundtrip(tmp_path):
    entries = {"0,0": [3, 3, "aabbccdd"], "3": [3, "11223344"]}
    f = tmp_path / "blocks.json"
    f.write_text(integrity.manifest_with_crc(entries))
    assert integrity.read_manifest_shard(f) == entries
    # flip one byte inside an entry -> the shard fails its self-check
    f.write_text(f.read_text().replace("aabbccdd", "aabbccde"))
    assert integrity.read_manifest_shard(f) is None
    # torn JSON also reads as None, not an exception
    f.write_text('{"__crc__": "00000000", "0,0": [3,')
    assert integrity.read_manifest_shard(f) is None


def test_assemble_verifies_tile_checksums(tmp_path):
    N = 4
    w = store.TileWriter(tmp_path / "w", N)
    w.write_tile(0, 0, np.ones((2, N), np.float32))
    w.write_tile(2, 0, np.full((2, N), 2.0, np.float32))
    tf = tmp_path / "w" / "tile_00000002_00000000.npy"
    raw = bytearray(tf.read_bytes())
    raw[-2] ^= 0x20
    tf.write_bytes(bytes(raw))
    with pytest.raises(integrity.IntegrityError, match="fsck"):
        store.TileWriter(tmp_path / "w", N).assemble()


# ---------------------------------------------------------- fingerprint
def test_fingerprint_pins_data_and_config(tmp_path):
    ts = np.random.default_rng(0).standard_normal((6, 30)).astype(np.float32)
    fp = integrity.fingerprint_of(ts, CFG)
    assert fp == integrity.fingerprint_of(ts.copy(), CFG)
    assert fp != integrity.fingerprint_of(ts + 1, CFG)
    changed = integrity.fingerprint_of(
        ts, EDMConfig(E_max=5, lib_block=4, target_tile=6))
    assert fp["fingerprint"] != changed["fingerprint"]
    # byte-invisible geometry knobs are canonicalized OUT: a resume under
    # a different tile size or worker mesh is the SAME run
    geom = integrity.fingerprint_of(
        ts, EDMConfig(E_max=4, lib_block=2, target_tile=3))
    assert fp["fingerprint"] == geom["fingerprint"]

    integrity.stamp_fingerprint(tmp_path, fp)
    integrity.stamp_fingerprint(tmp_path, fp)  # idempotent
    with pytest.raises(integrity.IntegrityError, match="fingerprint"):
        integrity.stamp_fingerprint(tmp_path, integrity.fingerprint_of(ts + 1, CFG))


# ------------------------------------------------------ fleet store fsck
@pytest.fixture(scope="module")
def pristine(tmp_path_factory):
    """One complete single-worker fleet store; tests copy it, damage the
    copy, and compare healed recomputes byte-for-byte against it."""
    root = tmp_path_factory.mktemp("pristine")
    ts = np.random.default_rng(7).standard_normal((16, 250)).astype(np.float32)
    store.save_dataset(root / "dataset", ts, {"synthetic": "16x250"})
    out = root / "fleet"
    edm_fleet.init_fleet(out, root / "dataset", CFG, SIG)
    edm_fleet.FleetWorker(out, "w0", progress=False).run()
    rep = integrity.fsck_store(out)
    assert rep["clean"], json.dumps(rep, indent=1)
    return out


def _damaged_copy(pristine: pathlib.Path, dst_root: pathlib.Path) -> pathlib.Path:
    out = dst_root / "fleet"
    shutil.copytree(pristine, out)
    return out


def _bytes_of(out: pathlib.Path) -> dict:
    return {n: (out / n / "data.npy").read_bytes() for n in ARTIFACTS}


def _heal_and_recompute(out: pathlib.Path) -> None:
    rep = integrity.fsck_store(out, heal=True)
    assert "refused" not in rep["healed"]
    assert integrity.fsck_store(out)["clean"]
    edm_fleet.FleetWorker(out, "wheal", progress=False).run()
    assert integrity.fsck_store(out)["clean"]


@pytest.mark.parametrize("damage", ["truncate", "bitflip", "orphan",
                                    "delete", "sig_bitflip", "torn_shard"])
def test_fsck_detects_and_heals_byte_identical(pristine, tmp_path, damage):
    base = _bytes_of(pristine)
    out = _damaged_copy(pristine, tmp_path)
    tiles = sorted(out.glob("tile_*.npy"))
    if damage == "truncate":
        tiles[0].write_bytes(tiles[0].read_bytes()[:32])
        expect = ("phase2", "corrupt")
    elif damage == "bitflip":
        raw = bytearray(tiles[2].read_bytes())
        raw[len(raw) // 2] ^= 0x01
        tiles[2].write_bytes(bytes(raw))
        expect = ("phase2", "corrupt")
    elif damage == "orphan":
        (out / "tile_99999999_00000000.npy").write_bytes(b"\x93NUMPY junk")
        expect = ("phase2", "orphaned")
    elif damage == "delete":
        tiles[1].unlink()
        expect = ("phase2", "missing")
    elif damage == "sig_bitflip":
        st = sorted((out / "pvals").glob("tile_*.npy"))[0]
        raw = bytearray(st.read_bytes())
        raw[-3] ^= 0x80
        st.write_bytes(bytes(raw))
        expect = ("pvals", "corrupt")
    else:  # torn_shard
        shard = next(out.glob("blocks*.json"))
        shard.write_text(shard.read_text()[:25])
        expect = ("phase2", "torn_shards")

    rep = integrity.fsck_store(out)
    art, kind = expect
    assert not rep["clean"]
    assert rep["artifacts"][art][kind], json.dumps(rep, indent=1)
    _heal_and_recompute(out)
    assert _bytes_of(out) == base  # recomputed units are byte-identical


def test_fsck_heals_corrupt_assembled_map(pristine, tmp_path):
    base = _bytes_of(pristine)
    out = _damaged_copy(pristine, tmp_path)
    f = out / "causal_map" / "data.npy"
    raw = bytearray(f.read_bytes())
    raw[-5] ^= 0x04
    f.write_bytes(bytes(raw))
    rep = integrity.fsck_store(out)
    assert not rep["clean"]
    assert rep["artifacts"]["causal_map"]["status"] == "corrupt"
    _heal_and_recompute(out)
    assert _bytes_of(out) == base


def test_fsck_stale_fingerprint_refuses_heal(pristine, tmp_path):
    out = _damaged_copy(pristine, tmp_path)
    # swap the dataset content in place: same path, different bytes
    ds = pathlib.Path(json.loads((out / "fleet.json").read_text())["dataset"])
    ts = np.asarray(store.load_dataset(ds), np.float32)
    store.save_dataset(ds, ts + 0.5)
    try:
        rep = integrity.fsck_store(out, heal=True)
        assert rep["fingerprint"]["status"] == "stale"
        assert not rep["clean"]
        assert "refused" in rep["healed"]
        # a worker joining against the swapped dataset is refused too
        with pytest.raises(integrity.IntegrityError, match="fingerprint"):
            edm_fleet.FleetWorker(out, "wjoin", progress=False)
    finally:  # module-scoped pristine shares this dataset — restore it
        store.save_dataset(ds, ts)


def test_fsck_cli_json_and_exit_codes(pristine, tmp_path, capsys):
    out = _damaged_copy(pristine, tmp_path)
    edm_fleet.main(["fsck", "--out", str(out), "--json", "--expect-clean"])
    rep = json.loads(capsys.readouterr().out)
    assert rep["clean"] and rep["problems"] == 0
    # damage -> --expect-clean exits 1 and the report names the tile
    bad = sorted(out.glob("tile_*.npy"))[0]
    bad.write_bytes(bad.read_bytes()[:16])
    with pytest.raises(SystemExit) as ei:
        edm_fleet.main(["fsck", "--out", str(out), "--json", "--expect-clean"])
    assert ei.value.code == 1
    rep = json.loads(capsys.readouterr().out)
    assert bad.name in rep["artifacts"]["phase2"]["corrupt"]
    # --heal through the CLI, then a fleet pass -> clean and identical
    edm_fleet.main(["fsck", "--out", str(out), "--heal"])
    capsys.readouterr()
    edm_fleet.FleetWorker(out, "wcli", progress=False).run()
    edm_fleet.main(["fsck", "--out", str(out), "--expect-clean"])
    assert _bytes_of(out) == _bytes_of(pristine)
