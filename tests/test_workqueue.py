"""Work-queue + multi-writer store (DESIGN.md SS10): lease claim /
expiry / steal semantics, duplicate-claim exclusion under contention,
writer_id-sharded TileWriter manifests, crash-mid-tile recovery, and the
fleet-style significance path (sharded writers + finalize recount) being
byte-identical to the single-process driver."""
import concurrent.futures
import errno
import json
import threading
import time

import numpy as np
import pytest

from repro.data.store import TileWriter
from repro.runtime.workqueue import (
    LeaseQueue,
    UnitFailedError,
    WorkUnit,
    plan_units,
)


# ------------------------------------------------------------ unit grids
def test_plan_units_deterministic_grid():
    units = plan_units("phase2", 20, 8)
    assert units == [
        WorkUnit("phase2", 0, 8),
        WorkUnit("phase2", 8, 8),
        WorkUnit("phase2", 16, 4),
    ]
    # every worker derives the same queue from the same spec
    assert plan_units("phase2", 20, 8) == units
    assert [u.uid for u in units] == [
        "phase2_00000000_00008",
        "phase2_00000008_00008",
        "phase2_00000016_00004",
    ]
    # singleton stages have one whole-run unit
    assert plan_units("phase1", 20, 8) == [WorkUnit("phase1", 0, 20)]
    assert plan_units("finalize", 20, 8)[0].uid == "finalize"
    with pytest.raises(ValueError, match="unit_rows"):
        plan_units("sig", 20, 0)


# ------------------------------------------------------- claim semantics
def test_claim_is_exclusive(tmp_path):
    u = WorkUnit("phase2", 0, 8)
    qa = LeaseQueue(tmp_path, "a", ttl=60)
    qb = LeaseQueue(tmp_path, "b", ttl=60)
    assert qa.try_claim(u)
    assert not qb.try_claim(u)  # live foreign lease
    assert not qa.is_done(u)
    qa.mark_done(u)
    assert qb.is_done(u)
    assert not qb.try_claim(u)  # done units are never claimable again
    assert qb.pending([u]) == []


def test_expired_lease_is_stolen(tmp_path):
    u = WorkUnit("sig", 0, 4)
    qa = LeaseQueue(tmp_path, "a", ttl=0.5)
    qb = LeaseQueue(tmp_path, "b", ttl=60)
    assert qa.try_claim(u)
    assert not qb.try_claim(u)
    time.sleep(0.6)  # a's lease expires (simulated crash)
    assert qb.try_claim(u)
    # a is no longer the owner: renew refuses, and finishing is harmless
    assert not qa.renew(u)
    assert qb.renew(u)


def test_relaunched_worker_reclaims_own_lease_instantly(tmp_path):
    """SIGKILL + relaunch under the same worker id must not wait out the
    TTL: the id names the queue slot."""
    u = WorkUnit("phase2", 0, 8)
    q1 = LeaseQueue(tmp_path, "w0", ttl=3600)
    assert q1.try_claim(u)
    # the relaunched process is a NEW LeaseQueue with the same id
    q2 = LeaseQueue(tmp_path, "w0", ttl=3600)
    assert q2.try_claim(u)
    # a foreign worker still cannot
    assert not LeaseQueue(tmp_path, "w1", ttl=3600).try_claim(u)


def test_release_returns_unit(tmp_path):
    u = WorkUnit("phase2", 0, 8)
    qa = LeaseQueue(tmp_path, "a", ttl=60)
    qb = LeaseQueue(tmp_path, "b", ttl=60)
    assert qa.try_claim(u)
    qa.release(u)
    assert qb.try_claim(u)
    qb.release(u)  # release of a foreign-owned unit is refused
    assert not qa.renew(u) or True  # a does not own it
    assert LeaseQueue(tmp_path, "c", ttl=60).try_claim(u)


def test_torn_lease_gets_mtime_grace_then_expires(tmp_path):
    """An unreadable lease (foreign non-atomic writer) is NOT stolen
    while fresh — it might be mid-protocol — but is reclaimed once its
    file age exceeds the TTL."""
    u = WorkUnit("phase2", 0, 8)
    lease = tmp_path / f"{u.uid}.lease"
    lease.write_text("{not json")
    assert not LeaseQueue(tmp_path, "a", ttl=60).try_claim(u)
    q = LeaseQueue(tmp_path, "a", ttl=0.2)
    time.sleep(0.3)
    assert q.try_claim(u)


def test_duplicate_claim_exclusion_under_contention(tmp_path):
    """8 workers racing claim_next over 24 units: every unit is claimed
    exactly once, none is lost."""
    units = plan_units("phase2", 24 * 4, 4)
    claims: dict[str, list[WorkUnit]] = {}

    def worker(wid: str):
        q = LeaseQueue(tmp_path, wid, ttl=600)
        mine = []
        while True:
            u = q.claim_next(units)
            if u is None:
                return mine
            mine.append(u)
            q.mark_done(u)

    with concurrent.futures.ThreadPoolExecutor(8) as ex:
        futs = {f"w{i}": ex.submit(worker, f"w{i}") for i in range(8)}
        claims = {w: f.result() for w, f in futs.items()}
    seen = [u for mine in claims.values() for u in mine]
    assert len(seen) == len(units)  # no duplicates ...
    assert set(seen) == set(units)  # ... and no losses
    q = LeaseQueue(tmp_path, "check", ttl=600)
    assert q.pending(units) == []


def test_run_stage_barrier_completes_and_skips_already_done(tmp_path):
    units = plan_units("sig", 12, 4)
    done_log = []
    q = LeaseQueue(tmp_path, "a", ttl=60, poll=0.01)
    n = q.run_stage(
        units, lambda u: done_log.append(u),
        already_done=lambda u: u.row0 == 4,  # durable in the store already
    )
    assert n == 2 and {u.row0 for u in done_log} == {0, 8}
    assert q.pending(units) == []
    # second pass over a completed stage computes nothing
    assert q.run_stage(units, lambda u: done_log.append(u)) == 0


def test_run_stage_waits_for_foreign_holder_then_finishes(tmp_path):
    """The masterless barrier: B sleeps while A holds the last unit, and
    returns once A's done marker lands."""
    units = plan_units("phase2", 8, 4)
    qa = LeaseQueue(tmp_path, "a", ttl=60, poll=0.01)
    qb = LeaseQueue(tmp_path, "b", ttl=60, poll=0.01)
    assert qa.try_claim(units[0])

    def finish_a():
        time.sleep(0.15)
        qa.mark_done(units[0])

    t = threading.Thread(target=finish_a)
    t.start()
    n = qb.run_stage(units, lambda u: None, timeout=10)
    t.join()
    assert n == 1  # b computed only the unit a never held
    assert qb.pending(units) == []


def test_run_stage_timeout_raises(tmp_path):
    units = plan_units("phase2", 4, 4)
    assert LeaseQueue(tmp_path, "dead", ttl=3600).try_claim(units[0])
    q = LeaseQueue(tmp_path, "b", ttl=3600, poll=0.01)
    with pytest.raises(TimeoutError, match="phase2"):
        q.run_stage(units, lambda u: None, timeout=0.1)


def test_run_stage_reclaims_crashed_holder_after_expiry(tmp_path):
    """A holder that dies mid-unit surfaces back as claimable once its
    lease expires — the barrier cannot deadlock on a crash."""
    units = plan_units("phase2", 4, 4)
    assert LeaseQueue(tmp_path, "dead", ttl=0.05).try_claim(units[0])
    q = LeaseQueue(tmp_path, "b", ttl=60, poll=0.01)
    assert q.run_stage(units, lambda u: None, timeout=10) == 1


def test_slow_but_alive_worker_keeps_lease_via_renew(tmp_path):
    """The fleet's per-chunk keepalive (FleetWorker._renew_chunk): a
    compute whose total time outlives the TTL, but which renews between
    chunks, is never stolen — while a dead holder (no renews) still is."""
    u = plan_units("phase2", 4, 4)[0]
    qa = LeaseQueue(tmp_path, "a", ttl=0.3)
    qb = LeaseQueue(tmp_path, "b", ttl=0.3)
    assert qa.try_claim(u)
    for _ in range(4):  # 0.6s of "compute" >> ttl, renewed per chunk
        time.sleep(0.15)
        assert qa.renew(u)
        assert not qb.try_claim(u)
    qa.mark_done(u)
    # contrast: a holder that stops renewing (crashed) is stolen
    u2 = plan_units("sig", 4, 4)[0]
    assert qa.try_claim(u2)
    time.sleep(0.4)
    assert qb.try_claim(u2)


def test_disk_full_poisons_immediately_not_retried(tmp_path):
    """ENOSPC-class failures are environment verdicts, not flaky units:
    one attempt, immediate poison with the 'out of space' error, no
    retry-budget burn (every retry would hit the same full disk)."""
    units = plan_units("phase2", 4, 4)
    q = LeaseQueue(tmp_path, "a", ttl=60, poll=0.01, fail_limit=3)

    def compute(u):
        raise OSError(errno.ENOSPC, f"out of space at {tmp_path}/tile")

    with pytest.raises(UnitFailedError) as ei:
        q.run_stage(units, compute, timeout=10)
    assert ei.value.attempts == 1  # poisoned on the FIRST attempt
    info = json.loads((tmp_path / f"{units[0].uid}.poison").read_text())
    assert info["fatal"] and "out of space" in info["error"]
    # a chained fatal errno (the store wraps and re-raises) also counts
    u2 = plan_units("sig", 4, 4)[0]

    def compute2(u):
        try:
            raise OSError(errno.EDQUOT, "quota")
        except OSError as e:
            raise RuntimeError("tile write failed") from e

    q2 = LeaseQueue(tmp_path, "b", ttl=60, poll=0.01, fail_limit=3)
    with pytest.raises(UnitFailedError):
        q2.run_stage([u2], compute2, timeout=10)
    assert json.loads((tmp_path / f"{u2.uid}.poison").read_text())["fatal"]


# --------------------------------------------------------- bounded retries
def test_flaky_unit_retried_then_succeeds(tmp_path):
    """A transiently-failing compute is a counted attempt, not instant
    death: the unit is released, retried, and completes."""
    units = plan_units("sig", 4, 4)
    q = LeaseQueue(tmp_path, "a", ttl=60, poll=0.01, fail_limit=3)
    calls = []

    def compute(u):
        calls.append(u.uid)
        if len(calls) < 2:
            raise RuntimeError("transient")

    assert q.run_stage(units, compute, timeout=10) == 1
    assert len(calls) == 2
    assert q.pending(units) == []
    # the attempt was durably counted, but the unit was never poisoned
    assert (tmp_path / f"{units[0].uid}.fail").exists()
    assert not (tmp_path / f"{units[0].uid}.poison").exists()


def test_unit_poisoned_at_fail_limit(tmp_path):
    units = plan_units("phase2", 4, 4)
    q = LeaseQueue(tmp_path, "a", ttl=60, poll=0.01, fail_limit=2)

    def compute(u):
        raise ValueError("deterministically broken")

    with pytest.raises(UnitFailedError) as ei:
        q.run_stage(units, compute, timeout=10)
    assert ei.value.uid == units[0].uid
    assert ei.value.attempts == 2
    assert "broken" in ei.value.error
    assert (tmp_path / f"{units[0].uid}.poison").exists()
    info = json.loads((tmp_path / f"{units[0].uid}.fail").read_text())
    assert info["attempts"] == 2 and len(info["errors"]) == 2


def test_poison_drains_every_worker_not_just_the_failer(tmp_path):
    """The fleet-exit property: once a unit is poisoned, EVERY worker's
    barrier raises with the failing uid instead of spinning on TTL
    steals forever."""
    units = plan_units("sig", 8, 4)
    qa = LeaseQueue(tmp_path, "a", ttl=60, poll=0.01, fail_limit=1)
    with pytest.raises(UnitFailedError):
        qa.run_stage(
            units,
            lambda u: (_ for _ in ()).throw(RuntimeError("boom")),
            timeout=10,
        )
    qb = LeaseQueue(tmp_path, "b", ttl=60, poll=0.01)
    with pytest.raises(UnitFailedError, match=units[0].uid):
        qb.run_stage(units, lambda u: None, timeout=10)
    assert qb.poisoned(units)["uid"] == units[0].uid


def test_retry_budget_is_fleet_wide(tmp_path):
    """Attempts accumulate across workers — a unit that crashes every
    claimer exhausts ONE shared budget, not one per worker."""
    u = plan_units("sig", 4, 4)[0]
    qa = LeaseQueue(tmp_path, "a", ttl=60, fail_limit=3)
    qb = LeaseQueue(tmp_path, "b", ttl=60, fail_limit=3)
    assert qa.try_claim(u)
    assert qa.record_failure(u, "e1") == 1
    assert qb.try_claim(u)  # record_failure released a's lease
    assert qb.record_failure(u, "e2") == 2
    assert qa.try_claim(u)
    assert qa.record_failure(u, "e3") == 3
    assert (tmp_path / f"{u.uid}.poison").exists()


def test_interrupt_releases_without_counting_an_attempt(tmp_path):
    """Ctrl-C / SystemExit is a shutdown, not a unit failure: the lease
    is returned and the retry budget untouched."""
    units = plan_units("phase2", 4, 4)
    q = LeaseQueue(tmp_path, "a", ttl=3600, poll=0.01)

    def compute(u):
        raise KeyboardInterrupt

    with pytest.raises(KeyboardInterrupt):
        q.run_stage(units, compute, timeout=10)
    assert not (tmp_path / f"{units[0].uid}.fail").exists()
    assert LeaseQueue(tmp_path, "b", ttl=3600).try_claim(units[0])


# ----------------------------------------- multi-writer TileWriter store
def test_tile_writer_sharded_manifests_merge(tmp_path):
    N = 8
    rho = np.arange(N * N, dtype=np.float32).reshape(N, N)
    wa = TileWriter(tmp_path / "w", N, writer_id="wa")
    wb = TileWriter(tmp_path / "w", N, writer_id="wb")
    wa.write_block(0, rho[:4])
    wb.write_block(4, rho[4:])
    # each worker committed only its own shard — no lock, no lost update
    assert set(json.loads(
        (tmp_path / "w" / "blocks.wa.json").read_text())) == {"__crc__", "0"}
    assert set(json.loads(
        (tmp_path / "w" / "blocks.wb.json").read_text())) == {"__crc__", "4"}
    # a's in-memory view predates b's commit; refresh merges it in
    assert not wa.covered().all()
    assert wa.refresh().covered().all()
    # fresh readers (writer_id=None) see the union at load
    r = TileWriter(tmp_path / "w", N)
    assert r.covered().all()
    np.testing.assert_array_equal(r.assemble(), rho)
    assert r.chunk_plan(4) == []


def test_tile_writer_crash_mid_write_leaves_no_torn_state(tmp_path):
    """A worker killed mid-write leaves only ignorable .tmp residue —
    never a torn manifest or tile."""
    N = 6
    w = TileWriter(tmp_path / "w", N, writer_id="wa")
    w.write_tile(0, 0, np.ones((3, N), np.float32))
    # simulated kill artifacts: torn foreign shard + orphan tmp files
    (tmp_path / "w" / "blocks.crashed.json").write_text('{"3,0": [3,')
    (tmp_path / "w" / "tile_00000003_00000000.npy.tmp-999").write_bytes(b"\x93NUM")
    (tmp_path / "w" / "blocks.wb.json.tmp-999").write_text("{}")
    r = TileWriter(tmp_path / "w", N)
    np.testing.assert_array_equal(r.covered(), [True] * 3 + [False] * 3)
    assert r.chunk_plan(3) == [(3, 3)]
    # and the crashed worker's rows are recomputable by anyone
    wb = TileWriter(tmp_path / "w", N, writer_id="wb")
    wb.write_tile(3, 0, np.full((3, N), 2, np.float32))
    assert TileWriter(tmp_path / "w", N).covered().all()


def test_tile_writer_duplicate_tiles_identical_content_benign(tmp_path):
    """Lease-steal races can compute a unit twice; both workers then
    write the same tile key with identical bytes — last replace wins."""
    N = 4
    block = np.arange(2 * N, dtype=np.float32).reshape(2, N)
    wa = TileWriter(tmp_path / "w", N, writer_id="wa")
    wb = TileWriter(tmp_path / "w", N, writer_id="wb")
    wa.write_tile(0, 0, block)
    wb.write_tile(0, 0, block.copy())
    wa.write_tile(2, 0, block)
    r = TileWriter(tmp_path / "w", N)
    assert r.covered().all()
    np.testing.assert_array_equal(r.assemble(), np.vstack([block, block]))


def test_legacy_single_writer_layout_unchanged(tmp_path):
    """writer_id=None keeps the PR 2-4 on-disk layout: one blocks.json,
    same keys — old stores resume under the new code.  Entries now carry
    a content crc and the shard a __crc__ self-checksum (DESIGN.md SS12)."""
    N = 4
    w = TileWriter(tmp_path / "w", N)
    w.write_block(0, np.zeros((4, N), np.float32))
    files = {p.name for p in (tmp_path / "w").iterdir()}
    assert "blocks.json" in files
    assert not any(
        f.startswith("blocks.") and f != "blocks.json"
        for f in files if not f.endswith(".crc32")
    )
    man = json.loads((tmp_path / "w" / "blocks.json").read_text())
    assert set(man) == {"__crc__", "0"}
    nrows, crc = man["0"]
    assert nrows == 4 and len(crc) == 8


def test_legacy_manifest_without_checksums_still_resumes(tmp_path):
    """A pre-integrity store (bare-int block entries, [nr, nc] tiles, no
    __crc__) must keep loading: coverage, chunk_plan, and assemble all
    work, with verification simply skipped for legacy entries."""
    N = 4
    d = tmp_path / "w"
    d.mkdir()
    w = TileWriter(d, N)
    w.write_block(0, np.arange(2 * N, dtype=np.float32).reshape(2, N))
    w.write_tile(2, 0, np.zeros((2, 2), np.float32), commit=False)
    w.write_tile(2, 2, np.zeros((2, 2), np.float32))
    # rewrite the manifest the way PR 5 wrote it: no crcs, no __crc__
    (d / "blocks.json").write_text(
        json.dumps({"0": 2, "2,0": [2, 2], "2,2": [2, 2]})
    )
    r = TileWriter(d, N)
    assert r.covered().all()
    out = r.assemble()
    assert out.shape == (N, N)
    np.testing.assert_array_equal(out[:2], np.arange(2 * N).reshape(2, N))


# ------------------------------- fleet-style significance, crash + recount
@pytest.mark.parametrize("crash_mid_tile", [False, True])
def test_sharded_sig_writers_finalize_matches_driver(tmp_path, crash_mid_tile):
    """Two fleet-style workers split the significance chunks through
    writer_id-sharded writers; finalize (assemble + RECOUNT of the
    p histogram + BH + edges) must be byte-identical to the one-process
    run_significance driver.  With crash_mid_tile a worker dies after
    writing a partial, uncommitted tile of its unit; the reclaiming
    worker recomputes the whole unit."""
    import jax

    from repro.core.pipeline import run_causal_inference
    from repro.core.types import EDMConfig
    from repro.inference import SignificanceConfig, run_significance
    from repro.inference.pipeline import (
        SignificanceChunkRunner,
        _writer,
        finalize_significance,
        make_store_drain,
    )
    from repro.data.synthetic import dummy_brain

    ts = dummy_brain(12, 220, seed=11)
    cfg = EDMConfig(E_max=4, lib_block=4, target_tile=5)
    sig = SignificanceConfig(lib_sizes=(30, 60, 120), n_surrogates=6, seed=1)
    base = run_causal_inference(ts, cfg)
    optE, rho = np.asarray(base.optE), np.asarray(base.rho)

    ref_dir = tmp_path / "ref"
    ref = run_significance(ts, optE, rho, cfg, sig, out_dir=str(ref_dir))

    out = tmp_path / "fleet"
    out.mkdir()
    N = ts.shape[0]
    units = plan_units("sig", N, 4)
    queue = {}

    def worker(wid):
        runner = SignificanceChunkRunner(ts, optE, cfg, sig)
        ws = {
            "conv": _writer(out, "rho_conv", N, runner.order, writer_id=wid),
            "trend": _writer(out, "rho_trend", N, runner.order, writer_id=wid),
            "pv": _writer(out, "pvals", N, runner.order, writer_id=wid),
        }

        drain = make_store_drain(N, ws["conv"], ws["trend"], ws["pv"])
        return runner, ws, drain

    runner_a, ws_a, drain_a = worker("wa")
    runner_b, ws_b, drain_b = worker("wb")
    qa = LeaseQueue(out / "queue", "wa", ttl=0.05)
    qb = LeaseQueue(out / "queue", "wb", ttl=60, poll=0.01)

    # worker A claims the first unit ...
    assert qa.try_claim(units[0])
    if crash_mid_tile:
        # ... and dies mid-unit: one partial pvals tile on disk, nothing
        # committed, lease left to expire
        ws_a["pv"].write_tile(0, 0, np.zeros((4, 5), np.float32), commit=False)
        time.sleep(0.1)
    else:
        runner_a.run([(0, 4)], rho, drain_a)
        for w in ws_a.values():
            w.commit()
        qa.mark_done(units[0])

    # worker B drains the rest of the stage (reclaiming A's unit when it
    # crashed), then wins the finalize unit
    def compute(unit):
        runner_b.run([(unit.row0, unit.nrows)], rho, drain_b)
        for w in ws_b.values():
            w.commit()

    def already_done(unit):
        cov = ws_b["conv"].refresh().covered()
        cov &= ws_b["trend"].refresh().covered()
        cov &= ws_b["pv"].refresh().covered()
        return bool(cov[unit.row0 : unit.row0 + unit.nrows].all())

    qb.run_stage(units, compute, already_done=already_done, timeout=60)
    got = finalize_significance(str(out), rho, cfg, sig)

    for art in ("rho_conv", "rho_trend", "pvals", "edges"):
        a = np.load(out / art / "data.npy")
        b = np.load(ref_dir / art / "data.npy")
        assert a.tobytes() == b.tobytes(), art
    assert got.p_threshold == ref.p_threshold
    assert got.n_tests == ref.n_tests
    del jax, queue  # (imports kept for parity with the fleet worker)


def test_finalize_refuses_incomplete_store(tmp_path):
    from repro.core.types import EDMConfig
    from repro.inference import SignificanceConfig, finalize_significance

    N = 6
    w = TileWriter(tmp_path / "pvals", N, writer_id="wa")
    w.write_tile(0, 0, np.ones((3, N), np.float32))
    with pytest.raises(ValueError, match="incomplete"):
        finalize_significance(
            str(tmp_path), np.ones((N, N), np.float32), EDMConfig(E_max=4),
            SignificanceConfig(lib_sizes=(), n_surrogates=4),
        )


# ---------------------------------------------------- hold-time counters
def test_mark_done_emits_done_and_held_counters(tmp_path):
    """mark_done records the unit's terminal hold time twice — on the
    done counter (joined to the unit by uid) and as a ``held`` sample
    (the TTL-autotune / straggler-watch histogram) — and flushes both
    BEFORE the durable marker lands (the loss-window bound)."""
    from repro.runtime import telemetry

    mem = telemetry.MemorySink()
    telemetry.configure(mem, worker="wa")
    try:
        u = WorkUnit("phase2", 0, 8)
        q = LeaseQueue(tmp_path, "wa", ttl=60)
        assert q.try_claim(u)
        time.sleep(0.02)
        q.mark_done(u)
        held = [r for r in mem.records if r["name"] == "held"]
        assert len(held) == 1
        assert held[0]["stage"] == "phase2"
        assert held[0]["attrs"] == {"uid": u.uid, "outcome": "done"}
        assert held[0]["value"] >= 0.015
        done = [r for r in mem.records if r["name"] == "done"]
        assert done[0]["attrs"]["held_s"] == held[0]["value"]
    finally:
        telemetry.shutdown()


def test_release_and_steal_emit_held_outcomes(tmp_path):
    """A graceful release samples the hold with outcome=release; a TTL
    steal makes the STEALER record the victim's terminal hold
    (outcome=stolen) — the victim is dead and cannot."""
    from repro.runtime import telemetry

    mem = telemetry.MemorySink()
    telemetry.configure(mem, worker="a")
    try:
        u = WorkUnit("phase2", 0, 8)
        qa = LeaseQueue(tmp_path, "a", ttl=0.05)
        qb = LeaseQueue(tmp_path, "b", ttl=0.05)
        assert qa.try_claim(u)
        qa.release(u)
        rel = [r for r in mem.records if r["name"] == "held"]
        assert len(rel) == 1 and rel[0]["attrs"]["outcome"] == "release"

        assert qa.try_claim(u)
        time.sleep(0.12)  # let the lease expire; "a" is now the victim
        assert qb.try_claim(u)
        stolen = [r for r in mem.records
                  if r["name"] == "held"
                  and r["attrs"].get("outcome") == "stolen"]
        assert len(stolen) == 1
        assert stolen[0]["attrs"]["uid"] == u.uid
        assert stolen[0]["attrs"]["prev_worker"] == "a"
        assert stolen[0]["value"] >= 0.05  # at least the TTL elapsed
    finally:
        telemetry.shutdown()
