"""Telemetry spine + autotuner (DESIGN.md SS11): record schema, sink
protocol (memory / stdout / crash-safe JSONL), byte-invisibility of
sinks to pipeline outputs, and the recorded-timing autotuner deriving
tuned geometry knobs that reproduce byte-identical artifacts."""
import io
import json

import numpy as np
import pytest

from repro.runtime import autotune, telemetry


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Every test starts and ends with no sinks installed — telemetry is
    process-global state."""
    telemetry.shutdown()
    telemetry.set_identity("main")
    yield
    telemetry.shutdown()
    telemetry.set_identity("main")


# ---------------------------------------------------------------- schema
def test_span_and_counter_records_validate(tmp_path):
    mem = telemetry.MemorySink()
    telemetry.configure(mem, worker="w7")
    telemetry.counter("queue", "claim", uid="sig_0", lease_age_s=0.0)
    with telemetry.span("phase2", "chunk", row0=0) as t:
        t["rows"] = 8  # attrs discovered mid-span merge into the record
    assert len(mem.records) == 2
    for rec in mem.records:
        assert telemetry.validate(rec) == [], rec
        assert rec["worker"] == "w7"
    c, s = mem.records
    assert c["kind"] == "counter" and c["value"] == 1.0
    assert s["kind"] == "span" and s["dur_s"] >= 0
    assert s["attrs"] == {"row0": 0, "rows": 8}
    assert s["seq"] > c["seq"]  # per-process monotonic


def test_validate_rejects_malformed_records():
    good = {"v": 1, "kind": "counter", "stage": "queue", "name": "x",
            "t": 0.0, "value": 1.0, "worker": "w", "pid": 1, "seq": 1,
            "attrs": {}}
    assert telemetry.validate(good) == []
    assert telemetry.validate({**good, "stage": "warp"})  # unknown stage
    assert telemetry.validate({**good, "kind": "gauge"})
    assert telemetry.validate({**good, "v": 99})
    bad = dict(good)
    del bad["worker"]
    assert any("worker" in e for e in telemetry.validate(bad))
    span = {**good, "kind": "span"}
    span.pop("value")
    assert telemetry.validate(span)  # span without dur_s
    assert telemetry.validate({**span, "dur_s": -1.0})
    assert telemetry.validate({**good, "attrs": {"x": object()}})


def test_disabled_telemetry_is_a_noop():
    assert not telemetry.enabled()
    telemetry.counter("queue", "claim")  # must not raise
    with telemetry.span("sig", "chunk") as t:
        t["rows"] = 4  # the yielded dict is a harmless scratch pad
    telemetry.flush()


# ----------------------------------------------------------------- sinks
def test_stdout_sink_greppable_lines():
    buf = io.StringIO()
    telemetry.configure(telemetry.StdoutSink(file=buf))
    telemetry.counter("fleet", "run_config", 3.0, workers=3)
    line = buf.getvalue().strip()
    assert line.startswith("telemetry,fleet,run_config,3.000000,")
    assert json.loads(line.split(",", 4)[4]) == {"workers": 3}


def test_jsonl_sink_crash_safe_and_reloads_previous_generation(tmp_path):
    p = tmp_path / "telemetry" / "w0.jsonl"
    sink = telemetry.JsonlSink(p, flush_every=1)
    telemetry.configure(sink, worker="w0")
    telemetry.counter("queue", "claim", uid="a")
    telemetry.counter("queue", "done", uid="a")
    # every generation on disk is complete, parseable JSONL
    recs = telemetry.read_jsonl(p)
    assert [r["name"] for r in recs] == ["claim", "done"]
    assert all(telemetry.validate(r) == [] for r in recs)

    # relaunch after SIGKILL: a new sink on the same path preloads the
    # previous generation, so the rewrite never loses records
    telemetry.configure()  # simulate death without another flush
    sink2 = telemetry.JsonlSink(p, flush_every=1)
    telemetry.configure(sink2, worker="w0")
    telemetry.counter("sig", "done", uid="b")
    names = [r["name"] for r in telemetry.read_jsonl(p)]
    assert names == ["claim", "done", "done"]

    # a torn trailing line (foreign non-atomic writer) is tolerated
    with open(p, "a") as f:
        f.write('{"v": 1, "kind": "cou')
    assert len(telemetry.read_jsonl(p)) == 3


def test_jsonl_sink_batches_flushes(tmp_path):
    p = tmp_path / "w.jsonl"
    telemetry.configure(telemetry.JsonlSink(p, flush_every=100))
    telemetry.counter("queue", "claim")
    assert telemetry.read_jsonl(p) == []  # buffered, not yet durable
    telemetry.flush()
    assert len(telemetry.read_jsonl(p)) == 1


def test_configure_from_env(tmp_path, monkeypatch, capsys):
    default = tmp_path / "telemetry" / "main.jsonl"
    monkeypatch.setenv("EDM_TELEMETRY", "off")
    telemetry.configure_from_env(default_path=default, worker="m")
    assert not telemetry.enabled()

    monkeypatch.setenv("EDM_TELEMETRY", f"jsonl:{tmp_path / 'x.jsonl'}")
    telemetry.configure_from_env(default_path=default, worker="m")
    telemetry.counter("fleet", "run_config")
    telemetry.flush()
    assert len(telemetry.read_jsonl(tmp_path / "x.jsonl")) == 1

    monkeypatch.delenv("EDM_TELEMETRY")
    telemetry.configure_from_env(default_path=default, worker="m")
    telemetry.counter("fleet", "run_config")
    telemetry.flush()
    assert len(telemetry.read_jsonl(default)) == 1

    telemetry.configure_from_env(default_path=None, worker="m")
    assert not telemetry.enabled()  # no default, no env -> disabled


# ------------------------------------------- byte-invisibility + autotune
def _small_run(out_dir, cfg=None, telemetry_on=False):
    from repro.core.pipeline import run_causal_inference
    from repro.core.types import EDMConfig
    from repro.data.synthetic import dummy_brain
    from repro.inference import SignificanceConfig, run_significance

    ts = dummy_brain(10, 200, seed=3)
    cfg = cfg or EDMConfig(E_max=3, lib_block=5, target_tile=4)
    sig = SignificanceConfig(lib_sizes=(30, 60), n_surrogates=4, seed=0)
    if telemetry_on:
        telemetry.configure(
            telemetry.JsonlSink(
                telemetry.worker_jsonl(out_dir, "main"), flush_every=1),
            worker="main",
        )
    res = run_causal_inference(ts, cfg, out_dir=str(out_dir))
    run_significance(ts, np.asarray(res.optE), np.asarray(res.rho),
                     cfg, sig, out_dir=str(out_dir))
    telemetry.shutdown()
    return ts, cfg, sig


def test_sinks_byte_invisible_and_all_stages_recorded(tmp_path):
    """The tentpole invariant: a JSONL-sink run produces byte-identical
    artifacts to a sink-disabled run, and its records are schema-valid
    and cover every pipeline stage the run walked."""
    _small_run(tmp_path / "off", telemetry_on=False)
    _small_run(tmp_path / "on", telemetry_on=True)
    for art in ("causal_map", "rho_conv", "rho_trend", "pvals", "edges"):
        a = np.load(tmp_path / "on" / art / "data.npy")
        b = np.load(tmp_path / "off" / art / "data.npy")
        assert a.tobytes() == b.tobytes(), f"{art} differs with sink on"
    # a sink-disabled run writes no telemetry at all
    assert not (tmp_path / "off" / "telemetry").exists()

    recs = [r for _, r in telemetry.iter_store_records(tmp_path / "on")]
    assert recs, "sink-enabled run recorded nothing"
    for r in recs:
        assert telemetry.validate(r) == [], r
    span_stages = {r["stage"] for r in recs if r["kind"] == "span"}
    for stage in ("phase1", "phase2", "assemble", "sig", "finalize"):
        assert stage in span_stages, f"no span recorded for {stage}"
    # store + stream layers report through the same spine
    names = {(r["stage"], r["name"]) for r in recs}
    assert ("store", "manifest_commit") in names or any(
        n in ("write_tile", "write_block") for _, n in names
    )


def test_autotune_recommend_write_load_apply_roundtrip(tmp_path):
    """replay -> recommend from recorded timings; tuned.json roundtrip;
    apply_to_cfg stamps the shapes; a rerun under the tuned shapes is
    byte-identical (the invariant that makes autotuning safe)."""
    import dataclasses

    out = tmp_path / "run"
    _, cfg, _ = _small_run(out, telemetry_on=True)

    tuned = autotune.recommend(out)
    assert tuned is not None and tuned["v"] == autotune.TUNED_VERSION
    rec = tuned["recommend"]
    assert rec.get("chunk_rows", 0) >= autotune.CHUNK_ROWS_MIN
    ev = tuned["evidence"]
    assert ev["chunks"] > 0 and ev["chunk_rows_done"] > 0

    p = autotune.write_tuned(out, tuned)
    assert p.name == "tuned.json" and p.parent == out
    assert autotune.load_tuned(out) == tuned
    assert autotune.load_tuned(tmp_path) is None  # absent store
    p.write_text("{broken")
    assert autotune.load_tuned(out) is None  # torn file never applies
    autotune.write_tuned(out, tuned)

    cfg2 = autotune.apply_to_cfg(cfg, tuned, n_devices=1)
    if rec.get("chunk_rows"):
        assert cfg2.lib_block == rec["chunk_rows"]
    if rec.get("target_tile"):
        assert cfg2.target_tile == rec["target_tile"]
    if rec.get("knn_tile_c"):
        assert cfg2.knn_tile_c == rec["knn_tile_c"]

    # geometry is bit-invisible: rerun under the tuned shapes == original
    clamped = dataclasses.replace(
        cfg2, lib_block=min(cfg2.lib_block, 10),
        target_tile=min(cfg2.target_tile, 10),
    )
    _small_run(tmp_path / "tuned", cfg=clamped, telemetry_on=False)
    for art in ("causal_map", "rho_conv", "pvals"):
        a = np.load(tmp_path / "tuned" / art / "data.npy")
        b = np.load(out / art / "data.npy")
        assert a.tobytes() == b.tobytes(), f"{art} differs under tuning"


def test_autotune_no_telemetry_returns_none(tmp_path):
    assert autotune.recommend(tmp_path) is None
    with pytest.raises(SystemExit, match="no chunk telemetry"):
        autotune.main([str(tmp_path)])


def test_autotune_decision_rules(tmp_path):
    """Synthetic telemetry exercising each band of the decision rules
    (no pipeline run needed — the tuner replays records, not stores)."""
    def store_with(records):
        import shutil
        d = tmp_path / "synth"
        if d.exists():
            shutil.rmtree(d)
        p = telemetry.worker_jsonl(d, "w0")
        p.parent.mkdir(parents=True)
        base = {"v": 1, "t": 0.0, "worker": "w0", "pid": 1, "attrs": {}}
        p.write_text("".join(
            json.dumps({**base, "seq": i, **r}) + "\n"
            for i, r in enumerate(records)
        ))
        return d

    chunk = {"kind": "span", "stage": "sig", "name": "chunk",
             "attrs": {"rows": 8, "chunk_rows": 8, "tile": 32,
                       "n_tiles": 4}}
    write = {"kind": "span", "stage": "store", "name": "write_tile"}
    cal = {"kind": "counter", "stage": "engine", "name": "knn_tile",
           "value": 256.0, "attrs": {"Lc": 400}}
    nrec = {"kind": "span", "stage": "assemble", "name": "causal_map",
            "dur_s": 0.1, "attrs": {"N": 512}}

    # 2 rows/s -> chunk_rows grows toward TARGET_CHUNK_S of compute
    d = store_with([{**chunk, "dur_s": 4.0}, nrec, cal])
    t = autotune.recommend(d)["recommend"]
    assert t["chunk_rows"] == 40  # 2 rows/s * 20 s, rounded to 8s
    assert t["knn_tile_c"] == 256

    # write-dominated tiles (ratio > HI) -> target_tile doubles
    d = store_with([{**chunk, "dur_s": 4.0},
                    {**write, "dur_s": 0.5}, nrec])
    assert autotune.recommend(d)["recommend"]["target_tile"] == 64

    # negligible write cost with several tiles/chunk -> tile halves
    d = store_with([{**chunk, "dur_s": 40.0},
                    {**write, "dur_s": 0.0001}, nrec])
    assert autotune.recommend(d)["recommend"]["target_tile"] == 16

    # recommendations never exceed the run's N
    small = {**chunk, "attrs": {**chunk["attrs"]}}
    nsmall = {**nrec, "attrs": {"N": 24}}
    d = store_with([{**small, "dur_s": 8.0}, nsmall])
    assert autotune.recommend(d)["recommend"]["chunk_rows"] <= 24


def test_compile_cache_probe(tmp_path, monkeypatch):
    monkeypatch.delenv("JAX_COMPILATION_CACHE_DIR", raising=False)
    assert telemetry.compile_cache_entries() is None
    cache = tmp_path / "cache"
    cache.mkdir()
    (cache / "a").write_text("")
    monkeypatch.setenv("JAX_COMPILATION_CACHE_DIR", str(cache))
    assert telemetry.compile_cache_entries() == 1
    mem = telemetry.MemorySink()
    telemetry.configure(mem)
    (cache / "b").write_text("")
    telemetry.emit_compile_cache("phase1", before=1)
    (rec,) = mem.records
    assert rec["name"] == "compile_cache" and rec["value"] == 1.0
    assert rec["attrs"] == {"entries": 2, "new": 1}
