"""Per-architecture smoke tests (reduced configs) + decode-path consistency.

Every assigned arch: one forward/train step on CPU asserting output shapes
and the absence of NaNs; prefill+decode logits must match the full forward
(MoE archs tested with drop-free capacity, since capacity-based dispatch is
legitimately grouping-dependent)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import TrainConfig
from repro.models import ssm as SSM
from repro.models import transformer as T

B, S = 2, 32


def make_batch(cfg, rng, S=S):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "audio":
        batch["audio"] = jnp.asarray(
            0.1 * rng.standard_normal((B, cfg.n_frontend_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            0.1 * rng.standard_normal((B, cfg.n_frontend_tokens, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, np.random.default_rng(0))
    logits, aux = T.forward(params, batch, cfg, remat=False)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    loss, metrics = T.loss_fn(params, batch, cfg, TrainConfig(remat=False))
    assert np.isfinite(float(loss))
    # loss at init ~ ln(vocab)
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab_size)) < 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_train_step(arch):
    from repro.launch.steps import TrainState, make_train_step

    cfg = get_config(arch, smoke=True)
    tc = TrainConfig(remat=True, lr=1e-3, warmup_steps=1, total_steps=10)
    state = TrainState.create(cfg, tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tc))
    batch = make_batch(cfg, np.random.default_rng(1))
    l0 = float(T.loss_fn(state.params, batch, cfg, tc)[0])
    for _ in range(3):
        state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state.step) == 3
    l1 = float(T.loss_fn(state.params, batch, cfg, tc)[0])
    assert l1 < l0  # memorizes a repeated batch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.n_experts:  # drop-free so results are grouping-independent
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts) / cfg.experts_per_tok
        )
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    batch = make_batch(cfg, rng, S=33)  # odd prefix exercises ssm padding
    toks = batch["tokens"]
    logits_full, _ = T.forward(params, batch, cfg, remat=False)
    cache = T.init_cache(cfg, B, 33, dtype=jnp.float32)
    pre = dict(batch)
    pre["tokens"] = toks[:, :32]
    lp, cache = T.prefill(params, pre, cache, cfg, remat=False)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(logits_full[:, :32]), rtol=2e-4, atol=2e-4
    )
    ld, _ = T.decode_step(
        params, {"token": toks[:, 32:33], "pos": jnp.asarray(32, jnp.int32)}, cache, cfg
    )
    np.testing.assert_allclose(
        np.asarray(ld[:, 0]), np.asarray(logits_full[:, 32]), rtol=2e-4, atol=2e-4
    )


def test_mamba_fwd_equals_stepwise_decode():
    """Chunked SSD == per-token recurrence, token by token."""
    dims = SSM.SSMDims(d_model=32, d_state=8, head_dim=8, chunk=8)
    p = SSM.init_mamba(jax.random.PRNGKey(1), dims, jnp.float32)
    rng = np.random.default_rng(0)
    u = jnp.asarray(0.5 * rng.standard_normal((2, 24, 32)), jnp.float32)
    y_chunked = SSM.mamba_fwd(p, dims, u)
    state = SSM.mamba_init_state(dims, 2, jnp.float32)
    ys = []
    for t in range(24):
        y_t, state = SSM.mamba_decode_step(p, dims, u[:, t : t + 1], state)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_chunked), np.asarray(y_seq), rtol=2e-4, atol=2e-4
    )


def test_mamba_prefill_state_continues_correctly():
    """State handed off by prefill must continue the exact recurrence."""
    dims = SSM.SSMDims(d_model=16, d_state=4, head_dim=4, chunk=8)
    p = SSM.init_mamba(jax.random.PRNGKey(2), dims, jnp.float32)
    rng = np.random.default_rng(1)
    u = jnp.asarray(0.5 * rng.standard_normal((1, 20, 16)), jnp.float32)
    _, st = SSM.mamba_fwd(p, dims, u[:, :19], return_state=True)
    y_last, _ = SSM.mamba_decode_step(p, dims, u[:, 19:20], st)
    y_full = SSM.mamba_fwd(p, dims, u)
    np.testing.assert_allclose(
        np.asarray(y_last[:, 0]), np.asarray(y_full[:, 19]), rtol=2e-4, atol=2e-4
    )


def test_moe_no_drop_is_exact_topk_mixture():
    """With no_drop, MoE output equals the explicit per-token top-k sum."""
    from repro.models.moe import init_moe, moe_fwd

    d, f, E, k = 16, 32, 4, 2
    p = init_moe(jax.random.PRNGKey(0), d, f, E, "swiglu", jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8, d)), jnp.float32)
    y, _ = moe_fwd(p, x, E, k, "swiglu", group_size=16, no_drop=True)

    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, k)
    gv = gv / gv.sum(-1, keepdims=True)
    def expert(e, xt):
        return (jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])) @ p["w_down"][e]
    y_ref = jnp.zeros_like(x)
    for b in range(2):
        for s in range(8):
            acc = jnp.zeros((d,))
            for j in range(k):
                acc += gv[b, s, j] * expert(int(ei[b, s, j]), x[b, s])
            y_ref = y_ref.at[b, s].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


def test_vocab_padding():
    cfg = get_config("whisper-medium")
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab_size
