import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — tests must see the real single CPU device.
# Multi-device integration tests spawn subprocesses with their own flags.


@pytest.fixture(scope="session")
def coupled_pair():
    from repro.data.synthetic import coupled_logistic

    x, y = coupled_logistic(800, beta_xy=0.0, beta_yx=0.12, seed=3)
    return np.stack([x, y])


@pytest.fixture(scope="session")
def small_network():
    from repro.data.synthetic import logistic_network

    return logistic_network(10, 300, density=0.2, strength=0.25, seed=4)
