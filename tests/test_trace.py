"""Fleet trace assembly + run history + schedule autotune (DESIGN.md
SS13): unit-lifecycle reconstruction from recorded telemetry, clock-skew
alignment, critical path + wall-time buckets, Chrome trace-event export,
the crash-safe run-history store with trends rendering, the three
schedule-knob decision rules, and `status --watch` straggler flags.

Everything here runs on SYNTHETIC telemetry fixtures (handwritten JSONL
records with known timings) — the trace layer replays records, it never
needs a live pipeline, so the tests pin exact expected numbers.
"""
import io
import json
import threading
import time

import pytest

from repro.runtime import autotune, history, telemetry, trace


@pytest.fixture(autouse=True)
def _clean_telemetry():
    telemetry.shutdown()
    telemetry.set_identity("main")
    yield
    telemetry.shutdown()
    telemetry.set_identity("main")


# ------------------------------------------------------------- fixtures
def _write_worker(out, worker, records, pid=1, mono_offset=900.0):
    """One worker's JSONL: fills schema boilerplate, derives ``mono``
    from ``t`` minus the worker's epoch-mono offset (a real worker's
    monotonic clock has an arbitrary zero)."""
    p = telemetry.worker_jsonl(out, worker)
    p.parent.mkdir(parents=True, exist_ok=True)
    lines = []
    for i, r in enumerate(records):
        rec = {"v": 1, "worker": worker, "pid": pid, "seq": i + 1,
               "attrs": {}, **r}
        rec.setdefault("mono", rec["t"] - mono_offset)
        lines.append(json.dumps(rec) + "\n")
    with open(p, "a") as f:
        f.writelines(lines)
    return p


def _span(stage, name, end, dur, **attrs):
    return {"kind": "span", "stage": stage, "name": name, "t": end,
            "dur_s": dur, "attrs": attrs}


def _ctr(stage, name, t, value=1.0, **attrs):
    return {"kind": "counter", "stage": stage, "name": name, "t": t,
            "value": value, "attrs": attrs}


U0, U1 = "phase2_00000000_00008", "phase2_00000008_00008"


def _two_worker_store(out, w1_skew=0.0, w1_mono_offset=None):
    """The recorded 2-worker fixture: two phase-2 units (one per worker,
    w1's the straggler) and an assemble unit claimed by w1 after the
    barrier.  ``w1_skew`` shifts every w1 EPOCH stamp (its mono stays
    truthful relative to its own epoch) — the clock-skew scenario."""
    _write_worker(out, "w0", [
        _ctr("phase2", "claim", 1000.0, uid=U0, row0=0, nrows=8,
             lease_age_s=0.0),
        _span("phase2", "chunk", 1010.0, 10.0, row0=0, rows=8,
              chunk_rows=8, gather_s=1.0),
        _span("store", "write_tile", 1010.5, 0.5, row0=0, col0=0,
              bytes=100),
        _ctr("phase2", "done", 1011.0, uid=U0, row0=0, nrows=8,
             held_s=11.0),
        _ctr("phase2", "held", 1011.0, value=11.0, uid=U0, outcome="done"),
        _span("phase2", "stage", 1012.0, 12.5),
    ], pid=10)
    s = w1_skew
    off = 900.0 if w1_mono_offset is None else w1_mono_offset
    _write_worker(out, "w1", [
        _ctr("phase2", "claim", 1000.5 + s, uid=U1, row0=8, nrows=8,
             lease_age_s=0.0),
        _span("phase2", "chunk", 1015.0 + s, 14.0, row0=8, rows=8,
              chunk_rows=8, gather_s=2.0),
        _ctr("phase2", "done", 1015.5 + s, uid=U1, row0=8, nrows=8,
             held_s=15.0),
        _ctr("phase2", "held", 1015.5 + s, value=15.0, uid=U1,
             outcome="done"),
        _span("phase2", "stage", 1016.0 + s, 16.2),
        # assemble happens strictly AFTER the phase-2 barrier drained
        _ctr("assemble", "claim", 1016.5 + s, uid="assemble", row0=0,
             nrows=16, lease_age_s=0.0),
        _ctr("assemble", "done", 1017.0 + s, uid="assemble", row0=0,
             nrows=16, held_s=0.5),
    ], pid=11, mono_offset=off)
    return out


# ------------------------------------------------------- trace assembly
def test_unit_lifecycles_and_buckets(tmp_path):
    tr = trace.assemble_trace(_two_worker_store(tmp_path))
    assert tr["workers"] == ["w0", "w1"]
    assert set(tr["units"]) == {U0, U1, "assemble"}

    u1 = tr["units"][U1]
    assert u1["worker"] == "w1" and u1["steals"] == 0
    assert u1["held_s"] == 15.0 and u1["chunks"] == 1
    assert u1["compute_s"] == pytest.approx(14.0)
    assert u1["gather_s"] == pytest.approx(2.0)
    u0 = tr["units"][U0]
    assert u0["store_s"] == pytest.approx(0.5)  # write_tile joined via row0

    p2 = tr["stages"]["phase2"]
    assert p2["units"] == 2 and p2["done_units"] == 2 and p2["chunks"] == 2
    # stage wall spans first stage-span start to last phase2 event
    # (the store span and the assemble claim belong to other stages)
    assert p2["start"] == pytest.approx(1012.0 - 12.5)
    assert p2["end"] == pytest.approx(1016.0)
    b = p2["buckets"]
    assert b["compute"] == pytest.approx(24.0)  # both workers' chunk time
    assert b["gather"] == pytest.approx(3.0)
    assert b["store"] == pytest.approx(0.5)
    # w1 finished last: w0 idles from its last busy moment to stage end
    assert b["straggler_tail"] >= 1015.0 - 1010.5 - 0.1
    # nearest-rank (n-1)-indexed percentiles: 2 samples -> both lower
    assert p2["chunk_p50_s"] == 10.0 and p2["chunk_p95_s"] == 10.0

    # span_totals is the `fleet status` aggregation: EVERY span dur per
    # stage (this is the 1%-reconcile surface)
    assert tr["span_totals"]["phase2"] == pytest.approx(10 + 14 + 12.5 + 16.2)
    assert tr["span_totals"]["store"] == pytest.approx(0.5)

    # critical path: per stage, the unit the barrier waited on
    path = {e["stage"]: e for e in tr["critical_path"]}
    assert list(path) == ["phase2", "assemble"]  # DAG order
    assert path["phase2"]["uid"] == U1 and path["phase2"]["worker"] == "w1"
    assert path["phase2"]["queue_wait_s"] == pytest.approx(1.0)  # 999.5->1000.5
    # done at 1015.5, stage end 1016.0 (w1's own stage span close)
    assert path["phase2"]["straggler_tail_s"] == pytest.approx(0.5)
    assert path["assemble"]["uid"] == "assemble"

    # render never throws and names the straggler unit
    text = trace.render_trace(tr)
    assert U1 in text and "critical path" in text


def test_duplicate_done_records_dedupe(tmp_path):
    """A SIGKILL between the flushed done record and the durable marker
    recomputes the unit: w1's done record survives but no marker landed,
    so w2 steals after the TTL, redoes the work, and emits a SECOND done.
    The trace keeps the FIRST completion; alignment must not mistake the
    post-crash steal for clock skew."""
    _write_worker(tmp_path, "w0", [
        _ctr("phase2", "claim", 1000.0, uid=U0, row0=0, nrows=8),
        _ctr("phase2", "done", 1011.0, uid=U0, row0=0, nrows=8,
             held_s=11.0),
    ], pid=10)
    _write_worker(tmp_path, "w1", [  # crashed before the marker
        _ctr("phase2", "claim", 1000.5, uid=U1, row0=8, nrows=8),
        _ctr("phase2", "done", 1015.5, uid=U1, row0=8, nrows=8,
             held_s=15.0),
    ], pid=11)
    _write_worker(tmp_path, "w2", [
        _ctr("phase2", "steal", 1020.0, uid=U1, row0=8, nrows=8,
             lease_age_s=600.0),
        _ctr("phase2", "done", 1030.0, uid=U1, row0=8, nrows=8,
             held_s=10.0),
        _ctr("assemble", "claim", 1031.0, uid="assemble", row0=0,
             nrows=16),
        _ctr("assemble", "done", 1031.5, uid="assemble", row0=0,
             nrows=16, held_s=0.5),
    ], pid=12)
    tr = trace.assemble_trace(tmp_path)
    # the steal-after-done sequence is protocol-legal, not skew
    assert all(abs(s) < 1e-6 for s in tr["clock_shift_s"].values())
    u = tr["units"][U1]
    assert u["done_t"] == pytest.approx(1015.5)  # first completion wins
    assert u["worker"] == "w1" and u["held_s"] == 15.0
    assert u["steals"] == 1  # the steal is still part of the lifecycle
    assert len(u["claims"]) == 2


def test_clock_skew_alignment(tmp_path):
    """w1's epoch clock runs 50 s behind.  Queue causality (every
    phase-2 done precedes the assemble claim; w0's done is on the true
    timeline) pushes w1's whole timeline forward — alignment recovers
    the causally-required part of the skew without any clock exchange."""
    tr = trace.assemble_trace(_two_worker_store(tmp_path, w1_skew=-50.0,
                                                w1_mono_offset=850.0))
    shift = tr["clock_shift_s"]
    assert shift["w0"] == pytest.approx(0.0, abs=1e-6)
    # w0's phase2 done at 1011 must precede w1's assemble claim (raw
    # 966.5): the violation is 44.5 s — causal alignment recovers a
    # LOWER BOUND of the true 50 s skew, never overshoots it
    assert 44.0 <= shift["w1"] <= 50.0
    # aligned DAG order is causal again: the assemble claim follows
    # every phase-2 done (stage-span ends are not queue events, so only
    # the done/claim ordering is guaranteed after alignment)
    last_done = max(u["done_t"] for u in tr["units"].values()
                    if u["stage"] == "phase2")
    assert tr["units"]["assemble"]["claimed_t"] >= last_done - 1e-3
    # and the skew-free fixture needs (and gets) no correction
    tr0 = trace.assemble_trace(_two_worker_store(tmp_path / "clean"))
    assert all(abs(s) < 1e-6 for s in tr0["clock_shift_s"].values())


def test_ntp_step_immunity_via_mono(tmp_path):
    """An NTP step mid-run yanks one record's epoch stamp by +500 s; the
    median epoch-mono offset rebuilds the timeline on mono, so the
    stepped record lands where it causally belongs."""
    _write_worker(tmp_path, "w0", [
        _ctr("phase2", "claim", 1000.0, uid=U0, row0=0, nrows=8),
        # true time 1005 but epoch stepped +500; mono stays truthful
        {**_ctr("phase2", "done", 1505.0, uid=U0, row0=0, nrows=8,
                held_s=5.0), "mono": 105.0},
        _span("phase2", "stage", 1006.0, 6.0),
    ])
    tr = trace.assemble_trace(tmp_path)
    assert tr["units"][U0]["done_t"] == pytest.approx(1005.0)
    assert tr["total_wall_s"] < 10.0  # not 500+


def test_empty_store_yields_wellformed_trace(tmp_path):
    tr = trace.assemble_trace(tmp_path)
    assert tr["units"] == {} and tr["stages"] == {}
    assert tr["critical_path"] == [] and tr["total_wall_s"] == 0.0
    assert "no telemetry records" in trace.render_trace(tr)
    ct = trace.chrome_trace(tmp_path)
    assert ct["traceEvents"] == []


# ---------------------------------------------------------- chrome trace
def test_chrome_trace_golden(tmp_path):
    """Golden export of the 2-worker fixture: valid Chrome trace-event
    JSON (the Perfetto-loadable subset) with per-worker process rows,
    µs timestamps from run start, and span/instant events."""
    out = _two_worker_store(tmp_path)
    ct = trace.chrome_trace(out)
    evs = ct["traceEvents"]
    assert ct["displayTimeUnit"] == "ms"

    meta = [e for e in evs if e["ph"] == "M"]
    procs = {e["args"]["name"] for e in meta if e["name"] == "process_name"}
    assert procs == {"w0", "w1"}
    assert all(set(e) >= {"ph", "pid", "tid", "name"} for e in evs)

    xs = [e for e in evs if e["ph"] == "X"]
    inst = [e for e in evs if e["ph"] == "i"]
    assert len(xs) == 5  # 2 chunk + 1 write_tile + 2 stage spans
    assert len(inst) == 8  # claim/done/held x2 workers + assemble pair
    # metadata first, then strictly non-decreasing timestamps
    assert [e["ph"] for e in evs[:len(meta)]] == ["M"] * len(meta)
    ts = [e["ts"] for e in evs[len(meta):]]
    assert ts == sorted(ts) and all(isinstance(t, int) for t in ts)

    # t0 = earliest span start = 999.5 (w0 stage span); w0's chunk span
    # [1000, 1010] therefore sits at ts=500000 µs, dur=10 s
    chunk = next(e for e in xs if e["name"] == "phase2.chunk"
                 and e["args"]["row0"] == 0)
    assert chunk["ts"] == 500000 and chunk["dur"] == 10_000_000
    assert chunk["pid"] == 0  # w0 is the first (sorted) worker process
    done = next(e for e in inst if e["name"] == "phase2.done"
                and e["args"]["uid"] == U0)
    assert done["ts"] == 11_500_000

    # the written file round-trips as JSON
    p = trace.write_chrome_trace(out, tmp_path / "trace.json")
    assert json.loads(p.read_text())["traceEvents"]


# --------------------------------------------------------- reconciliation
def test_reconcile_matches_fleet_status_aggregation(tmp_path):
    """The acceptance gate: trace span totals vs `edm_fleet status`
    span_s — both sum every valid span's dur_s per stage, so they agree
    to rounding; a doctored status breaks the 1% gate."""
    out = _two_worker_store(tmp_path)
    tr = trace.assemble_trace(out)
    # fleet_status's aggregation, reproduced over the same records
    per_stage = {}
    for _, rec in telemetry.iter_store_records(out):
        if telemetry.validate(rec) or rec["kind"] != "span":
            continue
        st = per_stage.setdefault(rec["stage"], {"span_s": 0.0})
        st["span_s"] += rec["dur_s"]
    rep = trace.reconcile(tr, {"telemetry": {"stages": per_stage}})
    assert rep["ok"], rep
    assert all(s["delta_pct"] <= 1.0 for s in rep["stages"].values())

    per_stage["phase2"]["span_s"] *= 1.5  # drifted reader
    rep = trace.reconcile(tr, {"telemetry": {"stages": per_stage}})
    assert not rep["ok"]
    assert rep["stages"]["phase2"]["delta_pct"] > 1.0


# ------------------------------------------------------------ run history
def test_history_build_append_replace_roundtrip(tmp_path, monkeypatch):
    monkeypatch.delenv("EDM_HISTORY", raising=False)
    out = _two_worker_store(tmp_path / "run")
    (out / "fingerprint.json").parent.mkdir(exist_ok=True)
    (out / "fingerprint.json").write_text(json.dumps({"fingerprint": "fpA"}))

    rec = history.build_record(out)
    assert rec["v"] == history.HISTORY_VERSION
    assert rec["fingerprint"] == "fpA" and rec["workers"] == 2
    assert rec["chunks"] == 2 and rec["units_done"] == 3
    assert rec["chunk_p95_s"] == 10.0  # nearest-rank over 2 samples
    assert rec["held_p95_s"] == 11.0  # nearest-rank over [11, 15]
    assert rec["bytes_written"] == 100
    assert rec["rows_per_s"] == pytest.approx(16 / 24.0, rel=1e-3)
    assert rec["stages"]["phase2"]["span_s"] == pytest.approx(52.7)

    hp = tmp_path / "history.jsonl"
    history.append_record(hp, rec)
    history.append_record(hp, {**rec, "total_span_s": 99.0})  # same run
    got = history.load_history(hp)
    assert len(got) == 1  # replaced, not duplicated
    assert got[0]["total_span_s"] == 99.0
    other = {**rec, "out": "/elsewhere", "t": rec["t"] + 1}
    history.append_record(hp, other)
    assert len(history.load_history(hp)) == 2
    # torn foreign tail is tolerated (telemetry read_jsonl semantics)
    with open(hp, "a") as f:
        f.write('{"v": 1, "tor')
    assert len(history.load_history(hp)) == 2


def test_record_run_gating_and_env_override(tmp_path, monkeypatch):
    out = _two_worker_store(tmp_path / "run")
    monkeypatch.delenv("EDM_HISTORY", raising=False)
    # telemetry off + no env -> no-op: the store stays pristine
    assert history.record_run(out) is None
    assert not (out / "history.jsonl").exists()

    shared = tmp_path / "shared_history.jsonl"
    monkeypatch.setenv("EDM_HISTORY", str(shared))
    p = history.record_run(out)
    assert p == shared and len(history.load_history(shared)) == 1
    history.record_run(out)  # same run again -> replaced
    assert len(history.load_history(shared)) == 1

    monkeypatch.delenv("EDM_HISTORY")
    telemetry.configure(telemetry.MemorySink())
    p = history.record_run(out)  # sink active -> default store path
    assert p == out / "history.jsonl"


def test_trends_rendering_and_regression_flags(tmp_path):
    """Synthetic multi-run history: a 2x slowdown on the same
    fingerprint is flagged; the knob table ranks geometries."""
    base = {
        "v": 1, "out": "/runs/a", "fingerprint": "fp1", "N": 64,
        "engine": "reference", "workers": 2,
        "geometry": {"target_tile": 32, "stream_depth": 2,
                     "unit_rows": 8},
        "steals": 0, "retries": 0, "poisoned": 0, "chunk_p95_s": 1.0,
    }
    recs = [
        {**base, "t": 1000.0, "total_span_s": 10.0, "rows_per_s": 50.0},
        {**base, "t": 2000.0, "total_span_s": 11.0, "rows_per_s": 48.0},
        {**base, "t": 3000.0, "total_span_s": 22.0, "rows_per_s": 24.0,
         "geometry": {"target_tile": 64, "stream_depth": 2,
                      "unit_rows": 8}, "steals": 3},
    ]
    a = history.analyze_trends(recs)
    assert a["runs"][0]["regression_pct"] is None  # nothing to compare
    assert a["runs"][1]["regression_pct"] == pytest.approx(10.0)
    assert a["runs"][2]["regression_pct"] == pytest.approx(100.0)
    assert len(a["regressions"]) == 1
    assert len(a["knobs"]) == 2  # two geometries
    assert a["knobs"][0]["tile"] == 32  # faster geometry ranks first

    text = history.render_trends(recs)
    assert "REGRESSION +100.0%" in text
    assert "3 steal(s)" in text
    assert "knob vs throughput" in text
    assert "no runs recorded" in history.render_trends([])


# ------------------------------------------------- schedule-knob autotune
def _synth_store(tmp_path, records):
    import shutil
    d = tmp_path / "synth"
    if d.exists():
        shutil.rmtree(d)
    _write_worker(d, "w0", records)
    return d


CHUNK = _span("sig", "chunk", 1010.0, 0.0, rows=8, chunk_rows=8)


def test_schedule_knob_ttl_rule(tmp_path):
    """ttl = TTL_SAFETY x held p95, clamped to [TTL_MIN, TTL_MAX]."""
    held = [_ctr("sig", "held", 1000.0 + i, value=100.0, uid=f"u{i}",
                 outcome="done") for i in range(20)]
    d = _synth_store(tmp_path, [{**CHUNK, "dur_s": 4.0}] + held)
    rec = autotune.recommend(d)["recommend"]
    assert rec["ttl"] == pytest.approx(autotune.TTL_SAFETY * 100.0)

    tiny = [_ctr("sig", "held", 1000.0, value=0.5, uid="u0")]
    d = _synth_store(tmp_path, [{**CHUNK, "dur_s": 4.0}] + tiny)
    assert autotune.recommend(d)["recommend"]["ttl"] == autotune.TTL_MIN

    # no held evidence -> no schedule recommendation (geometry only)
    d = _synth_store(tmp_path, [{**CHUNK, "dur_s": 4.0}])
    assert "ttl" not in autotune.recommend(d)["recommend"]


def test_schedule_knob_workers_rule(tmp_path):
    """Straggler-tail share model: W = busy x TAIL_TARGET /
    (p95 x (1 - TAIL_TARGET)) — 400 s of work at p95=10 s supports 10
    workers before the tail exceeds 20% of the schedule."""
    chunks = [{**CHUNK, "dur_s": 40.0, "t": 1000.0 + i} for i in range(10)]
    held = [_ctr("sig", "held", 2000.0 + i, value=10.0, uid=f"u{i}")
            for i in range(20)]
    d = _synth_store(tmp_path, chunks + held)
    rec = autotune.recommend(d)["recommend"]
    assert rec["workers"] == 10
    assert rec["ttl"] == pytest.approx(autotune.TTL_MIN)  # 4x10 < 60 clamp

    # a heavier tail (p95 40 s) over the same work -> fewer workers
    held = [_ctr("sig", "held", 2000.0 + i, value=40.0, uid=f"u{i}")
            for i in range(20)]
    d = _synth_store(tmp_path, chunks + held)
    assert autotune.recommend(d)["recommend"]["workers"] == 2


def test_schedule_knob_stream_depth_rule(tmp_path):
    """Drain gather share steers depth: device-bound (> GATHER_HI) grows
    it, negligible (< GATHER_LO at depth > 2) shrinks it, mid-band
    keeps the recorded depth; clamped to [1, DEPTH_MAX]."""
    def with_drain(gather_s, depth, chunk_s=10.0):
        return [
            {**CHUNK, "dur_s": chunk_s},
            _span("phase2", "drain", 1011.0, gather_s + 0.01,
                  tag="(0, 8)", in_flight=0, depth=depth,
                  gather_s=gather_s),
        ]

    d = _synth_store(tmp_path, with_drain(gather_s=2.0, depth=2))
    assert autotune.recommend(d)["recommend"]["stream_depth"] == 3  # 20% share

    d = _synth_store(tmp_path, with_drain(gather_s=0.05, depth=3))
    assert autotune.recommend(d)["recommend"]["stream_depth"] == 2  # 0.5%

    d = _synth_store(tmp_path, with_drain(gather_s=0.5, depth=2))
    assert autotune.recommend(d)["recommend"]["stream_depth"] == 2  # 5%: keep

    d = _synth_store(tmp_path, with_drain(gather_s=9.0, depth=4))
    assert autotune.recommend(d)["recommend"]["stream_depth"] == \
        autotune.DEPTH_MAX  # never beyond the clamp


def test_held_percentiles_reader(tmp_path):
    d = _synth_store(tmp_path, [
        _ctr("phase2", "held", 1000.0 + i, value=float(i + 1), uid=f"u{i}")
        for i in range(100)
    ])
    pc = trace.held_percentiles(d)
    assert pc["n"] == 100
    assert pc["p50"] == 50.0 and pc["p95"] == 95.0 and pc["p99"] == 99.0
    assert trace.held_percentiles(tmp_path / "none") == {
        "n": 0, "p50": None, "p95": None, "p99": None}


# ------------------------------------------------------- status --watch
def test_watch_status_stragglers_and_throughput(tmp_path):
    """A handcrafted fleet store: one live lease far older than the
    fleet's p95 hold time is flagged STRAGGLER; a done marker landing
    between refreshes produces a throughput/ETA line."""
    from repro.launch import edm_fleet

    out = tmp_path / "fleet"
    out.mkdir()
    (out / "fleet.json").write_text(json.dumps(
        {"N": 16, "L": 100, "unit_rows": 8, "seed": 0, "sig": None,
         "cfg": {}}))
    qdir = out / "queue"
    qdir.mkdir()
    (qdir / "phase1.done").write_text(json.dumps({"worker": "w0"}))
    (qdir / "phase2_00000000_00008.lease").write_text(json.dumps(
        {"worker": "w9", "t": time.time() - 30.0, "ttl": 600.0}))
    _write_worker(out, "w0", [
        _ctr("phase2", "held", 1000.0 + i, value=2.0, uid=f"u{i}",
             outcome="done") for i in range(20)
    ])

    def land_done():
        time.sleep(0.3)
        (qdir / "phase2_00000008_00008.done").write_text(
            json.dumps({"worker": "w0"}))

    t = threading.Thread(target=land_done)
    t.start()
    buf = io.StringIO()
    st = edm_fleet.watch_status(out, interval=0.6, iterations=2, file=buf)
    t.join()
    text = buf.getvalue()
    assert "STRAGGLER phase2_00000000_00008@w9" in text
    assert "fleet p95 2.0s" in text
    assert "watch: phase2" in text and "units/s" in text and "ETA" in text
    assert not st["complete"]
