"""Checkpoint/restart + fault-tolerance: bit-exact kill/resume, atomic
commit, GC, resilient-loop retry, straggler telemetry, EDM row-block
resume (including elastic resume with a different chunk size)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import TokenStream
from repro.launch.steps import TrainState, make_train_step
from repro.runtime.fault import ResilientLoop, StepTelemetry


def _setup(tmp):
    cfg = get_config("smollm-135m", smoke=True)
    tc = TrainConfig(remat=False, lr=1e-3, warmup_steps=1, total_steps=20)
    state = TrainState.create(cfg, tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tc))
    stream = TokenStream(cfg.vocab_size, 2, 16, seed=0)
    return cfg, tc, state, step, stream


def test_checkpoint_roundtrip_bitexact(tmp_path):
    _, _, state, step, stream = _setup(tmp_path)
    state, _ = step(state, stream.batch_at(0))
    ckpt = CheckpointManager(tmp_path, keep_last=2)
    ckpt.save(1, state, blocking=True)
    restored = ckpt.restore(1, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kill_and_resume_is_bitexact(tmp_path):
    """train 6 steps straight == train 3, 'crash', restore, train 3 more."""
    _, _, state0, step, stream = _setup(tmp_path)

    sA = state0
    for i in range(6):
        sA, _ = step(sA, stream.batch_at(i))

    sB = state0
    for i in range(3):
        sB, _ = step(sB, stream.batch_at(i))
    ckpt = CheckpointManager(tmp_path / "c", keep_last=2)
    ckpt.save(3, sB, blocking=True)
    del sB  # "crash"
    step_n, sB = ckpt.restore_latest(jax.eval_shape(lambda: state0))
    assert step_n == 3
    for i in range(3, 6):
        sB, _ = step(sB, stream.batch_at(i))
    for a, b in zip(jax.tree.leaves(sA), jax.tree.leaves(sB)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_gc_and_latest(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep_last=2)
    tree = {"a": jnp.arange(4)}
    for s in (1, 2, 3, 4):
        ckpt.save(s, tree, blocking=True)
    assert ckpt.all_steps() == [3, 4]
    assert ckpt.latest_step() == 4


def test_async_save_then_wait(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep_last=1)
    ckpt.save(7, {"w": jnp.ones((256, 256))})
    ckpt.wait()
    assert ckpt.latest_step() == 7


def test_resilient_loop_recovers_from_injected_failure(tmp_path):
    _, _, state, step, stream = _setup(tmp_path)
    ckpt = CheckpointManager(tmp_path, keep_last=2)
    ckpt.save(0, state, blocking=True)
    calls = {"n": 0}

    def flaky_step(s, b):
        calls["n"] += 1
        if calls["n"] == 3:  # one transient failure
            raise RuntimeError("simulated preemption")
        return step(s, b)

    loop = ResilientLoop(flaky_step, ckpt, save_every=2, max_retries=2)
    final, step_n, _ = loop.run(state, stream.batch_at, n_steps=5)
    assert step_n == 5
    assert loop.telemetry.n_steps >= 5
    # the recovery replayed from the step-2 checkpoint: same final state as
    # an uninterrupted run (deterministic stream + bit-exact restore)
    clean = state
    for i in range(5):
        clean, _ = step(clean, stream.batch_at(i))
    for a, b in zip(jax.tree.leaves(clean.params), jax.tree.leaves(final.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resilient_loop_gives_up_after_max_retries(tmp_path):
    _, _, state, step, stream = _setup(tmp_path)
    ckpt = CheckpointManager(tmp_path, keep_last=1)
    ckpt.save(0, state, blocking=True)

    def always_fails(s, b):
        raise RuntimeError("hard failure")

    loop = ResilientLoop(always_fails, ckpt, save_every=10, max_retries=2)
    with pytest.raises(RuntimeError):
        loop.run(state, stream.batch_at, n_steps=1)


def test_straggler_telemetry():
    t = StepTelemetry(threshold=2.0)
    for _ in range(10):
        t.record(1.0)
    assert t.record(5.0) is True
    assert t.n_stragglers == 1


def test_edm_pipeline_resume_and_elastic(tmp_path, small_network):
    """Kill the CCM phase mid-run; resume — even with a different chunk
    size (elastic) — and match the uninterrupted result exactly."""
    from repro.core.pipeline import run_causal_inference
    from repro.core.types import EDMConfig
    from repro.data.store import RowBlockWriter

    ts, _ = small_network
    cfg = EDMConfig(E_max=4, lib_block=3)
    full = run_causal_inference(ts, cfg)

    out = tmp_path / "rho"
    # simulate a partial run: compute only the first block then "crash"
    partial = RowBlockWriter(out, ts.shape[0])
    partial.write_block(0, full.rho[:4])
    # resume with a DIFFERENT worker-chunk size (elastic restart)
    resumed = run_causal_inference(
        ts, EDMConfig(E_max=4, lib_block=2), out_dir=str(out)
    )
    np.testing.assert_allclose(resumed.rho, full.rho, rtol=1e-6, atol=1e-6)


@pytest.mark.slow
def test_edm_resume_partial_chunk_different_mesh_bit_identical(tmp_path):
    """Kill after a PARTIAL chunk (mid-chunk offset, partial coverage), rerun
    on a different mesh size (4 fake workers -> 2), and assert the assembled
    rho is BIT-identical to a fresh uninterrupted run.  Exercises the
    double-buffered streamer's ordered-drain guarantee: the resume manifest
    may only cover rows whose blocks are durably on disk."""
    import os
    import subprocess
    import sys
    import textwrap

    out = tmp_path / "rho"
    code = textwrap.dedent(
        """
        import sys, numpy as np, jax
        from repro.core.pipeline import run_causal_inference
        from repro.core.types import EDMConfig
        from repro.data.store import RowBlockWriter
        from repro.data.synthetic import logistic_network

        stage, out = sys.argv[1], sys.argv[2]
        ts, _ = logistic_network(11, 250, density=0.2, strength=0.25, seed=6)
        if stage == "fresh":
            full = run_causal_inference(ts, EDMConfig(E_max=4, lib_block=2))
            np.save(out, full.rho)
        elif stage == "partial":
            # 4 workers x lib_block 2 = chunk of 8; die after writing a
            # PARTIAL chunk (3 rows at offset 0) — mid-first-chunk crash.
            full = run_causal_inference(ts, EDMConfig(E_max=4, lib_block=2))
            w = RowBlockWriter(out, ts.shape[0])
            w.write_block(0, full.rho[:3])
        else:  # resume on whatever mesh this process has
            res = run_causal_inference(
                ts, EDMConfig(E_max=4, lib_block=2), out_dir=out
            )
            np.save(out + "/resumed.npy", res.rho)
        """
    )

    def run(stage, path, devices):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
        )
        r = subprocess.run(
            [sys.executable, "-c", code, stage, str(path)],
            capture_output=True, text=True, env=env, timeout=600,
        )
        assert r.returncode == 0, r.stdout + "\n" + r.stderr

    run("fresh", tmp_path / "fresh.npy", devices=4)
    run("partial", out, devices=4)
    run("resume", out, devices=2)  # elastic: different mesh size
    fresh = np.load(tmp_path / "fresh.npy")
    resumed = np.load(out / "resumed.npy")
    np.testing.assert_array_equal(resumed, fresh)
