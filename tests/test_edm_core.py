"""EDM core correctness: embeddings, weights, simplex, improved-vs-naive
CCM equivalence, and causal-direction recovery on known systems.

Hypothesis property tests live in tests/test_properties.py (hypothesis is
an optional dev dependency; see requirements-dev.txt)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EDMConfig,
    ccm_convergence,
    ccm_matrix,
    ccm_naive,
    delay_embed,
    lag_matrix,
    pearson,
    simplex_batch,
    simplex_weights,
)


# ---------------------------------------------------------------- embedding
def test_lag_matrix_matches_delay_embed():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(64), jnp.float32)
    E_max, tau, Lp = 5, 2, 64 - 4 * 2
    V = lag_matrix(x, E_max, tau, Lp)
    emb = delay_embed(x, E_max, tau)
    # V[k, t] is the k-th lag of point t; delay_embed rows are points
    assert V.shape == (E_max, Lp)
    np.testing.assert_allclose(np.asarray(V.T), np.asarray(emb)[:Lp], rtol=0, atol=0)


def test_simplex_weights_basic_distribution():
    rng = np.random.default_rng(0)
    d = np.sort(rng.uniform(0, 10, size=(4, 8)).astype(np.float32), axis=-1)
    w = np.asarray(simplex_weights(jnp.asarray(d**2), 8))
    assert np.all(w >= 0)
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-5)
    assert np.all(w[:, 0] + 1e-6 >= w[:, -1])


def test_pearson_bounds_and_degenerate():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal(100), jnp.float32)
    assert abs(float(pearson(a, a)) - 1.0) < 1e-5
    assert abs(float(pearson(a, -a)) + 1.0) < 1e-5
    assert float(pearson(a, jnp.zeros(100))) == 0.0  # constant -> 0 skill


# ------------------------------------------------- improved == naive (Alg 1 vs 2)
def test_improved_ccm_equals_naive(small_network):
    """mpEDM Alg. 2 == cppEDM Alg. 1 outputs.  Neighbour tables are
    bit-identical (termwise-sequential distance accumulation, same
    tie-breaking — checked below); rho tolerates only the fp reassociation
    that vmap batching introduces in the final correlation sums."""
    ts, _ = small_network
    cfg = EDMConfig(E_max=6)
    ts = jnp.asarray(ts)
    _, optE = simplex_batch(ts, cfg)
    rho_fast = np.asarray(ccm_matrix(ts, optE, cfg))
    rho_naive = np.asarray(ccm_naive(ts, optE, cfg))
    np.testing.assert_allclose(rho_fast, rho_naive, rtol=0, atol=1e-6)

    # the tables themselves ARE bit-exact between the two algorithms
    from repro.core import knn, lag_matrix

    x = ts[0]
    Lp = cfg.n_points(x.shape[0])
    V = lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    idx_all, sqd_all = knn.knn_tables_dense(V, V, cfg.k_max, exclude_self=True)
    for E in (1, 3, 6):
        idx_s, sqd_s = knn.knn_table_single_E(V, V, E, E + 1, exclude_self=True)
        np.testing.assert_array_equal(
            np.asarray(idx_all[E - 1][:, : E + 1]), np.asarray(idx_s)
        )
        # distances agree to 1 ulp (XLA fuses FMAs differently per path)
        np.testing.assert_allclose(
            np.asarray(sqd_all[E - 1][:, : E + 1]), np.asarray(sqd_s),
            rtol=1e-6, atol=1e-8,
        )


def test_target_block_invariance(small_network):
    """Chunking targets (lax.map blocks) must not change results."""
    ts, _ = small_network
    ts = jnp.asarray(ts)
    _, optE = simplex_batch(ts, EDMConfig(E_max=5))
    a = ccm_matrix(ts, optE, EDMConfig(E_max=5, target_block=3))
    b = ccm_matrix(ts, optE, EDMConfig(E_max=5, target_block=1024))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------- causal inference quality
def test_ccm_recovers_direction(coupled_pair):
    """x drives y (beta_yx>0, beta_xy=0) => skill of x-hat|M_y exceeds
    y-hat|M_x (Sugihara 2012)."""
    cfg = EDMConfig(E_max=6)
    ts = jnp.asarray(coupled_pair)
    _, optE = simplex_batch(ts, cfg)
    rho = np.asarray(ccm_matrix(ts, optE, cfg))
    assert rho[1, 0] > rho[0, 1] + 0.1, rho


def test_ccm_convergence_with_library_size(coupled_pair):
    """True causation: rho increases with library size (the subsampling
    test the paper's hot path omits, SSIII-A)."""
    cfg = EDMConfig(E_max=4)
    x, y = jnp.asarray(coupled_pair[0]), jnp.asarray(coupled_pair[1])
    rhos = np.asarray(
        ccm_convergence(y, x, 3, (40, 150, 700), cfg, jax.random.PRNGKey(0))
    )
    assert rhos[-1] > rhos[0], rhos


def test_simplex_finds_low_dim_for_logistic(coupled_pair):
    """The logistic map is 1-dimensional: optimal E should be small."""
    cfg = EDMConfig(E_max=10)
    _, optE = simplex_batch(jnp.asarray(coupled_pair), cfg)
    assert int(optE[0]) <= 3


def test_network_edges_score_higher(small_network):
    ts, adj = small_network
    cfg = EDMConfig(E_max=5)
    ts = jnp.asarray(ts)
    _, optE = simplex_batch(ts, cfg)
    rho = np.asarray(ccm_matrix(ts, optE, cfg))
    mask = ~np.eye(adj.shape[0], dtype=bool)
    linked = rho.T[adj]
    unlinked = rho.T[(~adj) & mask]
    assert linked.mean() > unlinked.mean() + 0.05
