"""Streaming candidate-tiled kNN selection (DESIGN.md SS8).

Contracts under test:
  * streaming == slab BIT-identity (idx AND float32 distances) on both
    the jnp builders and the Pallas kernels, for tile widths that do and
    do not divide Lc — including the tie-heavy duplicate/dead-neuron
    cases (the PR 2 simplex_weights d1~0 regime);
  * the streaming kernel's per-program block/scratch shapes are a pure
    function of (E_max, k, block_q, tile_c) — INDEPENDENT of Lc (the
    VMEM-budget CI guard);
  * the library-sharded builder + host-side merge reproduce the
    unsharded table bit-for-bit;
  * EDMConfig.knn_tile_c routing (auto threshold / force) is shared by
    every engine and invisible in the causal map.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import EDMConfig, ccm_matrix, knn, simplex_batch
from repro.data.synthetic import dummy_brain


def _rand_V(E, L, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((E, L)), jnp.float32)


# ------------------------------------------------------- jnp builders
@pytest.mark.parametrize(
    "Lq,Lc,E,k,exclude_self,tile_c",
    [
        (130, 130, 8, 9, True, 48),   # non-dividing tile
        (128, 128, 6, 7, True, 32),   # dividing tile
        (100, 257, 5, 6, False, 64),  # rectangular, non-dividing
        (50, 300, 5, 6, False, 300),  # single tile == slab width
        (60, 60, 4, 60, True, 16),    # k == Lc (masked self selected)
    ],
)
def test_streaming_bit_identical_to_slab(Lq, Lc, E, k, exclude_self, tile_c):
    Vq = _rand_V(E, Lq, Lq * 1000 + Lc)
    Vc = Vq if exclude_self else _rand_V(E, Lc, Lc)
    i0, d0 = knn.knn_tables_all_E(Vq, Vc, k, exclude_self, impl="unroll")
    i1, d1 = knn.knn_tables_all_E_streaming(
        Vq, Vc, k, exclude_self, tile_c=tile_c
    )
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("tile_c", [32, 48])  # dividing / non-dividing of 96
def test_streaming_ties_dead_and_duplicate_neurons(tile_c):
    """All-tied rows (dead series: every distance exactly 0) and duplicate
    candidates must resolve ties identically in the tiled merge and
    lax.top_k — the d1~0 simplex_weights regime from PR 2."""
    # dead neuron: constant series -> V all equal -> D == 0 everywhere
    Vdead = jnp.zeros((5, 96), jnp.float32)
    i0, d0 = knn.knn_tables_all_E(Vdead, Vdead, 6, True, impl="unroll")
    i1, d1 = knn.knn_tables_all_E_streaming(Vdead, Vdead, 6, True, tile_c=tile_c)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
    # ties resolve to the LOWEST candidate id (self masked out)
    assert np.asarray(i1)[0, 0, :3].tolist() == [1, 2, 3]
    assert np.all(np.asarray(d1) == 0.0)

    # duplicate neurons: pairs of identical candidate columns
    rng = np.random.default_rng(7)
    half = jnp.asarray(rng.standard_normal((5, 48)), jnp.float32)
    Vdup = jnp.concatenate([half, half], axis=1)  # cols j and j+48 identical
    i0, d0 = knn.knn_tables_all_E(Vdup, Vdup, 7, True, impl="unroll")
    i1, d1 = knn.knn_tables_all_E_streaming(Vdup, Vdup, 7, True, tile_c=tile_c)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
    # each query's zero-distance duplicate is found, lowest-id first
    assert np.all(np.asarray(d1)[:, :, 0] == 0.0)


def test_streaming_bucketed_bit_identical(tile_sizes=(33, 70, 140)):
    V = _rand_V(8, 140, 2)
    buckets = (2, 5, 8)
    i0, d0 = knn.knn_tables_bucketed(V, V, 9, True, buckets)
    for tc in tile_sizes:
        i1, d1 = knn.knn_tables_bucketed_streaming(
            V, V, 9, True, buckets, tile_c=tc
        )
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_streaming_counts_table_rows():
    knn.reset_table_counters()
    V = _rand_V(6, 90, 3)
    knn.knn_tables_all_E_streaming(V, V, 7, True, tile_c=30)
    assert knn.TABLE_ROWS_BUILT["all_E"] == 6
    knn.knn_tables_bucketed_streaming(V, V, 7, True, (2, 6), tile_c=30)
    assert knn.TABLE_ROWS_BUILT["bucketed"] == 2
    knn.reset_table_counters()


def test_streaming_rejects_bad_args():
    V = _rand_V(4, 50, 4)
    with pytest.raises(ValueError, match="exceeds candidate count"):
        knn.knn_tables_all_E_streaming(V, V, 51, True, tile_c=16)
    with pytest.raises(ValueError, match="ascending"):
        knn.knn_tables_bucketed_streaming(V, V, 5, True, (3, 2), tile_c=16)


# ------------------------------------------------------ pallas kernels
@pytest.mark.parametrize(
    "E,Lq,Lc,k,exclude_self,block_q,tile_c",
    [
        (4, 100, 100, 5, True, 64, 48),    # ragged Lq tail, non-dividing tile
        (6, 128, 192, 7, False, 64, 64),   # dividing everything
        (3, 129, 257, 4, False, 64, 100),  # ragged both axes
    ],
)
def test_stream_kernel_bit_identical_to_slab_kernel(
    E, Lq, Lc, k, exclude_self, block_q, tile_c
):
    from repro.kernels.knn_topk.ops import knn_topk, knn_topk_streaming

    Vq = _rand_V(E, Lq, E * 100 + Lq)
    Vc = Vq if exclude_self else _rand_V(E, Lc, Lc + 1)
    i_sl, d_sl = knn_topk(Vq, Vc, k, exclude_self=exclude_self, block_q=block_q)
    i_st, d_st = knn_topk_streaming(
        Vq, Vc, k, exclude_self=exclude_self, block_q=block_q, tile_c=tile_c
    )
    np.testing.assert_array_equal(np.asarray(i_sl), np.asarray(i_st))
    np.testing.assert_array_equal(np.asarray(d_sl), np.asarray(d_st))


def test_stream_kernel_vs_streaming_oracle():
    from repro.kernels.knn_topk.ops import knn_topk_streaming
    from repro.kernels.knn_topk.ref import knn_topk_stream_ref

    V = _rand_V(6, 150, 11)
    idx, d = knn_topk_streaming(V, V, 7, exclude_self=True, block_q=64, tile_c=40)
    ridx, rd = knn_topk_stream_ref(V, V, 7, exclude_self=True, tile_c=64)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), rtol=1e-5, atol=1e-5)


def test_stream_kernel_ties_match_slab_kernel():
    from repro.kernels.knn_topk.ops import knn_topk, knn_topk_streaming

    V = jnp.zeros((5, 90), jnp.float32)  # dead neuron: all ties
    i_sl, d_sl = knn_topk(V, V, 6, exclude_self=True, block_q=32)
    i_st, d_st = knn_topk_streaming(V, V, 6, exclude_self=True, block_q=32, tile_c=24)
    np.testing.assert_array_equal(np.asarray(i_sl), np.asarray(i_st))
    np.testing.assert_array_equal(np.asarray(d_sl), np.asarray(d_st))


def test_dist_dtype_bf16_reaches_kernels():
    """EDMConfig.dist_dtype is honoured by the Pallas kernels (bf16 tile
    accumulation, float32 merge keys): slab and streaming stay mutually
    bit-identical under bf16, and bf16 actually changes the numerics
    (proof it reached the accumulator, not a silently ignored knob)."""
    from repro.kernels.knn_topk.ops import knn_topk, knn_topk_streaming

    V = _rand_V(6, 120, 13)
    i_sl, d_sl = knn_topk(V, V, 7, exclude_self=True, block_q=64,
                          dist_dtype="bfloat16")
    i_st, d_st = knn_topk_streaming(V, V, 7, exclude_self=True, block_q=64,
                                    tile_c=40, dist_dtype="bfloat16")
    np.testing.assert_array_equal(np.asarray(i_sl), np.asarray(i_st))
    np.testing.assert_array_equal(np.asarray(d_sl), np.asarray(d_st))
    assert d_sl.dtype == jnp.float32  # merge keys / outputs stay f32
    _, d_f32 = knn_topk(V, V, 7, exclude_self=True, block_q=64)
    assert not np.array_equal(np.asarray(d_f32), np.asarray(d_sl))
    # bf16 distances agree with f32 to bf16 resolution
    np.testing.assert_allclose(
        np.asarray(d_f32), np.asarray(d_sl), rtol=2e-2, atol=2e-2
    )


def test_ragged_tail_split_covers_all_queries():
    """_query_splits: full blocks + one 8-aligned tail block; outputs for
    every query row match the unsplit reference (the padded-query waste
    fix must not change results)."""
    from repro.kernels.knn_topk.knn_topk import _query_splits
    from repro.kernels.knn_topk.ops import knn_topk
    from repro.kernels.knn_topk.ref import knn_topk_ref

    assert _query_splits(256, 128) == [(0, 256, 128)]
    assert _query_splits(130, 128) == [(0, 128, 128), (128, 2, 8)]
    assert _query_splits(50, 128) == [(0, 50, 56)]
    for Lq in (130, 50, 255):
        V = _rand_V(4, Lq, Lq)
        idx, d = knn_topk(V, V, 5, exclude_self=True, block_q=128)
        ridx, rd = knn_topk_ref(V, V, 5, True)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
        np.testing.assert_allclose(np.asarray(d), np.asarray(rd), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------- CI guard: VMEM
def test_stream_kernel_blocks_independent_of_Lc():
    """CI guard: the streaming kernel's per-program block/scratch shapes
    and VMEM budget are a pure function of (E_max, k, block_q, tile_c) —
    the library length only scales the grid.  stream_block_shapes is the
    SAME function knn_topk_stream_pallas builds its BlockSpecs from."""
    from repro.kernels.knn_topk.knn_topk import (
        stream_block_shapes,
        stream_vmem_bytes,
    )

    shapes = stream_block_shapes(20, 21, 128, 512)
    import inspect
    sig = inspect.signature(stream_block_shapes)
    assert "Lc" not in sig.parameters  # shape function cannot even see Lc
    assert shapes["vc_tile"] == (20, 512)
    assert shapes["scratch_idx"] == (20, 128, 21)
    # paper-scale budget: E_max=20, k=21, block_q=128, tile_c=512 fits
    # a 16 MB VMEM with generous headroom, at ANY library length
    assert stream_vmem_bytes(20, 21, 128, 512) < 4 * 2**20
    # slab VMEM, by contrast, grows linearly in Lc and busts the budget
    assert knn.slab_bytes(128, 8528) + 8528 * 20 * 4 > 4 * 2**20
    # the jnp streaming working-set model takes no Lc parameter either
    # (structural flatness); pin its concrete value so the model cannot
    # silently grow a hidden Lc term
    assert "Lc" not in inspect.signature(knn.streaming_bytes).parameters
    assert knn.streaming_bytes(128, 21, 512, 20) < 4 * 2**20


def test_resolve_knn_tile_thresholds():
    assert knn.resolve_knn_tile(1000, 0) == 0  # auto: small -> slab
    assert knn.resolve_knn_tile(knn.SLAB_AUTO_MAX_LC + 1, 0) == (
        knn.STREAM_DEFAULT_TILE_C
    )
    assert knn.resolve_knn_tile(100, -1) == 0  # forced slab
    assert knn.resolve_knn_tile(100, 64) == 64  # forced streaming
    with pytest.raises(ValueError, match="knn_tile_c"):
        EDMConfig(knn_tile_c=-2)


# ------------------------------------------------- library sharding
def test_merge_shard_tables_bit_identical():
    """Per-shard top-k + host merge == unsharded table, bit for bit,
    across shard counts (including shards narrower than k)."""
    rng = np.random.default_rng(17)
    Vq = jnp.asarray(rng.standard_normal((6, 120)), jnp.float32)
    i0, d0 = knn.knn_tables_all_E(Vq, Vq, 7, True, impl="unroll")
    for S in (2, 3, 5):
        shard = -(-120 // S)
        parts = [
            knn.knn_tables_all_E_streaming(
                Vq, Vq[:, s * shard : min((s + 1) * shard, 120)],
                min(7, shard), True, tile_c=16,
                col_offset=s * shard, col_hi=min((s + 1) * shard, 120),
            )
            for s in range(S)
        ]
        mi, md = knn.merge_shard_tables(
            [p[0] for p in parts], [p[1] for p in parts], k=7
        )
        np.testing.assert_array_equal(mi, np.asarray(i0))
        np.testing.assert_array_equal(md, np.asarray(d0))


def test_library_sharded_pipeline_builder():
    """The shard_map-backed builder (local mesh) == slab table."""
    from repro.core.pipeline import knn_tables_library_sharded

    Vq = _rand_V(5, 110, 23)
    cfg = EDMConfig(E_max=5)
    mi, md = knn_tables_library_sharded(Vq, Vq, 6, cfg, exclude_self=True)
    i0, d0 = knn.knn_tables_all_E(Vq, Vq, 6, True, impl="unroll")
    np.testing.assert_array_equal(mi, np.asarray(i0))
    np.testing.assert_array_equal(md, np.asarray(d0))


def test_library_sharded_multi_device():
    """4 fake devices: each selects over its candidate shard, the host
    merge reproduces the unsharded table bit-for-bit (subprocess — the
    in-process suite must see the real single CPU device)."""
    import os
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import EDMConfig, knn
        from repro.core.pipeline import knn_tables_library_sharded

        assert len(jax.devices()) == 4
        rng = np.random.default_rng(31)
        Vq = jnp.asarray(rng.standard_normal((5, 130)), jnp.float32)
        cfg = EDMConfig(E_max=5, knn_tile_c=16)  # force streaming shards
        mi, md = knn_tables_library_sharded(Vq, Vq, 6, cfg, exclude_self=True)
        i0, d0 = knn.knn_tables_all_E(Vq, Vq, 6, True, impl="unroll")
        np.testing.assert_array_equal(mi, np.asarray(i0))
        np.testing.assert_array_equal(md, np.asarray(d0))
        print("sharded-4dev == unsharded: OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=600, cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


# --------------------------------------------------- engine routing
@pytest.mark.parametrize("engine", ["reference", "pallas-interpret"])
def test_causal_map_invariant_under_knn_tile(engine):
    """Forced streaming (dividing and non-dividing tiles) and forced slab
    produce the SAME causal map on both engines — the acceptance bit."""
    ts = jnp.asarray(dummy_brain(10, 260, seed=21))
    base = EDMConfig(E_max=4, engine=engine)
    _, optE = simplex_batch(ts, EDMConfig(E_max=4))
    rho_slab = np.asarray(
        ccm_matrix(ts, optE, EDMConfig(E_max=4, engine=engine, knn_tile_c=-1))
    )
    for tile in (32, 37):  # divides / does not divide Lp
        rho_t = np.asarray(
            ccm_matrix(
                ts, optE, EDMConfig(E_max=4, engine=engine, knn_tile_c=tile)
            )
        )
        np.testing.assert_array_equal(rho_slab, rho_t)
    del base


def test_phase1_invariant_under_knn_tile():
    """Phase 1 (simplex sweep) also routes through the streaming builders
    unchanged: optE and rhos identical under forced streaming."""
    ts = jnp.asarray(dummy_brain(8, 240, seed=29))
    r0, e0 = simplex_batch(ts, EDMConfig(E_max=4, knn_tile_c=-1))
    r1, e1 = simplex_batch(ts, EDMConfig(E_max=4, knn_tile_c=41))
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))
