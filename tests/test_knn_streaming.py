"""Streaming candidate-tiled kNN selection (DESIGN.md SS8).

Contracts under test:
  * the partial merge network (core/knn.merge_topk_sorted) reproduces
    lax.top_k over the union of any column partition BIT-identically
    (idx AND float32 distances) — k not a power of two, k == Lc,
    duplicate/dead-neuron ties, lists narrower than k;
  * streaming == dense-oracle BIT-identity on both the jnp builders and
    the Pallas stream kernel, for tile widths that do and do not divide
    Lc — including the tie-heavy duplicate/dead-neuron cases (the PR 2
    simplex_weights d1~0 regime) and the bf16-accumulate path;
  * the in-kernel prefix snapshots == the per-size rebuild oracle,
    bit-for-bit, with and without the col_ids permutation;
  * the streaming kernel's per-program block/scratch shapes are a pure
    function of (E_max, k, block_q, tile_c) — INDEPENDENT of Lc — and
    the VMEM model counts the merge network's doubled top-k working set
    (the CI guard);
  * the library-sharded builder + host-side merge reproduce the
    unsharded table bit-for-bit;
  * EDMConfig.knn_tile_c resolution (auto-calibrated / forced width) is
    shared by every engine and invisible in the causal map.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import EDMConfig, ccm_matrix, knn, simplex_batch
from repro.data.synthetic import dummy_brain


def _rand_V(E, L, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((E, L)), jnp.float32)


# -------------------------------------------------- merge network unit
def _merge_vs_topk_oracle(D, k, split):
    """Partition columns at `split`, top-k each part, fold through the
    merge network; must equal lax.top_k over all columns, bit for bit."""
    Lc = D.shape[1]
    ka = min(k, split)
    kb = min(k, Lc - split)
    neg_a, ia = jax.lax.top_k(-D[:, :split], ka)
    neg_b, ib = jax.lax.top_k(-D[:, split:], kb)
    mi, md = knn.merge_topk_sorted(
        ia.astype(jnp.int32), -neg_a,
        (ib + split).astype(jnp.int32), -neg_b, k,
    )
    neg_o, io = jax.lax.top_k(-D, k)
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(io))
    np.testing.assert_array_equal(np.asarray(md), np.asarray(-neg_o))


@pytest.mark.parametrize(
    "Lq,Lc,k,split",
    [
        (17, 40, 5, 13),    # k not a power of two, ragged split
        (8, 64, 21, 30),    # paper k=21 (not pow2), both parts >= k
        (9, 12, 12, 5),     # k == Lc: BOTH parts narrower than k
        (5, 30, 16, 16),    # k a power of two, exact split
        (7, 9, 8, 1),       # run list of width 1
    ],
)
def test_merge_network_vs_topk_oracle(Lq, Lc, k, split):
    rng = np.random.default_rng(Lq * 100 + Lc)
    D = jnp.asarray(rng.standard_normal((Lq, Lc)) ** 2, jnp.float32)
    _merge_vs_topk_oracle(D, k, split)


@pytest.mark.parametrize("split", [7, 24, 31])
def test_merge_network_tie_rule(split):
    """All-tied and duplicate-column distances: equal keys must resolve
    to the LOWEST candidate id (running list before tile, position
    ascending) — exactly the lax.top_k rule."""
    Lq, Lc, k = 6, 48, 9
    _merge_vs_topk_oracle(jnp.zeros((Lq, Lc), jnp.float32), k, split)
    rng = np.random.default_rng(3)
    half = jnp.asarray(rng.standard_normal((Lq, 24)) ** 2, jnp.float32)
    _merge_vs_topk_oracle(jnp.concatenate([half, half], axis=1), k, split)


def test_merge_network_keeps_sorted_invariant():
    """Merged output is sorted ascending — the invariant the running
    carry relies on across tiles."""
    rng = np.random.default_rng(11)
    D = jnp.asarray(rng.standard_normal((13, 57)) ** 2, jnp.float32)
    neg_a, ia = jax.lax.top_k(-D[:, :29], 7)
    neg_b, ib = jax.lax.top_k(-D[:, 29:], 7)
    mi, md = knn.merge_topk_sorted(
        ia.astype(jnp.int32), -neg_a, (ib + 29).astype(jnp.int32), -neg_b, 7
    )
    assert np.all(np.diff(np.asarray(md), axis=-1) >= 0)


# ------------------------------------------------------- jnp builders
@pytest.mark.parametrize(
    "Lq,Lc,E,k,exclude_self,tile_c",
    [
        (130, 130, 8, 9, True, 48),   # non-dividing tile
        (128, 128, 6, 7, True, 32),   # dividing tile
        (100, 257, 5, 6, False, 64),  # rectangular, non-dividing
        (50, 300, 5, 6, False, 300),  # single tile == library width
        (60, 60, 4, 60, True, 16),    # k == Lc (masked self selected)
    ],
)
def test_streaming_bit_identical_to_dense(Lq, Lc, E, k, exclude_self, tile_c):
    Vq = _rand_V(E, Lq, Lq * 1000 + Lc)
    Vc = Vq if exclude_self else _rand_V(E, Lc, Lc)
    i0, d0 = knn.knn_tables_dense(Vq, Vc, k, exclude_self, impl="unroll")
    i1, d1 = knn.knn_tables_all_E_streaming(
        Vq, Vc, k, exclude_self, tile_c=tile_c
    )
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("tile_c", [32, 48])  # dividing / non-dividing of 96
def test_streaming_ties_dead_and_duplicate_neurons(tile_c):
    """All-tied rows (dead series: every distance exactly 0) and duplicate
    candidates must resolve ties identically in the tiled merge and
    lax.top_k — the d1~0 simplex_weights regime from PR 2."""
    # dead neuron: constant series -> V all equal -> D == 0 everywhere
    Vdead = jnp.zeros((5, 96), jnp.float32)
    i0, d0 = knn.knn_tables_dense(Vdead, Vdead, 6, True, impl="unroll")
    i1, d1 = knn.knn_tables_all_E_streaming(Vdead, Vdead, 6, True, tile_c=tile_c)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
    # ties resolve to the LOWEST candidate id (self masked out)
    assert np.asarray(i1)[0, 0, :3].tolist() == [1, 2, 3]
    assert np.all(np.asarray(d1) == 0.0)

    # duplicate neurons: pairs of identical candidate columns
    rng = np.random.default_rng(7)
    half = jnp.asarray(rng.standard_normal((5, 48)), jnp.float32)
    Vdup = jnp.concatenate([half, half], axis=1)  # cols j and j+48 identical
    i0, d0 = knn.knn_tables_dense(Vdup, Vdup, 7, True, impl="unroll")
    i1, d1 = knn.knn_tables_all_E_streaming(Vdup, Vdup, 7, True, tile_c=tile_c)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
    # each query's zero-distance duplicate is found, lowest-id first
    assert np.all(np.asarray(d1)[:, :, 0] == 0.0)


def test_streaming_bucketed_bit_identical(tile_sizes=(33, 70, 140)):
    V = _rand_V(8, 140, 2)
    buckets = (2, 5, 8)
    i0, d0 = knn.knn_tables_bucketed_dense(V, V, 9, True, buckets)
    for tc in tile_sizes:
        i1, d1 = knn.knn_tables_bucketed_streaming(
            V, V, 9, True, buckets, tile_c=tc
        )
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_streaming_counts_table_rows():
    knn.reset_table_counters()
    V = _rand_V(6, 90, 3)
    knn.knn_tables_all_E_streaming(V, V, 7, True, tile_c=30)
    assert knn.TABLE_ROWS_BUILT["all_E"] == 6
    knn.knn_tables_bucketed_streaming(V, V, 7, True, (2, 6), tile_c=30)
    assert knn.TABLE_ROWS_BUILT["bucketed"] == 2
    knn.reset_table_counters()


def test_streaming_rejects_bad_args():
    V = _rand_V(4, 50, 4)
    with pytest.raises(ValueError, match="exceeds candidate count"):
        knn.knn_tables_all_E_streaming(V, V, 51, True, tile_c=16)
    with pytest.raises(ValueError, match="ascending"):
        knn.knn_tables_bucketed_streaming(V, V, 5, True, (3, 2), tile_c=16)


# ------------------------------------------------------ pallas kernels
@pytest.mark.parametrize(
    "E,Lq,Lc,k,exclude_self,block_q,tile_c",
    [
        (4, 100, 100, 5, True, 64, 48),    # ragged Lq tail, non-dividing tile
        (6, 128, 192, 7, False, 64, 64),   # dividing everything
        (3, 129, 257, 4, False, 64, 100),  # ragged both axes
        (5, 60, 60, 60, True, 32, 16),     # k == Lc (tile clamped up to k)
    ],
)
def test_stream_kernel_bit_identical_to_dense_oracle(
    E, Lq, Lc, k, exclude_self, block_q, tile_c
):
    from repro.kernels.knn_topk.ops import knn_topk_streaming
    from repro.kernels.knn_topk.ref import knn_topk_ref

    Vq = _rand_V(E, Lq, E * 100 + Lq)
    Vc = Vq if exclude_self else _rand_V(E, Lc, Lc + 1)
    i0, d0 = knn_topk_ref(Vq, Vc, k, exclude_self)
    i_st, d_st = knn_topk_streaming(
        Vq, Vc, k, exclude_self=exclude_self, block_q=block_q, tile_c=tile_c
    )
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i_st))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d_st))


def test_stream_kernel_vs_streaming_oracle():
    from repro.kernels.knn_topk.ops import knn_topk_streaming
    from repro.kernels.knn_topk.ref import knn_topk_stream_ref

    V = _rand_V(6, 150, 11)
    idx, d = knn_topk_streaming(V, V, 7, exclude_self=True, block_q=64, tile_c=40)
    ridx, rd = knn_topk_stream_ref(V, V, 7, exclude_self=True, tile_c=64)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(rd))


def test_stream_kernel_ties_match_dense_oracle():
    from repro.kernels.knn_topk.ops import knn_topk_streaming
    from repro.kernels.knn_topk.ref import knn_topk_ref

    V = jnp.zeros((5, 90), jnp.float32)  # dead neuron: all ties
    i0, d0 = knn_topk_ref(V, V, 6, True)
    i_st, d_st = knn_topk_streaming(V, V, 6, exclude_self=True, block_q=32, tile_c=24)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i_st))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d_st))


def test_dist_dtype_bf16_reaches_kernels():
    """EDMConfig.dist_dtype is honoured by the Pallas stream kernel (bf16
    tile accumulation, float32 merge keys): bf16 actually changes the
    numerics vs f32 (proof it reached the accumulator, not a silently
    ignored knob) while agreeing with the f32 dense oracle to bf16
    resolution (TOLERANCE oracle — bit-identity across differently-fused
    bf16 paths is not a contract: XLA's excess-precision simplification
    elides convert pairs inside fused accumulate chains, so two
    fusion contexts can round differently)."""
    from repro.kernels.knn_topk.ops import knn_topk_streaming
    from repro.kernels.knn_topk.ref import knn_topk_ref

    V = _rand_V(6, 120, 13)
    i_st, d_st = knn_topk_streaming(V, V, 7, exclude_self=True, block_q=64,
                                    tile_c=40, dist_dtype="bfloat16")
    assert d_st.dtype == jnp.float32  # merge keys / outputs stay f32
    _, d_f32 = knn_topk_ref(V, V, 7, True)
    assert not np.array_equal(np.asarray(d_f32), np.asarray(d_st))
    # bf16 distances agree with the f32 dense oracle to bf16 resolution
    np.testing.assert_allclose(
        np.asarray(d_f32), np.asarray(d_st), rtol=2e-2, atol=2e-2
    )
    # the jnp streaming builder's bf16 path holds the same tolerance
    _, d_j = knn.knn_tables_all_E_streaming(
        V, V, 7, True, tile_c=40, dist_dtype=jnp.bfloat16
    )
    np.testing.assert_allclose(
        np.asarray(d_f32), np.asarray(d_j), rtol=2e-2, atol=2e-2
    )


def test_ragged_tail_split_covers_all_queries():
    """_query_splits: full blocks + one 8-aligned tail block; outputs for
    every query row match the unsplit reference (the padded-query waste
    fix must not change results)."""
    from repro.kernels.knn_topk.knn_topk import _query_splits
    from repro.kernels.knn_topk.ops import knn_topk_streaming
    from repro.kernels.knn_topk.ref import knn_topk_ref

    assert _query_splits(256, 128) == [(0, 256, 128)]
    assert _query_splits(130, 128) == [(0, 128, 128), (128, 2, 8)]
    assert _query_splits(50, 128) == [(0, 50, 56)]
    for Lq in (130, 50, 255):
        V = _rand_V(4, Lq, Lq)
        idx, d = knn_topk_streaming(V, V, 5, exclude_self=True, block_q=128,
                                    tile_c=64)
        ridx, rd = knn_topk_ref(V, V, 5, True)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(rd))


# ------------------------------------------- in-kernel prefix snapshots
@pytest.mark.parametrize("tile_c", [16, 37, 120, 512])
def test_prefix_kernel_bit_identical_to_rebuild(tile_c):
    """The prefix-snapshot kernel (tiles clipped at library-size
    boundaries, carry emitted per boundary) == the per-size rebuild
    oracle, bit for bit, at tile widths that land inside, across, and
    beyond every segment."""
    from repro.kernels.knn_topk.ops import knn_topk_prefix

    Vq = _rand_V(5, 37, 100)
    Vc = _rand_V(5, 203, 101)
    buckets, lib_sizes = (1, 3, 5), (40, 97, 203)
    oi, od = knn.knn_tables_prefix_rebuild(
        Vq, Vc, 7, False, buckets, lib_sizes, 64
    )
    pi, pd = knn_topk_prefix(
        Vq, Vc, 7, False, buckets, lib_sizes, tile_c=tile_c
    )
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(oi))
    np.testing.assert_array_equal(np.asarray(pd), np.asarray(od))


def test_prefix_kernel_col_ids_and_self_exclusion():
    from repro.kernels.knn_topk.ops import knn_topk_prefix

    V = _rand_V(5, 96, 102)
    buckets, lib_sizes = (2, 5), (30, 96)
    rng = np.random.default_rng(9)
    cid = jnp.asarray(rng.permutation(96).astype(np.int32))
    oi, od = knn.knn_tables_prefix_rebuild(
        V, V, 6, True, buckets, lib_sizes, 32, col_ids=cid
    )
    pi, pd = knn_topk_prefix(
        V, V, 6, True, buckets, lib_sizes, tile_c=40, col_ids=cid
    )
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(oi))
    np.testing.assert_array_equal(np.asarray(pd), np.asarray(od))


def test_pallas_engine_prefix_uses_in_kernel_snapshots():
    """Engine.knn_tables_prefix on the Pallas engines routes to the
    in-kernel snapshot kernel — no per-size rebuild fallback — and stays
    bit-identical to the reference one-sweep builder (tol 0)."""
    import repro.engine as engines

    eng = engines.get_engine("pallas-interpret")
    ref = engines.get_engine("reference")
    assert type(eng).knn_tables_prefix is not engines.base.Engine.knn_tables_prefix
    V = _rand_V(4, 80, 103)
    cfg = EDMConfig(E_max=4, engine="pallas-interpret")
    kw = dict(buckets=(1, 4), lib_sizes=(25, 80), exclude_self=True, cfg=cfg)
    ei, ed = eng.knn_tables_prefix(V, V, 5, **kw)
    ri, rd = ref.knn_tables_prefix(V, V, 5, **kw)
    np.testing.assert_array_equal(np.asarray(ei), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(ed), np.asarray(rd))


# ----------------------------------------------------- CI guard: VMEM
def test_stream_kernel_blocks_independent_of_Lc():
    """CI guard: the streaming kernel's per-program block/scratch shapes
    and VMEM budget are a pure function of (E_max, k, block_q, tile_c) —
    the library length only scales the grid.  stream_block_shapes is the
    SAME function knn_topk_stream_pallas builds its BlockSpecs from."""
    from repro.kernels.knn_topk.knn_topk import (
        prefix_block_shapes,
        stream_block_shapes,
        stream_vmem_bytes,
    )

    shapes = stream_block_shapes(20, 21, 128, 512)
    import inspect
    sig = inspect.signature(stream_block_shapes)
    assert "Lc" not in sig.parameters  # shape function cannot even see Lc
    assert shapes["vc_tile"] == (20, 512)
    assert shapes["scratch_idx"] == (20, 128, 21)
    # the merge network's DOUBLED (2 * next_pow2(k)) top-k working set is
    # part of the shape contract and the VMEM model (the budget bugfix):
    # k=21 -> next_pow2 32 -> 64 merge lanes x (dist, id, rank) triples
    assert shapes["merge"] == (128, 64)
    assert stream_vmem_bytes(20, 21, 128, 512) >= (4 + 4 + 4) * 128 * 64
    assert prefix_block_shapes(20, 3, 21, 128, 512)["merge"] == (128, 64)
    # paper-scale budget: E_max=20, k=21, block_q=128 fits a 16 MB VMEM
    # with headroom at ANY library length, even at the calibrator's
    # widest 4096 tile
    assert stream_vmem_bytes(20, 21, 128, 512) < 4 * 2**20
    assert stream_vmem_bytes(20, 21, 128, 4096) < 8 * 2**20
    # the jnp streaming working-set model takes no Lc parameter either
    # (structural flatness); pin that so the model cannot silently grow
    # a hidden Lc term
    assert "Lc" not in inspect.signature(knn.streaming_bytes).parameters
    assert knn.streaming_bytes(128, 21, 512, 20) < 4 * 2**20


def test_tile_resolution_and_calibration():
    """knn_tile_c semantics: > 0 forced, 0 one-shot calibrated (widest
    power-of-two tile under the VMEM budget, clamped to the library),
    -1 (the removed dense path) a clear deprecation error."""
    assert knn.resolve_stream_tile(100, EDMConfig(knn_tile_c=64)) == 64
    auto = knn.resolve_stream_tile(1000, EDMConfig())
    assert auto == knn.calibrate_knn_tile(1000)
    # small library: the calibrated tile covers it entirely (degenerates
    # to one direct selection — no small-L regression vs a dense pass)
    assert knn.calibrate_knn_tile(1000) >= 1000
    # large library: widest tile under the budget, capped and pow2
    big = knn.calibrate_knn_tile(64000)
    assert big == knn.calibrate_knn_tile(16000)  # cap reached
    assert big & (big - 1) == 0 and knn.KNN_TILE_MIN <= big <= knn.KNN_TILE_MAX
    assert knn.streaming_bytes(128, 21, big, 20) <= knn.KNN_TILE_BUDGET_BYTES
    with pytest.raises(ValueError, match="deprecated"):
        EDMConfig(knn_tile_c=-1)
    with pytest.raises(ValueError, match="knn_tile_c"):
        EDMConfig(knn_tile_c=-2)
    class _FakeCfg:
        knn_tile_c = -1
        E_max, dist_dtype = 20, "float32"
        k_max = 21
    with pytest.raises(ValueError, match="deprecated"):
        knn.resolve_stream_tile(100, _FakeCfg())


# ------------------------------------------------- library sharding
def test_merge_shard_tables_bit_identical():
    """Per-shard top-k + host merge == unsharded table, bit for bit,
    across shard counts (including shards narrower than k)."""
    rng = np.random.default_rng(17)
    Vq = jnp.asarray(rng.standard_normal((6, 120)), jnp.float32)
    i0, d0 = knn.knn_tables_dense(Vq, Vq, 7, True, impl="unroll")
    for S in (2, 3, 5):
        shard = -(-120 // S)
        parts = [
            knn.knn_tables_all_E_streaming(
                Vq, Vq[:, s * shard : min((s + 1) * shard, 120)],
                min(7, shard), True, tile_c=16,
                col_offset=s * shard, col_hi=min((s + 1) * shard, 120),
            )
            for s in range(S)
        ]
        mi, md = knn.merge_shard_tables(
            [p[0] for p in parts], [p[1] for p in parts], k=7
        )
        np.testing.assert_array_equal(mi, np.asarray(i0))
        np.testing.assert_array_equal(md, np.asarray(d0))


def test_merge_topk_tree_bit_identical_vs_oracle():
    """The DEVICE-side tree merge (DESIGN.md SS14) == the host lexsort
    oracle == the unsharded table — idx AND f32 dists, ties included —
    across shard counts (pow2 and not, shards narrower than k) and at
    the k == Lc exclude-self edge where +inf masked entries reach the
    final table."""
    rng = np.random.default_rng(17)
    V = rng.standard_normal((6, 120)).astype(np.float32)
    # duplicate columns across future shard boundaries force exact
    # cross-shard distance ties — the (distance, id) rule must decide
    V[:, 50] = V[:, 10]
    V[:, 90] = V[:, 10]
    V[:, 91] = V[:, 33]
    Vq = jnp.asarray(V)
    for k in (7, 120):  # 120 == Lc: one masked +inf (self) entry survives
        i0, d0 = knn.knn_tables_all_E_streaming(Vq, Vq, k, True, tile_c=32)
        for S in (2, 3, 4, 5):
            shard = -(-120 // S)
            parts = [
                knn.knn_tables_all_E_streaming(
                    Vq, Vq[:, s * shard : min((s + 1) * shard, 120)],
                    min(k, shard, 120 - s * shard), True, tile_c=16,
                    col_offset=s * shard, col_hi=min((s + 1) * shard, 120),
                )
                for s in range(S)
            ]
            ti, td = knn.merge_topk_tree(
                [p[0] for p in parts], [p[1] for p in parts], k
            )
            oi, od = knn.merge_shard_tables(
                [p[0] for p in parts], [p[1] for p in parts], k=k
            )
            np.testing.assert_array_equal(np.asarray(ti), oi)
            np.testing.assert_array_equal(np.asarray(td), od)
            np.testing.assert_array_equal(np.asarray(ti), np.asarray(i0))
            np.testing.assert_array_equal(np.asarray(td), np.asarray(d0))


@pytest.mark.parametrize("engine_name", ["reference", "pallas-interpret"])
def test_merge_tree_on_engine_tables(engine_name):
    """Acceptance bit (DESIGN.md SS14): the device-side merge is
    bit-identical to the merge_shard_tables oracle on per-shard tables
    built by BOTH the jnp and the Pallas engines, for >= 2 shard
    counts."""
    from repro import engine

    eng = engine.get_engine(engine_name)
    rng = np.random.default_rng(29)
    V = rng.standard_normal((4, 96)).astype(np.float32)
    V[:, 64] = V[:, 3]  # cross-shard tie
    Vq = jnp.asarray(V)
    cfg = EDMConfig(E_max=4)
    k = 6
    u_i, u_d = eng.knn_tables(Vq, Vq, k, exclude_self=False, cfg=cfg)
    for S in (2, 4):
        shard = 96 // S
        idx_p, d_p = [], []
        for s in range(S):
            li, ld = eng.knn_tables(
                Vq, Vq[:, s * shard : (s + 1) * shard], min(k, shard),
                exclude_self=False, cfg=cfg,
            )
            idx_p.append(li + s * shard)  # local -> global candidate ids
            d_p.append(ld)
        ti, td = knn.merge_topk_tree(idx_p, d_p, k)
        oi, od = knn.merge_shard_tables(idx_p, d_p, k=k)
        np.testing.assert_array_equal(np.asarray(ti), oi)
        np.testing.assert_array_equal(np.asarray(td), od)
        np.testing.assert_array_equal(np.asarray(ti), np.asarray(u_i))
        np.testing.assert_array_equal(np.asarray(td), np.asarray(u_d))


def test_library_sharded_pipeline_builder():
    """The shard_map-backed builder (local mesh) == dense-oracle table,
    and — the SS14 bugfix — it returns DEVICE arrays (no host np
    round-trip on the collective path)."""
    import jax

    from repro.core.pipeline import knn_tables_library_sharded

    Vq = _rand_V(5, 110, 23)
    cfg = EDMConfig(E_max=5)
    mi, md = knn_tables_library_sharded(Vq, Vq, 6, cfg, exclude_self=True)
    assert isinstance(mi, jax.Array) and isinstance(md, jax.Array)
    i0, d0 = knn.knn_tables_dense(Vq, Vq, 6, True, impl="unroll")
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(i0))
    np.testing.assert_array_equal(np.asarray(md), np.asarray(d0))


def test_library_sharded_sim_path():
    """The simulated-shard path (sequential per-shard builds + the same
    device tree merge; used by benchmarks/CI on few devices) matches the
    unsharded table bit-for-bit across shard counts."""
    from repro.core.pipeline import knn_tables_library_sharded_sim

    Vq = _rand_V(5, 110, 23)
    cfg = EDMConfig(E_max=5)
    i0, d0 = knn.knn_tables_dense(Vq, Vq, 6, True, impl="unroll")
    for S in (2, 3, 4):
        si, sd = knn_tables_library_sharded_sim(
            Vq, Vq, 6, cfg, exclude_self=True, shards=S
        )
        np.testing.assert_array_equal(np.asarray(si), np.asarray(i0))
        np.testing.assert_array_equal(np.asarray(sd), np.asarray(d0))


def test_library_sharded_multi_device():
    """4 fake devices: each selects over its candidate shard and the
    DEVICE-side collective (ppermute butterfly at W=4, all_gather fold
    at W=3) reproduces the unsharded table bit-for-bit (subprocess — the
    in-process suite must see the real single CPU device)."""
    import os
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import EDMConfig, knn
        from repro.core.pipeline import knn_tables_library_sharded

        assert len(jax.devices()) == 4
        rng = np.random.default_rng(31)
        Vq = jnp.asarray(rng.standard_normal((5, 130)), jnp.float32)
        cfg = EDMConfig(E_max=5, knn_tile_c=16)  # force a narrow tile
        i0, d0 = knn.knn_tables_dense(Vq, Vq, 6, True, impl="unroll")
        # W=4: power-of-two ppermute butterfly; device arrays out
        mi, md = knn_tables_library_sharded(Vq, Vq, 6, cfg, exclude_self=True)
        assert isinstance(mi, jax.Array) and isinstance(md, jax.Array)
        np.testing.assert_array_equal(np.asarray(mi), np.asarray(i0))
        np.testing.assert_array_equal(np.asarray(md), np.asarray(d0))
        # W=3: non-power-of-two all_gather + tree fold
        mesh3 = jax.make_mesh((3,), ("workers",), devices=jax.devices()[:3])
        mi, md = knn_tables_library_sharded(
            Vq, Vq, 6, cfg, exclude_self=True, mesh=mesh3)
        np.testing.assert_array_equal(np.asarray(mi), np.asarray(i0))
        np.testing.assert_array_equal(np.asarray(md), np.asarray(d0))
        print("sharded-4dev collective == unsharded: OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=600, cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


# --------------------------------------------------- engine routing
@pytest.mark.parametrize("engine", ["reference", "pallas-interpret"])
def test_causal_map_invariant_under_knn_tile(engine):
    """Auto-calibrated and forced tiles (dividing and non-dividing)
    produce the SAME causal map on both engines — the acceptance bit."""
    ts = jnp.asarray(dummy_brain(10, 260, seed=21))
    _, optE = simplex_batch(ts, EDMConfig(E_max=4))
    rho_auto = np.asarray(
        ccm_matrix(ts, optE, EDMConfig(E_max=4, engine=engine))
    )
    for tile in (32, 37):  # divides / does not divide Lp
        rho_t = np.asarray(
            ccm_matrix(
                ts, optE, EDMConfig(E_max=4, engine=engine, knn_tile_c=tile)
            )
        )
        np.testing.assert_array_equal(rho_auto, rho_t)


def test_phase1_invariant_under_knn_tile():
    """Phase 1 (simplex sweep) also routes through the streaming builders
    unchanged: optE and rhos identical under any forced tile width."""
    ts = jnp.asarray(dummy_brain(8, 240, seed=29))
    r0, e0 = simplex_batch(ts, EDMConfig(E_max=4))
    r1, e1 = simplex_batch(ts, EDMConfig(E_max=4, knn_tile_c=41))
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))
