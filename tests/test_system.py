"""End-to-end behaviour tests for the whole system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_full_pipeline_recovers_network(tmp_path):
    """Synthetic 'brain' -> distributed pipeline -> causal map separates
    true edges from non-edges (AUC check) — the paper's scientific claim
    at miniature scale."""
    from repro.core.pipeline import run_causal_inference
    from repro.core.types import EDMConfig
    from repro.data.synthetic import logistic_network

    ts, adj = logistic_network(14, 400, density=0.15, strength=0.3, seed=9)
    out = run_causal_inference(ts, EDMConfig(E_max=5), out_dir=str(tmp_path / "o"))
    rho = out.rho.T  # rho[dst, src] -> score for edge src->dst
    mask = ~np.eye(14, dtype=bool)
    pos, neg = rho[adj], rho[(~adj) & mask]
    # rank-based AUC
    allv = np.concatenate([pos, neg])
    order = allv.argsort().argsort()
    auc = (order[: len(pos)].mean() + 1 - (len(pos) + 1) / 2) / len(neg)
    assert auc > 0.7, f"AUC {auc}"


def test_train_lm_end_to_end_loss_decreases():
    """~100M-class arch (smoke width) trained for 30 steps on a synthetic
    stream: loss must drop materially from ln(V)."""
    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import TokenStream
    from repro.launch.steps import TrainState, make_train_step

    cfg = get_config("smollm-135m", smoke=True)
    tc = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=30, remat=False)
    state = TrainState.create(cfg, tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tc))
    # narrow token range -> learnable unigram structure
    stream = TokenStream(64, 4, 32, seed=0)
    losses = []
    for i in range(30):
        state, m = step(state, stream.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_serve_greedy_decode_runs():
    """Prefill + 8 greedy decode steps with the serving API."""
    from repro.configs import get_config
    from repro.models import transformer as T

    cfg = get_config("qwen2-1.5b", smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    cache = T.init_cache(cfg, B, S + 8, dtype=jnp.float32)
    logits, cache = T.prefill(params, {"tokens": toks}, cache, cfg, remat=False)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    outs = []
    for t in range(8):
        logits, cache = T.decode_step(
            params, {"token": tok, "pos": jnp.asarray(S + t, jnp.int32)}, cache, cfg
        )
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outs.append(int(tok[0, 0]))
    assert len(outs) == 8
    assert all(0 <= t < cfg.padded_vocab for t in outs)
