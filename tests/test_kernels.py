"""Pallas kernel validation (interpret mode on CPU) vs pure-jnp oracles:
shape/dtype sweeps and equivalence of the full kernel-backed CCM row
against the reference path (hypothesis property tests:
tests/test_properties.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ccm_lookup.ops import ccm_lookup
from repro.kernels.ccm_lookup.ref import ccm_lookup_ref
from repro.kernels.knn_topk.ops import knn_topk_streaming
from repro.kernels.knn_topk.ref import knn_topk_ref


@pytest.mark.parametrize(
    "E_max,Lq,Lc,k,exclude_self,tile_c",
    [
        (1, 64, 64, 2, False, 32),
        (4, 100, 100, 5, True, 48),
        (6, 200, 150, 7, False, 64),
        (3, 129, 257, 4, False, 96),  # non-multiple of block/tile sizes
        (8, 50, 300, 9, False, 512),  # tile wider than the library
        (20, 130, 130, 21, True, 64),  # paper-scale E_max and k
    ],
)
def test_knn_topk_streaming_vs_oracle(E_max, Lq, Lc, k, exclude_self, tile_c):
    """The streaming (candidate-tiled, Lc-independent VMEM) kernel against
    the dense lax.top_k oracle — bit-identical indices at every tile
    width; full tie/merge coverage is in test_knn_streaming.py."""
    rng = np.random.default_rng(E_max * 1000 + Lq)
    Vq = jnp.asarray(rng.standard_normal((E_max, Lq)), jnp.float32)
    Vc = Vq if exclude_self else jnp.asarray(
        rng.standard_normal((E_max, Lc)), jnp.float32
    )
    idx, d = knn_topk_streaming(
        Vq, Vc, k, exclude_self=exclude_self, block_q=64, tile_c=tile_c
    )
    ridx, rd = knn_topk_ref(Vq, Vc, k, exclude_self)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), rtol=1e-5, atol=1e-5)


def test_knn_topk_sorted_and_self_excluded():
    rng = np.random.default_rng(7)
    V = jnp.asarray(rng.standard_normal((4, 90)), jnp.float32)
    idx, d = knn_topk_streaming(V, V, 5, exclude_self=True, tile_c=32)
    d = np.asarray(d)
    idx = np.asarray(idx)
    assert np.all(np.diff(d, axis=-1) >= -1e-6)  # ascending distances
    rows = np.arange(90)
    for e in range(4):
        assert not np.any(idx[e] == rows[:, None])  # self never a neighbour


@pytest.mark.parametrize("B,Lq,Lp,k", [(1, 50, 80, 3), (37, 200, 300, 9), (64, 256, 256, 21)])
def test_ccm_lookup_vs_oracle(B, Lq, Lp, k):
    rng = np.random.default_rng(B)
    idx = jnp.asarray(rng.integers(0, Lp, size=(Lq, k)), jnp.int32)
    w = jnp.asarray(rng.uniform(size=(Lq, k)), jnp.float32)
    Y = jnp.asarray(rng.standard_normal((B, Lp)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ccm_lookup(idx, w, Y, block_b=16, block_t=64)),
        np.asarray(ccm_lookup_ref(idx, w, Y)),
        rtol=1e-5, atol=1e-6,
    )


def test_kernel_backed_ccm_row_matches_reference(small_network):
    """engine='pallas-interpret' routes tables + lookup through the Pallas
    kernels; the causal map must match the reference engine."""
    from repro.core import EDMConfig, ccm_matrix, simplex_batch

    ts, _ = small_network
    ts = jnp.asarray(ts)
    _, optE = simplex_batch(ts, EDMConfig(E_max=4))
    rho_ref = ccm_matrix(ts, optE, EDMConfig(E_max=4, engine="reference"))
    rho_ker = ccm_matrix(ts, optE, EDMConfig(E_max=4, engine="pallas-interpret"))
    np.testing.assert_allclose(
        np.asarray(rho_ref), np.asarray(rho_ker), rtol=1e-5, atol=1e-5
    )


# ------------------------------------------------------------- flash_attn
@pytest.mark.parametrize(
    "B,Sq,Sk,H,K,dh,causal,bq,bk",
    [
        (2, 128, 128, 4, 2, 64, True, 64, 64),
        (1, 256, 256, 6, 6, 32, True, 128, 128),
        (2, 64, 64, 8, 4, 16, False, 32, 32),
        (1, 96, 96, 2, 1, 8, True, 32, 32),  # non-power-of-two seq
    ],
)
def test_flash_attn_vs_oracle(B, Sq, Sk, H, K, dh, causal, bq, bk):
    from repro.kernels.flash_attn.ops import flash_attn
    from repro.kernels.flash_attn.ref import flash_attn_ref

    rng = np.random.default_rng(Sq + H)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sk, K, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sk, K, dh)), jnp.float32)
    o = flash_attn(q, k, v, causal=causal, block_q=bq, block_k=bk)
    r = flash_attn_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-5, atol=2e-5)


def test_flash_attn_matches_model_sdpa():
    """The kernel's numerics contract == the model's dense/chunked paths."""
    from repro.kernels.flash_attn.ops import flash_attn
    from repro.models.layers import _sdpa_chunked, _sdpa_dense

    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 2, 32)), jnp.float32)
    a = _sdpa_dense(q, k, v, causal=True)
    b = _sdpa_chunked(q, k, v, causal=True, chunk=64)
    c = flash_attn(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-5, atol=2e-5)


def test_knn_impl_variants_agree():
    """scan / unroll / blocked:g dense-oracle variants produce identical
    tables (SSPerf HC3)."""
    from repro.core.knn import knn_tables_dense

    rng = np.random.default_rng(3)
    V = jnp.asarray(rng.standard_normal((8, 150)), jnp.float32)
    i0, d0 = knn_tables_dense(V, V, 9, True, impl="scan")
    for impl in ("unroll", "blocked:4", "blocked:2"):
        i1, d1 = knn_tables_dense(V, V, 9, True, impl=impl)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-6, atol=1e-8)
