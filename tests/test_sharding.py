"""Sharding policy unit tests: every generated PartitionSpec must divide
its dimension, batch/cache specs must degrade to replication gracefully,
and the multi-device integration tests (subprocess with fake devices)
verify sharded == unsharded numerics."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import shape_cell

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh_axis_sizes():
    return {"data": 16, "model": 16}


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}
    size = 256


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divide_dimensions(arch):
    """For every full-size arch, each sharded dim must be divisible by the
    product of its assigned mesh axes (no silent GSPMD padding)."""
    from repro.models import transformer as T
    from repro.sharding import policy as POL

    cfg = get_config(arch)
    pol = POL.ShardingPolicy(mesh=FakeMesh(), fsdp=True)
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    specs = POL.param_specs(pol, shapes)

    def check(path, leaf, spec):
        assert len(spec) <= leaf.ndim
        for dim, ax in zip(leaf.shape[: len(spec)], spec):
            if ax is None:
                continue
            size = pol.axis_size(ax)
            assert dim % size == 0, (path, leaf.shape, spec)

    for (path, leaf), (_, spec) in zip(
        jax.tree_util.tree_flatten_with_path(shapes)[0],
        jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )[0],
    ):
        check(path, leaf, spec)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("cell", ["decode_32k", "long_500k"])
def test_cache_specs_divide(arch, cell):
    from repro.configs import cell_applicable
    from repro.models import transformer as T
    from repro.sharding import policy as POL

    cfg = get_config(arch)
    c = shape_cell(cell)
    if not cell_applicable(cfg, c)[0]:
        pytest.skip("cell not applicable")
    pol = POL.ShardingPolicy(mesh=FakeMesh(), fsdp=False)
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, c.global_batch, c.seq_len)
    )
    specs = POL.cache_specs_tree(pol, cache, cfg)
    for (_, leaf), (_, spec) in zip(
        jax.tree_util.tree_flatten_with_path(cache)[0],
        jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )[0],
    ):
        for dim, ax in zip(leaf.shape[: len(spec)], spec):
            if ax is not None:
                assert dim % pol.axis_size(ax) == 0, (leaf.shape, spec)


def _run_subprocess(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.mark.slow
def test_edm_pipeline_sharded_equals_single_device():
    """8 fake workers vs 1: identical causal maps (SPMD decomposition is
    numerics-preserving)."""
    _run_subprocess("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core.pipeline import run_causal_inference
        from repro.core import EDMConfig, simplex_batch, ccm_matrix
        from repro.data.synthetic import logistic_network
        ts, _ = logistic_network(16, 200, density=0.2, strength=0.25, seed=5)
        cfg = EDMConfig(E_max=4, lib_block=2)
        out = run_causal_inference(ts, cfg)  # 8-worker mesh
        _, optE = simplex_batch(jnp.asarray(ts), cfg)
        ref = np.asarray(ccm_matrix(jnp.asarray(ts), optE, cfg))
        assert np.array_equal(out.rho, ref), np.abs(out.rho - ref).max()
        print("sharded == single-device: OK")
    """)


@pytest.mark.slow
def test_lm_train_step_sharded_equals_single_device():
    """One train step under a (2 data, 2 model) mesh == unsharded step."""
    _run_subprocess("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.launch.steps import TrainState, make_train_step
        from repro.sharding import policy as POL
        from repro.data.pipeline import TokenStream

        cfg = get_config("qwen2-1.5b", smoke=True)
        tc = TrainConfig(remat=False, lr=1e-3, warmup_steps=1, total_steps=5)
        state = TrainState.create(cfg, tc, jax.random.PRNGKey(0))
        batch = jax.tree.map(jnp.asarray, TokenStream(cfg.vocab_size, 4, 16, 0).batch_at(0))
        ref_state, ref_metrics = jax.jit(make_train_step(cfg, tc))(state, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        pol = POL.ShardingPolicy(mesh=mesh, fsdp=True)
        p_specs = POL.param_specs(pol, state.params)
        from repro.launch.dryrun import _opt_specs
        st_specs = TrainState(params=p_specs,
                              opt=_opt_specs(pol, p_specs, state.params, tc),
                              step=P())
        named = jax.tree.map(lambda s: NamedSharding(mesh, s), st_specs)
        state_sh = jax.device_put(state, named)
        b_specs = POL.batch_specs(pol, batch, "train")
        batch_sh = jax.device_put(batch, jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs))
        with mesh:
            out_state, metrics = jax.jit(make_train_step(cfg, tc),
                                         in_shardings=(named, None))(state_sh, batch_sh)
        np.testing.assert_allclose(float(metrics["loss"]), float(ref_metrics["loss"]), rtol=2e-5)
        for a, b in zip(jax.tree.leaves(ref_state.params), jax.tree.leaves(out_state.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)
        print("sharded train step == unsharded: OK")
    """)


@pytest.mark.slow
def test_compressed_psum_matches_mean_grad():
    """int8 psum with error feedback approximates the exact DP mean-grad."""
    _run_subprocess("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim import grad_compress as GC

        mesh = jax.make_mesh((8,), ("data",))
        g = jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)), jnp.float32)
        err = jnp.zeros((8, 64), jnp.float32)

        def body(g_loc, e_loc):
            m, ne = GC.compressed_psum(g_loc[0], e_loc[0], ("data",))
            return m[None], ne[None]

        f = shard_map(body, mesh=mesh, in_specs=(P("data", None), P("data", None)),
                      out_specs=(P("data", None), P("data", None)), check_rep=False)
        with mesh:
            mean_c, _ = f(g, err)
        exact = g.mean(0)
        # every worker sees the same compressed mean, close to exact
        mc = np.asarray(mean_c)
        assert np.allclose(mc, mc[0], atol=1e-6)
        np.testing.assert_allclose(mc[0], np.asarray(exact), atol=0.05)
        print("compressed psum OK")
    """)
