"""Hypothesis property tests (embedding invariants, weight distributions,
kernel oracles, quantization bounds).

hypothesis is an OPTIONAL dev dependency (requirements-dev.txt): this
module is skipped wholesale when it is absent so the rest of the tier-1
suite still collects and runs (the seed hard-imported hypothesis from
three modules, erroring collection everywhere).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402


# ---------------------------------------------------------------- embedding
@given(
    E=st.integers(1, 6),
    tau=st.integers(1, 3),
    L=st.integers(40, 120),
)
@settings(max_examples=15, deadline=None)
def test_embedding_point_invariant(E, tau, L):
    """Every embedded point's coordinates are exact series values."""
    from repro.core import delay_embed

    rng = np.random.default_rng(E * 100 + tau)
    x = rng.standard_normal(L).astype(np.float32)
    Lp = L - (E - 1) * tau
    emb = np.asarray(delay_embed(jnp.asarray(x), E, tau))
    t = rng.integers(0, Lp)
    p = t + (E - 1) * tau
    np.testing.assert_array_equal(emb[t], x[[p - k * tau for k in range(E)]])


# ------------------------------------------------------------------ weights
@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_simplex_weights_are_a_distribution(seed):
    from repro.core import simplex_weights

    rng = np.random.default_rng(seed)
    k = rng.integers(2, 22)
    d = np.sort(rng.uniform(0, 10, size=(4, k)).astype(np.float32), axis=-1)
    w = np.asarray(simplex_weights(jnp.asarray(d**2), k))
    assert np.all(w >= 0)
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-5)
    # nearest neighbour never gets less weight than the farthest
    assert np.all(w[:, 0] + 1e-6 >= w[:, -1])


# ------------------------------------------------------------------ kernels
@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_knn_topk_property(seed):
    from repro.kernels.knn_topk.ops import knn_topk_streaming
    from repro.kernels.knn_topk.ref import knn_topk_ref

    rng = np.random.default_rng(seed)
    E_max = int(rng.integers(1, 8))
    Lq = int(rng.integers(16, 150))
    Lc = int(rng.integers(E_max + 3, 150))
    k = int(rng.integers(1, min(8, Lc - 1)))
    tile_c = int(rng.integers(8, 150))
    Vq = jnp.asarray(rng.standard_normal((E_max, Lq)), jnp.float32)
    Vc = jnp.asarray(rng.standard_normal((E_max, Lc)), jnp.float32)
    idx, d = knn_topk_streaming(Vq, Vc, k, block_q=32, tile_c=tile_c)
    ridx, rd = knn_topk_ref(Vq, Vc, k, False)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(rd))


# ------------------------------------------------------------- optimization
@given(st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error_bound(seed):
    from repro.optim import grad_compress

    g = jnp.asarray(np.random.default_rng(seed).standard_normal(64), jnp.float32)
    q, scale = grad_compress.quantize(g)
    err = jnp.abs(grad_compress.dequantize(q, scale) - g)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6
