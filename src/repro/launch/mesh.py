"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model) — the pod axis is an
outer data-parallel axis by default (the EDM pipeline flattens all axes into
one worker grid, matching the paper's 512 nodes).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(n: int | None = None, model: int = 1):
    """Small mesh over the local (possibly fake) CPU devices, for tests."""
    n = n or len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"


def flat_axes(mesh) -> tuple[str, ...]:
    """All axes — the EDM pipeline's flat worker grid."""
    return tuple(mesh.axis_names)
