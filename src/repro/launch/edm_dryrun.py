"""EDM pipeline dry-run: lower + compile the CCM chunk step for the
production mesh at the paper's dataset scales (Table I), ShapeDtypeStruct
only.

Cost extrapolation (cost_analysis counts loop bodies once):
the chunk function has two sequential loops — the per-tile unrolled loop
over embedding dimensions E (knn_tables_all_E_streaming) and the lax.map
over target blocks (ccm_library_row).  Cost is affine:
c(E, t) = b + E*e + t*l.
Three compiles at (E,t) = (1,1), (2,1), (2,2) identify e, l, b; the full
cell is b + E_max*e + n_tb*l.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.edm_datasets import DATASETS
from repro.core.pipeline import make_ccm_chunk_fn
from repro.core.types import EDMConfig
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh


def _lower_chunk(mesh, cfg: EDMConfig, chunk: int, N: int, L: int):
    Lp = cfg.n_points(L)
    fn = make_ccm_chunk_fn(mesh, cfg)
    args = (
        jax.ShapeDtypeStruct((chunk, L), jnp.float32),
        jax.ShapeDtypeStruct((N, Lp), jnp.float32),
        jax.ShapeDtypeStruct((N,), jnp.int32),
    )
    with mesh:
        return fn.lower(*args)


def _cost(compiled) -> dict:
    rl = RL.from_compiled(compiled)
    return {
        "flops": rl.flops_per_chip,
        "bytes": rl.bytes_per_chip,
        **{f"coll:{k}": v for k, v in rl.coll_by_kind.items()},
    }


def lower_edm_cell(dataset: str, multi_pod: bool = False, cfg: EDMConfig | None = None):
    ds = DATASETS[dataset]
    cfg = cfg or ds.edm
    mesh = make_production_mesh(multi_pod=multi_pod)
    chunk = mesh.size * cfg.lib_block
    N, L = ds.n_time_series, ds.n_time_steps

    t0 = time.time()
    lowered = _lower_chunk(mesh, cfg, chunk, N, L)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    # Cost extrapolation: with target_block = N the lookup lax.map has trip
    # count 1, so cost_analysis counts it EXACTLY; only the scan over E
    # (counted once) needs scaling.  The scan unit is 1 E for scan/unroll
    # impls and g Es for blocked:g — compile at E_max = unit and 2*unit:
    # total = c(unit) + (E_max/unit - 1) * (c(2*unit) - c(unit)).
    unit = 1
    if cfg.knn_impl.startswith("blocked"):
        unit = int(cfg.knn_impl.split(":")[1]) if ":" in cfg.knn_impl else 4
    k_pin = cfg.k_max  # production table width, pinned across reduced-E compiles
    c1 = _cost(_lower_chunk(mesh, dataclasses.replace(cfg, E_max=unit, target_block=N, k_override=k_pin), chunk, N, L).compile())
    c2 = _cost(_lower_chunk(mesh, dataclasses.replace(cfg, E_max=2 * unit, target_block=N, k_override=k_pin), chunk, N, L).compile())
    e_body = {k: c2[k] - c1[k] for k in c1}
    cost = {k: c1[k] + (cfg.E_max // unit - 1) * e_body[k] for k in c1}
    coll = {k.split(":", 1)[1]: v for k, v in cost.items() if k.startswith("coll:")}
    rl = RL.Roofline(
        flops_per_chip=cost["flops"],
        bytes_per_chip=cost["bytes"],
        coll_bytes_per_chip=float(sum(coll.values())),
        coll_by_kind=coll,
    )

    n_chunks = -(-N // chunk)
    # whole-run roofline terms = per-chunk terms x number of chunks
    return {
        "arch": f"edm-{dataset}",
        "cell": f"ccm_N{N}_L{L}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": mesh.size,
        "chunk_rows": chunk,
        "n_chunks": n_chunks,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes_per_device": int(
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
            ),
        },
        "roofline": rl.to_dict(),
        "roofline_whole_run": {
            "t_compute_s": rl.t_compute * n_chunks,
            "t_memory_s": rl.t_memory * n_chunks,
            "t_collective_s": rl.t_collective * n_chunks,
        },
    }
