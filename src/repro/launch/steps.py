"""Step functions: train_step / prefill_step / decode step builders.

These are the units the launcher jits and the dry-run lowers: one function
per (arch x shape-kind), closed over ModelConfig/TrainConfig, taking only
arrays (state, batch, cache) so in_shardings map 1:1.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import transformer as T
from repro.optim import adafactor, adamw
from repro.optim.schedule import make_schedule


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array

    @staticmethod
    def create(cfg: ModelConfig, tc: TrainConfig, key) -> "TrainState":
        params = T.init_params(cfg, key)
        if tc.optimizer == "adamw":
            opt = adamw.init(params, moment_dtype=jnp.dtype(tc.moment_dtype))
        else:
            opt = adafactor.init(params)
        return TrainState(params=params, opt=opt, step=jnp.zeros((), jnp.int32))


def _opt_mod(tc: TrainConfig):
    return {"adamw": adamw, "adafactor": adafactor}[tc.optimizer]


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    sched = make_schedule(tc.schedule, tc.lr, tc.warmup_steps, tc.total_steps)
    opt = _opt_mod(tc)

    def loss_of(params, batch):
        return T.loss_fn(params, batch, cfg, tc)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if tc.microbatch > 0:
            grads, (loss, metrics) = _accumulated_grads(loss_of, state.params, batch, tc.microbatch)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                state.params, batch
            )
        grads, gnorm = adamw.clip_by_global_norm(grads, tc.grad_clip)
        lr = sched(state.step)
        if tc.optimizer == "adamw":
            new_params, new_opt = adamw.update(
                grads, state.opt, state.params, lr, weight_decay=tc.weight_decay
            )
        else:
            new_params, new_opt = adafactor.update(
                grads, state.opt, state.params, lr, weight_decay=tc.weight_decay
            )
        metrics = {**metrics, "loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def _accumulated_grads(loss_of, params, batch, microbatch: int):
    """Gradient accumulation: scan over micro-batches (batch axis 0 split)."""
    B = jax.tree.leaves(batch)[0].shape[0]
    assert B % microbatch == 0
    n_micro = B // microbatch
    mb = jax.tree.map(
        lambda x: x.reshape((n_micro, microbatch) + x.shape[1:]), batch
    )

    def body(carry, micro):
        g_acc, l_acc = carry
        (loss, metrics), g = jax.value_and_grad(loss_of, has_aux=True)(params, micro)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
        return (g_acc, l_acc + loss), metrics

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (g_sum, l_sum), metrics = jax.lax.scan(body, (g0, 0.0), mb)
    grads = jax.tree.map(lambda g: g / n_micro, g_sum)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return grads, (l_sum / n_micro, metrics)


def make_prefill_step(cfg: ModelConfig, policy=None):
    """policy: optional ShardingPolicy — constrains the internally-created
    cache (decode layout: seq sharded on the model axis) so GSPMD does not
    have to guess its placement from the write pattern."""

    def prefill_step(params, batch):
        B, S = batch["tokens"].shape
        cache = T.init_cache(cfg, B, S)
        if policy is not None:
            from jax.sharding import NamedSharding

            from repro.sharding.policy import cache_specs_tree

            specs = cache_specs_tree(policy, cache, cfg)
            cache = jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, NamedSharding(policy.mesh, s)
                ),
                cache, specs,
            )
        return T.prefill(params, batch, cache, cfg, remat=False)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, batch, cache):
        return T.decode_step(params, batch, cache, cfg)

    return decode_step
