import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend init, and the dry-run needs 512 placeholder CPU
# devices to build the production meshes (16x16 and 2x16x16).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct stand-ins (no allocation) and record memory / cost /
collective-roofline analysis.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --cell train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]   # every applicable cell
  python -m repro.launch.dryrun --edm subject11       # the EDM pipeline cell
Results are appended to benchmarks/results/dryrun/<name>.json.
"""
import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    ARCHS,
    cell_applicable,
    get_config,
    input_specs,
    shape_cell,
)
from repro.configs.base import SHAPE_CELLS, TrainConfig
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    TrainState,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.models import transformer as T
from repro.sharding import policy as POL

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# Giant MoE archs train with Adafactor (factored moments) — DESIGN.md SS6.
_ADAFACTOR_ARCHS = {"dbrx-132b", "grok-1-314b"}


def optimized_cfg(cfg, cell):
    """Beyond-paper optimized configuration (SSPerf): chunked flash-in-XLA
    attention (with per-chunk remat), sequence-parallel attention for
    prefill of indivisible-head archs, last-position-only serving prefill,
    tighter SSD chunks, bf16 Adam moments."""
    kw = {}
    if cfg.n_heads > 0:
        kw["attn_impl"] = "chunked"
        kw["attn_chunk"] = 1024
        # seq-parallel attention: a prefill win for archs whose heads don't
        # divide TP-16; in training its backward all-gathers outweigh the
        # savings (measured: qwen2-1.5b train 0.3x) — prefill only.
        if cfg.n_heads % 16 != 0 and cell.kind == "prefill":
            kw["attn_seq_shard"] = True
    if cfg.ssm_state > 0 and cell.kind == "train":
        kw["ssm_chunk"] = 64  # halves the SSD decay-matrix footprint
    if cell.kind == "prefill":
        kw["prefill_last_only"] = True
    return dataclasses.replace(cfg, **kw)


def optimized_policy_kw(cfg, cell) -> dict:
    """Sub-1B archs at train_4k: replicate weights, use the model axis as
    extra batch parallelism (TP only replicates their attention compute).
    Serving cells keep TP: their global batch (32/128) does not divide the
    256-way grid — dp_only would replicate the whole batch per device
    (measured: whisper prefill 135x REGRESSION before this guard)."""
    from repro.sharding.policy import estimate_params

    if (
        cell.kind == "train"
        and cell.global_batch % 256 == 0
        and estimate_params(cfg) < 1_000_000_000
    ):
        return {"dp_only": True, "fsdp": False}
    return {}


def train_config_for(arch: str) -> TrainConfig:
    return TrainConfig(
        optimizer="adafactor" if arch in _ADAFACTOR_ARCHS else "adamw",
        schedule="wsd" if arch == "minicpm-2b" else "cosine",
        remat=True,
    )


def optimized_train_config_for(arch: str) -> TrainConfig:
    return dataclasses.replace(train_config_for(arch), moment_dtype="bfloat16")


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def _opt_specs(policy, p_specs, p_shapes, tc: TrainConfig):
    if tc.optimizer == "adamw":
        return {
            "m": p_specs,
            "v": p_specs,
            "count": P(),
        }
    # adafactor: factored accumulators drop one dim of the param spec
    def acc_spec(spec, shape):
        if len(shape.shape) >= 2:
            return {
                "vr": P(*spec[:-1]),
                "vc": P(*(list(spec[:-2]) + [spec[-1]])),
            }
        return {"v": spec}

    return {
        "acc": jax.tree.map(
            acc_spec, p_specs, p_shapes,
            is_leaf=lambda x: isinstance(x, P),
        ),
        "count": P(),
    }


def _unit_layers(cfg) -> int:
    """Layers per repeating unit (for depth-reduced cost extrapolation)."""
    if cfg.family == "hybrid":
        return len(cfg.hybrid_pattern)
    if cfg.family == "vlm":
        return cfg.cross_attn_period
    return 1


def _depth_cfg(cfg, units: int, scan: bool):
    """Config with `units` repeating units and optionally unrolled layers."""
    kw = {"n_layers": units * _unit_layers(cfg), "scan_layers": scan}
    if cfg.family == "audio":
        kw["n_enc_layers"] = units
        kw["n_layers"] = units
    return dataclasses.replace(cfg, **kw)


def _n_units(cfg) -> int:
    if cfg.family == "audio":
        return cfg.n_layers  # enc and dec scale together
    return cfg.n_layers // _unit_layers(cfg)


def _build_lowered(cfg, cell, mesh, policy, tc, key):
    """Lower the step function of one cell under explicit shardings."""
    from repro.sharding.ctx import sharding_ctx

    batch_sds = input_specs(cfg, cell)
    batch_specs = POL.batch_specs(policy, batch_sds, cell.kind)
    if cell.kind == "train":
        state_sds = jax.eval_shape(lambda: TrainState.create(cfg, tc, key))
        p_specs = POL.param_specs(policy, state_sds.params)
        o_specs = _opt_specs(policy, p_specs, state_sds.params, tc)
        state_specs = TrainState(params=p_specs, opt=o_specs, step=P())
        step = make_train_step(cfg, tc)
        jitted = jax.jit(
            step,
            in_shardings=(_named(mesh, state_specs), _named(mesh, batch_specs)),
            donate_argnums=(0,),
        )
        with mesh, sharding_ctx(mesh, policy):
            return jitted.lower(state_sds, batch_sds)
    params_sds = jax.eval_shape(lambda: T.init_params(cfg, key))
    p_specs = POL.param_specs(policy, params_sds)
    if cell.kind == "prefill":
        step = make_prefill_step(cfg, policy)
        jitted = jax.jit(
            step,
            in_shardings=(_named(mesh, p_specs), _named(mesh, batch_specs)),
        )
        with mesh, sharding_ctx(mesh, policy):
            return jitted.lower(params_sds, batch_sds)
    cache_sds = jax.eval_shape(
        lambda: T.init_cache(cfg, cell.global_batch, cell.seq_len)
    )
    c_specs = POL.cache_specs_tree(policy, cache_sds, cfg)
    step = make_decode_step(cfg)
    jitted = jax.jit(
        step,
        in_shardings=(
            _named(mesh, p_specs),
            _named(mesh, batch_specs),
            _named(mesh, c_specs),
        ),
        donate_argnums=(2,),
    )
    with mesh, sharding_ctx(mesh, policy):
        return jitted.lower(params_sds, batch_sds, cache_sds)


def _cost_vector(compiled) -> dict:
    rl = RL.from_compiled(compiled)
    return {
        "flops": rl.flops_per_chip,
        "bytes": rl.bytes_per_chip,
        **{f"coll:{k}": v for k, v in rl.coll_by_kind.items()},
    }


def lower_cell(arch: str, cell_name: str, multi_pod: bool = False, cfg=None,
               policy_kw: dict | None = None, variant: str = ""):
    """Lower + compile one (arch x shape x mesh) cell; return results dict.

    Three compiles: (1) the full scan-over-layers program — the runnability
    proof and the memory analysis; (2)+(3) unrolled depth-1/-2 variants whose
    cost difference gives exact per-layer-unit flops/bytes/collectives
    (XLA cost_analysis counts while bodies once, so the full-depth numbers
    must be extrapolated: total = d1 + (units-1) * (d2 - d1)).
    """
    base_cfg = cfg or get_config(arch)
    cfg = base_cfg
    cell = shape_cell(cell_name)
    ok, why = cell_applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "cell": cell_name, "skipped": why,
                "mesh": "2x16x16" if multi_pod else "16x16"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = POL.auto_policy(cfg, mesh)
    if policy_kw:
        policy = dataclasses.replace(policy, **policy_kw)
    tc = train_config_for(arch)
    key = jax.random.PRNGKey(0)

    t0 = time.time()
    lowered = _build_lowered(cfg, cell, mesh, policy, tc, key)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    # per-unit cost extrapolation from unrolled depth-1 / depth-2 programs
    c1 = _cost_vector(
        _build_lowered(_depth_cfg(cfg, 1, scan=False), cell, mesh, policy, tc, key).compile()
    )
    c2 = _cost_vector(
        _build_lowered(_depth_cfg(cfg, 2, scan=False), cell, mesh, policy, tc, key).compile()
    )
    U = _n_units(cfg)
    cost = {k: c1[k] + (U - 1) * (c2[k] - c1[k]) for k in c1}
    coll_by_kind = {k.split(":", 1)[1]: v for k, v in cost.items() if k.startswith("coll:")}
    rl = RL.Roofline(
        flops_per_chip=cost["flops"],
        bytes_per_chip=cost["bytes"],
        coll_bytes_per_chip=float(sum(coll_by_kind.values())),
        coll_by_kind=coll_by_kind,
    )

    params_shapes = jax.eval_shape(lambda: T.init_params(cfg, key))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_shapes))
    n_active = RL.active_params(cfg, params_shapes)
    n_tokens = cell.global_batch * (cell.seq_len if cell.kind in ("train", "prefill") else 1)
    mf = RL.model_flops(cfg, n_tokens, n_params, n_active)  # 6*N*D
    if cell.kind != "train":
        mf /= 3.0  # forward-only: 2*N*D

    n_chips = mesh.size
    result = {
        "arch": arch,
        "cell": cell_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "n_params": int(n_params),
        "n_active_params": int(n_active),
        "fsdp": policy.fsdp,
        "dp_only": policy.dp_only,
        "variant": variant,
        "attn_impl": cfg.attn_impl,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes_per_device": int(
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
            ),
        },
        "roofline": rl.to_dict(),
        "model_flops_global": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / max(rl.flops_per_chip, 1.0),
    }
    return result


def save_result(res: dict, tag: str = ""):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    name = f"{res['arch']}__{res['cell']}__{res.get('mesh', 'na')}{tag}.json"
    path = RESULTS_DIR / name
    path.write_text(json.dumps(res, indent=2))
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--cell", choices=[c.name for c in SHAPE_CELLS])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--edm", choices=["fish1_normo", "subject6", "subject11"])
    ap.add_argument("--optimized", action="store_true",
                    help="beyond-paper optimized configs (SSPerf)")
    args = ap.parse_args()

    if args.edm:
        from repro.launch.edm_dryrun import lower_edm_cell

        res = lower_edm_cell(args.edm, multi_pod=args.multi_pod)
        path = save_result(res)
        print(json.dumps(res, indent=2))
        print(f"saved -> {path}")
        return

    cells = (
        [(a, c.name) for a in ARCHS for c in SHAPE_CELLS]
        if args.all
        else [(args.arch, args.cell)]
    )
    for arch, cell in cells:
        if args.optimized:
            base = get_config(arch)
            c = optimized_cfg(base, shape_cell(cell))
            res = lower_cell(arch, cell, multi_pod=args.multi_pod, cfg=c,
                             policy_kw=optimized_policy_kw(base, shape_cell(cell)),
                             variant="optimized")
            res_tag = "__opt"
        else:
            res = lower_cell(arch, cell, multi_pod=args.multi_pod)
            res_tag = ""
        path = save_result(res, tag=res_tag)
        if "skipped" in res:
            print(f"SKIP {arch} x {cell}: {res['skipped']}")
            continue
        rl = res["roofline"]
        print(
            f"OK {arch} x {cell} [{res['mesh']}] compile={res['compile_s']}s "
            f"peak_mem={res['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
            f"t_comp={rl['t_compute_s']:.4f}s t_mem={rl['t_memory_s']:.4f}s "
            f"t_coll={rl['t_collective_s']:.4f}s bottleneck={rl['bottleneck']}"
        )


if __name__ == "__main__":
    main()
