"""EDM causal-inference launcher — the paper's end-to-end workflow.

  PYTHONPATH=src python -m repro.launch.edm_run \
      --dataset /path/to/store --out /tmp/causal_map
  PYTHONPATH=src python -m repro.launch.edm_run --synthetic 64x600 --out ...
  # brain-scale memory profile: 2D-tiled phase 2 (DESIGN.md SS7)
  PYTHONPATH=src python -m repro.launch.edm_run \
      --synthetic 128x600 --target-tile 32 --out /tmp/causal_map

  # statistically validated causal graph (DESIGN.md SS9)
  PYTHONPATH=src python -m repro.launch.edm_run --synthetic 64x600 \
      --lib-sizes 100,200,400 --surrogates 20 --fdr 0.05 --seed 0 --out ...

  # multi-process elastic fleet (DESIGN.md SS10): W masterless workers
  # claim (row-span) work units from a lease queue over the store;
  # output is bit-identical to --workers 0 (the in-process path)
  PYTHONPATH=src python -m repro.launch.edm_run --synthetic 64x500 \
      --surrogates 20 --workers 4 --out /tmp/fleet

Reads a zarr-lite dataset (data/store.py), runs distributed simplex
projection + CCM on all local devices (the production launch wraps the
same entry point under the pod mesh), streams (row-chunk x col-tile)
blocks to the output store, and can RESUME from a killed run (--out
manifest).  With --out the causal map is assembled into a disk-backed
memmap (<out>/causal_map/data.npy) — no dense (N, N) host allocation —
and --target-tile additionally streams targets through column tiles
instead of replicating the full (N, Lp) future matrix per device:
nothing then scales beyond the O(N x L) inputs (host working set
O(chunk x tile), device O(lib_block x buckets x Lp x k + tile x Lp)).

--lib-sizes / --surrogates run the causal-significance subsystem on the
freshly assembled map: one-sweep convergence CCM (rho_conv/ +
rho_trend/), surrogate-null p-values (pvals/), and the BH-FDR
significance-masked edge list (edges/) — all streamed through the same
TileWriter store, resumable like phase 2.  --seed makes the whole run
reproducible (subsampling permutation + every surrogate draw derive
from it; recorded in the run's meta.json)."""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.pipeline import run_causal_inference
from repro.core.types import EDMConfig
from repro.data import store
from repro.data.synthetic import dummy_brain
from repro.engine import available_engines
from repro.inference import SignificanceConfig, run_significance
from repro.runtime import autotune, history, platform, telemetry


def _run_fleet(args, ts, cfg, sig):
    """--workers N: self-spawn a local masterless fleet (DESIGN.md SS10).

    The driver only prepares the shared store (dataset + fleet.json) and
    spawns/waits on worker processes — it schedules nothing; workers
    claim work units from the lease queue themselves.  A worker that
    dies is NOT fatal: the survivors reclaim its units after lease
    expiry, so the run completes as long as one worker lives (the
    driver re-raises only if ALL workers failed or artifacts are
    missing).
    """
    import json
    import pathlib

    from repro.launch import edm_fleet

    out = pathlib.Path(args.out)
    dataset = args.dataset
    if args.synthetic:
        dataset = out / "dataset"
        meta_f = dataset / "meta.json"
        if meta_f.exists():
            # Resume: the stored dataset must BE the requested one — a
            # changed spec silently reusing old data (same N, different
            # L or seed semantics) would compute over the wrong series.
            have = json.loads(meta_f.read_text()).get("synthetic")
            if have != args.synthetic:
                raise SystemExit(
                    f"--out {out} holds a --synthetic {have} dataset but "
                    f"this run asks for {args.synthetic}; use a fresh "
                    "--out dir"
                )
        else:
            store.save_dataset(dataset, ts, {"synthetic": args.synthetic})
    edm_fleet.init_fleet(
        out, dataset, cfg, sig, unit_rows=args.unit_rows, seed=args.seed,
        # Fleet workers re-apply the driver's platform tier from
        # fleet.json; `distributed` opts externally-launched workers into
        # the multi-host mesh via their OWN rank env (DESIGN.md SS14) —
        # locally-spawned children have the mesh vars stripped.
        platform=args.platform,
        distributed=platform.distributed_spec_from_env() is not None,
    )
    t0 = time.time()

    def spawn(wid):
        # tuned_ttl: schedule knob from --autotune (lease expiry sized
        # to the measured hold-time tail); None -> worker default.
        return edm_fleet.spawn_worker(out, wid,
                                      ttl=getattr(args, "tuned_ttl", None),
                                      unit_retries=args.unit_retries)

    procs = {f"w{i}": spawn(f"w{i}") for i in range(args.workers)}
    restarts = dict.fromkeys(procs, 0)
    fails = []
    # Supervise instead of blind-waiting: a POISON marker (a work unit
    # that exhausted its bounded retries fleet-wide) means no surviving
    # worker can ever finish — kill the fleet and surface the unit id,
    # instead of letting the barrier spin on TTL steals until timeout.
    # A worker that merely CRASHED (nonzero exit, no poison) is
    # relaunched under the same id — it reclaims its own leases
    # instantly — up to --max-worker-restarts times.
    while procs:
        poison = sorted((out / "queue").glob("*.poison"))
        if poison:
            for p in procs.values():
                p.terminate()
            for p in procs.values():
                p.wait()
            info = json.loads(poison[0].read_text())
            raise SystemExit(
                f"fleet failed: work unit {info.get('uid')} failed "
                f"permanently after {info.get('attempts')} attempt(s): "
                f"{info.get('error')}"
            )
        for wid in list(procs):
            rc = procs[wid].poll()
            if rc is None:
                continue
            del procs[wid]
            if rc == 0:
                continue
            if restarts[wid] < args.max_worker_restarts:
                restarts[wid] += 1
                print(f"worker {wid} exited {rc}; relaunching "
                      f"({restarts[wid]}/{args.max_worker_restarts})")
                procs[wid] = spawn(wid)
            else:
                fails.append(wid)
                print(f"warning: worker {wid} exited {rc} with restarts "
                      "exhausted (surviving workers cover its units)")
        if procs:
            time.sleep(0.25)
    # Success = the queue's durable stage witnesses exist (done markers
    # are written strictly AFTER the store commit they certify — a mere
    # data.npy can be a torn open_memmap of a fleet that died
    # mid-assemble) AND every artifact this run was asked for is present.
    required = [out / "queue" / "assemble.done",
                out / "causal_map" / "data.npy",
                out / "causal_map" / "meta.json"]
    if sig is not None:
        required.append(out / "queue" / "finalize.done")
        if sig.lib_sizes:
            required += [out / "rho_conv" / "data.npy",
                         out / "rho_trend" / "data.npy"]
        if sig.n_surrogates:
            required += [out / "pvals" / "data.npy",
                         out / "edges" / "data.npy"]
    missing = [str(p) for p in required if not p.exists()]
    if missing:
        raise SystemExit(
            f"fleet failed: missing completion witness(es) {missing} "
            f"(worker failures: {fails or 'none reported'})"
        )
    meta = json.loads((out / "causal_map" / "meta.json").read_text())
    N = meta["shape"][0]
    dt = time.time() - t0
    print(f"fleet[{args.workers}] causal map {N}x{N} in {dt:.1f}s "
          f"({N * N / dt:.0f} cross-maps/s); engine {cfg.engine}; "
          f"buckets {meta['n_buckets']}/{cfg.E_max}; "
          f"tile {cfg.target_tile or N}")
    if sig is not None:
        emeta = json.loads((out / "edges" / "meta.json").read_text()) \
            if (out / "edges" / "meta.json").exists() else None
        if emeta is not None:
            print(f"significance: {emeta['n_edges']} edges at FDR "
                  f"{emeta['alpha']} (p* = {emeta['p_threshold']:.4g}, "
                  f"{emeta['n_tests']} tests)")


_FLAGS_EPILOG = """\
flag groups:
  input          --dataset | --synthetic NxL
  embedding      --e-max --tau
  geometry       --lib-block --target-tile --knn-tile --stream-depth
                 (all byte-invisible to outputs; see --autotune)
  engine         --engine {reference,pallas-*}
  platform       --platform {cpu,gpu,tpu} (runtime/platform.py tier:
                 XLA flags + default engine; DESIGN.md SS14).  Multi-
                 host mesh joins via env: EDM_COORDINATOR host:port,
                 EDM_NUM_PROCESSES, EDM_PROCESS_ID (docs/OPERATIONS.md)
  significance   --lib-sizes --surrogates --fdr --surrogate-kind --seed
  fleet          --workers --unit-rows --unit-retries
                 --max-worker-restarts
  observability  --no-telemetry (default sink: <out>/telemetry/
                 main.jsonl; EDM_TELEMETRY=off|stdout|jsonl:<path>
                 overrides); `edm_fleet status --out DIR [--watch]`
                 renders a store's live state; `edm_fleet trace` the
                 assembled causal trace + Chrome trace JSON; `edm_fleet
                 trends` the cross-run history (one summary appended
                 per finished run to <out>/history.jsonl or
                 $EDM_HISTORY; DESIGN.md SS13)
  integrity      every store artifact is checksummed at write time and
                 the run fingerprint (dataset content + config) is
                 stamped into <out>; `edm_fleet fsck --out DIR [--heal]`
                 verifies a store and revokes damaged units for
                 recompute (DESIGN.md SS12)
  autotuning     --autotune --tune-from (recorded-timing tuner ->
                 <out>/tuned.json; geometry knobs + schedule knobs:
                 lease ttl applied to spawned workers, worker count
                 recommended, stream depth from drain gather share;
                 DESIGN.md SS11/SS13)
"""


def build_parser() -> argparse.ArgumentParser:
    """The edm_run CLI surface — exposed as a function so tests
    (tests/test_docs.py) can parse README/runbook invocations against
    the REAL parser."""
    ap = argparse.ArgumentParser(
        prog="edm_run",
        description=__doc__.split("\n")[0],
        epilog=_FLAGS_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--dataset", help="zarr-lite dataset dir")
    ap.add_argument("--synthetic", help="NxL dummy dataset, e.g. 128x1000")
    ap.add_argument("--out", required=True)
    ap.add_argument("--e-max", type=int, default=20)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--lib-block", type=int, default=8)
    ap.add_argument(
        "--platform", default=None, choices=platform.available_tiers(),
        help="execution tier (runtime/platform.py, DESIGN.md SS14): sets "
        "the jax platform, the tier's tuned XLA flags, and — unless "
        "--engine overrides — the tier's default engine.  Applied before "
        "the first jax backend touch; multi-host meshes additionally join "
        "via EDM_COORDINATOR/EDM_NUM_PROCESSES/EDM_PROCESS_ID",
    )
    ap.add_argument(
        "--target-tile", type=int, default=0,
        help="phase-2 column tile width (0 = untiled); > 0 streams targets "
        "in tiles so phase 2 allocates nothing beyond the O(NL) inputs "
        "(DESIGN.md SS7); output is bit-identical to the untiled path",
    )
    ap.add_argument(
        "--engine", default=None, choices=available_engines(),
        help="execution backend (repro.engine registry; default: reference)",
    )
    ap.add_argument(
        "--knn-tile", type=int, default=0,
        help="streaming kNN candidate-tile width (DESIGN.md SS8): 0 = "
        "auto-calibrated (widest tile under the VMEM budget), > 0 = force "
        "this width (distance working set flat in library length); output "
        "is bit-identical at every width",
    )
    ap.add_argument(
        "--no-bucketed", action="store_true",
        help="disable optE-bucketed phase 2 (all-E tables; A/B baseline)",
    )
    ap.add_argument(
        "--stream-depth", type=int, default=2,
        help="CCM blocks in flight (2 = double buffering, 1 = synchronous)",
    )
    ap.add_argument(
        "--use-kernels", action="store_true",
        help="DEPRECATED: same as --engine pallas-compiled",
    )
    ap.add_argument(
        "--lib-sizes", default="",
        help="comma-separated ascending library sizes for the convergence "
        "diagnostic (DESIGN.md SS9), e.g. 100,200,400; writes rho_conv/ "
        "(delta-rho) and rho_trend/ (monotonic-trend) store artifacts",
    )
    ap.add_argument(
        "--surrogates", type=int, default=0,
        help="surrogate-null draws per target (0 = skip significance): "
        "writes per-pair p-values (pvals/) and the FDR-masked causal "
        "edge list (edges/)",
    )
    ap.add_argument(
        "--fdr", type=float, default=0.05,
        help="Benjamini-Hochberg FDR level of the edge mask",
    )
    ap.add_argument(
        "--surrogate-kind", default="phase", choices=("phase", "shuffle"),
        help="null model: FFT phase-randomized (spectrum-preserving) or "
        "random shuffle (amplitude-distribution only)",
    )
    ap.add_argument(
        "--seed", type=int, default=0,
        help="root seed of the significance stage: ONE jax.random key "
        "derived from it drives the convergence subsampling permutation "
        "and every surrogate draw (recorded in meta.json)",
    )
    ap.add_argument(
        "--workers", type=int, default=0,
        help="self-spawn a local fleet of this many masterless worker "
        "processes over the output store (DESIGN.md SS10); 0 = run "
        "in-process.  Any W produces bit-identical causal_map/rho_conv/"
        "pvals arrays; workers share a JAX compilation cache under --out",
    )
    ap.add_argument(
        "--unit-rows", type=int, default=0,
        help="fleet work-unit height in rows (claim granularity); "
        "0 = one worker chunk (devices x lib-block)",
    )
    ap.add_argument(
        "--unit-retries", type=int, default=3,
        help="failed compute attempts (fleet-wide, durable) before a work "
        "unit is poisoned and the fleet exits nonzero with its id",
    )
    ap.add_argument(
        "--max-worker-restarts", type=int, default=2,
        help="times the fleet driver relaunches a crashed worker process "
        "under the same id before giving its units to the survivors",
    )
    ap.add_argument(
        "--no-telemetry", action="store_true",
        help="disable the default per-run telemetry JSONL sink "
        "(<out>/telemetry/main.jsonl); records are byte-invisible to "
        "outputs, so this only saves the write traffic.  EDM_TELEMETRY="
        "off|stdout|jsonl:<path> overrides the default sink instead",
    )
    ap.add_argument(
        "--autotune", action="store_true",
        help="apply tuned geometry (<out or --tune-from>/tuned.json, or "
        "a fresh replay of recorded telemetry) before the run, and write "
        "<out>/tuned.json from this run's telemetry after it; shapes are "
        "byte-invisible to outputs (DESIGN.md SS11)",
    )
    ap.add_argument(
        "--tune-from",
        help="store whose recorded telemetry / tuned.json seeds "
        "--autotune (default: --out itself, i.e. a rerun tunes from the "
        "previous run)",
    )
    return ap


def main():
    ap = build_parser()
    args = ap.parse_args()

    # Platform tier + multi-host mesh join, BEFORE any jax backend touch
    # (XLA flags and jax_platform_name are latched at backend init).
    if args.platform:
        applied = platform.apply_platform(args.platform)
        print(f"platform: tier {applied['tier']} "
              f"(engine default {applied['engine']})")
    dist = platform.init_distributed()
    if dist is not None:
        print(f"distributed: process {dist['process_id']}/"
              f"{dist['num_processes']} via {dist['coordinator']}")

    if args.synthetic:
        N, L = map(int, args.synthetic.split("x"))
        ts = dummy_brain(N, L)
    else:
        ts = np.asarray(store.load_dataset(args.dataset), np.float32)
    if args.use_kernels:
        if args.engine not in (None, "pallas-compiled"):
            ap.error("--use-kernels conflicts with --engine "
                     f"{args.engine}; drop the deprecated flag")
        print("note: --use-kernels is deprecated; use --engine pallas-compiled")
        engine = "pallas-compiled"
    elif args.engine:
        engine = args.engine
    elif args.platform:
        # The tier's default engine (registry tie-in): gpu/tpu tiers run
        # the Pallas kernels, cpu stays on the jnp reference engine.
        engine = platform.default_engine(args.platform)
    else:
        engine = "reference"
    cfg = EDMConfig(
        E_max=args.e_max, tau=args.tau, lib_block=args.lib_block,
        engine=engine, bucketed=not args.no_bucketed,
        stream_depth=args.stream_depth, target_tile=args.target_tile,
        knn_tile_c=args.knn_tile,
    )
    if not args.no_telemetry:
        telemetry.configure_from_env(
            default_path=telemetry.worker_jsonl(args.out, "main"),
            worker="main",
        )
    if args.autotune:
        # Tuned shapes are byte-invisible to outputs, so applying a
        # recommendation can only ever change wall time.  A fleet
        # restart reads the SAME tuned.json it wrote, so its fleet.json
        # spec check still passes (deterministic restart shapes).
        src = args.tune_from or args.out
        tuned = autotune.load_tuned(src) or autotune.recommend(src)
        if tuned is not None:
            import jax

            cfg = autotune.apply_to_cfg(cfg, tuned, len(jax.devices()))
            rec = tuned["recommend"]
            # Schedule knobs (DESIGN.md SS13): the tuned lease TTL is
            # applied to the workers this driver spawns; the worker
            # count is a budget decision, so it is RECOMMENDED, never
            # silently applied.
            if rec.get("ttl"):
                args.tuned_ttl = float(rec["ttl"])
            if rec.get("workers") and args.workers > 0 \
                    and rec["workers"] != args.workers:
                print(f"autotune: recommend --workers {rec['workers']} "
                      f"(this run uses {args.workers}; straggler-tail "
                      "model, see tuned.json evidence)")
            print(f"autotune: applied {rec} from {src}")
        elif args.tune_from:
            raise SystemExit(
                f"--tune-from {src}: no tuned.json and no chunk telemetry "
                "to replay"
            )
    # Run-start clock anchor (runtime/trace.py aligns timelines on it),
    # then the run's config snapshot.
    telemetry.emit_clock_anchor(driver=True, workers=args.workers)
    telemetry.counter(
        "fleet", "run_config", engine=cfg.engine, lib_block=cfg.lib_block,
        target_tile=cfg.target_tile, knn_tile_c=cfg.knn_tile_c,
        stream_depth=cfg.stream_depth, workers=args.workers,
        autotune=bool(args.autotune),
    )
    # ONE sig construction for both drivers — the fleet path must run
    # exactly the config the in-process path would (bit-identity).
    lib_sizes = tuple(int(s) for s in args.lib_sizes.split(",") if s)
    sig = None
    if lib_sizes or args.surrogates:
        sig = SignificanceConfig(
            lib_sizes=lib_sizes, n_surrogates=args.surrogates,
            alpha=args.fdr, surrogate=args.surrogate_kind, seed=args.seed,
        )
    if args.workers > 0:
        try:
            _run_fleet(args, ts, cfg, sig)
            # Refresh the run-history record the finalize claimer wrote
            # so it also covers the driver's own telemetry tail (same
            # run identity -> replaces, never duplicates).
            history.record_run(args.out)
        finally:
            telemetry.shutdown()
        _autotune_epilogue(args)
        return
    t0 = time.time()
    result = run_causal_inference(ts, cfg, out_dir=args.out, progress=True)
    dt = time.time() - t0
    N = ts.shape[0]
    n_buckets = len(np.unique(np.asarray(result.optE)))
    print(f"causal map {N}x{N} in {dt:.1f}s "
          f"({N * N / dt:.0f} cross-maps/s); optE mean {result.optE.mean():.2f}; "
          f"engine {cfg.engine}; buckets {n_buckets}/{cfg.E_max}; "
          f"tile {cfg.target_tile or N}")
    meta = {
        "optE": result.optE.tolist(),
        "engine": cfg.engine,
        "bucketed": cfg.bucketed,
        "n_buckets": int(n_buckets),
        "stream_depth": cfg.stream_depth,
        "target_tile": cfg.target_tile,
        "knn_tile_c": cfg.knn_tile_c,
        "seed": args.seed,
    }
    # The pipeline already assembled the map into <out>/causal_map/data.npy
    # (memmap; no dense host copy) — only the zarr-lite meta is missing.
    # Re-saving result.rho here would truncate the very file backing it.
    store.save_meta(
        args.out + "/causal_map", result.rho.shape, result.rho.dtype, meta
    )

    if sig is not None:
        t1 = time.time()
        out = run_significance(
            ts, np.asarray(result.optE), np.asarray(result.rho), cfg, sig,
            out_dir=args.out, progress=True,
        )
        stages = [s for s, on in (("convergence", sig.lib_sizes),
                                  ("surrogates", sig.n_surrogates)) if on]
        print(f"significance [{'+'.join(stages)}] in {time.time() - t1:.1f}s"
              + (f"; {len(out.edges)} edges at FDR {args.fdr} "
                 f"(p* = {out.p_threshold:.4g}, {out.n_tests} tests)"
                 if out.edges is not None else ""))
    history.record_run(args.out)  # run-history summary (DESIGN.md SS13)
    telemetry.shutdown()  # flush the run's JSONL before any replay
    _autotune_epilogue(args)


def _autotune_epilogue(args) -> None:
    """--autotune: replay the telemetry THIS run just recorded and
    persist the recommendation beside fleet.json for the next run."""
    if not args.autotune:
        return
    tuned = autotune.recommend(args.out)
    if tuned is None:
        print("autotune: no chunk telemetry recorded this run "
              "(nothing computed, or telemetry disabled); tuned.json "
              "not updated")
        return
    p = autotune.write_tuned(args.out, tuned)
    print(f"autotune: wrote {p}: {tuned['recommend']}")


if __name__ == "__main__":
    main()
