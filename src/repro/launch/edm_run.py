"""EDM causal-inference launcher — the paper's end-to-end workflow.

  PYTHONPATH=src python -m repro.launch.edm_run \
      --dataset /path/to/store --out /tmp/causal_map
  PYTHONPATH=src python -m repro.launch.edm_run --synthetic 64x600 --out ...

Reads a zarr-lite dataset (data/store.py), runs distributed simplex
projection + CCM on all local devices (the production launch wraps the
same entry point under the pod mesh), streams row blocks to the output
store, and can RESUME from a killed run (--out manifest)."""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.pipeline import run_causal_inference
from repro.core.types import EDMConfig
from repro.data import store
from repro.data.synthetic import dummy_brain


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", help="zarr-lite dataset dir")
    ap.add_argument("--synthetic", help="NxL dummy dataset, e.g. 128x1000")
    ap.add_argument("--out", required=True)
    ap.add_argument("--e-max", type=int, default=20)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--lib-block", type=int, default=8)
    ap.add_argument("--use-kernels", action="store_true")
    args = ap.parse_args()

    if args.synthetic:
        N, L = map(int, args.synthetic.split("x"))
        ts = dummy_brain(N, L)
    else:
        ts = np.asarray(store.load_dataset(args.dataset), np.float32)
    cfg = EDMConfig(
        E_max=args.e_max, tau=args.tau, lib_block=args.lib_block,
        use_kernels=args.use_kernels,
    )
    t0 = time.time()
    result = run_causal_inference(ts, cfg, out_dir=args.out, progress=True)
    dt = time.time() - t0
    N = ts.shape[0]
    print(f"causal map {N}x{N} in {dt:.1f}s "
          f"({N * N / dt:.0f} cross-maps/s); optE mean {result.optE.mean():.2f}")
    store.save_dataset(args.out + "/causal_map", result.rho,
                       {"optE": result.optE.tolist()})


if __name__ == "__main__":
    main()
