"""Masterless multi-process EDM fleet — the paper's 512-node master-worker
over the tile store, without the master (DESIGN.md SS10).

  # spawned for you:
  PYTHONPATH=src python -m repro.launch.edm_run --synthetic 64x500 \
      --workers 4 --surrogates 20 --out /tmp/fleet
  # or by hand / on other hosts sharing the filesystem:
  PYTHONPATH=src python -m repro.launch.edm_fleet --out /tmp/fleet \
      --worker-id w2

Every worker runs the SAME stage sequence and coordinates purely through
files in the shared ``--out`` store (works for local processes and for
hosts sharing a parallel filesystem alike):

  phase1   — one unit; the claimer runs simplex projection for all rows
             and persists optE + simplex rhos (the run's one broadcast).
  phase2   — (row-span) units claimed from a lease queue; each worker
             computes its units under its OWN local mesh with the
             existing chunk functions and streams tiles through a
             writer_id-sharded TileWriter.
  assemble — one unit: merge manifests, memmap-assemble causal_map/.
  sig      — (row-span) units of the significance stage: prefix-kNN
             convergence sweeps + surrogate-null batches per claimed
             chunk, through the same sharded writers.
  finalize — one unit: assemble rho_conv/rho_trend/pvals, recount the
             p histogram, BH-FDR edge list.

Elasticity: SIGKILL any worker at any point; its unclaimed units are
untouched, its claimed unit's lease expires (or is reclaimed instantly
by a relaunched worker with the same id) and is recomputed.  Because
every unit's values are geometry-independent and every store write is
an atomic replace of bit-identical content, the assembled causal_map,
rho_conv, and pvals arrays are byte-identical for ANY worker count,
kill schedule, or unit size — W=4 with a mid-run kill equals a fresh
W=1 run (asserted in CI).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

from repro.core import ccm
from repro.core.types import EDMConfig
from repro.data import store
from repro.data.store import TileWriter
from repro.inference import SignificanceConfig
from repro.runtime import faultpoints, history, integrity, telemetry, trace
from repro.runtime.workqueue import LeaseQueue, WorkUnit, plan_units

SPEC_NAME = "fleet.json"
STAGE_ORDER = ("phase1", "phase2", "assemble", "sig", "finalize")


# ------------------------------------------------------------------- spec
def init_fleet(
    out_dir: str | pathlib.Path,
    dataset: str | pathlib.Path,
    cfg: EDMConfig,
    sig: SignificanceConfig | None = None,
    unit_rows: int = 0,
    seed: int | None = None,
    platform: str | None = None,
    distributed: bool = False,
) -> dict:
    """Write the shared fleet spec every worker derives its queue from.

    unit_rows=0 resolves to one local-mesh chunk (devices x lib_block) —
    the natural claim granularity.  The spec pins dataset path, configs,
    and the unit grid so W workers agree on the queue with no exchange.

    ``platform`` / ``distributed`` are the multi-host opt-in (DESIGN.md
    SS14): workers apply the named runtime/platform.py tier before their
    first jax touch, and with ``distributed`` they join the logical mesh
    via their own EDM_COORDINATOR / EDM_NUM_PROCESSES / EDM_PROCESS_ID
    environment (docs/OPERATIONS.md) — the spec opts the fleet in; the
    per-process rank always comes from the worker's environment.
    """
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    meta = json.loads((pathlib.Path(dataset) / "meta.json").read_text())
    N, L = (int(s) for s in meta["shape"][:2])
    if unit_rows <= 0:
        import jax

        unit_rows = len(jax.devices()) * cfg.lib_block
    if seed is None:
        seed = 0 if sig is None else sig.seed
    # Run fingerprint: dataset CONTENT (not path — the same path can hold
    # different bytes tomorrow) + canonicalized config.  In the spec it
    # rides the existing resume equality check; workers re-derive it from
    # the bytes they actually loaded at join time.
    # float32 canonicalization matches what workers compute over, so the
    # two sides always hash the same bytes regardless of storage dtype.
    ts = np.asarray(store.load_dataset(dataset), np.float32)
    fp = integrity.fingerprint_of(ts, cfg)
    spec = {
        "dataset": str(pathlib.Path(dataset).resolve()),
        "N": N,
        "L": L,  # pins dataset identity: same-N, different-L swaps refuse
        "unit_rows": int(unit_rows),
        "seed": int(seed),
        "cfg": dataclasses.asdict(cfg),
        "sig": None if sig is None else dataclasses.asdict(sig),
        "dataset_crc32": fp["dataset_crc32"],
        "fingerprint": fp["fingerprint"],
        "platform": platform,
        "distributed": bool(distributed),
    }
    # JSON round-trip so the resume equality check compares like with
    # like (tuples become lists exactly as they will when read back).
    spec = json.loads(json.dumps(spec))
    existing = out / SPEC_NAME
    if existing.exists():
        have = json.loads(existing.read_text())
        if have != spec:
            raise ValueError(
                f"fleet spec mismatch in {out}: store was initialised with "
                f"{have} but this run asks for {spec}; use a fresh --out dir"
            )
        return have
    store.atomic_write_text(existing, json.dumps(spec, indent=1))
    integrity.stamp_fingerprint(out, fp)
    return spec


def load_fleet(out_dir: str | pathlib.Path) -> dict:
    spec = json.loads((pathlib.Path(out_dir) / SPEC_NAME).read_text())
    spec["cfg"] = EDMConfig(**spec["cfg"])
    if spec["sig"] is not None:
        s = dict(spec["sig"])
        s["lib_sizes"] = tuple(s["lib_sizes"])
        spec["sig"] = SignificanceConfig(**s)
    return spec


def spawn_worker(
    out_dir: str | pathlib.Path,
    worker_id: str,
    ttl: float | None = None,
    env: dict | None = None,
    unit_retries: int | None = None,
) -> subprocess.Popen:
    """Spawn one fleet worker as a detached subprocess.

    Workers share a JAX persistent compilation cache under the store
    (unless the caller already exported one): W processes compile the
    same jit signatures, so all but the first hit the disk cache —
    the fleet's answer to the paper's GPU-init straggler tail (SSIV-B2).
    """
    e = dict(os.environ if env is None else env)
    e.setdefault("JAX_COMPILATION_CACHE_DIR",
                 str(pathlib.Path(out_dir).resolve() / "jax_cache"))
    e.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
    # A locally-spawned worker must NOT inherit the driver's multi-host
    # rank: W children all claiming the driver's EDM_PROCESS_ID would
    # deadlock jax.distributed.initialize.  Cross-host workers are
    # launched externally (one per host, each with its own rank env —
    # docs/OPERATIONS.md); the fleet.json `distributed` flag opts them in.
    if env is None:
        from repro.runtime import platform as _platform

        for var in (_platform.ENV_COORDINATOR, _platform.ENV_NUM_PROCESSES,
                    _platform.ENV_PROCESS_ID,
                    _platform.ENV_LOCAL_DEVICE_IDS):
            e.pop(var, None)
    src = pathlib.Path(__file__).resolve().parents[2]
    e["PYTHONPATH"] = f"{src}:{e['PYTHONPATH']}" if e.get("PYTHONPATH") else str(src)
    cmd = [sys.executable, "-m", "repro.launch.edm_fleet",
           "--out", str(out_dir), "--worker-id", worker_id]
    if ttl is not None:
        cmd += ["--ttl", str(ttl)]
    if unit_retries is not None:
        cmd += ["--unit-retries", str(unit_retries)]
    return subprocess.Popen(cmd, env=e)


# ----------------------------------------------------------------- worker
def _sub_chunks(unit: WorkUnit, chunk: int) -> list[tuple[int, int]]:
    """Split a claimed unit into local-mesh-sized (row0, valid) chunks
    (a unit from a spec written under a different device count may span
    several of this worker's chunks — elastic across mesh sizes)."""
    hi = unit.row0 + unit.nrows
    return [(r, min(chunk, hi - r)) for r in range(unit.row0, hi, chunk)]


def _covered_and(writers: list[TileWriter]) -> np.ndarray:
    cov = writers[0].refresh().covered()
    for w in writers[1:]:
        cov &= w.refresh().covered()
    return cov


class FleetWorker:
    """One worker's walk through the stage sequence.  Usable in-process
    (tests drive several workers' stages by hand) or via main()."""

    def __init__(self, out_dir: str | pathlib.Path, worker_id: str,
                 ttl: float = 600.0, poll: float = 0.25,
                 timeout: float | None = 3600.0, progress: bool = True,
                 unit_retries: int = 3):
        self.out = pathlib.Path(out_dir)
        spec = load_fleet(self.out)
        self.cfg: EDMConfig = spec["cfg"]
        self.sig: SignificanceConfig | None = spec["sig"]
        self.unit_rows: int = spec["unit_rows"]
        self.seed: int = spec.get("seed", 0)
        self.ts = np.asarray(store.load_dataset(spec["dataset"]), np.float32)
        self.N = self.ts.shape[0]
        want = (spec["N"], spec.get("L", self.ts.shape[1]))
        if self.ts.shape != want:
            raise ValueError(
                f"dataset shape {self.ts.shape} != fleet spec {want}"
            )
        # Worker-join fingerprint check: the bytes THIS worker just
        # loaded must be the bytes the fleet was initialised on, or its
        # tiles would silently mix with everyone else's (DESIGN.md SS12).
        want_fp = spec.get("fingerprint")
        if want_fp is not None:
            have = integrity.fingerprint_of(self.ts, self.cfg)
            if have["fingerprint"] != want_fp:
                raise integrity.IntegrityError(
                    f"worker {worker_id}: run fingerprint "
                    f"{have['fingerprint']} (dataset crc "
                    f"{have['dataset_crc32']}) != fleet spec {want_fp} — "
                    f"the dataset at {spec['dataset']} changed since "
                    "init_fleet; use a fresh --out dir"
                )
        self.worker_id = worker_id
        self.queue = LeaseQueue(self.out / "queue", worker_id, ttl=ttl,
                                poll=poll, fail_limit=unit_retries)
        self.timeout = timeout
        self.progress = progress
        from repro.core.pipeline import default_mesh

        self.mesh = default_mesh()
        self.chunk = self.mesh.size * self.cfg.lib_block

    def _log(self, msg: str) -> None:
        if self.progress:
            print(f"[{self.worker_id}] {msg}", flush=True)

    def _renew_chunk(self, unit: WorkUnit) -> None:
        """Per-chunk keepalive: the ``chunk_pre`` fault point (chaos
        schedules inject errors/delays between chunks here) followed by
        the lease renewal that keeps a slow-but-alive unit unstolen."""
        faultpoints.fire("chunk_pre")
        self.queue.renew(unit)

    # -------------------------------------------------------- stage fns
    def _phase1(self) -> np.ndarray:
        from repro.core.pipeline import run_phase1

        p1 = self.out / "phase1"

        def compute(unit):
            self._log("phase1: simplex projection")
            rhos, optE = run_phase1(
                self.ts, self.cfg, self.mesh,
                on_chunk=lambda row0: self.queue.renew(unit),
            )
            p1.mkdir(parents=True, exist_ok=True)
            # optE.npy is the stage's completion WITNESS (already_done
            # below + pollers), so it must land LAST: a kill between
            # these writes then leaves an unwitnessed stage that gets
            # recomputed, never a witnessed stage missing artifacts.
            store.save_npy_checksummed(p1 / "simplex_rho.npy", rhos)
            store.save_meta(p1, optE.shape, optE.dtype, {"stat": "optE"})
            store.save_npy_checksummed(p1 / "optE.npy", optE)

        self.queue.run_stage(
            plan_units("phase1", self.N, self.unit_rows), compute,
            already_done=lambda u: (p1 / "optE.npy").exists(),
            timeout=self.timeout,
        )
        return np.load(p1 / "optE.npy")

    def _phase2(self, optE: np.ndarray) -> None:
        import jax.numpy as jnp

        from repro.core.pipeline import run_phase2_chunks

        ts_fut = np.asarray(ccm.all_futures(jnp.asarray(self.ts), self.cfg))
        writer = TileWriter(self.out, self.N, writer_id=self.worker_id)
        units = plan_units("phase2", self.N, self.unit_rows)

        def compute(unit):
            self._log(f"phase2 rows {unit.row0}..{unit.row0 + unit.nrows}")
            # Per-chunk lease renewal INSIDE the streaming loop: a unit
            # whose compute (first-touch Pallas compile, a straggler
            # chunk) outlives the TTL re-stamps its clock between chunks
            # instead of being stolen mid-flight.
            run_phase2_chunks(
                self.ts, ts_fut, optE, self.cfg, self.mesh,
                _sub_chunks(unit, self.chunk), writer=writer,
                on_chunk=lambda row0: self._renew_chunk(unit),
            )

        # Coverage snapshot ONCE per stage entry (refresh + covered walk
        # every manifest shard — O(tiles), not something to redo per
        # unit); units finished later are handled by the queue itself.
        cov = writer.refresh().covered()
        already_done = lambda u: bool(cov[u.row0 : u.row0 + u.nrows].all())
        self.queue.run_stage(units, compute, already_done=already_done,
                             timeout=self.timeout)

    def _assemble(self, optE: np.ndarray) -> np.ndarray:
        map_npy = self.out / "causal_map" / "data.npy"

        def compute(unit):
            self._log("assemble: causal_map")
            writer = TileWriter(self.out, self.N)
            if not writer.covered().all():
                # Queue markers say phase 2 is done but the store is not
                # covered — someone removed tiles or the fs lost data.
                # Fail loudly rather than assemble silent zero rows
                # (delete <out>/queue/ to force a recompute-from-coverage).
                raise RuntimeError(
                    f"phase-2 store {self.out} incomplete at assemble: "
                    f"{int((~writer.covered()).sum())} rows uncovered"
                )
            rho = writer.assemble(mmap_path=map_npy)
            n_buckets = len(np.unique(optE))
            store.save_meta(
                self.out / "causal_map", rho.shape, rho.dtype,
                {
                    "optE": optE.tolist(),
                    "engine": self.cfg.engine,
                    "bucketed": self.cfg.bucketed,
                    "n_buckets": int(n_buckets),
                    "stream_depth": self.cfg.stream_depth,
                    "target_tile": self.cfg.target_tile,
                    "knn_tile_c": self.cfg.knn_tile_c,
                    "seed": self.seed,
                    "fleet": True,
                },
            )
            # Run-history summary (DESIGN.md SS13): for a no-significance
            # fleet assemble IS finalize; a later sig finalize REPLACES
            # this record (same run identity).  Only the assemble claimer
            # writes — single history writer per run.
            history.record_run(self.out)

        self.queue.run_stage(
            plan_units("assemble", self.N, self.unit_rows), compute,
            timeout=self.timeout,
        )
        return np.load(map_npy, mmap_mode="r")

    def _significance(self, optE: np.ndarray, rho: np.ndarray) -> None:
        from repro.inference.pipeline import (
            SignificanceChunkRunner,
            _check_resume_config,
            _writer,
            finalize_significance,
            make_store_drain,
        )

        sig = self.sig
        _check_resume_config(self.out, sig)
        runner = SignificanceChunkRunner(
            self.ts, optE, self.cfg, sig, self.mesh
        )
        conv_w = trend_w = pv_w = None
        if runner.do_conv:
            conv_w = _writer(self.out, "rho_conv", self.N, runner.order,
                             writer_id=self.worker_id)
            trend_w = _writer(self.out, "rho_trend", self.N, runner.order,
                              writer_id=self.worker_id)
        if runner.do_null:
            pv_w = _writer(self.out, "pvals", self.N, runner.order,
                           writer_id=self.worker_id)
        writers = [w for w in (conv_w, trend_w, pv_w) if w is not None]
        drain = make_store_drain(self.N, conv_w, trend_w, pv_w)

        def compute(unit):
            self._log(f"sig rows {unit.row0}..{unit.row0 + unit.nrows}")
            runner.run(_sub_chunks(unit, self.chunk), rho, drain,
                       on_chunk=lambda row0: self._renew_chunk(unit))
            for w in writers:
                w.commit()

        # AND-of-coverages snapshot once per stage entry (SS9 resume
        # semantics: a chunk counts only when EVERY artifact has it).
        cov = _covered_and(writers)
        already_done = lambda u: bool(cov[u.row0 : u.row0 + u.nrows].all())
        with telemetry.span("sig", "stage"):
            self.queue.run_stage(
                plan_units("sig", self.N, self.unit_rows), compute,
                already_done=already_done, timeout=self.timeout,
            )
        telemetry.flush()

        def do_finalize(unit):
            self._log("finalize: assembly + recount + BH-FDR edges")
            out = finalize_significance(
                str(self.out), rho, self.cfg, sig, progress=self.progress
            )
            del out

        with telemetry.span("finalize", "stage"):
            self.queue.run_stage(
                plan_units("finalize", self.N, self.unit_rows), do_finalize,
                timeout=self.timeout,
            )
        telemetry.flush()

    # --------------------------------------------------------- full run
    def run(self) -> None:
        """Walk the full stage sequence.  Every stage is wrapped in a
        telemetry span (so each worker's JSONL covers all five stages
        even for units it never computed — the barrier wait IS the
        record) and flushed at the stage boundary, bounding what a
        SIGKILL can lose to one stage's unflushed tail."""
        t0 = time.time()
        # Run-start clock anchor: (epoch, monotonic) sample the trace
        # assembler aligns this worker's timeline on (DESIGN.md SS13).
        telemetry.emit_clock_anchor(worker_id=self.worker_id)
        with telemetry.span("phase1", "stage"):
            optE = self._phase1()
        telemetry.flush()
        with telemetry.span("phase2", "stage"):
            self._phase2(optE)
        telemetry.flush()
        with telemetry.span("assemble", "stage"):
            rho = self._assemble(optE)
        telemetry.flush()
        if self.sig is not None and (
            self.sig.lib_sizes or self.sig.n_surrogates > 0
        ):
            self._significance(optE, rho)
        self._log(f"done in {time.time() - t0:.1f}s")
        telemetry.flush()


# ----------------------------------------------------------------- status
def fleet_status(out_dir: str | pathlib.Path) -> dict:
    """Live fleet state for a store, from files alone (no worker RPC —
    masterless observability to match the masterless queue):

      stages    — per stage: total/done/poisoned unit counts plus every
                  live lease (worker, age, expired?) from the queue dir;
      coverage  — per store artifact: covered-row fraction from the
                  writer manifests (the ground truth the queue certifies);
      telemetry — per worker-file record/violation counts and per-stage
                  span-time + claim/steal/done rollups from the recorded
                  JSONL (empty when telemetry was off).

    Returns a JSON-safe dict; :func:`render_status` is the human form.
    """
    out = pathlib.Path(out_dir)
    spec = json.loads((out / SPEC_NAME).read_text())
    N, unit_rows = spec["N"], spec["unit_rows"]
    qdir = out / "queue"
    now = time.time()

    stages = {}
    for kind in STAGE_ORDER:
        if kind in ("sig", "finalize") and spec.get("sig") is None:
            continue
        units = plan_units(kind, N, unit_rows)
        done = sum((qdir / f"{u.uid}.done").exists() for u in units)
        poisoned, leases = [], []
        for u in units:
            pp = qdir / f"{u.uid}.poison"
            if pp.exists():
                try:
                    poisoned.append(json.loads(pp.read_text()))
                except ValueError:
                    poisoned.append({"uid": u.uid})
            lp = qdir / f"{u.uid}.lease"
            if lp.exists() and not (qdir / f"{u.uid}.done").exists():
                try:
                    held = json.loads(lp.read_text())
                except (OSError, ValueError):
                    continue
                age = now - held.get("t", now)
                leases.append({
                    "uid": u.uid, "worker": held.get("worker"),
                    "age_s": round(age, 1),
                    "expired": age > held.get("ttl", 0),
                })
        stages[kind] = {"total": len(units), "done": done,
                        "leases": leases, "poisoned": poisoned}

    coverage = {}
    artifacts = [("causal_map", out)]
    if spec.get("sig") is not None:
        s = spec["sig"]
        if s.get("lib_sizes"):
            artifacts += [("rho_conv", out / "rho_conv"),
                          ("rho_trend", out / "rho_trend")]
        if s.get("n_surrogates", 0) > 0:
            artifacts += [("pvals", out / "pvals")]
    for name, d in artifacts:
        if not pathlib.Path(d).exists():
            coverage[name] = {"covered": 0, "total": N, "pct": 0.0}
            continue
        cov = TileWriter(d, N).covered()
        coverage[name] = {
            "covered": int(cov.sum()), "total": N,
            "pct": round(100.0 * float(cov.mean()), 1),
        }

    workers: dict[str, dict] = {}
    per_stage: dict[str, dict] = {}
    violations = 0
    for stem, rec in telemetry.iter_store_records(out):
        w = workers.setdefault(stem, {"records": 0, "invalid": 0})
        w["records"] += 1
        if telemetry.validate(rec):
            w["invalid"] += 1
            violations += 1
            continue
        st = per_stage.setdefault(
            rec["stage"],
            {"span_s": 0.0, "claim": 0, "steal": 0, "done": 0},
        )
        if rec["kind"] == "span":
            st["span_s"] += rec["dur_s"]
        elif rec["name"] in ("claim", "steal", "done"):
            st[rec["name"]] += 1
    for st in per_stage.values():
        st["span_s"] = round(st["span_s"], 3)

    all_done = all(s["done"] == s["total"] for s in stages.values())
    full_cov = all(c["pct"] >= 100.0 for c in coverage.values())
    return {
        "out": str(out), "N": N, "L": spec.get("L"),
        "unit_rows": unit_rows,
        "stages": stages, "coverage": coverage,
        "telemetry": {"workers": workers, "stages": per_stage,
                      "violations": violations},
        "complete": bool(all_done and full_cov and coverage),
    }


def render_status(st: dict) -> str:
    lines = [
        f"fleet {st['out']}: N={st['N']} L={st['L']} "
        f"unit_rows={st['unit_rows']}"
        f"{'  [COMPLETE]' if st['complete'] else ''}",
        f"{'stage':<10} {'done':>9}  leases",
    ]
    for kind, s in st["stages"].items():
        parts = []
        for l in s["leases"]:
            flag = " EXPIRED" if l["expired"] else ""
            parts.append(f"{l['uid']}@{l['worker']} {l['age_s']}s{flag}")
        for p in s["poisoned"]:
            parts.append(f"{p.get('uid')} POISONED ({p.get('error', '?')})")
        lines.append(
            f"{kind:<10} {s['done']:>4}/{s['total']:<4}  "
            + ("; ".join(parts) or "-")
        )
    lines.append("coverage: " + ", ".join(
        f"{name} {c['pct']}% ({c['covered']}/{c['total']})"
        for name, c in st["coverage"].items()
    ))
    tel = st["telemetry"]
    if tel["workers"]:
        nrec = sum(w["records"] for w in tel["workers"].values())
        lines.append(
            f"telemetry: {len(tel['workers'])} worker file(s), {nrec} "
            f"records, {tel['violations']} schema violation(s)"
        )
        for stage, s in sorted(tel["stages"].items()):
            lines.append(
                f"  {stage:<10} span {s['span_s']:>8.3f}s  "
                f"claims {s['claim']}  steals {s['steal']}  "
                f"done {s['done']}"
            )
    else:
        lines.append("telemetry: no records (sink disabled or not started)")
    return "\n".join(lines)


def watch_status(
    out_dir: str | pathlib.Path,
    interval: float = 2.0,
    iterations: int | None = None,
    file=None,
) -> dict:
    """``status --watch``: re-render fleet state every ``interval``
    seconds until the run completes, adding what a single snapshot
    cannot show —

      * per-stage throughput (units done/s) and row-coverage rate with
        an ETA, both from deltas between refreshes;
      * STRAGGLER flags on live leases whose age exceeds the fleet's
        p95 unit hold time (the recorded ``held`` counters — a unit
        held longer than 95% of completed holds is statistically late,
        long before its TTL expires).

    ``iterations`` bounds the loop for tests/CI; returns the last
    status dict.  Pure reader — same files-only observability as
    :func:`fleet_status`, no worker RPC.
    """
    f = file or sys.stdout
    prev_t: float | None = None
    prev_cov: dict[str, int] = {}
    prev_done: dict[str, int] = {}
    n = 0
    while True:
        st = fleet_status(out_dir)
        now = time.time()
        lines = [render_status(st)]
        if prev_t is not None:
            dt = max(now - prev_t, 1e-6)
            for kind, s in st["stages"].items():
                d = s["done"] - prev_done.get(kind, s["done"])
                if d > 0 and s["done"] < s["total"]:
                    rate = d / dt
                    eta = (s["total"] - s["done"]) / rate
                    lines.append(f"watch: {kind} {rate:.2f} units/s, "
                                 f"ETA {eta:.0f}s")
            for name, c in st["coverage"].items():
                d = c["covered"] - prev_cov.get(name, c["covered"])
                if d > 0 and c["covered"] < c["total"]:
                    rate = d / dt
                    eta = (c["total"] - c["covered"]) / rate
                    lines.append(f"watch: {name} {rate:.1f} rows/s, "
                                 f"ETA {eta:.0f}s")
        held = trace.held_percentiles(out_dir)
        p95 = held.get("p95")
        if p95:
            for kind, s in st["stages"].items():
                for l in s["leases"]:
                    if l["age_s"] > p95:
                        lines.append(
                            f"watch: STRAGGLER {l['uid']}@{l['worker']} "
                            f"held {l['age_s']}s > fleet p95 {p95:.1f}s"
                            + (" (lease EXPIRED)" if l["expired"] else ""))
        print("\n".join(lines), file=f, flush=True)
        prev_t = now
        prev_cov = {k: c["covered"] for k, c in st["coverage"].items()}
        prev_done = {k: s["done"] for k, s in st["stages"].items()}
        n += 1
        if st["complete"] or (iterations is not None and n >= iterations):
            return st
        time.sleep(interval)


_FLAGS_EPILOG = """\
commands:
  work (default)      claim and compute units until the run completes
  status              render live lease/coverage/telemetry state and exit
  fsck                verify every store artifact against its recorded
                      checksum (masterless, from files alone) and exit
  trace               assemble the fleet-wide causal trace from recorded
                      telemetry: unit lifecycles, clock-skew-aligned
                      timelines, critical path through the stage DAG,
                      wall-time buckets (compute / gather / store /
                      queue-wait / straggler-tail); writes Chrome
                      trace-event JSON loadable in Perfetto
  trends              render the cross-run history (one summary record
                      appended per finished run): regression flags vs
                      the previous same-fingerprint run and a
                      knob-vs-throughput table

flags (work):
  --out DIR           shared fleet store holding fleet.json   [required]
  --worker-id ID      stable queue identity                   [required]
  --ttl SEC           lease expiry                            [600]
  --poll SEC          barrier poll interval                   [0.25]
  --timeout SEC       max wait on one stage barrier           [3600]
  --unit-retries N    attempts before a unit is poisoned      [3]

flags (status):
  --out DIR           fleet store to inspect                  [required]
  --json              machine-readable status dict
  --expect-complete   exit 1 unless all stages done AND every
                      artifact at 100% row coverage
  --watch             re-render every --interval seconds until complete,
                      with per-stage throughput, ETA, and STRAGGLER
                      flags on leases older than the fleet p95 hold time
  --interval SEC      --watch refresh period                  [2]

flags (fsck):
  --out DIR           store to verify                         [required]
  --json              machine-readable fsck report
  --heal              revoke damaged tiles' manifest entries + queue done
                      markers so one normal fleet pass recomputes exactly
                      the damaged units (refused on a stale fingerprint:
                      wrong INPUTS cannot be healed, only recomputed)
  --expect-clean      exit 1 unless the store verifies clean

flags (trace):
  --out DIR           fleet store whose telemetry to assemble [required]
  --trace-out FILE    Chrome trace JSON path     [<out>/trace.json]
  --json              machine-readable trace analysis (units, stages,
                      buckets, critical path) instead of the one-pager
  --reconcile         exit 1 unless per-stage span totals match
                      `status` within 1% (CI gate)

flags (trends):
  --history FILE      history JSONL to render [<out>/history.jsonl or
                      $EDM_HISTORY; --out optional when given]
  --json              machine-readable trends analysis

environment:
  EDM_TELEMETRY       off | stdout | jsonl:<path>; unset -> per-worker
                      JSONL at <out>/telemetry/<worker-id>.jsonl
  EDM_HISTORY         shared run-history JSONL (default:
                      <out>/history.jsonl; one summary record appended
                      per finished run, same-run reruns replace theirs)
  EDM_FAULTS          fault-injection spec (runtime/faultpoints.py), e.g.
                      tile_pre_rename:crash@3 — testing only
  EDM_COORDINATOR     multi-host mesh (DESIGN.md SS14; applied only when
  EDM_NUM_PROCESSES   fleet.json opts in via its `distributed` flag):
  EDM_PROCESS_ID      coordinator host:port of rank 0, world size, and
                      THIS process's rank; each externally-launched
                      worker exports its own rank before `work`
                      (docs/OPERATIONS.md has the per-host recipe)
"""


def apply_spec_platform(out_dir: str | pathlib.Path) -> None:
    """Fleet workers' platform/mesh opt-in (DESIGN.md SS14): apply the
    fleet.json `platform` tier and — when the spec says `distributed` —
    join the multi-host mesh from this process's own EDM_* rank env.
    MUST run before the worker's first jax backend touch (FleetWorker's
    constructor builds the mesh), hence a free function on the raw spec
    rather than a FleetWorker method."""
    raw = json.loads((pathlib.Path(out_dir) / SPEC_NAME).read_text())
    from repro.runtime import platform as rt_platform

    tier = raw.get("platform")
    if tier:
        rt_platform.apply_platform(tier)
    if raw.get("distributed"):
        rt_platform.init_distributed()


def build_parser() -> argparse.ArgumentParser:
    """The edm_fleet CLI surface — exposed as a function so tests
    (tests/test_docs.py) can parse README/runbook invocations against
    the REAL parser."""
    ap = argparse.ArgumentParser(
        prog="edm_fleet",
        description=__doc__.split("\n")[0],
        epilog=_FLAGS_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("cmd", nargs="?", default="work",
                    choices=["work", "status", "fsck", "trace", "trends"],
                    help="work: run a fleet worker (default); status: "
                    "render live fleet state for --out and exit; fsck: "
                    "verify store integrity (optionally --heal) and exit; "
                    "trace: assemble the fleet causal trace + Chrome "
                    "trace JSON; trends: render the cross-run history")
    ap.add_argument("--out",
                    help="shared fleet store (must hold fleet.json; see "
                    "edm_run --workers or init_fleet); required for every "
                    "command except `trends --history FILE`")
    ap.add_argument("--worker-id",
                    help="stable queue identity; relaunching a killed "
                    "worker under the SAME id reclaims its leases instantly")
    ap.add_argument("--ttl", type=float, default=600.0,
                    help="lease expiry seconds (crashed foreign workers' "
                    "units become claimable after this)")
    ap.add_argument("--poll", type=float, default=0.25,
                    help="barrier poll interval seconds")
    ap.add_argument("--timeout", type=float, default=3600.0,
                    help="max seconds to wait on any one stage barrier")
    ap.add_argument("--unit-retries", type=int, default=3,
                    help="failed compute attempts (fleet-wide) before a "
                    "unit is poisoned and the whole fleet exits nonzero")
    ap.add_argument("--json", action="store_true",
                    help="status: print the machine-readable status dict")
    ap.add_argument("--expect-complete", action="store_true",
                    help="status: exit 1 unless every stage is done and "
                    "every artifact reports 100%% row coverage")
    ap.add_argument("--heal", action="store_true",
                    help="fsck: revoke damaged coverage + done markers so "
                    "a normal fleet pass recomputes exactly what was lost")
    ap.add_argument("--expect-clean", action="store_true",
                    help="fsck: exit 1 unless the store verifies clean")
    ap.add_argument("--watch", action="store_true",
                    help="status: re-render every --interval seconds until "
                    "the run completes, with throughput, ETA, and "
                    "straggler flags (lease age > fleet p95 hold time)")
    ap.add_argument("--interval", type=float, default=2.0,
                    help="status --watch refresh period in seconds")
    ap.add_argument("--trace-out",
                    help="trace: Chrome trace-event JSON destination "
                    "(default <out>/trace.json; load in Perfetto)")
    ap.add_argument("--reconcile", action="store_true",
                    help="trace: exit 1 unless per-stage span totals "
                    "reconcile with `status` within 1%%")
    ap.add_argument("--history",
                    help="trends: history JSONL to render (default "
                    "$EDM_HISTORY or <out>/history.jsonl)")
    return ap


def main(argv=None) -> None:
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.out is None and not (args.cmd == "trends" and args.history):
        ap.error(f"{args.cmd} requires --out")

    if args.cmd == "status":
        if args.watch:
            watch_status(args.out, interval=args.interval)
            return
        st = fleet_status(args.out)
        print(json.dumps(st, indent=1) if args.json else render_status(st))
        if args.expect_complete and not st["complete"]:
            sys.exit(1)
        return

    if args.cmd == "trace":
        tr = trace.assemble_trace(args.out)
        dest = pathlib.Path(args.trace_out) if args.trace_out \
            else pathlib.Path(args.out) / "trace.json"
        trace.write_chrome_trace(args.out, dest)
        rep = trace.reconcile(tr, fleet_status(args.out)) \
            if args.reconcile else None
        if args.json:
            print(json.dumps(
                {**tr, "reconcile": rep} if rep else tr, indent=1))
        else:
            print(trace.render_trace(tr))
            print(f"chrome trace: {dest} (load in Perfetto / "
                  "chrome://tracing)")
            if rep is not None:
                for stage, s in sorted(rep["stages"].items()):
                    print(f"reconcile {stage}: trace {s['trace_s']}s vs "
                          f"status {s['status_s']}s "
                          f"(delta {s['delta_pct']}%)")
        if rep is not None and not rep["ok"]:
            sys.exit(1)
        return

    if args.cmd == "trends":
        hp = pathlib.Path(args.history) if args.history \
            else history.history_path(args.out)
        recs = history.load_history(hp)
        if args.json:
            print(json.dumps(
                {"path": str(hp), **history.analyze_trends(recs)}, indent=1))
        else:
            print(f"history: {hp}")
            print(history.render_trends(recs))
        return

    if args.cmd == "fsck":
        report = integrity.fsck_store(args.out, heal=args.heal)
        print(json.dumps(report, indent=1) if args.json
              else integrity.render_fsck(report))
        if args.expect_clean and not report["clean"]:
            sys.exit(1)
        return

    if not args.worker_id:
        ap.error("work requires --worker-id")
    # Platform tier + optional multi-host mesh join from the shared spec,
    # BEFORE the first jax touch below (DESIGN.md SS14).
    apply_spec_platform(args.out)
    telemetry.configure_from_env(
        default_path=telemetry.worker_jsonl(args.out, args.worker_id),
        worker=args.worker_id,
    )
    try:
        FleetWorker(args.out, args.worker_id, ttl=args.ttl, poll=args.poll,
                    timeout=args.timeout,
                    unit_retries=args.unit_retries).run()
    finally:
        telemetry.shutdown()


if __name__ == "__main__":
    main()
