"""Training launcher: --arch <id> on any mesh, with sharded state, data
prefetch, async checkpointing, and the resilient step loop.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 300 --batch 8 --seq 512 --smoke --ckpt-dir /tmp/ckpt

On a real pod this launches under the production mesh; in this container
it runs on the local CPU devices (optionally faked via XLA_FLAGS)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS, get_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import Prefetcher, TokenStream
from repro.launch.mesh import make_cpu_mesh, make_production_mesh
from repro.launch.steps import TrainState, make_train_step
from repro.runtime.fault import ResilientLoop
from repro.sharding import policy as POL


def build(cfg, tc, mesh, batch, seq):
    policy = POL.auto_policy(cfg, mesh)
    key = jax.random.PRNGKey(tc.seed)
    state_sds = jax.eval_shape(lambda: TrainState.create(cfg, tc, key))
    p_specs = POL.param_specs(policy, state_sds.params)
    from repro.launch.dryrun import _opt_specs  # shared spec logic

    state_specs = TrainState(
        params=p_specs,
        opt=_opt_specs(policy, p_specs, state_sds.params, tc),
        step=jax.sharding.PartitionSpec(),
    )
    named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    with mesh:
        state = jax.jit(
            lambda k: TrainState.create(cfg, tc, k),
            out_shardings=named(state_specs),
        )(key)
    step_fn = jax.jit(
        make_train_step(cfg, tc), donate_argnums=(0,),
        in_shardings=(named(state_specs), None),
    )
    return state, step_fn, named(state_specs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="smollm-135m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    tc = TrainConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(1, args.steps // 20))
    mesh = (
        make_production_mesh() if args.production_mesh else make_cpu_mesh()
    )
    state, step_fn, state_shardings = build(cfg, tc, mesh, args.batch, args.seq)

    extra = {}
    if cfg.family == "audio":
        extra["audio"] = ((args.batch, cfg.n_frontend_tokens, cfg.d_model), np.float32)
    if cfg.family == "vlm":
        extra["image_embeds"] = ((args.batch, cfg.n_frontend_tokens, cfg.d_model), np.float32)
    stream = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=tc.seed, extra_specs=extra)
    ckpt = CheckpointManager(args.ckpt_dir, keep_last=2)

    # resume if a checkpoint exists (elastic: any mesh)
    start = 0
    restored = ckpt.restore_latest(state, state_shardings)
    if restored[0] is not None:
        start, state = restored
        print(f"resumed from step {start}")

    def logging_step(state, batch):
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        step = int(state.step)
        if step % args.log_every == 0 or step == 1:
            print(
                f"step {step:5d} loss={float(metrics['loss']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} dt={time.time()-t0:.3f}s"
            )
        return state, metrics

    loop = ResilientLoop(logging_step, ckpt, save_every=args.save_every)
    with mesh:
        state, step, metrics = loop.run(
            state, stream.batch_at, n_steps=args.steps, start_step=start,
            shardings=state_shardings,
        )
    print(f"done at step {step}; final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
