"""Roofline term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips * peak_FLOPs)
memory term     = HLO_bytes / (chips * HBM_bw)
collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / bytes come from compiled.cost_analysis() (XLA reports the
per-device partitioned module; we normalize to per-chip).  Collective bytes
are not in cost_analysis: we parse the compiled HLO text and sum, per
collective op, max(operand bytes, result bytes).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# e.g.:  %ag = bf16[8,128]{1,0} all-gather(%x), ...
# NB: result types may be TUPLES with /*index=N*/ comments (variadic
# all-reduce of many gradient tensors), so the result group must not
# exclude '=' characters.
_LINE_RE = re.compile(
    r"=\s*(.*?)\s+(" + "|".join(k + r"(?:-start|-done)?" for k in _COLL_KINDS) + r")\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind byte totals from compiled HLO text (per device)."""
    out: dict[str, int] = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        result_part, kind = m.groups()
        kind = kind.replace("-start", "").replace("-done", "")
        if kind.endswith("-done"):
            continue
        # operands: everything inside the call parens
        call = line[m.end() :]
        result_bytes = _shape_bytes(result_part)
        operand_bytes = _shape_bytes(call.split(")", 1)[0]) if ")" in call else 0
        out[kind] += max(result_bytes, operand_bytes)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_by_kind: dict

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the run bounded by the compute roofline: t_comp/t_max."""
        t = max(self.t_memory, self.t_collective, self.t_compute, 1e-30)
        return self.t_compute / t

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_by_kind": self.coll_by_kind,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(compiled) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_ = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(
        flops_per_chip=flops,
        bytes_per_chip=bytes_,
        coll_bytes_per_chip=float(sum(coll.values())),
        coll_by_kind=coll,
    )


# --------------------------------------------------------------------------
# model FLOPs (the "useful compute" yardstick): 6 * N * D
# --------------------------------------------------------------------------
def model_flops(cfg, n_tokens: int, n_params: int, active_params: int | None = None) -> float:
    n = active_params if active_params is not None else n_params
    return 6.0 * n * n_tokens


def active_params(cfg, params_tree_shapes) -> int:
    """MoE: expert weights count at k/E; everything else fully."""
    import jax

    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree_shapes)[0]:
        names = [p.key for p in path if hasattr(p, "key")]
        n = int(np.prod(leaf.shape))
        if "moe" in names and names[-1] in ("w_up", "w_down", "w_gate"):
            n = int(n * cfg.experts_per_tok / cfg.n_experts)
        total += n
    return total
