"""Mixture-of-Experts block: top-k router + GShard-style capacity dispatch.

Dispatch/combine are one-hot einsums over (group, token, expert, capacity) —
the TPU-native formulation (dense MXU work, no scatter).  Tokens are split
into fixed-size groups so capacity is local and the dispatch tensor stays
bounded; overflow tokens are dropped (standard GShard semantics,
capacity_factor controls the drop rate).  An auxiliary load-balancing loss
(Switch Transformer eq. 4) is returned alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Params, _normal


def init_moe(key, d_model, d_ff, n_experts, act: str, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "router": _normal(ks[0], (d_model, n_experts), jnp.float32),
        "w_up": _normal(ks[1], (n_experts, d_model, d_ff), dtype),
        "w_down": _normal(ks[2], (n_experts, d_ff, d_model), dtype),
    }
    if act == "swiglu":
        p["w_gate"] = _normal(ks[3], (n_experts, d_model, d_ff), dtype)
    return p


def moe_fwd(
    p: Params,
    x: jax.Array,
    n_experts: int,
    top_k: int,
    act: str,
    capacity_factor: float = 1.25,
    group_size: int = 1024,
    no_drop: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    no_drop=True sets capacity = group size (nothing ever dropped) — used on
    the single-token decode path where the dispatch tensor is tiny and drop
    noise would corrupt generation.
    """
    B, S, d = x.shape
    T0 = B * S
    g = min(group_size, T0)
    T = -(-T0 // g) * g  # pad tokens to a group multiple
    xt = x.reshape(T0, d)
    if T != T0:
        xt = jnp.pad(xt, ((0, T - T0), (0, 0)))
    G = T // g
    xt = xt.reshape(G, g, d)

    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (G, g, E)

    # top-k gates, renormalized over the selected experts.
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # (G, g, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    C = g if no_drop else max(1, int(capacity_factor * g * top_k / n_experts))
    # Position of each (token, slot) within its expert's capacity buffer:
    # count prior assignments to the same expert, slot-major then token-major
    # (GShard ordering: earlier tokens and earlier slots win capacity).
    onehot = jax.nn.one_hot(expert_ids, n_experts, dtype=jnp.float32)  # (G,g,k,E)
    if T != T0:  # padded tokens never dispatch nor consume capacity
        valid = (jnp.arange(T) < T0).astype(jnp.float32).reshape(G, g)
        onehot = onehot * valid[:, :, None, None]
    slot_major = onehot.transpose(0, 2, 1, 3).reshape(G, top_k * g, n_experts)
    pos_sm = jnp.cumsum(slot_major, axis=1) - slot_major  # prior count
    pos = (
        pos_sm.reshape(G, top_k, g, n_experts).transpose(0, 2, 1, 3)
    )  # (G, g, k, E)
    within = pos < C
    keep = within * onehot  # (G,g,k,E) 1 where token-slot kept

    pos_idx = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)  # (G,g,k)
    cap_oh = jax.nn.one_hot(jnp.minimum(pos_idx, C - 1), C, dtype=jnp.float32)
    # dispatch[g,s,e,c] = 1 iff token s goes to expert e at capacity slot c
    dispatch = jnp.einsum("gske,gskc->gsec", keep, cap_oh)
    combine = jnp.einsum(
        "gske,gskc,gsk->gsec", keep, cap_oh, gate_vals.astype(jnp.float32)
    )

    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xt)
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) * jnp.einsum(
            "gecd,edf->gecf", xe, p["w_up"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, p["w_up"]))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)

    # Switch load-balancing loss: E * sum_e fraction_e * router_prob_e.
    frac = jnp.mean(keep.sum(2), axis=1)  # (G, E) fraction of tokens kept
    prob = jnp.mean(probs, axis=1)  # (G, E)
    aux = n_experts * jnp.mean(jnp.sum(frac * prob, axis=-1))
    return y.reshape(T, d)[:T0].reshape(B, S, d), aux
