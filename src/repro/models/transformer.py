"""Model assembly for all assigned architecture families.

Families and their layer layouts (scan-over-layers with stacked params):

  dense / moe   : uniform decoder blocks                       -> one scan
  ssm (mamba2)  : uniform Mamba2 blocks                        -> one scan
  hybrid(zamba2): repeating unit from cfg.hybrid_pattern, e.g. ("m","m","a");
                  "a" is ONE shared attention block (params reused every unit)
                  with per-unit LoRA adapters, reading concat(h, h0)
  audio(whisper): encoder scan (bidirectional self) + decoder scan
                  (causal self + cross-attn); frontend is a stub — batches
                  carry precomputed frame embeddings
  vlm (llama-v) : decoder units of cross_attn_period layers where the last-1
                  position is a gated cross-attention block over image
                  patch embeddings (stub frontend)

Each family provides: init, train loss, prefill (logits + cache), and
single-token decode (logits + updated cache).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

Params = dict


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------
def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _stacked_init(fn, key, n: int) -> Params:
    return jax.vmap(fn)(jax.random.split(key, n))


def _attn_dims(cfg: ModelConfig, cross: bool = False, d_in: int | None = None) -> L.AttnDims:
    return L.AttnDims(
        d_model=d_in or cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.head_dim,
        qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta,
        use_rope=(cfg.pos == "rope") and not cross,
        causal=not cross,
        kv_d_model=cfg.d_model if cross else None,
        impl=cfg.attn_impl,
        chunk=cfg.attn_chunk,
        unroll=not cfg.scan_layers,
        seq_shard=cfg.attn_seq_shard,
    )


def _ssm_dims(cfg: ModelConfig) -> SSM.SSMDims:
    return SSM.SSMDims(
        d_model=cfg.d_model,
        d_state=cfg.ssm_state,
        d_conv=cfg.ssm_conv,
        expand=cfg.ssm_expand,
        head_dim=cfg.ssm_head_dim,
        chunk=cfg.ssm_chunk,
    )


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------
def _init_dense_block(key, cfg: ModelConfig, causal=True) -> Params:
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    p = {
        "ln1": L.init_norm(cfg.norm, cfg.d_model, dt),
        "attn": L.init_attention(ks[0], _attn_dims(cfg), dt),
        "ln2": L.init_norm(cfg.norm, cfg.d_model, dt),
    }
    if cfg.n_experts > 0:
        p["moe"] = MOE.init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.mlp_act, dt)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act, dt)
    return p


def _dense_block_fwd(
    p: Params,
    cfg: ModelConfig,
    x,
    positions=None,
    cache=None,
    cache_pos=None,
):
    h, new_cache = L.attention_fwd(
        p["attn"], _attn_dims(cfg), L.apply_norm(cfg.norm, p["ln1"], x),
        positions=positions, cache=cache, cache_pos=cache_pos,
    )
    x = x + h
    hn = L.apply_norm(cfg.norm, p["ln2"], x)
    if cfg.n_experts > 0:
        h, aux = MOE.moe_fwd(
            p["moe"], hn, cfg.n_experts, cfg.experts_per_tok, cfg.mlp_act,
            cfg.capacity_factor, cfg.moe_group_size,
            no_drop=(x.shape[1] == 1),  # single-token decode: never drop
        )
    else:
        h, aux = L.mlp_fwd(p["mlp"], hn, cfg.mlp_act), 0.0
    return x + h, aux, new_cache


def _init_cross_block(key, cfg: ModelConfig, gated: bool) -> Params:
    """VLM gated cross-attn block / whisper-decoder cross sub-block."""
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    p = {
        "ln1": L.init_norm(cfg.norm, cfg.d_model, dt),
        "xattn": L.init_attention(ks[0], _attn_dims(cfg, cross=True), dt),
        "ln2": L.init_norm(cfg.norm, cfg.d_model, dt),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act, dt),
    }
    if gated:
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_mlp"] = jnp.zeros((), jnp.float32)
    return p


def _cross_block_fwd(p: Params, cfg: ModelConfig, x, src_kv: Params):
    """src_kv: precomputed {'k','v'} from image/encoder embeddings."""
    h, _ = L.attention_fwd(
        p["xattn"], _attn_dims(cfg, cross=True),
        L.apply_norm(cfg.norm, p["ln1"], x), cache=src_kv,
    )
    if "gate_attn" in p:
        h = jnp.tanh(p["gate_attn"]).astype(h.dtype) * h
    x = x + h
    h = L.mlp_fwd(p["mlp"], L.apply_norm(cfg.norm, p["ln2"], x), cfg.mlp_act)
    if "gate_mlp" in p:
        h = jnp.tanh(p["gate_mlp"]).astype(h.dtype) * h
    return x + h


def _cross_kv(p_attn: Params, cfg: ModelConfig, src: jax.Array) -> Params:
    """Precompute cross-attention K/V once per sequence (prefill/decode)."""
    B, Ssrc, _ = src.shape
    a = _attn_dims(cfg, cross=True)
    k = L.linear(p_attn["wk"], src).reshape(B, Ssrc, a.n_kv_heads, a.d_head)
    v = L.linear(p_attn["wv"], src).reshape(B, Ssrc, a.n_kv_heads, a.d_head)
    return {"k": k, "v": v}


# --------------------------------------------------------------------------
# embeddings / head
# --------------------------------------------------------------------------
def _init_embed(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    p = {"tok": L._normal(ks[0], (cfg.padded_vocab, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = L._normal(ks[1], (cfg.d_model, cfg.padded_vocab), dt)
    p["ln_f"] = L.init_norm(cfg.norm, cfg.d_model, dt)
    if cfg.pos == "learned":
        p["pos"] = L._normal(ks[2], (65536, cfg.d_model), dt)
    return p


def _embed(p: Params, cfg: ModelConfig, tokens, pos_offset=0):
    x = p["tok"][tokens]
    if cfg.pos == "learned":
        S = tokens.shape[1]
        x = x + jax.lax.dynamic_slice_in_dim(p["pos"], pos_offset, S, axis=0)
    return x


def _head(p: Params, cfg: ModelConfig, x):
    x = L.apply_norm(cfg.norm, p["ln_f"], x)
    w = p["tok"].T if cfg.tie_embeddings else p["lm_head"]
    return (x @ w).astype(jnp.float32)


# --------------------------------------------------------------------------
# scan machinery
# --------------------------------------------------------------------------
def _scan(body, x, xs, remat: bool, scan: bool = True):
    f = jax.checkpoint(body) if remat else body
    if scan:
        return jax.lax.scan(f, x, xs)
    # unrolled python loop with scan-identical semantics (stacked outputs);
    # used by the dry-run cost extrapolation (cost_analysis counts scan
    # bodies once) and available as a compile-time/perf knob.
    n = jax.tree.leaves(xs)[0].shape[0] if xs is not None else 0
    outs = []
    for i in range(n):
        x, out = f(x, jax.tree.map(lambda a: a[i], xs))
        outs.append(out)
    if outs and jax.tree.leaves(outs[0]):
        outs = jax.tree.map(lambda *a: jnp.stack(a), *outs)
    else:
        outs = jnp.zeros((n,))
    return x, outs


def _init_mamba_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    dt = _dtype(cfg)
    return {
        "ln": L.init_norm(cfg.norm, cfg.d_model, dt),
        "mixer": SSM.init_mamba(ks[0], _ssm_dims(cfg), dt),
    }



def _prefill_head(params, cfg: ModelConfig, x):
    """Serving prefill: optionally emit only the final position's logits."""
    if cfg.prefill_last_only:
        x = x[:, -1:]
    return _head(params["embed"], cfg, x)

# ==========================================================================
# dense / moe decoder-only family
# ==========================================================================
def _init_dense_family(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "embed": _init_embed(k1, cfg),
        "blocks": _stacked_init(
            lambda k: _init_dense_block(k, cfg), k2, cfg.n_layers
        ),
    }


def _fwd_dense(params, cfg: ModelConfig, tokens, remat=True):
    x = _embed(params["embed"], cfg, tokens)

    def body(h, lp):
        h2, aux, _ = _dense_block_fwd(lp, cfg, h)
        return h2, aux

    x, auxs = _scan(body, x, params["blocks"], remat, cfg.scan_layers)
    return _head(params["embed"], cfg, x), jnp.sum(auxs)


def _dense_cache(cfg: ModelConfig, B, cache_len, dtype):
    kshape = (cfg.n_layers, B, cache_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(kshape, dtype), "v": jnp.zeros(kshape, dtype)}


def _prefill_dense(params, cfg: ModelConfig, tokens, cache, remat=True):
    x = _embed(params["embed"], cfg, tokens)

    def body(h, inp):
        lp, cl = inp
        h2, aux, ncl = _dense_block_fwd(lp, cfg, h, cache=cl, cache_pos=0)
        return h2, (aux, ncl)

    x, (auxs, ncache) = _scan(body, x, (params["blocks"], cache), remat, cfg.scan_layers)
    return _prefill_head(params, cfg, x), ncache


def _decode_dense(params, cfg: ModelConfig, token, cache, pos):
    x = _embed(params["embed"], cfg, token, pos_offset=pos)

    def body(h, inp):
        lp, cl = inp
        h2, _, ncl = _dense_block_fwd(lp, cfg, h, cache=cl, cache_pos=pos)
        return h2, ncl

    x, ncache = _scan(body, x, (params["blocks"], cache), False, cfg.scan_layers)
    return _head(params["embed"], cfg, x), ncache


# ==========================================================================
# ssm (mamba2) family
# ==========================================================================
def _init_ssm_family(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "embed": _init_embed(k1, cfg),
        "blocks": _stacked_init(
            lambda k: _init_mamba_block(k, cfg), k2, cfg.n_layers
        ),
    }


def _fwd_ssm(params, cfg: ModelConfig, tokens, remat=True):
    x = _embed(params["embed"], cfg, tokens)
    dims = _ssm_dims(cfg)

    def body(h, lp):
        h2 = h + SSM.mamba_fwd(lp["mixer"], dims, L.apply_norm(cfg.norm, lp["ln"], h))
        return h2, 0.0

    x, _ = _scan(body, x, params["blocks"], remat, cfg.scan_layers)
    return _head(params["embed"], cfg, x), jnp.asarray(0.0)


def _ssm_cache(cfg: ModelConfig, B, cache_len, dtype):
    del cache_len  # O(1) state — the whole point of the ssm family
    dims = _ssm_dims(cfg)
    st = SSM.mamba_init_state(dims, B, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), st
    )


def _prefill_ssm(params, cfg: ModelConfig, tokens, cache, remat=True):
    x = _embed(params["embed"], cfg, tokens)
    dims = _ssm_dims(cfg)

    def body(h, inp):
        lp, _cl = inp
        y, st = SSM.mamba_fwd(
            lp["mixer"], dims, L.apply_norm(cfg.norm, lp["ln"], h), return_state=True
        )
        return h + y, st

    x, ncache = _scan(body, x, (params["blocks"], cache), remat, cfg.scan_layers)
    ncache = {"conv": ncache["conv"].astype(cache["conv"].dtype), "ssm": ncache["ssm"]}
    return _prefill_head(params, cfg, x), ncache


def _decode_ssm(params, cfg: ModelConfig, token, cache, pos):
    x = _embed(params["embed"], cfg, token, pos_offset=pos)
    dims = _ssm_dims(cfg)

    def body(h, inp):
        lp, cl = inp
        y, st = SSM.mamba_decode_step(
            lp["mixer"], dims, L.apply_norm(cfg.norm, lp["ln"], h), cl
        )
        return h + y, st

    x, ncache = _scan(body, x, (params["blocks"], cache), False, cfg.scan_layers)
    return _head(params["embed"], cfg, x), ncache


# ==========================================================================
# hybrid (zamba2) family: units of cfg.hybrid_pattern, "a" = shared block
# ==========================================================================
def _hybrid_counts(cfg: ModelConfig):
    unit = len(cfg.hybrid_pattern)
    assert cfg.n_layers % unit == 0, "n_layers must tile hybrid_pattern"
    n_units = cfg.n_layers // unit
    m_per_unit = sum(1 for s in cfg.hybrid_pattern if s == "m")
    return n_units, m_per_unit


def _init_shared_block(key, cfg: ModelConfig) -> Params:
    """Shared attention+MLP block reading concat(h, h0) (2*d_model wide)."""
    ks = jax.random.split(key, 6)
    dt = _dtype(cfg)
    d2 = 2 * cfg.d_model
    hd = cfg.head_dim
    return {
        "ln1": L.init_norm(cfg.norm, d2, dt),
        "wq": L.init_linear(ks[0], d2, cfg.n_heads * hd, dt),
        "wk": L.init_linear(ks[1], d2, cfg.n_kv_heads * hd, dt),
        "wv": L.init_linear(ks[2], d2, cfg.n_kv_heads * hd, dt),
        "wo": L.init_linear(ks[3], cfg.n_heads * hd, cfg.d_model, dt),
        "ln2": L.init_norm(cfg.norm, d2, dt),
        "w_up": L.init_linear(ks[4], d2, cfg.d_ff, dt),
        "w_down": L.init_linear(ks[5], cfg.d_ff, cfg.d_model, dt),
    }


def _init_lora(key, cfg: ModelConfig) -> Params:
    """Per-unit LoRA adapters on the shared block's q/k/v (arXiv:2411.15242)."""
    ks = jax.random.split(key, 6)
    dt = _dtype(cfg)
    d2, hd, r = 2 * cfg.d_model, cfg.head_dim, cfg.lora_rank
    out = {}
    for i, (nm, dout) in enumerate(
        [("q", cfg.n_heads * hd), ("k", cfg.n_kv_heads * hd), ("v", cfg.n_kv_heads * hd)]
    ):
        out[f"a_{nm}"] = L._normal(ks[2 * i], (d2, r), dt)
        out[f"b_{nm}"] = jnp.zeros((r, dout), dt)
    return out


def _shared_block_fwd(sp, lora, cfg: ModelConfig, x, x0, cache=None, cache_pos=None):
    B, S, _ = x.shape
    hd = cfg.head_dim
    xin = jnp.concatenate([x, x0], axis=-1)
    h = L.apply_norm(cfg.norm, sp["ln1"], xin)

    def proj(nm, wnm, nh):
        w = sp[wnm]["w"]
        y = h @ w + (h @ lora[f"a_{nm}"]) @ lora[f"b_{nm}"]
        return y.reshape(B, S, nh, hd)

    q = proj("q", "wq", cfg.n_heads)
    k = proj("k", "wk", cfg.n_kv_heads)
    v = proj("v", "wv", cfg.n_kv_heads)
    positions = (
        jnp.arange(S) if cache_pos is None else cache_pos + jnp.arange(S)
    )
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
        new_cache = {"k": k, "v": v}
        o = L._sdpa(q, k, v, causal=True, q_pos=positions,
                    impl=cfg.attn_impl, chunk=cfg.attn_chunk,
                    unroll=not cfg.scan_layers)
    else:
        o = L._sdpa(q, k, v, causal=True, impl=cfg.attn_impl,
                    chunk=cfg.attn_chunk, unroll=not cfg.scan_layers)
    x = x + L.linear(sp["wo"], o.reshape(B, S, cfg.n_heads * hd))

    xin2 = jnp.concatenate([x, x0], axis=-1)
    h2 = L.apply_norm(cfg.norm, sp["ln2"], xin2)
    x = x + L.linear(sp["w_down"], jax.nn.gelu(L.linear(sp["w_up"], h2)))
    return x, new_cache


def _init_hybrid_family(cfg: ModelConfig, key) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n_units, m_per_unit = _hybrid_counts(cfg)

    def unit_mambas(k):
        return _stacked_init(lambda kk: _init_mamba_block(kk, cfg), k, m_per_unit)

    return {
        "embed": _init_embed(k1, cfg),
        "mamba": _stacked_init(unit_mambas, k2, n_units),
        "shared": _init_shared_block(k3, cfg),
        "lora": _stacked_init(lambda k: _init_lora(k, cfg), k4, n_units),
    }


def _hybrid_unit_body(cfg: ModelConfig, shared, x, x0, mamba_u, lora_u,
                      ssm_states=None, attn_cache=None, pos=None, decode=False):
    dims = _ssm_dims(cfg)
    n_units, m_per_unit = _hybrid_counts(cfg)
    new_states = []
    mi = 0
    new_attn_cache = None
    for sym in cfg.hybrid_pattern:
        if sym == "m":
            lp = jax.tree.map(lambda a: a[mi], mamba_u)
            hn = L.apply_norm(cfg.norm, lp["ln"], x)
            if decode:
                st = jax.tree.map(lambda a: a[mi], ssm_states)
                y, nst = SSM.mamba_decode_step(lp["mixer"], dims, hn, st)
                new_states.append(nst)
            elif ssm_states is not None:  # prefill
                y, nst = SSM.mamba_fwd(lp["mixer"], dims, hn, return_state=True)
                new_states.append(nst)
            else:
                y = SSM.mamba_fwd(lp["mixer"], dims, hn)
            x = x + y
            mi += 1
        else:  # shared attention block
            x, new_attn_cache = _shared_block_fwd(
                shared, lora_u, cfg, x, x0, cache=attn_cache, cache_pos=pos
            )
    if new_states:
        new_states = jax.tree.map(lambda *a: jnp.stack(a), *new_states)
    else:
        new_states = None
    return x, new_states, new_attn_cache


def _fwd_hybrid(params, cfg: ModelConfig, tokens, remat=True):
    x = _embed(params["embed"], cfg, tokens)
    x0 = x

    def body(h, inp):
        mamba_u, lora_u = inp
        h2, _, _ = _hybrid_unit_body(cfg, params["shared"], h, x0, mamba_u, lora_u)
        return h2, 0.0

    x, _ = _scan(body, x, (params["mamba"], params["lora"]), remat, cfg.scan_layers)
    return _head(params["embed"], cfg, x), jnp.asarray(0.0)


def _hybrid_cache(cfg: ModelConfig, B, cache_len, dtype):
    n_units, m_per_unit = _hybrid_counts(cfg)
    dims = _ssm_dims(cfg)
    st = SSM.mamba_init_state(dims, B, dtype)
    kshape = (n_units, B, cache_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "ssm": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_units, m_per_unit) + a.shape), st
        ),
        "attn": {"k": jnp.zeros(kshape, dtype), "v": jnp.zeros(kshape, dtype)},
        "x0": jnp.zeros((B, 1, cfg.d_model), dtype),  # decode x0 convention
    }


def _prefill_hybrid(params, cfg: ModelConfig, tokens, cache, remat=True):
    x = _embed(params["embed"], cfg, tokens)
    x0 = x

    def body(h, inp):
        mamba_u, lora_u, ssm_c, attn_c = inp
        h2, nst, nattn = _hybrid_unit_body(
            cfg, params["shared"], h, x0, mamba_u, lora_u,
            ssm_states=ssm_c, attn_cache=attn_c, pos=0,
        )
        return h2, (nst, nattn)

    x, (nssm, nattn) = _scan(
        body, x, (params["mamba"], params["lora"], cache["ssm"], cache["attn"]), remat
    )
    nssm = jax.tree.map(lambda a, c: a.astype(c.dtype), nssm, cache["ssm"])
    ncache = {"ssm": nssm, "attn": nattn, "x0": cache["x0"]}
    return _prefill_head(params, cfg, x), ncache


def _decode_hybrid(params, cfg: ModelConfig, token, cache, pos):
    x = _embed(params["embed"], cfg, token, pos_offset=pos)
    x0 = x

    def body(h, inp):
        mamba_u, lora_u, ssm_c, attn_c = inp
        h2, nst, nattn = _hybrid_unit_body(
            cfg, params["shared"], h, x0, mamba_u, lora_u,
            ssm_states=ssm_c, attn_cache=attn_c, pos=pos, decode=True,
        )
        return h2, (nst, nattn)

    x, (nssm, nattn) = _scan(
        body, x, (params["mamba"], params["lora"], cache["ssm"], cache["attn"]),
        False, cfg.scan_layers,
    )
    ncache = {"ssm": nssm, "attn": nattn, "x0": cache["x0"]}
    return _head(params["embed"], cfg, x), ncache


# ==========================================================================
# audio (whisper) encoder-decoder family
# ==========================================================================
def _init_enc_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    dt = _dtype(cfg)
    a = _attn_dims(cfg)
    a = L.AttnDims(**{**a.__dict__, "causal": False, "use_rope": False})
    return {
        "ln1": L.init_norm(cfg.norm, cfg.d_model, dt),
        "attn": L.init_attention(ks[0], a, dt),
        "ln2": L.init_norm(cfg.norm, cfg.d_model, dt),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act, dt),
    }


def _enc_block_fwd(p, cfg: ModelConfig, x):
    a = _attn_dims(cfg)
    a = L.AttnDims(**{**a.__dict__, "causal": False, "use_rope": False})
    h, _ = L.attention_fwd(p["attn"], a, L.apply_norm(cfg.norm, p["ln1"], x))
    x = x + h
    return x + L.mlp_fwd(p["mlp"], L.apply_norm(cfg.norm, p["ln2"], x), cfg.mlp_act)


def _init_dec_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    return {
        "ln1": L.init_norm(cfg.norm, cfg.d_model, dt),
        "attn": L.init_attention(ks[0], _attn_dims(cfg), dt),
        "ln2": L.init_norm(cfg.norm, cfg.d_model, dt),
        "xattn": L.init_attention(ks[1], _attn_dims(cfg, cross=True), dt),
        "ln3": L.init_norm(cfg.norm, cfg.d_model, dt),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_act, dt),
    }


def _dec_block_fwd(p, cfg: ModelConfig, x, enc_kv, cache=None, cache_pos=None):
    h, ncache = L.attention_fwd(
        p["attn"], _attn_dims(cfg), L.apply_norm(cfg.norm, p["ln1"], x),
        cache=cache, cache_pos=cache_pos,
    )
    x = x + h
    h, _ = L.attention_fwd(
        p["xattn"], _attn_dims(cfg, cross=True),
        L.apply_norm(cfg.norm, p["ln2"], x), cache=enc_kv,
    )
    x = x + h
    return x + L.mlp_fwd(p["mlp"], L.apply_norm(cfg.norm, p["ln3"], x), cfg.mlp_act), ncache


def _init_audio_family(cfg: ModelConfig, key) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = _dtype(cfg)
    return {
        "embed": _init_embed(k1, cfg),
        "enc_pos": L._normal(k2, (cfg.n_frontend_tokens, cfg.d_model), dt),
        "enc_blocks": _stacked_init(lambda k: _init_enc_block(k, cfg), k3, cfg.n_enc_layers),
        "enc_ln_f": L.init_norm(cfg.norm, cfg.d_model, dt),
        "dec_blocks": _stacked_init(lambda k: _init_dec_block(k, cfg), k4, cfg.n_layers),
    }


def _encode_audio(params, cfg: ModelConfig, audio, remat=True):
    x = audio.astype(_dtype(cfg)) + params["enc_pos"]

    def body(h, lp):
        return _enc_block_fwd(lp, cfg, h), 0.0

    x, _ = _scan(body, x, params["enc_blocks"], remat, cfg.scan_layers)
    return L.apply_norm(cfg.norm, params["enc_ln_f"], x)


def _fwd_audio(params, cfg: ModelConfig, batch, remat=True):
    enc = _encode_audio(params, cfg, batch["audio"], remat)
    x = _embed(params["embed"], cfg, batch["tokens"])

    def body(h, lp):
        enc_kv = _cross_kv(lp["xattn"], cfg, enc)
        h2, _ = _dec_block_fwd(lp, cfg, h, enc_kv)
        return h2, 0.0

    x, _ = _scan(body, x, params["dec_blocks"], remat, cfg.scan_layers)
    return _head(params["embed"], cfg, x), jnp.asarray(0.0)


def _audio_cache(cfg: ModelConfig, B, cache_len, dtype):
    kshape = (cfg.n_layers, B, cache_len, cfg.n_kv_heads, cfg.head_dim)
    xshape = (cfg.n_layers, B, cfg.n_frontend_tokens, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(kshape, dtype), "v": jnp.zeros(kshape, dtype),
        "xk": jnp.zeros(xshape, dtype), "xv": jnp.zeros(xshape, dtype),
    }


def _prefill_audio(params, cfg: ModelConfig, batch, cache, remat=True):
    enc = _encode_audio(params, cfg, batch["audio"], remat)
    x = _embed(params["embed"], cfg, batch["tokens"])

    def body(h, inp):
        lp, cl = inp
        enc_kv = _cross_kv(lp["xattn"], cfg, enc)
        h2, nc = _dec_block_fwd(
            lp, cfg, h, enc_kv, cache={"k": cl["k"], "v": cl["v"]}, cache_pos=0
        )
        return h2, {**nc, "xk": enc_kv["k"].astype(cl["xk"].dtype),
                    "xv": enc_kv["v"].astype(cl["xv"].dtype)}

    x, ncache = _scan(body, x, (params["dec_blocks"], cache), remat, cfg.scan_layers)
    return _prefill_head(params, cfg, x), ncache


def _decode_audio(params, cfg: ModelConfig, token, cache, pos):
    x = _embed(params["embed"], cfg, token, pos_offset=pos)

    def body(h, inp):
        lp, cl = inp
        enc_kv = {"k": cl["xk"], "v": cl["xv"]}
        h2, nc = _dec_block_fwd(
            lp, cfg, h, enc_kv, cache={"k": cl["k"], "v": cl["v"]}, cache_pos=pos
        )
        return h2, {**nc, "xk": cl["xk"], "xv": cl["xv"]}

    x, ncache = _scan(body, x, (params["dec_blocks"], cache), False, cfg.scan_layers)
    return _head(params["embed"], cfg, x), ncache


# ==========================================================================
# vlm (llama-3.2-vision) family: units of cross_attn_period decoder layers,
# position (period-2) is a gated cross-attention block over image patches
# ==========================================================================
def _vlm_counts(cfg: ModelConfig):
    p = cfg.cross_attn_period
    assert cfg.n_layers % p == 0
    return cfg.n_layers // p, p


def _init_vlm_family(cfg: ModelConfig, key) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    n_units, period = _vlm_counts(cfg)

    def unit_selfs(k):
        return _stacked_init(lambda kk: _init_dense_block(kk, cfg), k, period - 1)

    return {
        "embed": _init_embed(k1, cfg),
        "selfs": _stacked_init(unit_selfs, k2, n_units),
        "cross": _stacked_init(lambda k: _init_cross_block(k, cfg, gated=True), k3, n_units),
    }


def _vlm_unit_body(cfg, x, selfs_u, cross_u, img_kv, self_caches=None, pos=None):
    """period-1 self layers with the gated cross block inserted before the
    last one (llama-3.2 layout: cross at in-unit index period-2)."""
    _, period = _vlm_counts(cfg)
    new_caches = []

    def run_self(x, j, cl):
        lp = jax.tree.map(lambda a: a[j], selfs_u)
        x, _, nc = _dense_block_fwd(lp, cfg, x, cache=cl, cache_pos=pos)
        return x, nc

    for j in range(period - 2):
        cl = None if self_caches is None else jax.tree.map(lambda a: a[j], self_caches)
        x, nc = run_self(x, j, cl)
        new_caches.append(nc)
    x = _cross_block_fwd(cross_u, cfg, x, img_kv)
    cl = None if self_caches is None else jax.tree.map(lambda a: a[period - 2], self_caches)
    x, nc = run_self(x, period - 2, cl)
    new_caches.append(nc)
    if self_caches is not None:
        new_caches = jax.tree.map(lambda *a: jnp.stack(a), *new_caches)
    else:
        new_caches = None
    return x, new_caches


def _fwd_vlm(params, cfg: ModelConfig, batch, remat=True):
    x = _embed(params["embed"], cfg, batch["tokens"])
    img = batch["image_embeds"].astype(_dtype(cfg))

    def body(h, inp):
        selfs_u, cross_u = inp
        img_kv = _cross_kv(cross_u["xattn"], cfg, img)
        h2, _ = _vlm_unit_body(cfg, h, selfs_u, cross_u, img_kv)
        return h2, 0.0

    x, _ = _scan(body, x, (params["selfs"], params["cross"]), remat, cfg.scan_layers)
    return _head(params["embed"], cfg, x), jnp.asarray(0.0)


def _vlm_cache(cfg: ModelConfig, B, cache_len, dtype):
    n_units, period = _vlm_counts(cfg)
    kshape = (n_units, period - 1, B, cache_len, cfg.n_kv_heads, cfg.head_dim)
    xshape = (n_units, B, cfg.n_frontend_tokens, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(kshape, dtype), "v": jnp.zeros(kshape, dtype),
        "xk": jnp.zeros(xshape, dtype), "xv": jnp.zeros(xshape, dtype),
    }


def _prefill_vlm(params, cfg: ModelConfig, batch, cache, remat=True):
    x = _embed(params["embed"], cfg, batch["tokens"])
    img = batch["image_embeds"].astype(_dtype(cfg))

    def body(h, inp):
        selfs_u, cross_u, cl = inp
        img_kv = _cross_kv(cross_u["xattn"], cfg, img)
        h2, ncs = _vlm_unit_body(
            cfg, h, selfs_u, cross_u, img_kv,
            self_caches={"k": cl["k"], "v": cl["v"]}, pos=0,
        )
        return h2, {**ncs, "xk": img_kv["k"].astype(cl["xk"].dtype),
                    "xv": img_kv["v"].astype(cl["xv"].dtype)}

    x, ncache = _scan(body, x, (params["selfs"], params["cross"], cache), remat, cfg.scan_layers)
    return _prefill_head(params, cfg, x), ncache


def _decode_vlm(params, cfg: ModelConfig, token, cache, pos):
    x = _embed(params["embed"], cfg, token, pos_offset=pos)

    def body(h, inp):
        selfs_u, cross_u, cl = inp
        img_kv = {"k": cl["xk"], "v": cl["xv"]}
        h2, ncs = _vlm_unit_body(
            cfg, h, selfs_u, cross_u, img_kv,
            self_caches={"k": cl["k"], "v": cl["v"]}, pos=pos,
        )
        return h2, {**ncs, "xk": cl["xk"], "xv": cl["xv"]}

    x, ncache = _scan(body, x, (params["selfs"], params["cross"], cache), False, cfg.scan_layers)
    return _head(params["embed"], cfg, x), ncache


# ==========================================================================
# public API
# ==========================================================================
def init_params(cfg: ModelConfig, key) -> Params:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _init_dense_family(cfg, key)
    if fam == "ssm":
        return _init_ssm_family(cfg, key)
    if fam == "hybrid":
        return _init_hybrid_family(cfg, key)
    if fam == "audio":
        return _init_audio_family(cfg, key)
    if fam == "vlm":
        return _init_vlm_family(cfg, key)
    raise ValueError(fam)


def forward(params, batch, cfg: ModelConfig, remat=True):
    """Full-sequence forward -> (logits (B,S,V) f32, moe aux loss)."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _fwd_dense(params, cfg, batch["tokens"], remat)
    if fam == "ssm":
        return _fwd_ssm(params, cfg, batch["tokens"], remat)
    if fam == "hybrid":
        return _fwd_hybrid(params, cfg, batch["tokens"], remat)
    if fam == "audio":
        return _fwd_audio(params, cfg, batch, remat)
    if fam == "vlm":
        return _fwd_vlm(params, cfg, batch, remat)
    raise ValueError(fam)


def loss_fn(params, batch, cfg: ModelConfig, tc: TrainConfig):
    logits, aux = forward(params, batch, cfg, remat=tc.remat)
    labels = batch["tokens"][:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    ce = -jnp.mean(
        jnp.take_along_axis(lp, labels[..., None].astype(jnp.int32), axis=-1)
    )
    loss = ce + tc.moe_aux_weight * aux
    return loss, {"ce": ce, "moe_aux": aux}


def init_cache(cfg: ModelConfig, B: int, cache_len: int, dtype=None) -> Params:
    dtype = dtype or _dtype(cfg)
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _dense_cache(cfg, B, cache_len, dtype)
    if fam == "ssm":
        return _ssm_cache(cfg, B, cache_len, dtype)
    if fam == "hybrid":
        return _hybrid_cache(cfg, B, cache_len, dtype)
    if fam == "audio":
        return _audio_cache(cfg, B, cache_len, dtype)
    if fam == "vlm":
        return _vlm_cache(cfg, B, cache_len, dtype)
    raise ValueError(fam)


def prefill(params, batch, cache, cfg: ModelConfig, remat=True):
    """Fill the cache from a full prompt -> (logits (B,S,V), cache)."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _prefill_dense(params, cfg, batch["tokens"], cache, remat)
    if fam == "ssm":
        return _prefill_ssm(params, cfg, batch["tokens"], cache, remat)
    if fam == "hybrid":
        return _prefill_hybrid(params, cfg, batch["tokens"], cache, remat)
    if fam == "audio":
        return _prefill_audio(params, cfg, batch, cache, remat)
    if fam == "vlm":
        return _prefill_vlm(params, cfg, batch, cache, remat)
    raise ValueError(fam)


def decode_step(params, batch, cache, cfg: ModelConfig):
    """One-token decode.  batch: {'token': (B,1), 'pos': scalar}."""
    token, pos = batch["token"], batch["pos"]
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _decode_dense(params, cfg, token, cache, pos)
    if fam == "ssm":
        return _decode_ssm(params, cfg, token, cache, pos)
    if fam == "hybrid":
        return _decode_hybrid(params, cfg, token, cache, pos)
    if fam == "audio":
        return _decode_audio(params, cfg, token, cache, pos)
    if fam == "vlm":
        return _decode_vlm(params, cfg, token, cache, pos)
    raise ValueError(fam)
