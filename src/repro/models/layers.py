"""Primitive layers: norms, rotary embeddings, linear, attention, MLP.

Functional style: ``init_*`` builds a param pytree (nested dicts of
jnp arrays); ``*_fwd`` applies it.  Stacked-layer params (leading layer
axis) are built by vmapping init over per-layer keys; application scans.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

Params = dict
_INIT_STD = 0.02


# --------------------------------------------------------------------------
# basics
# --------------------------------------------------------------------------
def _normal(key, shape, dtype, std=_INIT_STD):
    return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def init_linear(key, d_in, d_out, dtype, bias=False) -> Params:
    p = {"w": _normal(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_rmsnorm(d, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def init_norm(kind: str, d, dtype) -> Params:
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" else init_layernorm(d, dtype)


def apply_norm(kind: str, p: Params, x: jax.Array) -> jax.Array:
    return rms_norm(p, x) if kind == "rmsnorm" else layer_norm(p, x)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, d_head); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA, optional cross-attention, optional KV cache)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False
    rope_theta: float = 1.0e4
    use_rope: bool = True
    causal: bool = True
    kv_d_model: Optional[int] = None  # cross-attn source width
    impl: str = "xla"  # xla (dense S^2) | chunked (flash-in-XLA)
    chunk: int = 1024
    unroll: bool = False  # unrolled chunk loop (exact causal slicing)
    seq_shard: bool = False  # sequence-parallel attention (sharding/ctx)


def init_attention(key, a: AttnDims, dtype) -> Params:
    ks = jax.random.split(key, 4)
    kv_d = a.kv_d_model or a.d_model
    return {
        "wq": init_linear(ks[0], a.d_model, a.n_heads * a.d_head, dtype, a.qkv_bias),
        "wk": init_linear(ks[1], kv_d, a.n_kv_heads * a.d_head, dtype, a.qkv_bias),
        "wv": init_linear(ks[2], kv_d, a.n_kv_heads * a.d_head, dtype, a.qkv_bias),
        "wo": init_linear(ks[3], a.n_heads * a.d_head, a.d_model, dtype, False),
    }


def _sdpa_dense(q, k, v, causal: bool, q_pos=None):
    """Materialized-S^2 attention (the BASELINE path; see _sdpa_chunked for
    the optimized one).  q: (B,Sq,H,dh), k/v: (B,Sk,K,dh), H % K == 0.

    q_pos: absolute key-index positions of the queries (decode/prefill with a
    cache longer than Sq); the causal mask then also hides unwritten cache
    slots (their key index exceeds every query position).
    """
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    rep = H // K
    qf = q.astype(jnp.float32) / jnp.sqrt(dh)
    # (B, K, rep, Sq, Sk)
    logits = jnp.einsum(
        "bqkrd,bskd->bkrqs",
        qf.reshape(B, Sq, K, rep, dh),
        k.astype(jnp.float32),
    )
    Sk = k.shape[1]
    if causal:
        if q_pos is None:
            q_pos = jnp.arange(Sq)
        mask = q_pos[:, None] >= jnp.arange(Sk)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkrqs,bskd->bqkrd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, dh).astype(q.dtype)


def _sdpa_chunked(q, k, v, causal: bool, q_pos=None, chunk: int = 1024,
                  unroll: bool = False, seq_shard: bool = False):
    """Query-chunked attention ("flash-in-XLA", SSPerf hillclimb #1): the
    (Sq, Sk) score matrix is never materialized — one (chunk, Sk) strip
    per step.  In `unroll` mode (python loop; also what the dry-run cost
    extrapolation lowers) causal chunks additionally SLICE the key range to
    the causal frontier, halving attention FLOPs exactly.

    Numerics match _sdpa_dense: same f32 softmax over the same logits.
    """
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    rep = H // K
    C = min(chunk, Sq)
    if Sq % C != 0:  # fall back: irregular sizes (decode handles Sq==1)
        return _sdpa_dense(q, k, v, causal, q_pos)
    nq = Sq // C
    if q_pos is None:
        q_pos = jnp.arange(Sq)
    qf = (q.astype(jnp.float32) / jnp.sqrt(dh)).reshape(B, nq, C, K, rep, dh)
    qpos_c = q_pos.reshape(nq, C)
    Sk = k.shape[1]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def one_chunk(qc, pos_c, k_sl, v_sl):
        if seq_shard:
            # sequence parallelism WITHIN the chunk: each q-chunk spreads
            # over the model axis (constraining the full tensor instead
            # makes chunk slices land on single shards -> involuntary
            # remat in the partitioner)
            from repro.sharding.ctx import constrain_seq_parallel

            qc = constrain_seq_parallel(qc, seq_axis=1)
        logits = jnp.einsum("bqkrd,bskd->bkrqs", qc, k_sl)
        if causal:
            mask = pos_c[:, None] >= jnp.arange(k_sl.shape[1])[None, :]
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bkrqs,bskd->bqkrd", p, v_sl)

    # checkpoint each chunk: the backward pass recomputes the chunk's
    # logits instead of storing them — otherwise the chunk map stores a
    # full S^2-worth of residuals across chunks, defeating the point in
    # training (SSPerf: train-cell peaks).
    one_chunk_ckpt = jax.checkpoint(one_chunk)
    if unroll:
        outs = []
        for i in range(nq):
            hi = Sk
            if causal and Sk == Sq:  # exact causal frontier slice
                hi = (i + 1) * C
            outs.append(
                one_chunk_ckpt(qf[:, i], qpos_c[i], kf[:, :hi], vf[:, :hi])
            )
        o = jnp.stack(outs, axis=1)
    else:
        o = jax.lax.map(
            lambda ins: one_chunk_ckpt(ins[0], ins[1], kf, vf),
            (qf.transpose(1, 0, 2, 3, 4, 5), qpos_c),
        ).transpose(1, 0, 2, 3, 4, 5)
    return o.reshape(B, Sq, H, dh).astype(q.dtype)


def _sdpa(q, k, v, causal: bool, q_pos=None, impl: str = "xla",
          chunk: int = 1024, unroll: bool = False, seq_shard: bool = False):
    if seq_shard and impl != "chunked" and q.shape[1] > 1:
        from repro.sharding.ctx import constrain_seq_parallel

        q = constrain_seq_parallel(q, seq_axis=1)
    if impl == "chunked" and q.shape[1] > 1:
        return _sdpa_chunked(q, k, v, causal, q_pos, chunk=chunk,
                             unroll=unroll, seq_shard=seq_shard)
    return _sdpa_dense(q, k, v, causal, q_pos)


def attention_fwd(
    p: Params,
    a: AttnDims,
    x: jax.Array,
    kv_src: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    cache: Optional[Params] = None,
    cache_pos: Optional[jax.Array] = None,
) -> tuple[jax.Array, Optional[Params]]:
    """Self- or cross-attention.

    cache: {'k': (B, S_max, K, dh), 'v': ...} — decode path updates in place
    at cache_pos (scalar) and attends over the filled prefix.
    """
    B, Sq, _ = x.shape
    src = x if kv_src is None else kv_src
    q = linear(p["wq"], x).reshape(B, Sq, a.n_heads, a.d_head)
    k = linear(p["wk"], src).reshape(B, src.shape[1], a.n_kv_heads, a.d_head)
    v = linear(p["wv"], src).reshape(B, src.shape[1], a.n_kv_heads, a.d_head)


    if a.use_rope and kv_src is None:
        if positions is None:
            positions = jnp.arange(Sq) if cache_pos is None else cache_pos + jnp.arange(Sq)
        q = rope(q, positions, a.rope_theta)
        k = rope(k, positions, a.rope_theta)

    new_cache = None
    if cache is not None and cache_pos is not None and kv_src is None:
        # decode/prefill: write new kv at cache_pos, attend causally over the
        # written prefix (unwritten slots are masked by q_pos semantics)
        k_upd = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
        v_upd = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
        new_cache = {"k": k_upd, "v": v_upd}
        if Sq == cache["k"].shape[1]:
            # full-cache prefill: attend over the FRESH k/v — equivalent
            # math, but keeps attention off the seq-sharded cache layout
            # (avoids GSPMD ring-permuting the whole cache; SSPerf)
            o = _sdpa(q, k, v, causal=True, impl=a.impl, chunk=a.chunk,
                      unroll=a.unroll, seq_shard=a.seq_shard)
        else:
            o = _sdpa(q, k_upd, v_upd, causal=True,
                      q_pos=cache_pos + jnp.arange(Sq),
                      impl=a.impl, chunk=a.chunk, unroll=a.unroll,
                      seq_shard=a.seq_shard)
    elif cache is not None:  # cross-attn with precomputed source kv
        o = _sdpa(q, cache["k"], cache["v"], causal=False,
                  impl=a.impl, chunk=a.chunk, unroll=a.unroll,
                  seq_shard=a.seq_shard)
        new_cache = cache
    else:
        o = _sdpa(q, k, v, causal=a.causal and kv_src is None,
                  impl=a.impl, chunk=a.chunk, unroll=a.unroll,
                  seq_shard=a.seq_shard)
    y = linear(p["wo"], o.reshape(B, Sq, a.n_heads * a.d_head))
    return y, new_cache


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def init_mlp(key, d_model, d_ff, act: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "w_gate": init_linear(ks[0], d_model, d_ff, dtype),
            "w_up": init_linear(ks[1], d_model, d_ff, dtype),
            "w_down": init_linear(ks[2], d_ff, d_model, dtype),
        }
    return {
        "w_up": init_linear(ks[0], d_model, d_ff, dtype, bias=True),
        "w_down": init_linear(ks[1], d_ff, d_model, dtype, bias=True),
    }


def mlp_fwd(p: Params, x: jax.Array, act: str) -> jax.Array:
    if act == "swiglu":
        return linear(
            p["w_down"], jax.nn.silu(linear(p["w_gate"], x)) * linear(p["w_up"], x)
        )
    return linear(p["w_down"], jax.nn.gelu(linear(p["w_up"], x)))
