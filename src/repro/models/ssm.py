"""Mamba2 block — SSD (state-space duality) chunked algorithm
(arXiv:2405.21060), plus the O(1)-state decode step.

Train path: sequence split into chunks of length Q; intra-chunk term is a
decay-masked quadratic form (MXU matmuls), inter-chunk term is a scan over
per-chunk states — the TPU-native formulation (no sequential per-step scan).
Decode path: single recurrent state update per token; the "KV cache" is the
(B, H, P, N) state + a (B, d_conv-1, conv_dim) conv window, independent of
context length — which is why mamba2/zamba2 run the long_500k shape while
pure attention archs skip it (DESIGN.md SS5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import Params, _normal, init_linear, linear, rms_norm


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    n_groups: int = 1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def init_mamba(key, s: SSMDims, dtype) -> Params:
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * s.d_inner + 2 * s.n_groups * s.d_state + s.n_heads
    return {
        "in_proj": init_linear(ks[0], s.d_model, d_in_proj, dtype),
        "conv_w": _normal(ks[1], (s.d_conv, s.conv_dim), dtype, std=0.1),
        "conv_b": jnp.zeros((s.conv_dim,), dtype),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, s.n_heads, dtype=jnp.float32)
        ),
        "D": jnp.ones((s.n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((s.n_heads,), jnp.float32),
        "norm_scale": jnp.ones((s.d_inner,), dtype),
        "out_proj": init_linear(ks[4], s.d_inner, s.d_model, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, window d_conv (<= 4: unrolled shifts)."""
    d_conv = w.shape[0]
    y = x * w[-1]
    for i in range(1, d_conv):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        y = y + shifted * w[d_conv - 1 - i]
    return y + b


def _split_in_proj(zxbcdt: jax.Array, s: SSMDims):
    di, ds, ng = s.d_inner, s.d_state, s.n_groups
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * ng * ds]
    dt = zxbcdt[..., 2 * di + 2 * ng * ds :]
    return z, xBC, dt


def mamba_fwd(
    p: Params, s: SSMDims, u: jax.Array, return_state: bool = False
):
    """Chunked SSD training/prefill forward.  u: (B, S, d_model).

    return_state=True additionally returns the decode-ready recurrent state
    (final SSM state + raw conv window tail) for cache handoff at prefill.
    """
    B, S0, _ = u.shape
    Q = min(s.chunk, S0)
    H, P, N = s.n_heads, s.head_dim, s.d_state

    z, xBC, dt = _split_in_proj(linear(p["in_proj"], u), s)
    xBC_raw_tail = xBC[:, S0 - (s.d_conv - 1) :, :]
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))

    # pad to a chunk multiple; padded steps get dt=0 (identity state update)
    S = ((S0 + Q - 1) // Q) * Q
    pad = S - S0
    if pad:
        xBC = jnp.pad(xBC, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = S // Q

    x = xBC[..., : s.d_inner].reshape(B, S, H, P)
    Bm = xBC[..., s.d_inner : s.d_inner + N]  # n_groups=1: shared over heads
    Cm = xBC[..., s.d_inner + N :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    if pad:
        step_mask = (jnp.arange(S) < S0).astype(jnp.float32)
        dt = dt * step_mask[None, :, None]
    A = -jnp.exp(p["A_log"])  # (H,) negative

    # chunk views
    xc = x.reshape(B, nc, Q, H, P).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, N).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, H)
    ac = dtc * A  # (B,nc,Q,H) log-decay increments
    csum = jnp.cumsum(ac, axis=2)  # inclusive

    # ---- intra-chunk: decay-masked quadratic attention-like term ----
    # decay[b,c,h,t,j] = exp(csum_t - csum_j) for j <= t else 0.
    # Mask BEFORE exp: for t < j the exponent is positive and can overflow;
    # masking after exp would zero the forward but leave 0*inf = NaN in the
    # backward pass.
    rel = csum[:, :, :, None, :] - csum[:, :, None, :, :]  # (B,nc,Q,Q,H): t,j
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    rel = jnp.where(tri[None, None, :, :, None], rel, -1e30)
    decay = jnp.exp(rel)
    scores = jnp.einsum("bcqn,bcjn->bcqj", Cc, Bc)
    y_intra = jnp.einsum(
        "bcqj,bcqjh,bcjh,bcjhp->bcqhp", scores, decay, dtc, xc
    )

    # ---- inter-chunk: scan over per-chunk states ----
    decay_to_end = jnp.exp(csum[:, :, -1:, :] - csum)  # (B,nc,Q,H)
    chunk_state = jnp.einsum(
        "bcjh,bcjn,bcjhp->bchpn", dtc * decay_to_end, Bc, xc
    )
    chunk_decay = jnp.exp(csum[:, :, -1, :])  # (B,nc,H)

    def step(S_prev, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        S_out = dec[:, :, None, None] * S_prev + st
        return S_out, S_prev  # emit the INCOMING state for this chunk

    S_init = jnp.zeros((B, H, P, N), jnp.float32)
    S_last, S_in = jax.lax.scan(
        step,
        S_init,
        (
            chunk_state.transpose(1, 0, 2, 3, 4),
            chunk_decay.transpose(1, 0, 2),
        ),
    )
    S_in = S_in.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc, S_in) * jnp.exp(csum)[
        ..., None
    ]

    y = (y_intra + y_inter).reshape(B, S, H, P) + p["D"][:, None] * x.reshape(
        B, S, H, P
    ).astype(jnp.float32)
    y = y.reshape(B, S, s.d_inner)[:, :S0].astype(u.dtype)

    # gated RMSNorm then output projection
    y = rms_norm({"scale": p["norm_scale"]}, y * jax.nn.silu(z))
    out = linear(p["out_proj"], y)
    if return_state:
        return out, {"conv": xBC_raw_tail, "ssm": S_last}
    return out


def mamba_init_state(s: SSMDims, B: int, dtype) -> Params:
    return {
        "conv": jnp.zeros((B, s.d_conv - 1, s.conv_dim), dtype),
        "ssm": jnp.zeros((B, s.n_heads, s.head_dim, s.d_state), jnp.float32),
    }


def mamba_decode_step(
    p: Params, s: SSMDims, u: jax.Array, state: Params
) -> tuple[jax.Array, Params]:
    """One-token decode.  u: (B, 1, d_model) -> (y (B,1,d), new state)."""
    B = u.shape[0]
    H, P, N = s.n_heads, s.head_dim, s.d_state
    z, xBC, dt = _split_in_proj(linear(p["in_proj"], u), s)
    window = jnp.concatenate([state["conv"], xBC.astype(state["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32)) + p[
        "conv_b"
    ].astype(jnp.float32)
    xBC_t = jax.nn.silu(conv_out)[:, None].astype(u.dtype)  # (B,1,conv_dim)
    new_conv = window[:, 1:]

    x = xBC_t[..., : s.d_inner].reshape(B, H, P).astype(jnp.float32)
    Bm = xBC_t[:, 0, s.d_inner : s.d_inner + N].astype(jnp.float32)
    Cm = xBC_t[:, 0, s.d_inner + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)  # (B,H)

    S_new = a[:, :, None, None] * state["ssm"] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, x, Bm
    )
    y = jnp.einsum("bhpn,bn->bhp", S_new, Cm) + p["D"][:, None] * x
    y = y.reshape(B, 1, s.d_inner).astype(u.dtype)
    y = rms_norm({"scale": p["norm_scale"]}, y * jax.nn.silu(z))
    return linear(p["out_proj"], y), {"conv": new_conv, "ssm": S_new}
