"""Fleet trace assembly: the analysis layer above the telemetry spine
(DESIGN.md SS13).

The spine (runtime/telemetry.py) records WHERE each worker's wall time
went; this module stitches the per-worker JSONL files into ONE fleet
trace that answers "why did this run take as long as it did" — the
question the paper answered by hand-profiling per-node work shapes
(SSIV-B) before reaching 101,729 neurons in 199 s:

  * :func:`align_clocks` — per-worker clock alignment.  Every record
    carries both an epoch (``t``) and a monotonic (``mono``) timestamp;
    a worker's internal timeline is rebuilt on its monotonic clock
    (immune to NTP steps mid-run) shifted by the median epoch-mono
    offset, and CROSS-worker epoch skew is corrected against the
    queue's causal order: a unit's done counter cannot precede any of
    its claims, and no event of stage k+1 can precede the last done of
    stage k (run_stage is a barrier).  Violations shift the late
    worker's whole timeline — clock-skew tolerant without any RPC.
  * :func:`assemble_trace` — join spans/counters to work units via
    their ``(stage, uid/row0, col0)`` attrs and reconstruct each unit's
    lifecycle (queued -> claimed -> computed -> fsynced -> done,
    including steals, retries, and poison verdicts), then compute the
    critical path through the phase1 -> phase2 -> assemble -> sig ->
    finalize DAG (within a stage units are parallel; the unit that
    finishes LAST is what the barrier waited on) and attribute each
    stage's wall time to compute / device gather / store-fsync /
    queue-wait / straggler-tail buckets.
  * :func:`chrome_trace` — export as Chrome trace-event JSON (Perfetto
    / chrome://tracing loadable): one process row per worker, lanes for
    barrier / compute / io spans, instant events for queue counters.
  * :func:`reconcile` — per-stage span totals cross-checked against
    ``edm_fleet status`` (same aggregation over the same records; the
    CI acceptance gate holds them within 1%).

Everything here is READ-ONLY over the recorded JSONL — assembling a
trace can never perturb a run, and a store with no telemetry yields an
empty (but well-formed) trace.
"""
from __future__ import annotations

import ast
import json
import pathlib
from typing import Any, Iterable, Optional

from repro.runtime import telemetry

#: stage DAG order (a run may only walk a prefix / skip sig+finalize).
STAGE_ORDER = ("phase1", "phase2", "assemble", "sig", "finalize")
#: wall-time attribution buckets (DESIGN.md SS13).
BUCKETS = ("compute", "gather", "store", "queue_wait", "straggler_tail",
           "other")
_SKEW_EPS = 1e-3  # seconds of causality violation tolerated as jitter
_SKEW_ITERS = 64


# ------------------------------------------------------------ record load
def load_worker_records(
    out_dir: str | pathlib.Path,
) -> dict[str, list[dict]]:
    """Schema-valid records per worker file, in recorded (seq) order."""
    by_worker: dict[str, list[dict]] = {}
    for stem, rec in telemetry.iter_store_records(out_dir):
        if telemetry.validate(rec):
            continue
        by_worker.setdefault(stem, []).append(rec)
    for recs in by_worker.values():
        recs.sort(key=lambda r: (r.get("pid", 0), r.get("seq", 0)))
    return by_worker


# ---------------------------------------------------------- clock algebra
def _epoch_mono_offset(recs: list[dict]) -> Optional[float]:
    """Median (epoch - mono) over a worker's records — its epoch clock
    expressed as an offset of its monotonic clock; the median survives
    an NTP step that shifts a minority of records."""
    ds = sorted(r["t"] - r["mono"] for r in recs if "mono" in r)
    if not ds:
        return None
    return ds[len(ds) // 2]


def _raw_time(rec: dict, offset: Optional[float]) -> float:
    """Record end time on the worker's reconstructed timeline (pre
    cross-worker correction): monotonic + median offset when the record
    carries a mono clock, the raw epoch stamp otherwise (legacy/foreign
    records)."""
    if offset is not None and "mono" in rec:
        return rec["mono"] + offset
    return rec["t"]


def align_clocks(by_worker: dict[str, list[dict]]) -> dict[str, float]:
    """Per-worker additive corrections mapping every worker's records
    onto one shared fleet timeline.

    Phase 1 (intra-worker): each worker's timeline is rebuilt as
    ``mono + median(t - mono)`` — its own epoch clock, made robust to
    mid-run NTP steps.  Phase 2 (cross-worker): queue causality
    violations (a done observed before its claim, a stage event before
    the previous stage's barrier drained) shift the EARLY-reading
    worker's whole timeline forward by the violation, iterated to a
    fixed point.  On a single skew-free host every correction is ~0.

    Returns worker -> total offset to ADD to :func:`_raw_time`.
    """
    base: dict[str, Optional[float]] = {
        w: _epoch_mono_offset(recs) for w, recs in by_worker.items()
    }
    shift = {w: 0.0 for w in by_worker}

    # queue protocol events: (stage, name, uid, worker_file, raw_time)
    events: list[tuple[str, str, str, str, float]] = []
    for w, recs in by_worker.items():
        for r in recs:
            if r["kind"] != "counter":
                continue
            if r["name"] in ("claim", "steal", "done"):
                events.append((
                    r["stage"], r["name"], str(r["attrs"].get("uid", "")),
                    w, _raw_time(r, base[w]),
                ))

    # constraints: (early_worker, t_early, late_worker, t_late).  The
    # per-uid bound uses the LAST done record: a crash between the done
    # flush and the durable marker legitimately leaves an earlier done
    # record followed by a steal + recompute, and only the final
    # completion is causally after every claim/steal.
    cons: list[tuple[str, float, str, float]] = []
    done_at: dict[str, tuple[str, float]] = {}
    for stage, name, uid, w, t in events:
        if name == "done":
            cur = done_at.get(uid)
            if cur is None or t > cur[1]:
                done_at[uid] = (w, t)
    for stage, name, uid, w, t in events:
        if name in ("claim", "steal") and uid in done_at:
            dw, dt = done_at[uid]
            if dw != w:
                cons.append((w, t, dw, dt))
    # stage barrier: last done of stage k precedes first event of k+1
    per_stage: dict[str, list[tuple[str, str, float]]] = {}
    for stage, name, uid, w, t in events:
        per_stage.setdefault(stage, []).append((name, w, t))
    order = [s for s in STAGE_ORDER if s in per_stage]
    for prev, nxt in zip(order, order[1:]):
        dones = [(w, t) for name, w, t in per_stage[prev] if name == "done"]
        firsts = [(w, t) for name, w, t in per_stage[nxt]]
        if not dones or not firsts:
            continue
        for dw, dt in dones:
            for fw, ft in firsts:
                if dw != fw:
                    cons.append((dw, dt, fw, ft))

    # iterative relaxation: push the worker that READS EARLY forward.
    # Shifts only ever grow, bounded by the true total skew -> converges.
    for _ in range(_SKEW_ITERS):
        moved = False
        for we, te, wl, tl in cons:
            early = te + shift[we]
            late = tl + shift[wl]
            if late + _SKEW_EPS < early:
                shift[wl] += early - late
                moved = True
        if not moved:
            break
    return shift


class _Timeline:
    """Aligned time accessor for one fleet's records."""

    def __init__(self, by_worker: dict[str, list[dict]]):
        self.by_worker = by_worker
        self._base = {w: _epoch_mono_offset(r) for w, r in by_worker.items()}
        self.shift = align_clocks(by_worker)

    def end(self, worker: str, rec: dict) -> float:
        return _raw_time(rec, self._base[worker]) + self.shift[worker]

    def start(self, worker: str, rec: dict) -> float:
        return self.end(worker, rec) - float(rec.get("dur_s", 0.0))


# ------------------------------------------------------------- tag parsing
def _tag_row0(attrs: dict) -> Optional[int]:
    """row0 of a stream-drain span: tags are ``repr`` of the pipeline's
    (row0, valid) / (kind, row0, col0, valid) tuples."""
    if "row0" in attrs:
        return int(attrs["row0"])
    tag = attrs.get("tag")
    if not isinstance(tag, str):
        return None
    try:
        val = ast.literal_eval(tag)
    except (ValueError, SyntaxError):
        return None
    if isinstance(val, tuple):
        for x in val:
            if isinstance(x, int):
                return int(x)
    return None


# ---------------------------------------------------------- unit lifecycle
def _unit_key(stage: str, attrs: dict) -> Optional[str]:
    uid = attrs.get("uid")
    return str(uid) if uid else None


def assemble_trace(out_dir: str | pathlib.Path) -> dict:
    """The fleet-wide causal trace of one run store (JSON-safe dict).

    Keys:
      workers        sorted worker-file stems
      clock_shift_s  per-worker cross-clock correction applied
      units          uid -> lifecycle {stage,row0,nrows,claims,steals,
                     retries,poisoned,claimed_t,done_t,held_s,
                     compute_s,gather_s,store_s,chunks,worker}
      stages         stage -> {start,end,wall_s,units,done_units,
                     buckets{...},per_worker{busy_s,span_s},chunk_p50/
                     p95/p99}
      critical_path  one entry per stage walked: the unit the barrier
                     waited on, with queue_wait/compute/gather/store/
                     straggler_tail seconds
      span_totals    stage -> sum of ALL span dur_s (the exact
                     aggregation `edm_fleet status` reports — the
                     reconciliation surface)
      total_wall_s   aligned end - start over every record
    """
    by_worker = load_worker_records(out_dir)
    trace: dict[str, Any] = {
        "out": str(out_dir),
        "workers": sorted(by_worker),
        "clock_shift_s": {},
        "units": {},
        "stages": {},
        "critical_path": [],
        "span_totals": {},
        "total_wall_s": 0.0,
    }
    if not by_worker:
        return trace
    tl = _Timeline(by_worker)
    trace["clock_shift_s"] = {w: round(s, 6) for w, s in tl.shift.items()}

    units: dict[str, dict] = {}
    span_totals: dict[str, float] = {}
    # per (worker, stage): busy interval list + stage-span time + chunks
    busy: dict[tuple[str, str], list[tuple[float, float]]] = {}
    stage_span: dict[tuple[str, str], tuple[float, float]] = {}
    chunk_durs: dict[str, list[float]] = {}
    chunk_spans: dict[str, list[tuple[str, float, float, dict]]] = {}
    sub_spans: dict[str, list[tuple[str, float, float, str, dict]]] = {}
    t_min, t_max = float("inf"), float("-inf")

    def unit_for(stage: str, uid: str, attrs: dict) -> dict:
        u = units.get(uid)
        if u is None:
            u = units[uid] = {
                "stage": stage, "row0": int(attrs.get("row0", 0)),
                "nrows": int(attrs.get("nrows", 0)),
                "claims": [], "steals": 0, "retries": 0, "poisoned": False,
                "claimed_t": None, "done_t": None, "held_s": None,
                "worker": None, "compute_s": 0.0, "gather_s": 0.0,
                "store_s": 0.0, "chunks": 0,
            }
        return u

    for w, recs in by_worker.items():
        for r in recs:
            end = tl.end(w, r)
            start = tl.start(w, r)
            t_min, t_max = min(t_min, start), max(t_max, end)
            stage, name, attrs = r["stage"], r["name"], r["attrs"]
            if r["kind"] == "span":
                span_totals[stage] = span_totals.get(stage, 0.0) + r["dur_s"]
                if name == "stage":
                    stage_span[(w, stage)] = (start, end)
                elif name == "chunk":
                    chunk_durs.setdefault(stage, []).append(r["dur_s"])
                    chunk_spans.setdefault(stage, []).append(
                        (w, start, end, attrs))
                    busy.setdefault((w, stage), []).append((start, end))
                elif name in ("drain", "device_put", "write_tile",
                              "write_block", "manifest_commit",
                              "causal_map", "store"):
                    sub_spans.setdefault(stage, []).append(
                        (w, start, end, name, attrs))
                    busy.setdefault((w, stage), []).append((start, end))
                continue
            # counters: unit lifecycle joins
            uid = _unit_key(stage, attrs)
            if uid is None:
                continue
            if name in ("claim", "steal"):
                u = unit_for(stage, uid, attrs)
                u["claims"].append({"worker": w, "t": round(end, 6),
                                    "stolen": name == "steal"})
                u["steals"] += name == "steal"
                if u["claimed_t"] is None or end < u["claimed_t"]:
                    u["claimed_t"] = end
            elif name == "done":
                u = unit_for(stage, uid, attrs)
                # duplicate done records are possible (a SIGKILL between
                # the flushed record and the marker recomputes the
                # unit) — the FIRST completion is the causal one
                if u["done_t"] is None or end < u["done_t"]:
                    u["done_t"] = end
                    u["held_s"] = float(attrs.get("held_s", 0.0))
                    u["worker"] = w
            elif name == "unit_failed":
                unit_for(stage, uid, attrs)["retries"] += 1
            elif name == "unit_poisoned":
                unit_for(stage, uid, attrs)["poisoned"] = True

    # ---- join compute/gather/store spans to units ----------------------
    def covering_unit(stage: str, row0: Optional[int]) -> Optional[dict]:
        if row0 is None:
            return None
        for u in units.values():
            if u["stage"] == stage and (
                u["nrows"] == 0 or u["row0"] <= row0 < u["row0"] + u["nrows"]
            ):
                return u
        return None

    for stage, spans in chunk_spans.items():
        for w, start, end, attrs in spans:
            u = covering_unit(stage, attrs.get("row0", 0))
            if u is not None:
                u["chunks"] += 1
                u["compute_s"] += end - start
                u["gather_s"] += float(attrs.get("gather_s", 0.0))
    for stage, spans in sub_spans.items():
        pstage = stage if stage in STAGE_ORDER else None
        for w, start, end, name, attrs in spans:
            row0 = _tag_row0(attrs)
            target = pstage
            if target is None:
                # "store"-stage writes: find the pipeline stage whose
                # chunk/stage span of the SAME worker contains this span
                for ps, cspans in chunk_spans.items():
                    if any(cw == w and cs - _SKEW_EPS <= start
                           and end <= ce + _SKEW_EPS
                           for cw, cs, ce, _ in cspans):
                        target = ps
                        break
                target = target or "phase2"
            u = covering_unit(target, row0)
            if u is None:
                continue
            dur = end - start
            if name in ("write_tile", "write_block", "manifest_commit"):
                u["store_s"] += dur
            elif name == "drain":
                u["gather_s"] += float(attrs.get("gather_s", 0.0))
                # drain minus gather is dominated by the nested store
                # write, credited above via its own span
            elif name == "device_put":
                # device upload rides the compute bucket's chunk span;
                # subtract it from compute, credit gather (H2D+D2H both
                # count as device transfer time)
                u["compute_s"] -= dur
                u["gather_s"] += dur

    # ---- per-stage rollup + buckets ------------------------------------
    def merge_intervals(iv: list[tuple[float, float]]) -> float:
        total, cur_s, cur_e = 0.0, None, None
        for s, e in sorted(iv):
            if cur_e is None or s > cur_e:
                if cur_e is not None:
                    total += cur_e - cur_s
                cur_s, cur_e = s, e
            else:
                cur_e = max(cur_e, e)
        if cur_e is not None:
            total += cur_e - cur_s
        return total

    stages_present = [
        s for s in STAGE_ORDER
        if s in chunk_spans or s in span_totals
        or any(u["stage"] == s for u in units.values())
    ]
    for stage in stages_present:
        ss = [v for (w, st), v in stage_span.items() if st == stage]
        evs = [u[k] for u in units.values() if u["stage"] == stage
               for k in ("claimed_t", "done_t") if u[k] is not None]
        cts = [(s, e) for _, s, e, _ in chunk_spans.get(stage, [])]
        cts += [(s, e) for _, s, e, _, _ in sub_spans.get(stage, [])
                if stage in STAGE_ORDER]
        lo = min([s for s, _ in ss] + [s for s, _ in cts] + evs,
                 default=None)
        hi = max([e for _, e in ss] + [e for _, e in cts] + evs,
                 default=None)
        if lo is None:
            continue
        wall = max(hi - lo, 0.0)
        sunits = [u for u in units.values() if u["stage"] == stage]
        per_worker: dict[str, dict] = {}
        workers_in = {w for (w, st) in busy if st == stage} | {
            w for (w, st) in stage_span if st == stage}
        for w in sorted(workers_in):
            b = merge_intervals(busy.get((w, stage), []))
            sp = stage_span.get((w, stage))
            per_worker[w] = {
                "busy_s": round(b, 6),
                "span_s": round(sp[1] - sp[0], 6) if sp else None,
            }
        compute = sum(u["compute_s"] for u in sunits)
        gather = sum(u["gather_s"] for u in sunits)
        store_t = sum(u["store_s"] for u in sunits)
        if not sunits:  # in-process run: bucket from raw spans
            compute = sum(e - s for _, s, e, _ in chunk_spans.get(stage, []))
            for w, s, e, name, attrs in sub_spans.get(stage, []):
                if name in ("write_tile", "write_block", "manifest_commit",
                            "causal_map", "store"):
                    store_t += e - s
                elif name == "drain":
                    gather += float(attrs.get("gather_s", 0.0))
                elif name == "device_put":
                    compute -= e - s
                    gather += e - s
            for _, s, e, attrs in chunk_spans.get(stage, []):
                gather += float(attrs.get("gather_s", 0.0))
        # queue wait: time a worker spent inside the stage but not busy
        queue_wait = 0.0
        for w in workers_in:
            sp = stage_span.get((w, stage))
            if sp is not None:
                queue_wait += max(
                    0.0, (sp[1] - sp[0])
                    - merge_intervals(busy.get((w, stage), [])))
        # straggler tail: per worker, idle span between its last busy
        # moment and the fleet-wide stage end (the barrier wait on the
        # last unit) — a subset of queue_wait, surfaced separately
        # because it is what the worker-count knob tunes
        tail = 0.0
        for w in workers_in:
            iv = busy.get((w, stage), [])
            last = max((e for _, e in iv), default=None)
            if last is not None and len(workers_in) > 1:
                tail += max(0.0, hi - last)
        other = max(0.0, wall - compute - gather - store_t)
        durs = sorted(chunk_durs.get(stage, []))

        def pct(p: float) -> Optional[float]:
            if not durs:
                return None
            return round(durs[min(len(durs) - 1,
                                  int(p * (len(durs) - 1)))], 6)

        trace["stages"][stage] = {
            "start": round(lo, 6), "end": round(hi, 6),
            "wall_s": round(wall, 6),
            "units": len(sunits),
            "done_units": sum(u["done_t"] is not None for u in sunits),
            "chunks": len(durs),
            "chunk_p50_s": pct(0.50), "chunk_p95_s": pct(0.95),
            "chunk_p99_s": pct(0.99),
            "buckets": {
                "compute": round(max(compute, 0.0), 6),
                "gather": round(gather, 6),
                "store": round(store_t, 6),
                "queue_wait": round(queue_wait, 6),
                "straggler_tail": round(tail, 6),
                "other": round(other, 6),
            },
            "per_worker": per_worker,
        }

    # ---- critical path -------------------------------------------------
    for stage in stages_present:
        st = trace["stages"].get(stage)
        if st is None:
            continue
        sunits = [(uid, u) for uid, u in units.items()
                  if u["stage"] == stage and u["done_t"] is not None]
        if sunits:
            uid, u = max(sunits, key=lambda kv: kv[1]["done_t"])
            entry = {
                "stage": stage, "uid": uid, "worker": u["worker"],
                "queue_wait_s": round(
                    max(0.0, (u["claimed_t"] or st["start"]) - st["start"]),
                    6),
                "compute_s": round(max(u["compute_s"], 0.0), 6),
                "gather_s": round(u["gather_s"], 6),
                "store_s": round(u["store_s"], 6),
                "held_s": u["held_s"],
                "steals": u["steals"], "retries": u["retries"],
                "poisoned": u["poisoned"],
                "done_t": round(u["done_t"], 6),
                "straggler_tail_s": round(
                    max(0.0, st["end"] - u["done_t"]), 6),
            }
        else:  # in-process run: the stage itself is the path node
            b = st["buckets"]
            entry = {
                "stage": stage, "uid": stage, "worker": None,
                "queue_wait_s": 0.0,
                "compute_s": b["compute"], "gather_s": b["gather"],
                "store_s": b["store"], "held_s": None,
                "steals": 0, "retries": 0, "poisoned": False,
                "done_t": st["end"], "straggler_tail_s": 0.0,
            }
        trace["critical_path"].append(entry)

    trace["units"] = {
        uid: {**u,
              "claimed_t": None if u["claimed_t"] is None
              else round(u["claimed_t"], 6),
              "done_t": None if u["done_t"] is None
              else round(u["done_t"], 6),
              "compute_s": round(max(u["compute_s"], 0.0), 6),
              "gather_s": round(u["gather_s"], 6),
              "store_s": round(u["store_s"], 6)}
        for uid, u in sorted(units.items())
    }
    trace["span_totals"] = {k: round(v, 6) for k, v in span_totals.items()}
    trace["total_wall_s"] = round(max(0.0, t_max - t_min), 6)
    return trace


# -------------------------------------------------------- chrome trace JSON
_LANES = {"stage": 0, "chunk": 1, "device_put": 1, "drain": 2,
          "write_tile": 3, "write_block": 3, "manifest_commit": 3,
          "causal_map": 1, "store": 1}
_LANE_NAMES = {0: "barrier", 1: "compute", 2: "drain", 3: "store",
               9: "events"}


def chrome_trace(out_dir: str | pathlib.Path) -> dict:
    """Chrome trace-event JSON for a run store — load the written file
    in Perfetto (ui.perfetto.dev) or chrome://tracing.

    One process row per worker (named), thread lanes per span family
    (barrier / compute / drain / store), ``X`` complete events for
    spans, ``i`` instant events for queue counters; all timestamps on
    the skew-corrected fleet timeline, microseconds from run start.
    """
    by_worker = load_worker_records(out_dir)
    events: list[dict] = []
    if not by_worker:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    tl = _Timeline(by_worker)
    t0 = min(
        tl.start(w, r) for w, recs in by_worker.items() for r in recs
    )

    def us(t: float) -> int:
        return int(round((t - t0) * 1e6))

    for pid, w in enumerate(sorted(by_worker)):
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0, "args": {"name": w}})
        for tid, lane in sorted(_LANE_NAMES.items()):
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "args": {"name": lane}})
        for r in by_worker[w]:
            stage, name, attrs = r["stage"], r["name"], r["attrs"]
            if r["kind"] == "span":
                events.append({
                    "ph": "X", "pid": pid,
                    "tid": _LANES.get(name, 1),
                    "name": f"{stage}.{name}",
                    "ts": us(tl.start(w, r)),
                    "dur": max(1, int(round(r["dur_s"] * 1e6))),
                    "args": attrs,
                })
            else:
                events.append({
                    "ph": "i", "s": "t", "pid": pid, "tid": 9,
                    "name": f"{stage}.{name}",
                    "ts": us(tl.end(w, r)),
                    "args": {**attrs, "value": r.get("value")},
                })
    events.sort(key=lambda e: (e["ph"] != "M", e.get("ts", 0)))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(
    out_dir: str | pathlib.Path, path: str | pathlib.Path
) -> pathlib.Path:
    from repro.data.store import atomic_write_text

    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    atomic_write_text(p, json.dumps(chrome_trace(out_dir)))
    return p


# ------------------------------------------------------------ reconciliation
def reconcile(trace: dict, status: dict) -> dict:
    """Per-stage span totals: trace vs `edm_fleet status` (both sum the
    dur_s of every valid span record per stage — any drift means the
    two readers disagree about the same files).  ``ok`` when every
    common stage matches within 1%."""
    out: dict[str, Any] = {"stages": {}, "ok": True}
    st_tel = status.get("telemetry", {}).get("stages", {})
    for stage in set(trace.get("span_totals", {})) | set(st_tel):
        a = float(trace.get("span_totals", {}).get(stage, 0.0))
        b = float(st_tel.get(stage, {}).get("span_s", 0.0))
        denom = max(abs(a), abs(b), 1e-9)
        delta = abs(a - b) / denom
        out["stages"][stage] = {
            "trace_s": round(a, 6), "status_s": round(b, 6),
            "delta_pct": round(100.0 * delta, 4),
        }
        if delta > 0.01:
            out["ok"] = False
    return out


# ----------------------------------------------------------------- render
def render_trace(trace: dict) -> str:
    """Human one-pager: per-stage wall + buckets, then the critical path."""
    lines = [f"trace {trace['out']}: {len(trace['workers'])} worker(s), "
             f"total wall {trace['total_wall_s']:.3f}s"]
    shifts = {w: s for w, s in trace.get("clock_shift_s", {}).items()
              if abs(s) > 0.01}
    if shifts:
        lines.append("clock skew corrected: " + ", ".join(
            f"{w}+{s:.3f}s" for w, s in sorted(shifts.items())))
    if trace["stages"]:
        lines.append(
            f"{'stage':<10} {'wall':>9} {'compute':>9} {'gather':>9} "
            f"{'store':>9} {'wait':>9} {'tail':>9}")
        for stage in STAGE_ORDER:
            st = trace["stages"].get(stage)
            if st is None:
                continue
            b = st["buckets"]
            lines.append(
                f"{stage:<10} {st['wall_s']:>8.3f}s {b['compute']:>8.3f}s "
                f"{b['gather']:>8.3f}s {b['store']:>8.3f}s "
                f"{b['queue_wait']:>8.3f}s {b['straggler_tail']:>8.3f}s")
    if trace["critical_path"]:
        lines.append("critical path (the unit each stage barrier waited on):")
        for e in trace["critical_path"]:
            who = f"@{e['worker']}" if e["worker"] else ""
            extras = []
            if e["steals"]:
                extras.append(f"{e['steals']} steal(s)")
            if e["retries"]:
                extras.append(f"{e['retries']} retry(ies)")
            if e["poisoned"]:
                extras.append("POISONED")
            lines.append(
                f"  {e['stage']:<9} {e['uid']}{who}: wait "
                f"{e['queue_wait_s']:.3f}s, compute {e['compute_s']:.3f}s, "
                f"gather {e['gather_s']:.3f}s, store {e['store_s']:.3f}s, "
                f"tail {e['straggler_tail_s']:.3f}s"
                + (f" [{', '.join(extras)}]" if extras else ""))
    if not trace["stages"]:
        lines.append("no telemetry records (sink disabled or run not started)")
    return "\n".join(lines)


# ------------------------------------------------------ hold-time helpers
def held_percentiles(out_dir: str | pathlib.Path) -> dict:
    """p50/p95/p99 over every recorded unit hold (done + stolen +
    released) — the straggler threshold of `status --watch` and the TTL
    rule's evidence (DESIGN.md SS13)."""
    holds: list[float] = []
    for _, rec in telemetry.iter_store_records(out_dir):
        if rec.get("kind") == "counter" and rec.get("name") == "held":
            holds.append(float(rec.get("value", 0.0)))
        elif (rec.get("kind") == "counter" and rec.get("name") == "done"
              and "held_s" in (rec.get("attrs") or {})):
            holds.append(float(rec["attrs"]["held_s"]))
    holds.sort()

    def pct(p: float) -> Optional[float]:
        if not holds:
            return None
        return round(holds[min(len(holds) - 1,
                               int(p * (len(holds) - 1)))], 6)

    return {"n": len(holds), "p50": pct(0.50), "p95": pct(0.95),
            "p99": pct(0.99)}
