"""Execution-platform tiers + multi-host mesh bring-up (DESIGN.md SS14).

One place answers "what chip, what flags, what engine, how many hosts"
BEFORE the first jax backend touch:

  * :data:`TIERS` — named platform tiers (``cpu`` / ``gpu`` / ``tpu``):
    jax platform name, x64 default, the tier's tuned XLA flags, and the
    default execution engine the registry should select
    (``repro.engine``).  The ``gpu`` tier carries the
    latency-hiding/async-collective flag set that keeps the SS14 shard
    merge (ppermute butterfly) overlapped with the per-shard streaming
    builds.
  * :func:`apply_platform` — applies a tier (env XLA_FLAGS + jax.config)
    idempotently; ``edm_run --platform`` and fleet workers call it first
    thing.
  * :func:`init_distributed` — env-driven ``jax.distributed.initialize``
    (EDM_COORDINATOR / EDM_NUM_PROCESSES / EDM_PROCESS_ID) so one
    logical mesh spans processes and hosts; every process then sees the
    GLOBAL device list and ``pipeline.default_mesh()`` becomes the
    paper's flat cross-host worker grid.
  * :func:`spoof_cpu_devices` — the CI/dev lever: N virtual CPU devices
    in one process (XLA host-platform device-count spoof) so multi-shard
    collectives run anywhere.

Everything here is wall-clock/topology only — byte-invisible to outputs
(the bit-identity contracts of SS8/SS14 hold on every tier).
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass, field

_X64_FLAG = "jax_enable_x64"

#: Env var contract for multi-host bring-up (mirrored in
#: docs/OPERATIONS.md; fleet workers read the same three).
ENV_COORDINATOR = "EDM_COORDINATOR"      # host:port of process 0
ENV_NUM_PROCESSES = "EDM_NUM_PROCESSES"  # world size
ENV_PROCESS_ID = "EDM_PROCESS_ID"        # this process's rank
ENV_LOCAL_DEVICE_IDS = "EDM_LOCAL_DEVICE_IDS"  # optional, e.g. "0,1"


@dataclass(frozen=True)
class Tier:
    """One named execution tier: everything that must be decided before
    the jax backend initializes."""

    name: str
    platform: str          # jax_platform_name
    engine: str            # default repro.engine registry key
    x64: bool = False
    xla_flags: tuple[str, ...] = field(default_factory=tuple)
    notes: str = ""


TIERS: dict[str, Tier] = {
    t.name: t
    for t in (
        Tier(
            name="cpu",
            platform="cpu",
            engine="reference",
            notes="portable default; jnp reference engine, no extra flags",
        ),
        Tier(
            name="gpu",
            platform="gpu",
            engine="pallas-compiled",
            xla_flags=(
                # Tuned GPU set: fuse the softmax-shaped reductions and
                # small GEMMs into Triton, run collectives (the SS14
                # shard-merge ppermutes) async on the highest-priority
                # stream, and let the latency-hiding scheduler overlap
                # them with the streaming kNN builds.
                "--xla_gpu_enable_triton_softmax_fusion=true",
                "--xla_gpu_triton_gemm_any=True",
                "--xla_gpu_enable_async_collectives=true",
                "--xla_gpu_enable_latency_hiding_scheduler=true",
                "--xla_gpu_enable_highest_priority_async_stream=true",
            ),
            notes="tuned CUDA tier: Triton fusions + async collectives "
            "overlapping the SS14 shard merge",
        ),
        Tier(
            name="tpu",
            platform="tpu",
            engine="pallas-compiled",
            notes="native Pallas kernels; collectives on the ICI mesh",
        ),
    )
}


def available_tiers() -> tuple[str, ...]:
    return tuple(sorted(TIERS))


def default_engine(tier: str) -> str:
    """The engine registry key a tier selects (``edm_run --platform``
    uses this whenever --engine is not given explicitly)."""
    return TIERS[tier].engine


def _backend_initialized() -> bool:
    """True once the jax runtime has instantiated a backend — after which
    XLA_FLAGS / platform-name changes are silently ignored by jax."""
    try:
        from jax._src import xla_bridge

        return bool(xla_bridge._backends)
    except Exception:  # pragma: no cover - private-API drift
        return False


def _merge_xla_flags(flags: tuple[str, ...]) -> str:
    """Append tier flags to $XLA_FLAGS, dropping duplicates (by flag
    name, tier value wins) and preserving caller-provided extras."""
    have = os.environ.get("XLA_FLAGS", "").split()
    names = {f.split("=")[0] for f in flags}
    kept = [f for f in have if f.split("=")[0] not in names]
    merged = " ".join(kept + list(flags))
    os.environ["XLA_FLAGS"] = merged
    return merged


_APPLIED: dict | None = None


def apply_platform(
    tier: str, *, x64: bool | None = None, cpu_devices: int | None = None
) -> dict:
    """Apply a :data:`TIERS` entry: XLA_FLAGS env + jax.config platform
    selection + x64 mode.  MUST run before the first jax backend touch
    (device query, first op); a later call warns and changes nothing at
    the runtime level.  Returns {tier, platform, engine, x64, xla_flags}
    — the record edm_run stamps into telemetry.

    ``cpu_devices`` (cpu tier only) spoofs N host devices for local
    multi-shard runs — the same knob CI's scale-smoke uses.
    """
    global _APPLIED
    if tier not in TIERS:
        raise KeyError(f"unknown platform tier {tier!r}; "
                       f"available: {available_tiers()}")
    t = TIERS[tier]
    if _backend_initialized():
        warnings.warn(
            f"apply_platform({tier!r}) after the jax backend initialized: "
            "XLA flags / platform name will NOT take effect this process",
            RuntimeWarning,
            stacklevel=2,
        )
    if cpu_devices is not None:
        if t.platform != "cpu":
            raise ValueError("cpu_devices spoof only applies to the cpu tier")
        spoof_cpu_devices(cpu_devices)
    flags = _merge_xla_flags(t.xla_flags) if t.xla_flags \
        else os.environ.get("XLA_FLAGS", "")
    import jax

    jax.config.update("jax_platform_name", t.platform)
    use_x64 = t.x64 if x64 is None else x64
    jax.config.update(_X64_FLAG, use_x64)
    _APPLIED = {
        "tier": t.name,
        "platform": t.platform,
        "engine": t.engine,
        "x64": use_x64,
        "xla_flags": flags,
    }
    return dict(_APPLIED)


def current() -> dict | None:
    """The record of the last :func:`apply_platform`, or None."""
    return dict(_APPLIED) if _APPLIED is not None else None


def spoof_cpu_devices(n: int) -> None:
    """Present ``n`` virtual CPU devices in this process (must run before
    backend init).  Dev/CI only: lets shard_map collectives — the SS14
    merge butterfly included — execute real multi-device code paths on a
    laptop or a CI runner."""
    if n < 1:
        raise ValueError(f"need at least one device, got {n}")
    _merge_xla_flags((f"--xla_force_host_platform_device_count={n}",))


# ------------------------------------------------------- multi-host mesh
def distributed_spec_from_env(env=None) -> dict | None:
    """Read the EDM_* multi-host contract from ``env`` (default
    os.environ).  Returns {coordinator, num_processes, process_id
    [, local_device_ids]} or None when EDM_COORDINATOR is unset (the
    single-process default).  Partial settings raise — a worker joining
    a mesh with a guessed rank would deadlock the whole fleet."""
    env = os.environ if env is None else env
    coord = env.get(ENV_COORDINATOR)
    if not coord:
        return None
    missing = [v for v in (ENV_NUM_PROCESSES, ENV_PROCESS_ID)
               if not env.get(v)]
    if missing:
        raise ValueError(
            f"{ENV_COORDINATOR} is set but {missing} missing: a multi-host "
            "mesh needs coordinator, world size AND rank"
        )
    spec = {
        "coordinator": coord,
        "num_processes": int(env[ENV_NUM_PROCESSES]),
        "process_id": int(env[ENV_PROCESS_ID]),
    }
    if not 0 <= spec["process_id"] < spec["num_processes"]:
        raise ValueError(f"process_id {spec['process_id']} outside world "
                         f"size {spec['num_processes']}")
    ids = env.get(ENV_LOCAL_DEVICE_IDS)
    if ids:
        spec["local_device_ids"] = tuple(int(i) for i in ids.split(","))
    return spec


_DISTRIBUTED: dict | None = None


def init_distributed(spec: dict | None = None) -> dict | None:
    """Join (or form) the multi-host mesh via jax.distributed.

    ``spec`` defaults to :func:`distributed_spec_from_env`; None (no
    EDM_COORDINATOR) is the single-process no-op.  After a successful
    init every process sees the GLOBAL device list, so
    ``pipeline.default_mesh()`` — and with it the SS14 candidate-shard
    collective — spans hosts with no further code changes.  Idempotent:
    a second call with the same spec returns the first record; a
    CONFLICTING second call raises (one process, one mesh).
    """
    global _DISTRIBUTED
    spec = distributed_spec_from_env() if spec is None else dict(spec)
    if spec is None:
        return None
    if _DISTRIBUTED is not None:
        if _DISTRIBUTED == spec:
            return dict(_DISTRIBUTED)
        raise RuntimeError(
            f"jax.distributed already initialized with {_DISTRIBUTED}; "
            f"conflicting spec {spec}"
        )
    import jax

    jax.distributed.initialize(
        coordinator_address=spec["coordinator"],
        num_processes=spec["num_processes"],
        process_id=spec["process_id"],
        local_device_ids=spec.get("local_device_ids"),
    )
    _DISTRIBUTED = spec
    return dict(_DISTRIBUTED)


def distributed_info() -> dict | None:
    """The spec this process joined the mesh with, or None."""
    return dict(_DISTRIBUTED) if _DISTRIBUTED is not None else None


def describe() -> dict:
    """Telemetry snapshot: applied tier + mesh membership + live device
    census (device census only if the backend already initialized — this
    never forces initialization)."""
    out: dict = {"tier": current(), "distributed": distributed_info()}
    if _backend_initialized():
        import jax

        out["devices"] = {
            "platform": jax.devices()[0].platform,
            "global": len(jax.devices()),
            "local": len(jax.local_devices()),
            "process_index": jax.process_index(),
        }
    return out
