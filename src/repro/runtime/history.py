"""Run-history store: the fleet's memory across runs (DESIGN.md SS13).

A single run's trace (runtime/trace.py) answers "where did THIS run's
wall time go"; this module answers the cross-run questions — did the
last knob change help, is tonight's run slower than last week's on the
same workload — that the paper answered by keeping profiling notebooks
per node type (SSIV-B).  One summary record is appended per FINISHED
run, at finalize time, to an append-only JSONL:

  * default path ``<out>/history.jsonl`` (outside every artifact dir,
    so fsck and byte-identity checks never see it); the ``EDM_HISTORY``
    env var points it at a shared file instead, accumulating history
    across stores — the knob-vs-throughput table grows one row per run.
  * crash-safe by the store's one durability primitive (write-temp +
    fsync + os.replace): a reader always sees whole records, a SIGKILL
    mid-append leaves the previous generation.
  * re-finalizing the SAME run (elastic resume, fsck --heal recompute)
    REPLACES its record rather than duplicating it — run identity is
    (out, fingerprint), so history rows stay one-per-run.

Records are written only when there is evidence to summarize (a
telemetry sink is active or ``EDM_HISTORY`` is set) — a telemetry-off
run leaves the store exactly as before this module existed.

``edm_fleet trends`` renders a history file as a cross-run table with
regression flags (total wall vs the previous run of the same
fingerprint) and a knob-vs-throughput rollup grouped by geometry.
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Any, Optional

from repro.runtime import telemetry

HISTORY_NAME = "history.jsonl"
HISTORY_VERSION = 1
#: wall-time growth vs the previous same-fingerprint run that flags a
#: regression in `edm_fleet trends` (20% — above run-to-run jitter).
REGRESSION_PCT = 20.0


def history_path(out_dir: str | pathlib.Path) -> pathlib.Path:
    """EDM_HISTORY env override, else ``<out>/history.jsonl``."""
    env = os.environ.get("EDM_HISTORY", "")
    if env:
        return pathlib.Path(env)
    return pathlib.Path(out_dir) / HISTORY_NAME


# ------------------------------------------------------------ record build
def _run_identity(out: pathlib.Path) -> dict:
    """(N, L, engine, geometry, fingerprint) from the store's own files —
    fleet.json when the run was a fleet, causal_map/meta.json otherwise."""
    ident: dict[str, Any] = {
        "fingerprint": None, "N": None, "L": None, "engine": None,
        "geometry": {},
    }
    fp_f = out / "fingerprint.json"
    if fp_f.exists():
        try:
            ident["fingerprint"] = json.loads(
                fp_f.read_text()).get("fingerprint")
        except ValueError:
            pass
    spec_f = out / "fleet.json"
    if spec_f.exists():
        try:
            spec = json.loads(spec_f.read_text())
        except ValueError:
            spec = {}
        cfg = spec.get("cfg") or {}
        ident.update(
            N=spec.get("N"), L=spec.get("L"),
            engine=cfg.get("engine"),
            fingerprint=spec.get("fingerprint", ident["fingerprint"]),
        )
        ident["geometry"] = {
            "unit_rows": spec.get("unit_rows"),
            "lib_block": cfg.get("lib_block"),
            "target_tile": cfg.get("target_tile"),
            "knn_tile_c": cfg.get("knn_tile_c"),
            "stream_depth": cfg.get("stream_depth"),
        }
        return ident
    meta_f = out / "causal_map" / "meta.json"
    if meta_f.exists():
        try:
            meta = json.loads(meta_f.read_text())
        except ValueError:
            meta = {}
        shape = meta.get("shape") or [None, None]
        ident.update(N=shape[0], engine=meta.get("engine"))
        ident["geometry"] = {
            "target_tile": meta.get("target_tile"),
            "knn_tile_c": meta.get("knn_tile_c"),
            "stream_depth": meta.get("stream_depth"),
        }
    return ident


def build_record(out_dir: str | pathlib.Path) -> dict:
    """One run-summary record from a store's recorded telemetry + specs:
    fingerprint, geometry, engine, per-stage span durations, bytes
    written, chunk p50/p95/p99, steal/retry/poison counts, worker count,
    and derived rows/s throughput (phase2+sig chunk rows over their span
    time).  Telemetry-off stores yield a record with zeroed timings —
    identity fields still make it a useful trend row."""
    out = pathlib.Path(out_dir)
    rec: dict[str, Any] = {
        "v": HISTORY_VERSION,
        "t": time.time(),
        "out": str(out.resolve()),
        **_run_identity(out),
        "workers": 0,
        "stages": {},
        "total_span_s": 0.0,
        "bytes_written": 0,
        "chunks": 0,
        "chunk_p50_s": None, "chunk_p95_s": None, "chunk_p99_s": None,
        "rows_per_s": None,
        "steals": 0, "retries": 0, "poisoned": 0,
        "held_p95_s": None,
    }
    stems: set[str] = set()
    chunk_durs: list[float] = []
    held: list[float] = []
    chunk_rows = 0
    chunk_s = 0.0
    done_uids: set[str] = set()
    for stem, r in telemetry.iter_store_records(out):
        if telemetry.validate(r):
            continue
        stems.add(stem)
        stage, name, attrs = r["stage"], r["name"], r["attrs"] or {}
        if r["kind"] == "span":
            st = rec["stages"].setdefault(stage, {"span_s": 0.0})
            st["span_s"] += r["dur_s"]
            rec["total_span_s"] += r["dur_s"]
            if name == "chunk":
                chunk_durs.append(r["dur_s"])
                if stage in ("phase2", "sig"):
                    chunk_rows += int(attrs.get("rows", 0))
                    chunk_s += r["dur_s"]
            elif name in ("write_tile", "write_block"):
                rec["bytes_written"] += int(attrs.get("bytes", 0))
            continue
        if name == "steal":
            rec["steals"] += 1
        elif name == "unit_failed":
            rec["retries"] += 1
        elif name == "unit_poisoned":
            rec["poisoned"] += 1
        elif name == "held":
            held.append(float(r.get("value", 0.0)))
        elif name == "done":
            # dedupe: a crash between record-flush and marker can leave
            # two done records for one uid (see workqueue.mark_done)
            done_uids.add(str(attrs.get("uid", "")))
    rec["workers"] = len(stems)
    rec["chunks"] = len(chunk_durs)
    rec["units_done"] = len(done_uids)
    chunk_durs.sort()
    held.sort()

    def pct(vals: list[float], p: float) -> Optional[float]:
        if not vals:
            return None
        return round(vals[min(len(vals) - 1, int(p * (len(vals) - 1)))], 6)

    rec["chunk_p50_s"] = pct(chunk_durs, 0.50)
    rec["chunk_p95_s"] = pct(chunk_durs, 0.95)
    rec["chunk_p99_s"] = pct(chunk_durs, 0.99)
    rec["held_p95_s"] = pct(held, 0.95)
    if chunk_s > 0 and chunk_rows > 0:
        rec["rows_per_s"] = round(chunk_rows / chunk_s, 4)
    for st in rec["stages"].values():
        st["span_s"] = round(st["span_s"], 6)
    rec["total_span_s"] = round(rec["total_span_s"], 6)
    return rec


# ------------------------------------------------------------- persistence
def load_history(path: str | pathlib.Path) -> list[dict]:
    """Valid history records in file order (append order == time order)."""
    return [r for r in telemetry.read_jsonl(path)
            if isinstance(r, dict) and r.get("v") == HISTORY_VERSION]


def append_record(path: str | pathlib.Path, rec: dict) -> pathlib.Path:
    """Append ``rec``, replacing any previous record of the SAME run
    (identity = (out, fingerprint)) — re-finalizing after an elastic
    resume or a heal updates the run's row instead of duplicating it.
    Atomic whole-file rewrite (temp + fsync + rename): a reader never
    sees a torn line, a SIGKILL leaves the previous generation."""
    from repro.data.store import atomic_write_text

    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    key = (rec.get("out"), rec.get("fingerprint"))
    kept = [r for r in load_history(p)
            if (r.get("out"), r.get("fingerprint")) != key]
    kept.append(rec)
    atomic_write_text(p, "".join(json.dumps(r) + "\n" for r in kept))
    return p


def record_run(out_dir: str | pathlib.Path) -> Optional[pathlib.Path]:
    """Summarize a finished run into the history store; the finalize
    paths of both pipelines call this once per completed run.

    No-op (returns None) when there is nothing to remember the run BY —
    no telemetry sink active and no ``EDM_HISTORY`` override — so a
    telemetry-off run leaves its store byte-for-byte as before.  Flushes
    the active sink first: the summary must see this process's own tail
    records (the just-closed stage spans)."""
    if not telemetry.enabled() and not os.environ.get("EDM_HISTORY"):
        return None
    telemetry.flush()
    try:
        return append_record(history_path(out_dir), build_record(out_dir))
    except OSError:
        return None  # history is observability, never a run failure


# ----------------------------------------------------------------- trends
def analyze_trends(records: list[dict]) -> dict:
    """Cross-run analysis of a history: per-run rows (with a regression
    flag vs the previous run of the same fingerprint) and a
    knob-vs-throughput rollup grouped by geometry."""
    runs: list[dict] = []
    last_by_fp: dict[str, dict] = {}
    for r in records:
        row = {
            "t": r.get("t"), "out": r.get("out"),
            "fingerprint": r.get("fingerprint"),
            "N": r.get("N"), "engine": r.get("engine"),
            "workers": r.get("workers"),
            "geometry": r.get("geometry") or {},
            "total_span_s": r.get("total_span_s"),
            "rows_per_s": r.get("rows_per_s"),
            "chunk_p95_s": r.get("chunk_p95_s"),
            "steals": r.get("steals"), "retries": r.get("retries"),
            "poisoned": r.get("poisoned"),
            "regression_pct": None,
        }
        fp = r.get("fingerprint")
        prev = last_by_fp.get(fp) if fp else None
        if (prev is not None and prev.get("total_span_s")
                and row["total_span_s"]):
            delta = 100.0 * (row["total_span_s"] / prev["total_span_s"] - 1)
            row["regression_pct"] = round(delta, 1)
        if fp:
            last_by_fp[fp] = row
        runs.append(row)

    knobs: dict[str, dict] = {}
    for row in runs:
        g = row["geometry"]
        key = json.dumps({
            "engine": row["engine"], "workers": row["workers"],
            "tile": g.get("target_tile"), "depth": g.get("stream_depth"),
            "unit_rows": g.get("unit_rows") or g.get("lib_block"),
        }, sort_keys=True)
        k = knobs.setdefault(key, {"runs": 0, "rows_per_s": []})
        k["runs"] += 1
        if row["rows_per_s"]:
            k["rows_per_s"].append(row["rows_per_s"])
    knob_rows = []
    for key, k in knobs.items():
        vals = k["rows_per_s"]
        knob_rows.append({
            **json.loads(key), "runs": k["runs"],
            "rows_per_s_mean": round(sum(vals) / len(vals), 4)
            if vals else None,
        })
    knob_rows.sort(key=lambda r: -(r["rows_per_s_mean"] or 0.0))
    regressed = [r for r in runs
                 if (r["regression_pct"] or 0.0) > REGRESSION_PCT]
    return {"runs": runs, "knobs": knob_rows, "regressions": regressed}


def render_trends(records: list[dict]) -> str:
    """Human form of :func:`analyze_trends` over a loaded history."""
    if not records:
        return ("history: no runs recorded yet (runs append a summary at "
                "finalize when telemetry or EDM_HISTORY is active)")
    a = analyze_trends(records)
    lines = [f"history: {len(a['runs'])} run(s)"]
    lines.append(
        f"{'when':<20} {'N':>6} {'engine':<16} {'W':>3} {'tile':>5} "
        f"{'depth':>5} {'span_s':>9} {'rows/s':>8}  flags")
    for r in a["runs"]:
        when = time.strftime("%Y-%m-%d %H:%M:%S",
                             time.localtime(r["t"] or 0))
        g = r["geometry"]
        flags = []
        if r["regression_pct"] is not None:
            sign = "+" if r["regression_pct"] >= 0 else ""
            tag = (f"REGRESSION {sign}{r['regression_pct']}%"
                   if r["regression_pct"] > REGRESSION_PCT
                   else f"{sign}{r['regression_pct']}%")
            flags.append(tag)
        if r["steals"]:
            flags.append(f"{r['steals']} steal(s)")
        if r["poisoned"]:
            flags.append(f"{r['poisoned']} poisoned")
        lines.append(
            f"{when:<20} {r['N'] or '?':>6} {(r['engine'] or '?'):<16} "
            f"{r['workers'] or 0:>3} {g.get('target_tile') or 0:>5} "
            f"{g.get('stream_depth') or 0:>5} "
            f"{(r['total_span_s'] or 0.0):>9.3f} "
            f"{(r['rows_per_s'] or 0.0):>8.2f}  "
            + (", ".join(flags) or "-"))
    if len(a["knobs"]) > 1:
        lines.append("knob vs throughput (mean rows/s per geometry):")
        for k in a["knobs"]:
            lines.append(
                f"  engine={k['engine']} W={k['workers']} tile={k['tile']} "
                f"depth={k['depth']} unit_rows={k['unit_rows']}: "
                f"{k['rows_per_s_mean'] or 0.0:.2f} rows/s "
                f"over {k['runs']} run(s)")
    if a["regressions"]:
        lines.append(f"{len(a['regressions'])} regression(s) above "
                     f"{REGRESSION_PCT:.0f}% — see flags above")
    return "\n".join(lines)
