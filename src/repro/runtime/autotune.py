"""Recorded-timing autotuner: replay a store's telemetry, tune the knobs.

The fleet has three hand-set geometry knobs — ``chunk_rows`` (row-chunk
height = devices x lib_block), ``target_tile`` (phase-2 column tile
width), ``knn_tile_c`` (streaming kNN candidate-tile width) — and every
one of them is BIT-INVISIBLE to outputs (DESIGN.md SS7/SS8/SS10: any
geometry produces byte-identical causal_map/rho_conv/pvals).  That
invariant is what makes automated tuning safe: a recommendation can
never change results, only wall time.  This module closes the loop the
paper closed by hand (SSIV-B profiling -> per-node work shapes):

  recommend(store)  — replay the per-worker telemetry JSONL a run
                      recorded (runtime/telemetry.py) and derive tuned
                      knob values from MEASURED timings;
  write_tuned()     — persist them as ``tuned.json`` beside
                      ``fleet.json`` (same atomic-write discipline);
  load_tuned()      — read them back (fleet restart / --autotune);
  apply_to_cfg()    — stamp them into an EDMConfig for the next run.

Decision rules (documented in DESIGN.md SS11):

  chunk_rows   — rows/sec measured from phase2+sig "chunk" spans,
                 scaled to TARGET_CHUNK_S seconds of compute per chunk
                 (long enough to amortize dispatch, short enough that a
                 lease TTL covers several chunks), rounded to the
                 recorded chunk's row multiple and clamped to [min(8),
                 the run's N].
  target_tile  — the store-overhead ratio (mean write_tile span /
                 mean per-tile compute) steers a pow2 resize of the
                 recorded tile: > WRITE_RATIO_HI means tiles are too
                 narrow (per-tile overhead dominates) -> double;
                 < WRITE_RATIO_LO with more than one tile per row
                 chunk -> halve (narrower tiles shrink the live
                 working set for free).  Clamped to [TILE_MIN, N].
  knn_tile_c   — pin the width the engine actually calibrated at the
                 largest recorded library length (the "engine"/
                 "knn_tile" counter), so the next run skips calibration
                 and keeps the same kernel shapes across restarts.

Schedule knobs (DESIGN.md SS13; evidence comes from the lease queue's
held-time counters and the streamer's drain spans — ISSUE "Autotune
beyond geometry"):

  ttl          — lease expiry sized from the MEASURED hold-time tail:
                 TTL_SAFETY x held p95, clamped to [TTL_MIN, TTL_MAX].
                 A TTL far above real hold times parks crashed units
                 for minutes; far below it triggers spurious steals of
                 slow-but-alive workers.
  workers      — straggler-tail share model: with W workers a stage's
                 tail is ~ one unit hold (the barrier waits on the last
                 unit), so tail share ≈ p95 / (busy/W + p95).  Pick the
                 largest W keeping that share under TAIL_TARGET:
                 W = busy_total x TAIL_TARGET / (p95 x (1-TAIL_TARGET)).
  stream_depth — drain overlap: gather_share = (time the drain spent
                 blocked on device gathers) / (chunk compute time).
                 Above GATHER_HI the device is finishing ahead of the
                 host pipeline -> one more chunk in flight; below
                 GATHER_LO at depth > 2 the extra buffer is dead weight
                 -> shrink.  Clamped to [1, DEPTH_MAX].

Geometry knobs land in the EDMConfig (apply_to_cfg); schedule knobs are
applied by the DRIVER (edm_run spawns workers with the tuned ttl and
prints the worker recommendation — worker count is the user's budget
call, never silently changed).

Every recommendation carries its evidence (the aggregates it was
derived from) in tuned.json, so a recommendation is auditable and a
rerun under different hardware visibly re-derives different shapes.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

from repro.runtime import telemetry

TUNED_NAME = "tuned.json"
TUNED_VERSION = 1

#: target seconds of compute per row chunk (see module docstring).
TARGET_CHUNK_S = 20.0
#: store-overhead band steering the target_tile resize.
WRITE_RATIO_HI = 0.10
WRITE_RATIO_LO = 0.025
TILE_MIN = 16
CHUNK_ROWS_MIN = 8

#: schedule-knob bands (module docstring; DESIGN.md SS13).
TTL_SAFETY = 4.0
TTL_MIN = 60.0
TTL_MAX = 3600.0
TAIL_TARGET = 0.2
WORKERS_MAX = 64
GATHER_HI = 0.15
GATHER_LO = 0.02
DEPTH_MAX = 4


def _pow2_at_most(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def replay(out_dir: str | pathlib.Path) -> dict:
    """Aggregate a store's recorded telemetry into the sufficient
    statistics of the decision rules: per-stage chunk span sums, store
    write span sums, and the engine calibration counters."""
    agg = {
        "chunk_s": 0.0, "chunk_rows_done": 0, "chunks": 0,
        "tiles_per_chunk": 0, "rec_chunk_rows": 0, "rec_tile": 0,
        "write_s": 0.0, "writes": 0, "write_bytes": 0,
        "knn_tile": {},  # Lc -> calibrated width
        "records": 0, "N": 0,
        # schedule-knob evidence
        "held": [],          # unit hold durations (done + stolen + released)
        "gather_s": 0.0,     # drain time blocked on device gathers
        "busy_by_worker": {},  # worker file -> chunk-span seconds
        "rec_depth": 0,      # stream depth the run actually ran
        "rec_workers": 0,    # worker count the run actually ran
    }
    for stem, rec in telemetry.iter_store_records(out_dir):
        agg["records"] += 1
        stage, name = rec.get("stage"), rec.get("name")
        attrs = rec.get("attrs") or {}
        if name == "held" and rec.get("kind") == "counter":
            agg["held"].append(float(rec.get("value", 0.0)))
        elif name == "drain" and "dur_s" in rec:
            agg["gather_s"] += float(attrs.get("gather_s", 0.0))
            if attrs.get("depth"):
                agg["rec_depth"] = max(agg["rec_depth"], int(attrs["depth"]))
        elif name == "run_config":
            if attrs.get("stream_depth"):
                agg["rec_depth"] = max(agg["rec_depth"],
                                       int(attrs["stream_depth"]))
            if attrs.get("workers"):
                agg["rec_workers"] = max(agg["rec_workers"],
                                         int(attrs["workers"]))
        if name == "chunk" and stage in ("phase2", "sig"):
            agg["busy_by_worker"][stem] = (
                agg["busy_by_worker"].get(stem, 0.0) + rec.get("dur_s", 0.0)
            )
            agg["chunk_s"] += rec.get("dur_s", 0.0)
            agg["chunk_rows_done"] += int(attrs.get("rows", 0))
            agg["chunks"] += 1
            agg["rec_chunk_rows"] = max(
                agg["rec_chunk_rows"], int(attrs.get("chunk_rows", 0))
            )
            if attrs.get("tile"):
                agg["rec_tile"] = max(agg["rec_tile"], int(attrs["tile"]))
            if attrs.get("n_tiles"):
                agg["tiles_per_chunk"] = max(
                    agg["tiles_per_chunk"], int(attrs["n_tiles"])
                )
        elif name in ("write_tile", "write_block") and "dur_s" in rec:
            agg["write_s"] += rec["dur_s"]
            agg["writes"] += 1
            agg["write_bytes"] += int(attrs.get("bytes", 0))
        elif name == "knn_tile" and stage == "engine":
            agg["knn_tile"][int(attrs.get("Lc", 0))] = int(rec.get("value", 0))
        elif name == "causal_map" and stage == "assemble":
            agg["N"] = max(agg["N"], int(attrs.get("N", 0)))
    return agg


def recommend(out_dir: str | pathlib.Path) -> dict | None:
    """Tuned knob values for the next run over this workload, derived
    from the store's recorded telemetry; None when the store holds no
    usable chunk records (telemetry was off or the run never computed).
    """
    agg = replay(out_dir)
    if agg["chunks"] == 0 or agg["chunk_s"] <= 0:
        return None
    rec: dict = {}

    rows_per_s = agg["chunk_rows_done"] / agg["chunk_s"]
    base = agg["rec_chunk_rows"] or CHUNK_ROWS_MIN
    want = max(CHUNK_ROWS_MIN, rows_per_s * TARGET_CHUNK_S)
    # Round to the recorded chunk's row multiple so the recommendation
    # maps cleanly onto devices x lib_block at apply time.
    chunk_rows = max(base, int(round(want / base)) * base)
    if agg["N"]:
        chunk_rows = min(chunk_rows, agg["N"])
    rec["chunk_rows"] = chunk_rows

    if agg["rec_tile"]:
        tile = agg["rec_tile"]
        if agg["writes"] and agg["chunks"] and agg["tiles_per_chunk"]:
            per_tile_compute = agg["chunk_s"] / (
                agg["chunks"] * agg["tiles_per_chunk"]
            )
            per_write = agg["write_s"] / agg["writes"]
            ratio = per_write / per_tile_compute if per_tile_compute else 0.0
            if ratio > WRITE_RATIO_HI:
                tile *= 2
            elif ratio < WRITE_RATIO_LO and agg["tiles_per_chunk"] > 1:
                tile = max(TILE_MIN, tile // 2)
            rec["write_ratio"] = round(ratio, 4)
        tile = max(TILE_MIN, _pow2_at_most(tile) if tile & (tile - 1) else tile)
        if agg["N"]:
            tile = min(tile, agg["N"])
        rec["target_tile"] = tile

    if agg["knn_tile"]:
        lc = max(agg["knn_tile"])
        rec["knn_tile_c"] = agg["knn_tile"][lc]

    # ---- schedule knobs (module docstring; DESIGN.md SS13) -------------
    held = sorted(agg["held"])
    held_p95 = held[min(len(held) - 1, int(0.95 * (len(held) - 1)))] \
        if held else None
    if held_p95 is not None and held_p95 > 0:
        rec["ttl"] = round(
            min(TTL_MAX, max(TTL_MIN, TTL_SAFETY * held_p95)), 1)
        busy_total = sum(agg["busy_by_worker"].values())
        if busy_total > 0:
            w = busy_total * TAIL_TARGET / (held_p95 * (1.0 - TAIL_TARGET))
            rec["workers"] = int(min(WORKERS_MAX, max(1, w)))
        rec["held_p95_s"] = round(held_p95, 4)
    depth = agg["rec_depth"]
    if depth and agg["chunk_s"] > 0:
        gather_share = agg["gather_s"] / agg["chunk_s"]
        if gather_share > GATHER_HI:
            depth += 1
        elif gather_share < GATHER_LO and depth > 2:
            depth -= 1
        rec["stream_depth"] = int(min(DEPTH_MAX, max(1, depth)))
        rec["gather_share"] = round(gather_share, 4)

    evidence = {k: v for k, v in agg.items()
                if k not in ("knn_tile", "held")}
    evidence["knn_tile"] = {str(k): v for k, v in agg["knn_tile"].items()}
    evidence["held_n"] = len(held)
    evidence["held_p95_s"] = held_p95
    for k in ("held_p95_s", "gather_share"):
        if k in rec:
            evidence[k] = rec.pop(k)
    return {
        "v": TUNED_VERSION,
        "from": str(pathlib.Path(out_dir)),
        "recommend": {
            k: rec[k]
            for k in ("chunk_rows", "target_tile", "knn_tile_c",
                      "stream_depth", "ttl", "workers")
            if k in rec
        },
        "evidence": evidence,
    }


# ------------------------------------------------------------ persistence
def tuned_path(out_dir: str | pathlib.Path) -> pathlib.Path:
    return pathlib.Path(out_dir) / TUNED_NAME


def write_tuned(out_dir: str | pathlib.Path, tuned: dict) -> pathlib.Path:
    from repro.data.store import atomic_write_text

    p = tuned_path(out_dir)
    p.parent.mkdir(parents=True, exist_ok=True)
    atomic_write_text(p, json.dumps(tuned, indent=1))
    return p


def load_tuned(out_dir: str | pathlib.Path) -> dict | None:
    p = tuned_path(out_dir)
    if not p.exists():
        return None
    try:
        t = json.loads(p.read_text())
    except ValueError:
        return None
    return t if t.get("v") == TUNED_VERSION and "recommend" in t else None


def apply_to_cfg(cfg, tuned: dict, n_devices: int):
    """EDMConfig with the tuned shapes stamped in (byte-identity makes
    any of them safe to apply): chunk_rows -> lib_block (per-device row
    share), target_tile / knn_tile_c / stream_depth verbatim.  The
    remaining schedule knobs (ttl, workers) are process-level, not
    config-level — the driver applies/prints them (see edm_run)."""
    rec = tuned["recommend"]
    fields = {}
    if rec.get("chunk_rows"):
        fields["lib_block"] = max(1, int(rec["chunk_rows"]) // max(1, n_devices))
    if rec.get("target_tile"):
        fields["target_tile"] = int(rec["target_tile"])
    if rec.get("knn_tile_c"):
        fields["knn_tile_c"] = int(rec["knn_tile_c"])
    if rec.get("stream_depth"):
        fields["stream_depth"] = int(rec["stream_depth"])
    return dataclasses.replace(cfg, **fields) if fields else cfg


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Replay a run store's telemetry and print (or write) "
        "tuned geometry knobs for the next run (see edm_run --autotune)."
    )
    ap.add_argument("store", help="run store holding telemetry/*.jsonl")
    ap.add_argument("--write", action="store_true",
                    help="persist the recommendation as <store>/tuned.json")
    args = ap.parse_args(argv)
    tuned = recommend(args.store)
    if tuned is None:
        raise SystemExit(
            f"{args.store}: no chunk telemetry to tune from (was the run "
            "recorded with the JSONL sink enabled?)"
        )
    print(json.dumps(tuned, indent=1))
    if args.write:
        print(f"wrote {write_tuned(args.store, tuned)}")


if __name__ == "__main__":
    main()
