"""Store integrity: content checksums, run fingerprints, fsck + heal.

The paper's production run streams CCM blocks through a shared burst
buffer (SSIII-C); our fleet (DESIGN.md SS10) survives crashes via atomic
renames and TTL leases — but nothing *detected* silent damage: bit rot
under a manifest entry, a truncated tile on a flaky network FS, a resume
against the wrong dataset.  This module closes that gap (DESIGN.md SS12):

  * crc32 content checksums for every store artifact — tiles carry
    theirs in the manifest entry, standalone .npy files in a
    ``<file>.crc32`` sidecar, manifest shards in an embedded ``__crc__``
    field.  ``data/store.py`` records them at write time (the crc is
    accumulated WHILE the temp file streams out, no second read) and
    verifies tiles lazily at :meth:`TileWriter.assemble`.
  * a run FINGERPRINT — dataset content crc + canonicalized EDMConfig —
    stamped into the store once and re-derived on every resume and
    fleet-worker join, so tiles computed under different inputs can
    never silently mix.
  * :func:`fsck_store` — eager masterless verification of a whole store
    from files alone (like ``edm_fleet status``), reporting missing /
    corrupt / orphaned artifacts; with ``heal=True`` it revokes exactly
    the damaged units (manifest entries + queue done markers), so one
    normal fleet pass recomputes precisely what was lost.

Layering: this module's checksum/fingerprint primitives are pure (no
store imports), so ``data/store.py`` can import them at module scope;
the fsck half imports the store lazily inside functions — acyclic.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import zlib
from typing import Iterable, Optional

import numpy as np


class IntegrityError(RuntimeError):
    """A store artifact failed its recorded checksum (or a fingerprint
    mismatch): the bytes on disk are not the bytes that were written."""


# ---------------------------------------------------------------- checksums
class Crc32:
    """Incremental crc32 with the store's hex rendering.  File-like
    enough (``write``) to tee np.save's output stream."""

    def __init__(self, inner=None):
        self.value = 0
        self._inner = inner

    def write(self, data) -> int:
        self.value = zlib.crc32(data, self.value)
        return self._inner.write(data) if self._inner is not None else len(data)

    def update(self, data) -> "Crc32":
        self.value = zlib.crc32(data, self.value)
        return self

    @property
    def hex(self) -> str:
        return f"{self.value & 0xFFFFFFFF:08x}"


def checksum_bytes(data: bytes) -> str:
    return Crc32().update(data).hex


def checksum_file(path: str | pathlib.Path, bufsize: int = 1 << 20) -> str:
    c = Crc32()
    with open(path, "rb") as f:
        while True:
            buf = f.read(bufsize)
            if not buf:
                return c.hex
            c.update(buf)


def checksum_ndarray(a: np.ndarray, rows_per_step: int = 4096) -> str:
    """crc32 over an array's raw C-order bytes, streamed in row slabs so
    memmapped paper-scale inputs never materialize whole."""
    a = np.ascontiguousarray(a) if a.ndim == 0 else a
    c = Crc32()
    if a.ndim == 0 or a.shape[0] == 0:
        return c.update(np.ascontiguousarray(a).tobytes()).hex
    for r in range(0, a.shape[0], rows_per_step):
        c.update(np.ascontiguousarray(a[r : r + rows_per_step]).tobytes())
    return c.hex


# ----------------------------------------------------------------- sidecars
def sidecar_path(path: str | pathlib.Path) -> pathlib.Path:
    p = pathlib.Path(path)
    return p.parent / (p.name + ".crc32")


def write_sidecar(path: str | pathlib.Path, crc: str) -> None:
    """Record a file's checksum beside it.  Written AFTER the file it
    covers (both writes are atomic replaces of idempotent content, so a
    crash between them only leaves a verifiable-later gap, never a
    false mismatch)."""
    from repro.data.store import atomic_write_text  # lazy: no cycle

    atomic_write_text(sidecar_path(path), crc + "\n")


def read_sidecar(path: str | pathlib.Path) -> Optional[str]:
    try:
        return sidecar_path(path).read_text().strip() or None
    except OSError:
        return None


def verify_file(path: str | pathlib.Path) -> str:
    """"ok" | "corrupt" | "unverified" (no sidecar) | "missing"."""
    p = pathlib.Path(path)
    if not p.exists():
        return "missing"
    want = read_sidecar(p)
    if want is None:
        return "unverified"
    return "ok" if checksum_file(p) == want else "corrupt"


def load_npy_verified(path: str | pathlib.Path) -> np.ndarray:
    """np.load with lazy sidecar verification (the read-side integrity
    check for standalone artifacts like phase-1 optE)."""
    status = verify_file(path)
    if status == "corrupt":
        raise IntegrityError(
            f"{path}: content does not match its recorded checksum "
            f"(run `edm_fleet fsck --heal` on the store)"
        )
    return np.load(path)


# -------------------------------------------------------------- fingerprint
def run_fingerprint(
    dataset_crc: str, shape, dtype, cfg_dict: dict
) -> str:
    """Stable id of (dataset content, compute config): sha256 over the
    canonical JSON.  Everything that changes output bytes is in here;
    byte-invisible knobs (geometry: lib_block/target_tile/knn_tile_c/
    stream_depth/engine — DESIGN.md SS7/SS8/SS10) are canonicalized
    OUT, so a resume under tuned shapes or another engine still matches."""
    cfg = dict(cfg_dict)
    for knob in ("lib_block", "target_tile", "knn_tile_c", "stream_depth",
                 "engine"):
        cfg.pop(knob, None)
    canon = json.dumps(
        {"dataset_crc32": dataset_crc, "shape": list(shape),
         "dtype": str(dtype), "cfg": cfg},
        sort_keys=True,
    )
    return hashlib.sha256(canon.encode()).hexdigest()[:16]


def fingerprint_of(ts: np.ndarray, cfg) -> dict:
    """The full stamp for a run over in-memory series ``ts``."""
    import dataclasses

    cfg_dict = dataclasses.asdict(cfg) if dataclasses.is_dataclass(cfg) \
        else dict(cfg)
    crc = checksum_ndarray(np.ascontiguousarray(ts))
    return {
        "fingerprint": run_fingerprint(crc, ts.shape, ts.dtype, cfg_dict),
        "dataset_crc32": crc,
        "shape": list(ts.shape),
        "dtype": str(ts.dtype),
    }


FINGERPRINT_NAME = "fingerprint.json"


def stamp_fingerprint(out_dir: str | pathlib.Path, fp: dict) -> None:
    """Write (first run) or verify (resume) the store's fingerprint.
    A mismatch means the store's existing artifacts were computed from
    DIFFERENT inputs — refusing here is what keeps incompatible tiles
    from ever mixing."""
    from repro.data.store import atomic_write_text  # lazy: no cycle

    f = pathlib.Path(out_dir) / FINGERPRINT_NAME
    if f.exists():
        try:
            have = json.loads(f.read_text())
        except ValueError:
            have = {}
        if have.get("fingerprint") != fp["fingerprint"]:
            raise IntegrityError(
                f"run fingerprint mismatch in {out_dir}: store holds "
                f"{have.get('fingerprint')} (dataset crc "
                f"{have.get('dataset_crc32')}, shape {have.get('shape')}) "
                f"but this run derives {fp['fingerprint']} (dataset crc "
                f"{fp['dataset_crc32']}, shape {fp['shape']}); the store "
                "was written from different data or a different config — "
                "use a fresh --out dir"
            )
        return
    pathlib.Path(out_dir).mkdir(parents=True, exist_ok=True)
    atomic_write_text(f, json.dumps(fp, sort_keys=True))


# -------------------------------------------------------------------- fsck
#: tiled artifact dirs relative to the store root -> (stage whose units
#: cover its rows, downstream singleton stages stale after a heal).
TILED_ARTIFACTS = {
    ".": ("phase2", ("assemble", "finalize")),
    "rho_conv": ("sig", ("finalize",)),
    "rho_trend": ("sig", ("finalize",)),
    "pvals": ("sig", ("finalize",)),
}
#: assembled / standalone artifacts -> singleton stages to revoke on heal.
ASSEMBLED_ARTIFACTS = {
    "causal_map": ("assemble", "finalize"),
    "rho_conv": ("finalize",),
    "rho_trend": ("finalize",),
    "pvals": ("finalize",),
    "edges": ("finalize",),
}


def _tile_file(d: pathlib.Path, key: str) -> pathlib.Path:
    if "," in key:
        row0, col0 = (int(s) for s in key.split(","))
        return d / f"tile_{row0:08d}_{col0:08d}.npy"
    return d / f"rows_{int(key):08d}.npy"


def _entry_fields(val) -> tuple[int, Optional[int], Optional[str]]:
    """Manifest entry -> (nrows, ncols|None full-width, crc|None legacy)."""
    if isinstance(val, list):
        if len(val) >= 2 and isinstance(val[1], str):  # [nrows, crc] block
            return int(val[0]), None, val[1]
        nr = int(val[0])
        nc = int(val[1]) if len(val) > 1 else None
        crc = val[2] if len(val) > 2 else None
        return nr, nc, crc
    return int(val), None, None  # legacy bare-int row block


def read_manifest_shard(path: pathlib.Path) -> Optional[dict]:
    """Parse one blocks*.json shard, verifying its embedded ``__crc__``
    (when present) over the canonical entries JSON.  None = torn or
    corrupt (callers decide whether that is tolerable or reportable)."""
    try:
        raw = json.loads(path.read_text())
    except (OSError, ValueError):
        return None
    want = raw.pop("__crc__", None)
    if want is not None:
        if checksum_bytes(json.dumps(raw, sort_keys=True).encode()) != want:
            return None
    return raw


def manifest_with_crc(entries: dict) -> str:
    """Serialize a manifest shard with its self-checksum embedded."""
    crc = checksum_bytes(json.dumps(entries, sort_keys=True).encode())
    return json.dumps({"__crc__": crc, **entries})


def _scan_tiled_dir(d: pathlib.Path) -> dict:
    """Verify one tiled artifact dir: every manifest entry's file exists,
    matches its recorded crc (or at least its recorded shape, for
    pre-checksum legacy entries), no orphans, no torn shards."""
    rep = {
        "entries": 0, "ok": 0, "unverified": 0,
        "missing": [], "corrupt": [], "orphaned": [], "torn_shards": [],
        "damaged_rows": [],  # (row0, nrows) spans needing recompute
    }
    entries: dict[str, object] = {}
    for shard in sorted(d.glob("blocks*.json")):
        if shard.suffix != ".json":
            continue
        parsed = read_manifest_shard(shard)
        if parsed is None:
            rep["torn_shards"].append(shard.name)
            # a torn shard's row spans are unknowable — every row this
            # artifact covers is suspect (heal revokes the whole stage;
            # still-covered rows are re-certified from the OTHER shards
            # by the fleet's coverage check, so only real losses recompute)
            rep["damaged_rows"].append((0, 1 << 62))
            continue
        entries.update(parsed)
    rep["entries"] = len(entries)
    for key, val in sorted(entries.items()):
        nr, nc, crc = _entry_fields(val)
        f = _tile_file(d, key)
        if not f.exists():
            rep["missing"].append(f.name)
            rep["damaged_rows"].append((int(key.split(",")[0]), nr))
            continue
        if crc is not None:
            good = checksum_file(f) == crc
        else:
            try:  # legacy entry: header-only shape check
                shape = np.load(f, mmap_mode="r").shape
                good = shape[0] == nr and (nc is None or shape[1] == nc)
            except ValueError:
                good = False
            if good:
                rep["unverified"] += 1
                continue
        if good:
            rep["ok"] += 1
        else:
            rep["corrupt"].append(f.name)
            rep["damaged_rows"].append((int(key.split(",")[0]), nr))
    known = {_tile_file(d, k).name for k in entries}
    for f in sorted(d.glob("tile_*.npy")) + sorted(d.glob("rows_*.npy")):
        if f.name not in known:
            rep["orphaned"].append(f.name)
    co = d / "col_order.npy"
    if co.exists() and verify_file(co) == "corrupt":
        rep["corrupt"].append(co.name)
        # col_order pins the layout of EVERY tile — all rows suspect
        rep["damaged_rows"].append((0, 1 << 62))
    return rep


def _scan_assembled(d: pathlib.Path) -> Optional[dict]:
    """Verify one assembled artifact dir (<d>/data.npy + meta.json)."""
    data, meta_f = d / "data.npy", d / "meta.json"
    if not d.exists() or not (data.exists() or meta_f.exists()):
        return None
    rep = {"status": verify_file(data)}
    if rep["status"] in ("ok", "unverified") and meta_f.exists():
        try:
            meta = json.loads(meta_f.read_text())
            shape = tuple(np.load(data, mmap_mode="r").shape)
            if tuple(meta.get("shape", shape)) != shape:
                rep["status"] = "corrupt"
                rep["detail"] = f"shape {shape} != meta {meta.get('shape')}"
        except ValueError:
            rep["status"] = "corrupt"
            rep["detail"] = "unparseable data.npy or meta.json"
    return rep


def _tmp_residue(out: pathlib.Path) -> list[pathlib.Path]:
    return [p for p in out.rglob("*.tmp-*")
            if "jax_cache" not in p.parts and p.is_file()]


def fsck_store(
    out_dir: str | pathlib.Path, heal: bool = False
) -> dict:
    """Eagerly verify a whole run store from files alone; optionally
    revoke whatever is damaged so the normal fleet recomputes it.

    The report is JSON-safe.  ``clean`` is True when nothing is missing,
    corrupt, orphaned, or fingerprint-stale (``unverified`` legacy
    artifacts do not dirty a store).  With ``heal=True`` the report
    gains a ``healed`` section; a corrupt DATASET is never healed (the
    inputs are not ours to recompute — the report flags it fatal).
    """
    from repro.runtime import telemetry

    out = pathlib.Path(out_dir)
    if not out.exists():
        raise FileNotFoundError(f"store {out} does not exist")
    report: dict = {"out": str(out), "artifacts": {}, "problems": 0}

    # ---- fingerprint / dataset ----------------------------------------
    spec = None
    spec_f = out / "fleet.json"
    if spec_f.exists():
        spec = json.loads(spec_f.read_text())
    fp_f = out / FINGERPRINT_NAME
    stamped = json.loads(fp_f.read_text()) if fp_f.exists() else {}
    want_crc = (spec or {}).get("dataset_crc32") or stamped.get("dataset_crc32")
    ds_path = (spec or {}).get("dataset")
    if ds_path is None and (out / "dataset" / "data.npy").exists():
        ds_path = out / "dataset"
    fp_rep = {"status": "unverified"}
    if ds_path is not None and pathlib.Path(ds_path, "data.npy").exists():
        data_f = pathlib.Path(ds_path) / "data.npy"
        fp_rep["dataset"] = str(ds_path)
        # float32 view matches what init_fleet/workers hash (no-copy when
        # the dataset is already float32, the normal case).
        have_crc = checksum_ndarray(
            np.asarray(np.load(data_f, mmap_mode="r"), np.float32))
        fp_rep["dataset_crc32"] = have_crc
        if want_crc is None:
            fp_rep["status"] = "unverified"  # pre-integrity store
        elif have_crc == want_crc:
            fp_rep["status"] = "ok"
        else:
            fp_rep["status"] = "stale"
            fp_rep["detail"] = (
                f"dataset content crc {have_crc} != recorded {want_crc}: "
                "the store's tiles were computed from different data "
                "(NOT healable — recompute into a fresh --out)"
            )
    elif ds_path is not None:
        fp_rep["status"] = "missing"
        fp_rep["dataset"] = str(ds_path)
    report["fingerprint"] = fp_rep

    # ---- tiled artifacts ----------------------------------------------
    damaged_units: dict[str, list[tuple[int, int]]] = {}
    stale_downstream: set[str] = set()
    for rel, (stage, downstream) in TILED_ARTIFACTS.items():
        d = out if rel == "." else out / rel
        if not d.exists() or not any(d.glob("blocks*.json")):
            continue
        rep = _scan_tiled_dir(d)
        name = "phase2" if rel == "." else rel
        report["artifacts"][name] = rep
        if rep["missing"] or rep["corrupt"] or rep["torn_shards"]:
            damaged_units.setdefault(stage, []).extend(rep["damaged_rows"])
            stale_downstream.update(downstream)
        report["problems"] += (
            len(rep["missing"]) + len(rep["corrupt"])
            + len(rep["orphaned"]) + len(rep["torn_shards"])
        )

    # ---- assembled / standalone artifacts ------------------------------
    for rel, downstream in ASSEMBLED_ARTIFACTS.items():
        rep = _scan_assembled(out / rel)
        if rep is None:
            continue
        key = rel if rel not in report["artifacts"] else rel + "/assembled"
        report["artifacts"][key] = rep
        if rep["status"] in ("corrupt", "missing"):
            stale_downstream.update(downstream)
            report["problems"] += 1

    p1 = out / "phase1"
    if p1.exists():
        statuses = {f: verify_file(p1 / f)
                    for f in ("optE.npy", "simplex_rho.npy")}
        bad = [f for f, s in statuses.items() if s in ("corrupt", "missing")]
        report["artifacts"]["phase1"] = {"files": statuses}
        if bad:
            damaged_units.setdefault("phase1", []).append((0, 1 << 62))
            report["problems"] += len(bad)

    tmp = _tmp_residue(out)
    report["tmp_residue"] = len(tmp)

    fatal = fp_rep["status"] == "stale"
    report["clean"] = report["problems"] == 0 and not fatal
    if not report["clean"]:
        telemetry.counter("store", "fsck_problems", float(report["problems"]),
                          fatal=fatal)
    if heal and not fatal:
        report["healed"] = _heal(out, spec, report, damaged_units,
                                 stale_downstream, tmp)
    elif heal:
        report["healed"] = {"refused": fp_rep.get("detail", "stale fingerprint")}
    return report


def _heal(
    out: pathlib.Path,
    spec: Optional[dict],
    report: dict,
    damaged_units: dict[str, list[tuple[int, int]]],
    stale_downstream: set[str],
    tmp: list[pathlib.Path],
) -> dict:
    """Revoke exactly the damaged state: drop manifest entries for
    missing/corrupt tiles, delete corrupt/orphaned files, and clear the
    queue done markers of every unit whose rows are no longer covered —
    the normal fleet then recomputes precisely those units (bit-identical
    by DESIGN.md SS10), and a follow-up fsck is clean."""
    from repro.data.store import atomic_write_text  # lazy: no cycle
    from repro.runtime import telemetry

    healed = {"files_deleted": [], "entries_revoked": 0,
              "done_revoked": [], "tmp_removed": len(tmp)}
    for p in tmp:
        p.unlink(missing_ok=True)

    for rel in TILED_ARTIFACTS:
        name = "phase2" if rel == "." else rel
        rep = report["artifacts"].get(name)
        if rep is None:
            continue
        d = out if rel == "." else out / rel
        bad_files = set(rep["missing"]) | set(rep["corrupt"]) \
            | set(rep["orphaned"])
        for fname in set(rep["corrupt"]) | set(rep["orphaned"]):
            f = d / fname
            if f.exists():
                f.unlink()
                healed["files_deleted"].append(str(f.relative_to(out)))
            sc = sidecar_path(f)
            if sc.exists():
                sc.unlink()
        for shard in sorted(d.glob("blocks*.json")):
            if shard.suffix != ".json":
                continue
            parsed = read_manifest_shard(shard)
            if parsed is None:  # torn/corrupt shard: drop it whole
                shard.unlink()
                healed["files_deleted"].append(str(shard.relative_to(out)))
                continue
            keep = {k: v for k, v in parsed.items()
                    if _tile_file(d, k).name not in bad_files}
            if len(keep) != len(parsed):
                healed["entries_revoked"] += len(parsed) - len(keep)
                atomic_write_text(shard, manifest_with_crc(keep))
        if (d / "col_order.npy").name in rep["corrupt"]:
            (d / "col_order.npy").unlink(missing_ok=True)

    # Assembled artifacts: delete corrupt/half-gone ones WHOLE (data +
    # sidecar + meta) so the store reads as "not yet assembled" — clean
    # but incomplete — and the idempotent assemble/finalize stages
    # rebuild them from the (now healed) tiles.
    for rel in ASSEMBLED_ARTIFACTS:
        for key in (rel, rel + "/assembled"):
            rep = report["artifacts"].get(key)
            if rep is not None and isinstance(rep, dict) \
                    and rep.get("status") in ("corrupt", "missing"):
                f = out / rel / "data.npy"
                f.unlink(missing_ok=True)
                sidecar_path(f).unlink(missing_ok=True)
                (out / rel / "meta.json").unlink(missing_ok=True)
                healed["files_deleted"].append(str(f.relative_to(out)))
    p1rep = report["artifacts"].get("phase1", {}).get("files", {})
    for fname, status in p1rep.items():
        if status == "corrupt":
            (out / "phase1" / fname).unlink(missing_ok=True)
            sidecar_path(out / "phase1" / fname).unlink(missing_ok=True)
            healed["files_deleted"].append(f"phase1/{fname}")
    if any(s in ("corrupt", "missing") for s in p1rep.values()):
        # optE.npy is the phase-1 completion witness — dropping any
        # phase-1 file without it would leave a witnessed-but-partial
        # stage, so drop the witness too.
        (out / "phase1" / "optE.npy").unlink(missing_ok=True)

    # Queue done markers: a fleet store's durable "skip this unit"
    # records must not outlive the artifacts they certify.
    qdir = out / "queue"
    if spec is not None and qdir.exists():
        from repro.runtime.workqueue import plan_units

        N, unit_rows = int(spec["N"]), int(spec["unit_rows"])
        revoke: set[str] = set(stale_downstream)
        for stage, spans in damaged_units.items():
            for u in plan_units(stage, N, unit_rows):
                if any(u.row0 < r0 + nr and r0 < u.row0 + u.nrows
                       for r0, nr in spans):
                    revoke.add(u.uid)
        if "assemble" in stale_downstream or "phase1" in damaged_units \
                or damaged_units:
            revoke.add("assemble")
            if (qdir / "finalize.done").exists():
                revoke.add("finalize")
        for uid in sorted(revoke):
            for suffix in (".done", ".fail", ".poison", ".lease"):
                f = qdir / (uid + suffix)
                if f.exists():
                    f.unlink()
                    if suffix == ".done":
                        healed["done_revoked"].append(uid)
    telemetry.counter(
        "store", "fsck_healed",
        float(healed["entries_revoked"] + len(healed["files_deleted"])),
        done_revoked=len(healed["done_revoked"]),
    )
    return healed


def render_fsck(report: dict) -> str:
    verdict = ("CLEAN" if report["clean"]
               else f"{report['problems']} problem(s)" if report["problems"]
               else "NOT CLEAN (stale fingerprint)")
    lines = [f"fsck {report['out']}: {verdict}"]
    fp = report["fingerprint"]
    lines.append(f"fingerprint: {fp['status']}"
                 + (f" — {fp['detail']}" if "detail" in fp else ""))
    for name, rep in report["artifacts"].items():
        if "entries" in rep:
            parts = [f"{rep['ok']} ok"]
            if rep["unverified"]:
                parts.append(f"{rep['unverified']} unverified(legacy)")
            for k in ("missing", "corrupt", "orphaned", "torn_shards"):
                if rep[k]:
                    parts.append(f"{len(rep[k])} {k}: "
                                 + ", ".join(rep[k][:4])
                                 + ("…" if len(rep[k]) > 4 else ""))
            lines.append(f"  {name:<12} {rep['entries']} tiles — "
                         + "; ".join(parts))
        elif "files" in rep:
            lines.append(f"  {name:<12} " + ", ".join(
                f"{f}:{s}" for f, s in rep["files"].items()))
        else:
            lines.append(f"  {name:<12} {rep['status']}"
                         + (f" — {rep['detail']}" if "detail" in rep else ""))
    if report.get("tmp_residue"):
        lines.append(f"  tmp residue: {report['tmp_residue']} file(s)")
    if "healed" in report:
        h = report["healed"]
        if "refused" in h:
            lines.append(f"heal REFUSED: {h['refused']}")
        else:
            lines.append(
                f"healed: {h['entries_revoked']} manifest entr(ies) revoked, "
                f"{len(h['files_deleted'])} file(s) deleted, "
                f"{len(h['done_revoked'])} done marker(s) revoked, "
                f"{h['tmp_removed']} tmp file(s) removed — rerun the fleet "
                "to recompute"
            )
    return "\n".join(lines)
