"""Async chunk queue: overlap device compute with host-side drains —
the double-buffered chunk streaming of DESIGN.md SS6.

JAX dispatch is asynchronous — a jitted call returns device futures
immediately and only blocks when the host materializes them (np.asarray).
The seed pipeline serialized that: convert chunk i to numpy (blocking on
its compute AND device->host copy), write its row block, only then build
and dispatch chunk i+1.  :class:`ChunkStreamer` keeps up to ``depth``
chunks in flight instead, so with depth=2 (double buffering) chunk i+1's
host->device transfer and compute are already queued while chunk i's
copy-out and TileWriter write drain — the streaming store comes off
the critical path (paper SSIII-C's sequential-block-write design point,
now overlapped).

Backend-agnostic: nothing here is EDM-specific, and later sharding /
multi-host PRs can reuse the same queue for their own chunk loops.
"""
from __future__ import annotations

import collections
from typing import Any, Callable

import numpy as np

from time import perf_counter as _perf

from repro.runtime import telemetry


class ChunkStreamer:
    """Bounded queue of in-flight device chunks with ordered drains.

    drain(tag, host_array) is called in submission order — required by
    consumers like TileWriter whose resume manifest must only cover
    rows that are durably on disk.  Tags are opaque to the streamer; the
    EDM pipeline uses (row0, valid) for full-width row chunks and
    (row0, col0, valid) for the tiled 2D decomposition (DESIGN.md SS7),
    where depth bounds the number of (row-chunk x col-tile) blocks in
    flight — i.e. device-side live tiles — not just row chunks.
    """

    def __init__(
        self,
        drain: Callable[[Any, np.ndarray], None],
        depth: int = 2,
        stage: str = "stream",
    ):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.drain = drain
        self.depth = depth
        self.stage = stage  # telemetry label only (never touches bytes)
        self._pending: collections.deque[tuple[Any, Any]] = collections.deque()

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, tag: Any, device_value: Any) -> None:
        """Enqueue an (already dispatched) device value; drains the oldest
        chunk(s) once ``depth`` are in flight.  depth=1 therefore drains the
        chunk just submitted — the fully synchronous legacy behaviour; with
        depth=2 the next chunk can be built and dispatched while one older
        chunk is still in flight (double buffering)."""
        self._pending.append((tag, device_value))
        while len(self._pending) >= self.depth:
            self._drain_one()

    def _drain_one(self) -> None:
        tag, dev = self._pending.popleft()
        with telemetry.span(self.stage, "drain",
                            tag=repr(tag), in_flight=len(self._pending),
                            depth=self.depth) as t:
            t0 = _perf()
            host = np.asarray(dev)  # blocks: compute + D2H copy
            t["gather_s"] = _perf() - t0
            t["bytes"] = int(host.nbytes)
            self.drain(tag, host)

    def flush(self) -> None:
        """Drain everything still in flight (call once after the loop)."""
        while self._pending:
            self._drain_one()

    def __enter__(self) -> "ChunkStreamer":
        return self

    def __exit__(self, exc_type, *_exc) -> None:
        # Don't mask an in-loop exception with a drain of stale chunks.
        if exc_type is None:
            self.flush()
        else:
            self._pending.clear()
