"""Fault-tolerant execution: resilient step loop + straggler telemetry
(retry/poison verdicts feed the fleet queue of DESIGN.md SS10; the
telemetry spine is DESIGN.md SS11).

The paper's failure mode was GPU-init stragglers on 512 MPI workers
(median 4.6 s, max 22.9 s — SSIV-B2).  On TPU pods the analogues are
preemption, ICI link flaps, and host restarts; the mitigation is the same
shape: bounded-retry around the step, restore-from-checkpoint on failure,
and per-step timing telemetry that flags outliers.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

from repro.runtime import telemetry

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class StepTelemetry:
    """EMA-based straggler detector: a step slower than `threshold` x the
    EMA is flagged — logged AND emitted as a ``straggler`` counter through
    the runtime/telemetry spine, so stragglers land in the same sinks
    (JSONL, fleet status) as every other fleet signal instead of living
    on a parallel log-only path."""

    ema: float = 0.0
    alpha: float = 0.1
    threshold: float = 3.0
    n_stragglers: int = 0
    n_steps: int = 0
    stage: str = "engine"

    def record(self, dt: float) -> bool:
        self.n_steps += 1
        is_straggler = self.ema > 0 and dt > self.threshold * self.ema
        if is_straggler:
            self.n_stragglers += 1
            log.warning("straggler step: %.3fs vs EMA %.3fs", dt, self.ema)
            telemetry.counter(self.stage, "straggler", dt_s=dt,
                              ema_s=self.ema, step=self.n_steps)
        self.ema = dt if self.ema == 0 else (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler


class ResilientLoop:
    """Run `step_fn(state, batch) -> (state, metrics)` with checkpoint/restart.

    On any exception: restore the last checkpoint (elastic — the mesh may
    have changed) and replay.  `max_retries` consecutive failures abort.
    """

    def __init__(
        self,
        step_fn: Callable,
        ckpt,  # CheckpointManager
        save_every: int = 100,
        max_retries: int = 3,
    ):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.save_every = save_every
        self.max_retries = max_retries
        self.telemetry = StepTelemetry()

    def run(self, state, batch_at, n_steps: int, start_step: int = 0, shardings=None):
        """batch_at: step -> batch pytree (a deterministic stream, so a
        restore also REWINDS THE DATA — replay is bit-exact).  Returns
        (state, final_step, last_metrics)."""
        step = start_step
        retries = 0
        metrics = None
        while step < n_steps:
            try:
                batch = batch_at(step)
                t0 = time.time()
                state, metrics = self.step_fn(state, batch)
                # materialize before declaring success (async dispatch)
                metrics = {k: float(v) for k, v in metrics.items()}
                self.telemetry.record(time.time() - t0)
                step += 1
                retries = 0
                if step % self.save_every == 0:
                    self.ckpt.save(step, state)
            except Exception as e:  # noqa: BLE001 — the whole point
                retries += 1
                log.error("step %d failed (%s); retry %d/%d", step, e, retries, self.max_retries)
                telemetry.counter("engine", "step_retry", step=step,
                                  retry=retries, max_retries=self.max_retries,
                                  error=repr(e)[:200])
                if retries > self.max_retries:
                    raise
                restored = self.ckpt.restore_latest(state, shardings)
                if restored[0] is not None:
                    step, state = restored
        self.ckpt.save(step, state, blocking=True)
        return state, step, metrics
