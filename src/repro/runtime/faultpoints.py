"""Deterministic fault injection — named crash/error/delay points.

The fleet's crash-safety story (DESIGN.md SS10) rests on specific
ordering windows: tile temp-write -> fsync -> rename, store commit ->
done marker, lease steal readback.  Coarse SIGKILL testing hits those
windows only by luck; this module makes them addressable.  Production
code threads *named points* through the store, the work queue, and the
fleet stage loop via :func:`fire`; a fault SPEC (the ``EDM_FAULTS`` env
var, or :func:`configure` in-process) arms any subset of them:

    EDM_FAULTS="tile_pre_rename:crash@3,chunk_pre:delay=0.5"

Spec grammar (comma-separated arms)::

    <point>:<action>[@<n>]
    action   crash          SIGKILL self (no finally/atexit — the honest
                            crash the atomic-rename discipline must survive)
             exit=<code>    os._exit(code) (a non-signal hard death)
             error          raise InjectedFault (exercises bounded retries)
             delay=<secs>   time.sleep (exercises TTL / lease-age windows)
    @<n>     fire only on the n-th hit of the point in THIS process
             (1-based); omitted = fire on every hit.

Unarmed, :func:`fire` is a dict lookup on an empty table — cheap enough
for hot paths.  Hit counts are per-process, so a relaunched worker (new
process, typically spawned WITHOUT the spec) starts clean: one armed
crash kills one process generation, deterministically.

The point catalog lives in DESIGN.md SS12; grep ``faultpoints.fire`` for
the ground truth.
"""
from __future__ import annotations

import os
import signal
import threading
import time

from repro.runtime import telemetry


class InjectedFault(RuntimeError):
    """Raised by an ``error``-armed fault point (a synthetic compute
    failure the bounded-retry machinery must absorb)."""

    def __init__(self, point: str, hit: int):
        super().__init__(f"injected fault at point {point!r} (hit {hit})")
        self.point = point
        self.hit = hit


class FaultSpecError(ValueError):
    """Malformed ``EDM_FAULTS`` spec (fail loudly at parse time — a typo
    silently disarming a chaos schedule would void the test)."""


_lock = threading.Lock()
_arms: dict[str, tuple[str, float, int]] | None = None  # point -> (action, arg, nth)
_hits: dict[str, int] = {}


def parse_spec(spec: str) -> dict[str, tuple[str, float, int]]:
    """``"a:crash@3,b:delay=0.5"`` -> {point: (action, arg, nth)};
    nth=0 means every hit."""
    arms: dict[str, tuple[str, float, int]] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            point, action = part.split(":", 1)
        except ValueError:
            raise FaultSpecError(f"fault arm {part!r}: expected point:action")
        nth = 0
        if "@" in action:
            action, n = action.split("@", 1)
            nth = int(n)
            if nth < 1:
                raise FaultSpecError(f"fault arm {part!r}: @n must be >= 1")
        arg = 0.0
        if "=" in action:
            action, raw = action.split("=", 1)
            arg = float(raw)
        if action not in ("crash", "exit", "error", "delay"):
            raise FaultSpecError(
                f"fault arm {part!r}: unknown action {action!r}"
            )
        if action == "delay" and arg <= 0:
            raise FaultSpecError(f"fault arm {part!r}: delay needs =<secs>")
        arms[point.strip()] = (action, arg, nth)
    return arms


def configure(spec: str | None) -> None:
    """Arm (or with None/"" disarm) fault points in-process, resetting
    hit counts.  Subprocess workers are armed via the EDM_FAULTS env
    instead (see :func:`_load`)."""
    global _arms
    with _lock:
        _arms = parse_spec(spec) if spec else {}
        _hits.clear()


def _load() -> dict[str, tuple[str, float, int]]:
    global _arms
    if _arms is None:
        with _lock:
            if _arms is None:
                _arms = parse_spec(os.environ.get("EDM_FAULTS", ""))
    return _arms


def fire(point: str) -> None:
    """Hit a named fault point.  No-op unless a spec arms this point
    (and, with ``@n``, unless this is the n-th hit in this process)."""
    arms = _load()
    if not arms:
        return
    arm = arms.get(point)
    if arm is None:
        return
    with _lock:
        _hits[point] = hit = _hits.get(point, 0) + 1
    action, arg, nth = arm
    if nth and hit != nth:
        return
    telemetry.counter("fleet", "fault_fired", point=point, action=action,
                      hit=hit)
    if action == "delay":
        time.sleep(arg)
    elif action == "error":
        raise InjectedFault(point, hit)
    elif action == "exit":
        telemetry.flush()
        os._exit(int(arg))
    else:  # crash: the honest SIGKILL — no finally blocks, no atexit
        os.kill(os.getpid(), signal.SIGKILL)


def env_spec(*arms: str) -> dict[str, str]:
    """{"EDM_FAULTS": "<joined arms>"} — convenience for spawning one
    armed worker (chaos harness / spawn_worker(env=...))."""
    return {"EDM_FAULTS": ",".join(arms)}
