"""Elastic file-lock lease work queue — the paper's master-worker,
masterless (DESIGN.md SS10).

The paper schedules EDM work units from an MPI master onto 512 workers
(SSIII-C).  Our substrate is better than a master: the TileWriter store
already makes every (row-chunk x col-tile) block idempotent and
resumable, so scheduling reduces to *mutual exclusion with expiry* over
a deterministic unit list that every worker can compute on its own.
This module provides exactly that:

  * :class:`WorkUnit` — a (kind, row0, nrows) row span of one pipeline
    stage ("phase1", "phase2", "assemble", "sig", "finalize").  Unit
    lists derive deterministically from (N, unit_rows), so W workers
    pointed at the same store agree on the queue without any exchange.
  * :class:`LeaseQueue` — claim/renew/steal/done over lease files in a
    shared directory.  A claim is an O_CREAT|O_EXCL lease create (atomic
    on POSIX local *and* network filesystems); a crash leaves the lease
    to EXPIRE (wall-clock TTL), after which any worker may steal it by
    token-stamped atomic replace.  Completion is a separate durable done
    marker, written only after the store commit it certifies.

Safety model: leases make duplicate work *rare*, not impossible (two
stealers can race the replace; the loser's readback detects it, but a
worker may also outlive its own TTL mid-compute).  Correctness never
depends on exclusion: every unit's outputs are bit-identical regardless
of which worker computes them (geometry-independent values, DESIGN.md
SS7/SS9/SS10) and every store write is an atomic replace, so duplicated
units overwrite each other with identical bytes.  The queue is pure
coordination; the store is the ground truth.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time

# The ONE durability primitive (write-temp + fsync + os.replace) is
# owned by the store — queue files and store files share the same
# "SIGKILL can never tear shared state" contract, so they must share
# the same implementation.
from repro.data.store import FATAL_WRITE_ERRNOS, _unique_tmp, atomic_write_text
from repro.runtime import faultpoints, telemetry


def _fatal_oserror(e: BaseException) -> bool:
    """True for environment failures where retrying the unit elsewhere is
    pointless and poisons faster than burning the budget: the shared
    store's disk is full / quota'd / read-only (every worker writes the
    SAME filesystem, so the next attempt fails identically)."""
    while e is not None:
        if isinstance(e, OSError) and e.errno in FATAL_WRITE_ERRNOS:
            return True
        e = e.__cause__ or e.__context__
    return False

_STAGELESS = ("phase1", "assemble", "finalize")  # one unit per run


class UnitFailedError(RuntimeError):
    """A work unit exhausted its bounded retry budget (the unit is
    poisoned: every worker that observes the marker raises too, so the
    fleet drains instead of spinning on TTL steals forever)."""

    def __init__(self, uid: str, attempts: int, error: str):
        super().__init__(
            f"work unit {uid} failed permanently after {attempts} "
            f"attempt(s): {error}"
        )
        self.uid = uid
        self.attempts = attempts
        self.error = error


@dataclasses.dataclass(frozen=True, order=True)
class WorkUnit:
    """One claimable span of pipeline work.

    kind: stage name; "phase2" and "sig" units carry a [row0, row0+nrows)
    row span of the causal map, the singleton kinds ("phase1",
    "assemble", "finalize") span the whole run and exist once.
    """

    kind: str
    row0: int = 0
    nrows: int = 0

    @property
    def uid(self) -> str:
        if self.kind in _STAGELESS:
            return self.kind
        return f"{self.kind}_{self.row0:08d}_{self.nrows:05d}"


def plan_units(kind: str, N: int, unit_rows: int) -> list["WorkUnit"]:
    """Deterministic unit grid for a row-span stage: every worker calls
    this with the same (N, unit_rows) from the fleet spec and gets the
    same queue — no master required."""
    if kind in _STAGELESS:
        return [WorkUnit(kind, 0, N)]
    if unit_rows < 1:
        raise ValueError(f"unit_rows={unit_rows} must be >= 1")
    return [
        WorkUnit(kind, r, min(unit_rows, N - r)) for r in range(0, N, unit_rows)
    ]


class LeaseQueue:
    """File-lock lease queue over a shared directory.

    Per unit uid there are two files: ``<uid>.lease`` (current claim:
    worker, pid, token, t, ttl) and ``<uid>.done`` (durable completion
    marker).  The protocol:

      claim    — O_CREAT|O_EXCL create of the lease.  If it exists and is
                 expired (t + ttl < now), or belongs to THIS worker id (a
                 relaunch after SIGKILL reclaims its own units without
                 waiting out the TTL), steal: atomically replace with a
                 fresh token and read back — owning the readback token is
                 owning the lease.
      renew    — re-stamp t on an owned lease mid-compute (long units).
      mark_done— create the done marker (after the store commit), then
                 drop the lease.
      run_stage— the masterless barrier: loop {claim, compute, done}
                 until every unit of the stage is done, sleeping between
                 polls while other workers hold the remainder.
    """

    def __init__(
        self,
        root: str | pathlib.Path,
        worker: str,
        ttl: float = 600.0,
        poll: float = 0.25,
        fail_limit: int = 3,
    ):
        if ttl <= 0:
            raise ValueError("ttl must be > 0")
        if fail_limit < 1:
            raise ValueError("fail_limit must be >= 1")
        self.dir = pathlib.Path(root)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.worker = worker
        self.ttl = float(ttl)
        self.poll = float(poll)
        self.fail_limit = int(fail_limit)
        self._n = 0  # per-claim token counter
        self._claim_t: dict[str, float] = {}  # uid -> claim time (held span)

    # ------------------------------------------------------------ paths
    def _lease(self, unit: WorkUnit) -> pathlib.Path:
        return self.dir / f"{unit.uid}.lease"

    def _done(self, unit: WorkUnit) -> pathlib.Path:
        return self.dir / f"{unit.uid}.done"

    def _fail(self, unit: WorkUnit) -> pathlib.Path:
        return self.dir / f"{unit.uid}.fail"

    def _poison(self, unit: WorkUnit) -> pathlib.Path:
        return self.dir / f"{unit.uid}.poison"

    def _payload(self) -> dict:
        self._n += 1
        return {
            "worker": self.worker,
            "pid": os.getpid(),
            "token": f"{self.worker}-{os.getpid()}-{self._n}-{os.urandom(4).hex()}",
            "t": time.time(),
            "ttl": self.ttl,
        }

    @staticmethod
    def _read(path: pathlib.Path) -> dict | None:
        try:
            return json.loads(path.read_text())
        except (OSError, ValueError):
            return None  # missing, or torn by a non-atomic foreign writer

    # ----------------------------------------------------------- claims
    def is_done(self, unit: WorkUnit) -> bool:
        return self._done(unit).exists()

    def pending(self, units: list[WorkUnit]) -> list[WorkUnit]:
        return [u for u in units if not self.is_done(u)]

    def try_claim(self, unit: WorkUnit) -> bool:
        """True when this worker now holds the unit's lease."""
        if self.is_done(unit):
            return False
        path = self._lease(unit)
        payload = self._payload()
        # Atomic create-with-content: hard-link a fully-written temp onto
        # the lease name.  O_CREAT|O_EXCL alone is NOT enough — it makes
        # the (empty) file visible before the payload lands, and a
        # concurrent reader would mistake the moment for a torn lease.
        tmp = _unique_tmp(path)
        with open(tmp, "w") as f:
            f.write(json.dumps(payload))
            f.flush()
            os.fsync(f.fileno())
        try:
            os.link(tmp, path)
            # mark_done writes the done marker BEFORE unlinking the lease,
            # so if our link landed on a name a finisher just freed, the
            # marker is already visible — recheck and back off.
            return self._acquired(unit, stolen=False, lease_age=0.0)
        except FileExistsError:
            pass
        finally:
            os.unlink(tmp)
        held = self._read(path)
        now = time.time()
        if held is None:
            # Unreadable: torn by a foreign non-atomic writer, or unlinked
            # between our exists-check and read.  Grace it by file mtime —
            # never steal something that might be mid-protocol and fresh.
            try:
                expired = os.path.getmtime(path) + self.ttl < now
            except OSError:
                expired = True  # vanished: the holder finished or released
            own_ghost = False
        else:
            expired = held.get("t", 0) + held.get("ttl", 0) < now
            # A lease this worker id wrote in a PREVIOUS life (it was
            # killed and relaunched) is immediately reclaimable — the id
            # names the queue slot, and a live worker never claims the
            # same unit twice.
            own_ghost = held.get("worker") == self.worker
        if not (expired or own_ghost):
            return False
        if self.is_done(unit):  # the holder finished while we deliberated
            return False
        lease_age = now - held.get("t", now) if held is not None else self.ttl
        if expired and not own_ghost:
            telemetry.counter(
                unit.kind, "lease_expired", lease_age_s=lease_age,
                uid=unit.uid,
                prev_worker=None if held is None else held.get("worker"),
            )
            # The stolen-from holder can never report its own hold time
            # (it is dead or wedged) — the stealer records the observed
            # terminal hold on its behalf, so hold-time histograms (TTL
            # tuning, straggler attribution; DESIGN.md SS13) see steals
            # too, not just clean completions.
            telemetry.counter(
                unit.kind, "held", lease_age, uid=unit.uid,
                outcome="stolen",
                prev_worker=None if held is None else held.get("worker"),
            )
        # Steal by token-stamped replace; the readback arbitrates racing
        # stealers (at most one sees its own token as the survivor).
        faultpoints.fire("lease_pre_steal")
        atomic_write_text(path, json.dumps(payload))
        back = self._read(path)
        if back is None or back.get("token") != payload["token"]:
            return False
        return self._acquired(unit, stolen=True, lease_age=lease_age)

    def _acquired(self, unit: WorkUnit, stolen: bool,
                  lease_age: float) -> bool:
        """Post-acquisition done recheck: a finisher may have completed
        the unit in the window between our pre-checks and the lease
        landing.  Dropping the just-taken lease keeps done units
        lease-free (claim order: done marker always wins)."""
        if not self.is_done(unit):
            self._claim_t[unit.uid] = time.time()
            telemetry.counter(
                unit.kind, "steal" if stolen else "claim",
                uid=unit.uid, row0=unit.row0, nrows=unit.nrows,
                lease_age_s=lease_age,
            )
            return True
        try:
            self._lease(unit).unlink()
        except OSError:
            pass
        return False

    def claim_next(self, units: list[WorkUnit]) -> WorkUnit | None:
        for u in units:
            if self.try_claim(u):
                return u
        return None

    def renew(self, unit: WorkUnit) -> bool:
        """Re-stamp an owned lease's clock; False if no longer the owner
        (the unit was stolen after this worker outlived its TTL — finish
        anyway: duplicate completion is safe, see module docstring)."""
        held = self._read(self._lease(unit))
        if held is None or held.get("worker") != self.worker:
            return False
        held["t"] = time.time()
        atomic_write_text(self._lease(unit), json.dumps(held))
        return True

    def release(self, unit: WorkUnit) -> None:
        """Give a claimed-but-uncomputed unit back (graceful shutdown)."""
        held = self._read(self._lease(unit))
        if held is not None and held.get("worker") == self.worker:
            if unit.uid in self._claim_t:
                telemetry.counter(
                    unit.kind, "held",
                    time.time() - self._claim_t.pop(unit.uid),
                    uid=unit.uid, outcome="release",
                )
            try:
                self._lease(unit).unlink()
            except OSError:
                pass

    def mark_done(self, unit: WorkUnit) -> None:
        """Durable completion marker.  Call ONLY after the store writes
        the unit certifies are committed (the marker is what lets other
        workers skip the unit forever).

        Telemetry ORDER matters here: the done + held records are
        emitted and FLUSHED before the marker lands, so a durable done
        marker always implies its writer's records for the unit are
        durable too — the loss-window bound (a SIGKILL between flush and
        marker merely recomputes the unit, and duplicate done records
        are deduped at trace time)."""
        held_s = time.time() - self._claim_t.pop(unit.uid, time.time())
        telemetry.counter(
            unit.kind, "done", uid=unit.uid, row0=unit.row0,
            nrows=unit.nrows, held_s=held_s,
        )
        telemetry.counter(unit.kind, "held", held_s, uid=unit.uid,
                          outcome="done")
        telemetry.flush()  # unit boundary: make the unit's tail durable
        faultpoints.fire("done_pre_mark")
        atomic_write_text(
            self._done(unit),
            json.dumps({"worker": self.worker, "t": time.time()}),
            fault="done",
        )
        try:
            self._lease(unit).unlink()
        except OSError:
            pass

    # ---------------------------------------------------- bounded retries
    def record_failure(self, unit: WorkUnit, error: str,
                       fatal: bool = False) -> int:
        """Durably count one failed compute attempt of ``unit``; returns
        the total attempt count.  At ``fail_limit`` attempts the unit is
        POISONED (a durable ``.poison`` marker): every worker's
        run_stage raises :class:`UnitFailedError` on observing it, so a
        unit that crashes every claimer drains the fleet with a clear
        verdict instead of cycling through TTL steals forever.

        ``fatal=True`` (non-retryable environment failure, e.g. the
        shared store's disk is full — see :func:`_fatal_oserror`) poisons
        immediately: the error is one every retry would repeat.

        The count is a read-modify-write over an atomic file: racing
        workers may undercount one attempt, which only ever grants a
        poison unit one extra try — the bound stays bounded.
        """
        have = self._read(self._fail(unit)) or {"attempts": 0, "errors": []}
        attempts = int(have.get("attempts", 0)) + 1
        errors = (list(have.get("errors", [])) + [
            {"worker": self.worker, "t": time.time(), "error": error[:500]}
        ])[-self.fail_limit:]
        atomic_write_text(
            self._fail(unit),
            json.dumps({"attempts": attempts, "errors": errors}),
        )
        telemetry.counter(
            unit.kind, "unit_failed", uid=unit.uid, attempts=attempts,
            error=error[:200], fatal=fatal,
        )
        if fatal or attempts >= self.fail_limit:
            atomic_write_text(
                self._poison(unit),
                json.dumps({"uid": unit.uid, "attempts": attempts,
                            "worker": self.worker, "error": error[:500],
                            "fatal": fatal}),
            )
            telemetry.counter(unit.kind, "unit_poisoned", uid=unit.uid,
                              attempts=attempts, fatal=fatal)
        self.release(unit)
        telemetry.flush()  # unit boundary (failure): bound the loss window
        return attempts

    def poisoned(self, units: list[WorkUnit]) -> dict | None:
        """The first poison marker among ``units`` (or None)."""
        for u in units:
            p = self._read(self._poison(u))
            if p is not None:
                return {"uid": u.uid, **p}
        return None

    # ---------------------------------------------------------- barrier
    def run_stage(
        self,
        units: list[WorkUnit],
        compute,
        already_done=None,
        timeout: float | None = None,
    ) -> int:
        """Masterless stage barrier: claim and compute units until EVERY
        unit is done (by this worker or any other), then return how many
        this worker computed.

        already_done(unit) -> bool lets the caller skip units whose
        output is durable in the store from a prior run (elastic resume:
        queue markers and store coverage may disagree after a crash —
        the store wins).  While other workers hold the remaining units
        this worker sleeps ``poll`` between scans; a holder that dies
        mid-unit surfaces back as claimable once its lease expires, so
        the barrier cannot deadlock on a crash.  ``timeout`` (seconds)
        bounds the total wait and raises TimeoutError — a fleet-wide
        wedge is a bug, not a state to park in forever.

        A compute(unit) exception is a FAILED ATTEMPT, not instant
        death: it is durably counted (:meth:`record_failure`), the lease
        released, and the unit retried — by this worker or any other —
        up to ``fail_limit`` total attempts across the fleet, after
        which the unit is poisoned and every worker's barrier raises
        :class:`UnitFailedError` (bounded retries; the driver surfaces
        the failing unit id and exits nonzero).
        """
        t0 = time.monotonic()
        computed = 0
        if already_done is not None:
            for u in units:
                if not self.is_done(u) and already_done(u):
                    self.mark_done(u)
        while True:
            poison = self.poisoned(units)
            if poison is not None:
                raise UnitFailedError(
                    poison["uid"], int(poison.get("attempts", self.fail_limit)),
                    str(poison.get("error", "unknown")),
                )
            unit = self.claim_next(units)
            if unit is not None:
                try:
                    faultpoints.fire("unit_pre_compute")
                    compute(unit)
                    # The window the done-marker ordering protects: store
                    # bytes durable, completion not yet certified.
                    faultpoints.fire("unit_post_compute")
                except (KeyboardInterrupt, SystemExit):
                    self.release(unit)
                    raise
                except Exception as e:  # noqa: BLE001 - counted + rethrown at limit
                    fatal = _fatal_oserror(e)
                    attempts = self.record_failure(unit, repr(e), fatal=fatal)
                    if fatal or attempts >= self.fail_limit:
                        raise UnitFailedError(unit.uid, attempts,
                                              repr(e)) from e
                    continue
                self.mark_done(unit)
                computed += 1
                continue
            if not self.pending(units):
                return computed
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(
                    f"stage {units[0].kind}: {len(self.pending(units))} "
                    f"unit(s) still pending after {timeout:.0f}s"
                )
            time.sleep(self.poll)
