"""Structured runtime telemetry — the fleet's observability spine (DESIGN.md SS11).

The paper reached 101,729 neurons in 199 s only after profiling-driven
tuning of per-node work shapes (SSIV-B); our fleet has three geometry
knobs (chunk rows, target_tile, knn_tile_c) whose values are invisible
at runtime.  This module records WHERE the wall time goes, as structured
records every layer can emit without knowing who is listening:

  * :func:`span` — a timed context manager (``dur_s`` stamped on exit);
  * :func:`counter` — a point event with a value (claims, steals, bytes,
    cache entries, calibration results).

Records flow to pluggable SINKS (the ``HomebrewNLP-Jax`` wandblog idiom:
one emit call, N backends):

  * :class:`JsonlSink` — one JSON record per line under the run store
    (``<out>/telemetry/<worker>.jsonl``); the fleet default.  Crash-safe
    by the same temp+fsync+rename discipline as the store manifests: the
    file on disk is ALWAYS a complete, parseable JSONL — a SIGKILL
    mid-flush leaves the previous generation, never a torn line.
  * :class:`MemorySink` — in-process record list for tests.
  * :class:`StdoutSink` — one line per record for CI logs.

Telemetry is byte-invisible to outputs: nothing here touches compute,
and every sink writes only under ``telemetry/`` (never inside an
artifact dir), so W=1 == W=4 byte-identity holds with sinks enabled.
When no sink is configured, :func:`emit` is a cheap no-op — hot paths
may call it unconditionally.

Record schema (version 1; :func:`validate` is the shared checker used
by tests and ``edm_fleet status``):

  v        int     schema version (== 1)
  kind     str     "span" | "counter"
  stage    str     pipeline stage ("phase1", "phase2", "assemble",
                   "sig", "finalize") or runtime layer ("queue",
                   "store", "stream", "engine", "fleet")
  name     str     record name within the stage (e.g. "chunk",
                   "claim", "write_tile", "knn_tile")
  t        float   epoch seconds at emit (span: at exit)
  mono     float   CLOCK_MONOTONIC seconds at emit — the skew/NTP-step
                   immune sibling of ``t`` that runtime/trace.py aligns
                   cross-worker timelines on (extra field; schema-v1
                   validators ignore it)
  dur_s    float   span wall time (spans only)
  value    float   counter value (counters only)
  worker   str     emitting identity (worker id or "main")
  pid      int     emitting process
  seq      int     per-process monotonic sequence number
  attrs    dict    free-form JSON-safe details (row0, bytes, lease age…)

Loss window: the JSONL sink batches ``flush_every`` records per atomic
rewrite, so a SIGKILL can lose at most the records since the last
flush.  The queue flushes at every UNIT boundary (done/failure — see
runtime/workqueue.py) and the fleet at every STAGE boundary, bounding
the loss to the current unit's in-progress tail; an exit hook
(:mod:`atexit`, registered at configure time) flushes on every
non-SIGKILL death so only a hard kill can lose even that.
"""
from __future__ import annotations

import atexit
import contextlib
import json
import os
import pathlib
import sys
import threading
import time
from typing import Any, Callable, Iterable, Iterator

#: pipeline stages every full run walks (the "five stages" of the fleet);
#: validate() additionally accepts the runtime layers below.
PIPELINE_STAGES = ("phase1", "phase2", "assemble", "sig", "finalize")
RUNTIME_STAGES = ("queue", "store", "stream", "engine", "fleet")
SCHEMA_VERSION = 1

_lock = threading.Lock()
_sinks: list["Sink"] = []
_worker = "main"
_seq = 0
_atexit_registered = False


# ------------------------------------------------------------------- sinks
class Sink:
    """Sink protocol: ``write(record)`` per record, ``flush`` to make
    buffered records durable, ``close`` once at shutdown.  Subclasses
    need not be thread-safe — the module lock serializes calls."""

    def write(self, rec: dict) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()


class MemorySink(Sink):
    """In-memory record list (tests)."""

    def __init__(self):
        self.records: list[dict] = []

    def write(self, rec: dict) -> None:
        self.records.append(rec)


class StdoutSink(Sink):
    """One ``telemetry,<stage>,<name>,...`` line per record — greppable
    CI-log form, same field order as the JSONL schema."""

    def __init__(self, file=None):
        self._file = file

    def write(self, rec: dict) -> None:
        f = self._file or sys.stdout
        head = rec["dur_s"] if rec["kind"] == "span" else rec["value"]
        print(
            f"telemetry,{rec['stage']},{rec['name']},{head:.6f},"
            f"{json.dumps(rec.get('attrs') or {}, sort_keys=True)}",
            file=f, flush=True,
        )


class JsonlSink(Sink):
    """Crash-safe JSONL file sink.

    Records accumulate in memory and every flush atomically REWRITES the
    whole file (write-temp + fsync + os.replace — the store-manifest
    durability primitive, imported from data/store so there is one
    implementation).  A reader therefore always sees a complete JSONL
    generation, never a torn tail; a relaunched worker with the same
    sink path re-loads the previous generation so its records survive
    the rewrite.  Record volume is O(chunks + units + tiles) per run —
    small enough that the rewrite stays off any hot path (and flushes
    are batched every ``flush_every`` records regardless).
    """

    def __init__(self, path: str | pathlib.Path, flush_every: int = 32):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.flush_every = max(1, int(flush_every))
        self._records: list[dict] = list(read_jsonl(self.path))
        self._unflushed = 0

    def write(self, rec: dict) -> None:
        self._records.append(rec)
        self._unflushed += 1
        if self._unflushed >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        if self._unflushed == 0:
            return
        from repro.data.store import atomic_write_text  # lazy: no cycle

        atomic_write_text(
            self.path,
            "".join(json.dumps(r) + "\n" for r in self._records),
        )
        self._unflushed = 0


def read_jsonl(path: str | pathlib.Path) -> list[dict]:
    """Read a telemetry JSONL, tolerating a missing file and (for
    foreign, non-atomic writers) a torn trailing line."""
    p = pathlib.Path(path)
    if not p.exists():
        return []
    out: list[dict] = []
    for line in p.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except ValueError:
            continue  # torn tail of a non-atomic writer
    return out


# ------------------------------------------------------------ configuration
def configure(*sinks: Sink, worker: str | None = None) -> None:
    """Install the process's sink list (replacing any previous ones) and
    optionally its emitting identity.  ``configure()`` with no sinks
    disables telemetry."""
    global _sinks, _atexit_registered
    with _lock:
        for s in _sinks:
            try:
                s.close()
            except OSError:
                pass
        _sinks = list(sinks)
        if worker is not None:
            set_identity(worker)
        if _sinks and not _atexit_registered:
            # Last-chance flush on any non-SIGKILL exit (normal return,
            # sys.exit, unhandled exception): the batched JSONL tail is
            # lost only to a hard kill, and even that loss is bounded by
            # the unit-boundary flushes (see module docstring).
            atexit.register(flush)
            _atexit_registered = True


def configure_from_env(
    default_path: str | pathlib.Path | None = None,
    worker: str | None = None,
) -> None:
    """Honor ``EDM_TELEMETRY``: ``off`` (no sinks), ``stdout``,
    ``jsonl:<path>``, or unset — in which case ``default_path`` (when
    given) enables the JSONL sink there, the fleet/driver default."""
    spec = os.environ.get("EDM_TELEMETRY", "")
    if spec == "off":
        configure(worker=worker)
    elif spec == "stdout":
        configure(StdoutSink(), worker=worker)
    elif spec.startswith("jsonl:"):
        configure(JsonlSink(spec[len("jsonl:"):]), worker=worker)
    elif default_path is not None:
        configure(JsonlSink(default_path), worker=worker)
    else:
        configure(worker=worker)


def set_identity(worker: str) -> None:
    global _worker
    _worker = worker


def enabled() -> bool:
    return bool(_sinks)


def flush() -> None:
    with _lock:
        for s in _sinks:
            s.flush()


def shutdown() -> None:
    configure()


# ------------------------------------------------------------------- emit
def _emit(kind: str, stage: str, name: str, *, dur_s=None, value=None,
          attrs=None) -> None:
    global _seq
    if not _sinks:
        return
    with _lock:
        _seq += 1
        rec = {
            "v": SCHEMA_VERSION,
            "kind": kind,
            "stage": stage,
            "name": name,
            "t": time.time(),
            "mono": time.monotonic(),
            "worker": _worker,
            "pid": os.getpid(),
            "seq": _seq,
            "attrs": dict(attrs or {}),
        }
        if kind == "span":
            rec["dur_s"] = float(dur_s)
        else:
            rec["value"] = float(value)
        for s in _sinks:
            s.write(rec)


def counter(stage: str, name: str, value: float = 1.0, **attrs) -> None:
    """Point event: queue claims/steals/dones, bytes written, cache
    entries, calibration results…"""
    _emit("counter", stage, name, value=value, attrs=attrs)


def emit_clock_anchor(**attrs) -> None:
    """One explicit (epoch, monotonic) clock sample at run/worker start.

    Every record already carries both clocks (``t`` + ``mono``); the
    anchor marks the RUN START on both scales so runtime/trace.py can
    align workers on their monotonic clocks (immune to NTP steps
    mid-run) and detect cross-host epoch skew against the queue's
    causal order.  Emitted by the fleet worker and the edm_run driver,
    never implicitly by :func:`configure` (tests install sinks freely
    and count records)."""
    counter("fleet", "clock_anchor",
            epoch=time.time(), mono=time.monotonic(), **attrs)


@contextlib.contextmanager
def span(stage: str, name: str, **attrs):
    """Timed region; ``dur_s`` is wall time between enter and exit.  The
    yielded dict lets the body add attrs discovered mid-span (e.g. fsync
    time, tile count).  Emits nothing when no sink is configured."""
    if not _sinks:
        yield {}
        return
    extra: dict = {}
    t0 = time.perf_counter()
    try:
        yield extra
    finally:
        _emit("span", stage, name, dur_s=time.perf_counter() - t0,
              attrs={**attrs, **extra})


def timed(stage: str, name: str, fn: Callable, *args, **attrs):
    """Run ``fn(*args)`` under a span; returns fn's result."""
    with span(stage, name, **attrs):
        return fn(*args)


# ------------------------------------------------------------- validation
_REQUIRED = {"v": int, "kind": str, "stage": str, "name": str, "t": float,
             "worker": str, "pid": int, "seq": int, "attrs": dict}


def validate(rec: dict) -> list[str]:
    """Schema check; returns a list of violations (empty == valid)."""
    errs: list[str] = []
    for field, typ in _REQUIRED.items():
        if field not in rec:
            errs.append(f"missing field {field!r}")
        elif typ is float:
            if not isinstance(rec[field], (int, float)):
                errs.append(f"{field}={rec[field]!r} not a number")
        elif not isinstance(rec[field], typ):
            errs.append(f"{field}={rec[field]!r} not {typ.__name__}")
    if errs:
        return errs
    if rec["v"] != SCHEMA_VERSION:
        errs.append(f"schema version {rec['v']} != {SCHEMA_VERSION}")
    if rec["kind"] == "span":
        if not isinstance(rec.get("dur_s"), (int, float)) or rec["dur_s"] < 0:
            errs.append(f"span dur_s={rec.get('dur_s')!r} invalid")
    elif rec["kind"] == "counter":
        if not isinstance(rec.get("value"), (int, float)):
            errs.append(f"counter value={rec.get('value')!r} invalid")
    else:
        errs.append(f"kind={rec['kind']!r} not span|counter")
    if rec["stage"] not in PIPELINE_STAGES + RUNTIME_STAGES:
        errs.append(f"stage={rec['stage']!r} unknown")
    try:
        json.dumps(rec["attrs"])
    except (TypeError, ValueError):
        errs.append("attrs not JSON-serializable")
    return errs


# -------------------------------------------------------------- store I/O
def store_telemetry_dir(out_dir: str | pathlib.Path) -> pathlib.Path:
    return pathlib.Path(out_dir) / "telemetry"


def worker_jsonl(out_dir: str | pathlib.Path, worker: str) -> pathlib.Path:
    return store_telemetry_dir(out_dir) / f"{worker}.jsonl"


def iter_store_records(
    out_dir: str | pathlib.Path,
) -> Iterator[tuple[str, dict]]:
    """Yield (worker_file_stem, record) over every per-worker JSONL a
    run store holds — the replay input of ``runtime/autotune`` and the
    summary input of ``edm_fleet status``."""
    d = store_telemetry_dir(out_dir)
    if not d.exists():
        return
    for p in sorted(d.glob("*.jsonl")):
        for rec in read_jsonl(p):
            yield p.stem, rec


# ---------------------------------------------------- compile-cache probe
def compile_cache_entries() -> int | None:
    """Entry count of the JAX persistent compilation cache directory, or
    None when no cache is configured.  Pipelines snapshot this at stage
    boundaries: the DELTA is the number of fresh compilations the stage
    paid (everything else was a cache hit — the fleet's straggler
    metric, DESIGN.md SS10)."""
    d = os.environ.get("JAX_COMPILATION_CACHE_DIR")
    if not d:
        return None
    try:
        return sum(1 for _ in pathlib.Path(d).iterdir())
    except OSError:
        return None


def emit_compile_cache(stage: str, before: int | None) -> int | None:
    """Counter of new persistent-cache entries since ``before``; returns
    the new snapshot (chainable across stages)."""
    now = compile_cache_entries()
    if now is not None and before is not None:
        counter(stage, "compile_cache", float(now - before),
                entries=now, new=now - before)
    return now
