"""Shared oracle-checking harness: every engine op vs the reference
engine (the engine-layer contract of DESIGN.md SS5).

Used by tests and by ``python -m repro.engine.check`` as a smoke check on
new backends: random EDM-shaped inputs, max-abs deviation per op, hard
assert against per-op tolerances (indices must match exactly; distances
and forecasts to float32 round-off).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.types import EDMConfig
from repro.engine import get_engine

# op name -> (atol on values); kNN indices are compared exactly.
TOLERANCES = {
    "knn_tables": 1e-5,
    "knn_tables_bucketed": 1e-5,
    "knn_tables_prefix": 0.0,  # one-sweep vs rebuild is a BIT-identity claim
    "ccm_lookup": 1e-5,
}


def check_engine(
    name: str,
    E_max: int = 6,
    Lq: int = 120,
    Lc: int = 120,
    n_targets: int = 7,
    seed: int = 0,
    cfg: EDMConfig | None = None,
) -> dict[str, float]:
    """Run every op of engine ``name`` against the reference engine.

    Returns {op: max_abs_err} on success; raises AssertionError on any
    index mismatch or tolerance violation.
    """
    cfg = cfg or EDMConfig(E_max=E_max)
    ref = get_engine("reference")
    eng = get_engine(name)
    rng = np.random.default_rng(seed)
    Vq = jnp.asarray(rng.standard_normal((E_max, Lq)), jnp.float32)
    Vc = Vq if Lq == Lc else jnp.asarray(
        rng.standard_normal((E_max, Lc)), jnp.float32
    )
    k = E_max + 1
    errs: dict[str, float] = {}

    def _cmp(op, got, want):
        gi, gd = got
        wi, wd = want
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi), err_msg=op)
        err = float(np.max(np.abs(np.asarray(gd) - np.asarray(wd))))
        assert err <= TOLERANCES[op], f"{name}.{op}: max err {err}"
        errs[op] = err
        return gi, gd

    exclude = Lq == Lc
    idx, sqd = _cmp(
        "knn_tables",
        eng.knn_tables(Vq, Vc, k, exclude_self=exclude, cfg=cfg),
        ref.knn_tables(Vq, Vc, k, exclude_self=exclude, cfg=cfg),
    )

    buckets = tuple(sorted({1, max(1, E_max // 2), E_max}))
    _cmp(
        "knn_tables_bucketed",
        eng.knn_tables_bucketed(
            Vq, Vc, k, buckets=buckets, exclude_self=exclude, cfg=cfg
        ),
        ref.knn_tables_bucketed(
            Vq, Vc, k, buckets=buckets, exclude_self=exclude, cfg=cfg
        ),
    )

    lib_sizes = tuple(
        sorted({max(k + 2, Lc // 4), max(k + 3, Lc // 2), Lc})
    )
    _cmp(
        "knn_tables_prefix",
        eng.knn_tables_prefix(
            Vq, Vc, k, buckets=buckets, lib_sizes=lib_sizes,
            exclude_self=exclude, cfg=cfg,
        ),
        ref.knn_tables_prefix(
            Vq, Vc, k, buckets=buckets, lib_sizes=lib_sizes,
            exclude_self=exclude, cfg=cfg,
        ),
    )

    from repro.core import knn

    _, w = knn.tables_with_weights(idx, sqd)
    Y = jnp.asarray(rng.standard_normal((n_targets, Lc)), jnp.float32)
    got = np.asarray(eng.ccm_lookup(idx[-1], w[-1], Y))
    want = np.asarray(ref.ccm_lookup(idx[-1], w[-1], Y))
    err = float(np.max(np.abs(got - want)))
    assert err <= TOLERANCES["ccm_lookup"], f"{name}.ccm_lookup: max err {err}"
    errs["ccm_lookup"] = err
    return errs


def main() -> None:  # pragma: no cover - CLI smoke entry
    from repro.engine import available_engines

    for name in available_engines():
        errs = check_engine(name)
        print(name, {k: f"{v:.2e}" for k, v in errs.items()})


if __name__ == "__main__":  # pragma: no cover
    main()
