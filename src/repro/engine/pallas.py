"""Pallas-kernel engines (DESIGN.md SS5).

``pallas-compiled`` compiles the kernels natively on TPU and transparently
falls back to interpret mode elsewhere (exactly the old
``EDMConfig.use_kernels=True`` routing); ``pallas-interpret`` pins
interpret mode everywhere so the kernel numerics can be validated on any
backend, including TPU hosts.

Both route kNN-table construction through the streaming kernels in
kernels/knn_topk — including the in-kernel prefix-snapshot kernel for
``knn_tables_prefix`` (DESIGN.md SS9), so the CCM convergence diagnostic
no longer rebuilds per library size — and the batched CCM lookup through
kernels/ccm_lookup.
"""
from __future__ import annotations

from repro.engine.base import Engine, default_interpret

# knn_tables is entered at jit-trace time, so each distinct kernel shape
# emits its VMEM working set exactly once per compile — dedupe beyond
# that keeps recompiles (cache misses) visible.
_vmem_seen: set = set()


def _emit_vmem(E_max: int, k: int, tile: int, cfg) -> None:
    from repro.runtime import telemetry

    if not telemetry.enabled():
        return
    key = (E_max, k, tile, cfg.dist_dtype)
    if key in _vmem_seen:
        return
    _vmem_seen.add(key)
    from repro.kernels.knn_topk.knn_topk import stream_vmem_bytes

    telemetry.counter(
        "engine", "vmem_working_set",
        float(stream_vmem_bytes(E_max, k, 128, tile, cfg.dist_dtype)),
        E_max=E_max, k=k, tile_c=tile, dist_dtype=str(cfg.dist_dtype),
    )


class PallasEngine(Engine):
    """interpret=None -> native on TPU, interpret elsewhere."""

    name = "pallas-compiled"
    interpret: bool | None = None

    def _interpret(self) -> bool:
        return default_interpret() if self.interpret is None else self.interpret

    def knn_tables(self, Vq, Vc, k, *, exclude_self, cfg):
        from repro.kernels.knn_topk.ops import knn_topk_streaming

        # Streaming kernel (DESIGN.md SS8): per-program VMEM is flat in
        # Lc, so library length is HBM-bound, not VMEM-bound.
        tile = self.knn_selection_tile(Vc.shape[1], cfg)
        _emit_vmem(Vq.shape[0], k, tile, cfg)
        return knn_topk_streaming(
            Vq, Vc, k, exclude_self=exclude_self, tile_c=tile,
            dist_dtype=cfg.dist_dtype, interpret=self._interpret(),
        )

    # knn_tables_bucketed: the base truncate-to-max(buckets) + gather
    # (routed through knn_tables above, so it inherits the resolved tile
    # width) is the whole saving available without a bucket-aware kernel
    # (in-kernel bucket masking: DESIGN.md SS3, future work).

    def knn_tables_prefix(
        self, Vq, Vc, k, *, buckets, lib_sizes, exclude_self, cfg,
        col_ids=None,
    ):
        from repro.kernels.knn_topk.ops import knn_topk_prefix

        tile = self.knn_selection_tile(Vc.shape[1], cfg)
        return knn_topk_prefix(
            Vq, Vc, k, exclude_self, tuple(buckets), tuple(lib_sizes),
            tile_c=tile, dist_dtype=cfg.dist_dtype,
            interpret=self._interpret(), col_ids=col_ids,
        )

    def ccm_lookup(self, idx, w, Y_fut):
        from repro.kernels.ccm_lookup.ops import ccm_lookup

        return ccm_lookup(idx, w, Y_fut, interpret=self._interpret())


class PallasInterpretEngine(PallasEngine):
    name = "pallas-interpret"
    interpret: bool | None = True
