"""Execution-engine abstraction for the EDM hot path (DESIGN.md SS5).

An :class:`Engine` owns the three named ops that dominate EDM runtime —
kNN-table construction, simplex forecast, and the batched CCM lookup —
behind one interface so the pipeline, phase-1 simplex sweep, and the
benchmarks are backend-agnostic (the kEDM "performance portability"
design point).  Concrete engines:

  * ``reference``        — pure jnp (core/knn.py); the oracle everything
                           else is checked against.
  * ``pallas-interpret`` — Pallas kernels forced into interpret mode;
                           numerics of the TPU kernels, runs anywhere.
  * ``pallas-compiled``  — Pallas kernels compiled natively on TPU and
                           auto-falling back to interpret mode elsewhere
                           (the old ``use_kernels=True`` behaviour).

Engines are *stateless*; ops may be called inside jit/shard_map traces
(engine resolution happens at trace time because ``EDMConfig`` is a
static argument).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret


class Engine:
    """Base engine: named EDM ops with reference fallbacks.

    Subclasses override the ops they accelerate; anything not overridden
    falls back to a correct (if slower) composition of the others.
    """

    #: registry key; subclasses must set this.
    name: str = "base"

    # -------------------------------------------------------------- ops
    @staticmethod
    def knn_selection_tile(Lc: int, cfg) -> int:
        """Candidate-tile width for the (always streaming) kNN-table
        construction (DESIGN.md SS8): cfg.knn_tile_c > 0 forces that
        width, 0 takes the one-shot VMEM-budget calibration
        (knn.calibrate_knn_tile).  One resolver for every backend so
        cfg.knn_tile_c means the same thing under all engines.  Always
        returns a positive width — a tile covering the whole library
        degenerates to one direct selection, so small libraries pay
        nothing for the tiling."""
        from repro.core import knn

        return knn.resolve_stream_tile(Lc, cfg)

    def knn_tables(self, Vq, Vc, k, *, exclude_self, cfg):
        """kNN tables for every embedding dimension 1..E_max.

        Vq: (E_max, Lq) query lag matrix, Vc: (E_max, Lc) candidates.
        Returns (idx, sq_dists), each (E_max, Lq, k).  Implementations
        stream candidate tiles of width :meth:`knn_selection_tile`
        through the running sorted-merge; the tiling is invisible to
        callers (any tile width is bit-identical to the dense oracle).
        """
        raise NotImplementedError

    def knn_tables_bucketed(self, Vq, Vc, k, *, buckets, exclude_self, cfg):
        """kNN tables only for the embedding dimensions in ``buckets``.

        buckets: static ascending tuple of distinct E values (DESIGN.md
        SS3).  Returns (idx, sq_dists), each (len(buckets), Lq, k).

        Default: build tables up to max(buckets) and gather the bucket
        rows — already a ``max(buckets)/E_max`` truncation win (for the
        Pallas kernels it is the whole saving available without a
        bucket-aware kernel); the reference engine overrides this to also
        skip the top-k at non-bucket E.
        """
        E_hi = buckets[-1]
        idx, sqd = self.knn_tables(
            Vq[:E_hi], Vc[:E_hi], k, exclude_self=exclude_self, cfg=cfg
        )
        rows = jnp.asarray([e - 1 for e in buckets], jnp.int32)
        return idx[rows], sqd[rows]

    def knn_tables_prefix(
        self, Vq, Vc, k, *, buckets, lib_sizes, exclude_self, cfg,
        col_ids=None,
    ):
        """Per-library-size kNN tables for the CCM convergence diagnostic.

        lib_sizes: static ascending tuple of nested library prefix sizes
        (candidate COLUMNS [0, Ls)); col_ids: optional (Lc,) permutation
        making the prefixes seeded random subsamples (DESIGN.md SS9).
        Returns (idx, sq_dists), each (len(lib_sizes), len(buckets), Lq, k).

        Default: the per-size rebuild oracle — one independent streaming
        sweep per library size.  Correct on every backend, but every
        concrete engine overrides it with a ONE-sweep prefix-snapshot
        path (bit-identical output, ~S x less candidate traffic): the
        reference engine with the jnp one-sweep builder, the Pallas
        engines with the in-kernel snapshot kernel (running VMEM top-k
        emitted at library-size boundary tiles).
        """
        from repro.core import knn

        tile = self.knn_selection_tile(Vc.shape[1], cfg)
        return knn.knn_tables_prefix_rebuild(
            Vq, Vc, k, exclude_self, buckets, lib_sizes, tile,
            dist_dtype=jnp.dtype(cfg.dist_dtype), col_ids=col_ids,
        )

    def simplex_forecast(self, idx, w, fut_c):
        """Weighted neighbour-future average (paper Alg. 5).

        idx, w: (..., Lq, k); fut_c: (Lc,).  Returns (..., Lq).
        """
        return jnp.sum(w * fut_c[idx], axis=-1)

    def ccm_lookup(self, idx, w, Y_fut):
        """Batched simplex lookup: many targets sharing ONE library table.

        idx, w: (Lq, k); Y_fut: (B, Lp).  Returns preds (B, Lq).

        The batch axis is the unit of phase-2 column tiling (DESIGN.md
        SS7): a target tile's bucket segments map directly onto this op
        with the SAME table — per-target results are independent, so any
        tile/segment partition of the batch yields bit-identical rho.
        """
        return jax.vmap(lambda y: self.simplex_forecast(idx, w, y))(Y_fut)

    # ------------------------------------------------------------ misc
    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Engine {self.name}>"
