"""Pluggable EDM execution engines (DESIGN.md SS5).

Usage::

    from repro import engine
    eng = engine.get_engine(cfg.engine)      # cfg.engine is a str key
    idx, sqd = eng.knn_tables(Vq, Vc, k, exclude_self=True, cfg=cfg)

Registering a new backend is one call::

    engine.register(MyEngine())

and every consumer (phase-1 simplex, phase-2 CCM, benchmarks) picks it up
through ``EDMConfig(engine="my-engine")``.
"""
from __future__ import annotations

from repro.engine.base import Engine, default_interpret
from repro.engine.pallas import PallasEngine, PallasInterpretEngine
from repro.engine.reference import ReferenceEngine

_REGISTRY: dict[str, Engine] = {}


def register(eng: Engine) -> Engine:
    """Register an engine instance under its ``name`` (last one wins)."""
    if not eng.name or eng.name == "base":
        raise ValueError("engine must define a unique non-default .name")
    _REGISTRY[eng.name] = eng
    return eng


def get_engine(name: str) -> Engine:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown engine {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def available_engines() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register(ReferenceEngine())
register(PallasEngine())
register(PallasInterpretEngine())

__all__ = [
    "Engine",
    "PallasEngine",
    "PallasInterpretEngine",
    "ReferenceEngine",
    "available_engines",
    "default_interpret",
    "get_engine",
    "register",
]
