"""Pure-jnp reference engine — the oracle path (DESIGN.md SS5).

Delegates to core/knn.py: cumulative-E recurrence + lax.top_k, honouring
the ``knn_impl`` / ``dist_dtype`` hillclimb knobs on EDMConfig.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.base import Engine


class ReferenceEngine(Engine):
    name = "reference"

    def knn_tables(self, Vq, Vc, k, *, exclude_self, cfg):
        from repro.core import knn

        return knn.knn_tables_all_E(
            Vq, Vc, k, exclude_self=exclude_self,
            impl=cfg.knn_impl, dist_dtype=jnp.dtype(cfg.dist_dtype),
        )

    def knn_tables_bucketed(self, Vq, Vc, k, *, buckets, exclude_self, cfg):
        from repro.core import knn

        return knn.knn_tables_bucketed(
            Vq, Vc, k, exclude_self=exclude_self, buckets=buckets,
            impl=cfg.knn_impl, dist_dtype=jnp.dtype(cfg.dist_dtype),
        )
