"""Pure-jnp reference engine — the oracle path (DESIGN.md SS5).

Delegates to core/knn.py, honouring the ``knn_impl`` / ``dist_dtype``
hillclimb knobs on EDMConfig and the slab/streaming selection routing
(``knn_tile_c``, DESIGN.md SS8): small libraries take the slab +
lax.top_k path, large ones the candidate-tiled streaming scan.
Streaming is bit-identical to the CUMULATIVE slab impls
(scan/unroll/blocked); ``knn_impl="rebuild"`` — the paper-faithful
matmul-form A/B shape, whose near-tie ordering already differs from the
cumulative impls — is honoured only while the slab route is active, so
runs that pin it for an A/B should also pin ``knn_tile_c=-1`` to keep
the shape across the auto threshold.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.engine.base import Engine


class ReferenceEngine(Engine):
    name = "reference"

    def knn_tables(self, Vq, Vc, k, *, exclude_self, cfg):
        from repro.core import knn

        tile = self.knn_selection_tile(Vc.shape[1], cfg)
        if tile:
            return knn.knn_tables_all_E_streaming(
                Vq, Vc, k, exclude_self=exclude_self, tile_c=tile,
                dist_dtype=jnp.dtype(cfg.dist_dtype),
            )
        return knn.knn_tables_all_E(
            Vq, Vc, k, exclude_self=exclude_self,
            impl=cfg.knn_impl, dist_dtype=jnp.dtype(cfg.dist_dtype),
        )

    def knn_tables_prefix(
        self, Vq, Vc, k, *, buckets, lib_sizes, exclude_self, cfg,
        col_ids=None,
    ):
        from repro.core import knn

        tile = (
            self.knn_selection_tile(Vc.shape[1], cfg)
            or knn.STREAM_DEFAULT_TILE_C
        )
        return knn.knn_tables_prefix_streaming(
            Vq, Vc, k, exclude_self, buckets, lib_sizes, tile,
            dist_dtype=jnp.dtype(cfg.dist_dtype), col_ids=col_ids,
        )

    def knn_tables_bucketed(self, Vq, Vc, k, *, buckets, exclude_self, cfg):
        from repro.core import knn

        tile = self.knn_selection_tile(Vc.shape[1], cfg)
        if tile:
            return knn.knn_tables_bucketed_streaming(
                Vq, Vc, k, exclude_self=exclude_self, buckets=buckets,
                tile_c=tile, dist_dtype=jnp.dtype(cfg.dist_dtype),
            )
        return knn.knn_tables_bucketed(
            Vq, Vc, k, exclude_self=exclude_self, buckets=buckets,
            impl=cfg.knn_impl, dist_dtype=jnp.dtype(cfg.dist_dtype),
        )
