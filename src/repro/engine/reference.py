"""Pure-jnp reference engine — the oracle path (DESIGN.md SS5).

Delegates to the streaming builders in core/knn.py: candidate tiles of
the resolved width (``knn_tile_c``; 0 = one-shot VMEM-budget
calibration) folded through the running sorted-merge network.  Honours
the ``dist_dtype`` hillclimb knob (bfloat16 accumulate + f32 merge
keys).  Any tile width is bit-identical to the dense lax.top_k oracle
(``knn.knn_tables_dense``), which survives only as the A/B reference for
tests and benchmarks.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.engine.base import Engine


class ReferenceEngine(Engine):
    name = "reference"

    @staticmethod
    def knn_selection_tile(Lc, cfg):
        from repro.core import knn

        # Host profile: XLA:CPU top_k carries a large fixed per-call cost,
        # so the jnp path wants the widest tile the cache budget allows
        # (paper-scale L <= 16384 runs as a single direct-selection tile).
        return knn.resolve_stream_tile(Lc, cfg, profile="host")

    def knn_tables(self, Vq, Vc, k, *, exclude_self, cfg):
        from repro.core import knn

        tile = self.knn_selection_tile(Vc.shape[1], cfg)
        return knn.knn_tables_all_E_streaming(
            Vq, Vc, k, exclude_self=exclude_self, tile_c=tile,
            dist_dtype=jnp.dtype(cfg.dist_dtype),
        )

    def knn_tables_prefix(
        self, Vq, Vc, k, *, buckets, lib_sizes, exclude_self, cfg,
        col_ids=None,
    ):
        from repro.core import knn

        tile = self.knn_selection_tile(Vc.shape[1], cfg)
        return knn.knn_tables_prefix_streaming(
            Vq, Vc, k, exclude_self, buckets, lib_sizes, tile,
            dist_dtype=jnp.dtype(cfg.dist_dtype), col_ids=col_ids,
        )

    def knn_tables_bucketed(self, Vq, Vc, k, *, buckets, exclude_self, cfg):
        from repro.core import knn

        tile = self.knn_selection_tile(Vc.shape[1], cfg)
        return knn.knn_tables_bucketed_streaming(
            Vq, Vc, k, exclude_self=exclude_self, buckets=buckets,
            tile_c=tile, dist_dtype=jnp.dtype(cfg.dist_dtype),
        )
