"""Ambient sharding context: lets layer code place with_sharding_constraint
on internal activations (e.g. sequence-parallel attention) without threading
mesh/policy through every call signature.

Set by the launcher/dry-run around tracing:

    with sharding_ctx(mesh, policy):
        jitted.lower(...)

`constrain(x, *spec_axes)` is a no-op outside the context, so model code
stays runnable on a single device / in tests.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX = contextvars.ContextVar("repro_shard_ctx", default=None)


@contextlib.contextmanager
def sharding_ctx(mesh, policy):
    tok = _CTX.set((mesh, policy))
    try:
        yield
    finally:
        _CTX.reset(tok)


def current_policy():
    ctx = _CTX.get()
    return ctx[1] if ctx else None


def constrain(x: jax.Array, spec: P) -> jax.Array:
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, _ = ctx
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_seq_parallel(x: jax.Array, seq_axis: int) -> jax.Array:
    """Shard dim `seq_axis` on the model axis, batch dim 0 on the dp axes
    (divisibility-checked) — used by sequence-parallel attention."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, policy = ctx
    if policy.dp_only:
        return x  # model axis already consumed by batch parallelism
    spec = [None] * x.ndim
    spec[0] = policy._fit(policy.dp, x.shape[0])
    if x.shape[seq_axis] % policy.axis_size("model") == 0:
        spec[seq_axis] = "model"
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
