"""Divisibility-aware sharding policy: param/batch/cache PartitionSpecs.

Strategy (DESIGN.md SS6):
  * batch dims -> all data-parallel axes ("pod", "data");
  * weights: Megatron column/row tensor-parallel on "model"
    (column: d_ff / head projections; row: their inverses); vocab-parallel
    embedding + LM head (vocab padded to a multiple of 256);
  * FSDP (ZeRO-3): for large models the non-TP weight dim is additionally
    sharded on "data" — XLA all-gathers each layer's weights inside the
    scan-over-layers, which overlaps gather with compute;
  * MoE: expert-parallel on "model" when n_experts divides the axis, else
    tensor-parallel inside each expert;
  * decode caches: KV sharded along the *sequence* dim on "model"
    (flash-decode style — works for every kv-head count, balances memory;
    XLA inserts the small softmax-stat all-reduces), SSM states sharded on
    heads;
  * every rule degrades to replication when a dim is not divisible by the
    axis size (recorded — the roofline table shows the resulting all-gather
    cost, and hillclimbs may revisit).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    mesh: Any
    fsdp: bool = False  # shard weights on "data" too (ZeRO-3)
    seq_shard_cache: bool = True  # decode KV cache sharded along seq
    # dp_only: replicate weights, use the model axis as EXTRA batch
    # parallelism — the right regime for sub-1B archs where TP-16 only
    # replicates attention compute and adds collectives (SSPerf hillclimb).
    dp_only: bool = False

    @property
    def dp(self) -> tuple[str, ...]:
        return tuple(a for a in self.mesh.axis_names if a in ("pod", "data"))

    @property
    def tp(self):
        return None if self.dp_only else "model"

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return self.dp + (("model",) if self.dp_only else ())

    def axis_size(self, name) -> int:
        if isinstance(name, tuple):
            return int(np.prod([self.axis_size(a) for a in name]))
        return self.mesh.shape[name]

    def _fit(self, axis, dim: int):
        """axis if dim divides the axis size, else None (replicate)."""
        if axis is None:
            return None
        return axis if dim % self.axis_size(axis) == 0 else None

    @property
    def fsdp_axis(self) -> Optional[str]:
        return "data" if self.fsdp else None


def auto_policy(cfg: ModelConfig, mesh, n_params: int | None = None) -> ShardingPolicy:
    """FSDP kicks in when replicated-over-data weights would not fit:
    > ~2B params (bf16 params + grads + fp32 moments / 16-way TP)."""
    if n_params is None:
        n_params = estimate_params(cfg)
    return ShardingPolicy(mesh=mesh, fsdp=n_params > 2_000_000_000)


def estimate_params(cfg: ModelConfig) -> int:
    """Abstract param count via eval_shape (no allocation)."""
    from repro.models import transformer

    shapes = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0))
    )
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------
_COL_PARENTS = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj"}  # (din, dout): TP on dout
_ROW_PARENTS = {"wo", "w_down", "out_proj"}  # (din, dout): TP on din
_REPLICATED_LEAVES = {
    "scale", "bias", "A_log", "D", "dt_bias", "norm_scale",
    "gate_attn", "gate_mlp", "router",
}


def _path_names(path) -> list[str]:
    return [p.key for p in path if hasattr(p, "key")]


def param_spec(policy: ShardingPolicy, path, ndim: int, shape) -> P:
    names = _path_names(path)
    leaf = names[-1]
    parent = names[-2] if len(names) > 1 else ""
    fsdp, tp = policy.fsdp_axis, policy.tp
    in_moe = "moe" in names

    def lead(spec_tail: tuple) -> P:
        # leading scan/stack dims (layers, units, per-unit) stay unsharded
        return P(*([None] * (ndim - len(spec_tail)) + list(spec_tail)))

    if leaf in _REPLICATED_LEAVES:
        return P(*([None] * ndim))
    if leaf == "tok":  # (vocab, d): vocab-parallel embedding
        return lead((policy._fit(tp, shape[-2]), policy._fit(fsdp, shape[-1])))
    if leaf == "lm_head":  # (d, vocab)
        return lead((policy._fit(fsdp, shape[-2]), policy._fit(tp, shape[-1])))
    if leaf == "pos" or leaf == "enc_pos":  # (S, d)
        return lead((None, policy._fit(tp, shape[-1])))
    if in_moe and leaf in ("w_up", "w_gate", "w_down"):  # (E, d, f) / (E, f, d)
        E = shape[-3]
        if E % policy.axis_size(tp) == 0:  # expert-parallel
            return lead((tp, policy._fit(fsdp, shape[-2]), None))
        if leaf == "w_down":  # TP inside experts: contract dim f
            return lead((None, policy._fit(tp, shape[-2]), policy._fit(fsdp, shape[-1])))
        return lead((None, policy._fit(fsdp, shape[-2]), policy._fit(tp, shape[-1])))
    if leaf == "conv_w":  # (d_conv, conv_dim)
        return lead((None, policy._fit(tp, shape[-1])))
    if leaf == "conv_b":
        return lead((policy._fit(tp, shape[-1]),))
    if leaf.startswith("a_"):  # lora in: (2d, r)
        return lead((policy._fit(fsdp, shape[-2]), None))
    if leaf.startswith("b_"):  # lora out: (r, dout)
        return lead((None, policy._fit(tp, shape[-1])))
    if leaf == "w" and parent in _COL_PARENTS:
        return lead((policy._fit(fsdp, shape[-2]), policy._fit(tp, shape[-1])))
    if leaf == "w" and parent in _ROW_PARENTS:
        return lead((policy._fit(tp, shape[-2]), policy._fit(fsdp, shape[-1])))
    if leaf == "b" and parent in _COL_PARENTS:
        return lead((policy._fit(tp, shape[-1]),))
    if leaf == "b":
        return lead((None,))
    # default: replicate (and make it visible in reviews)
    return P(*([None] * ndim))


def param_specs(policy: ShardingPolicy, params_tree) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, x: param_spec(policy, path, x.ndim, x.shape), params_tree
    )


def param_shardings(policy: ShardingPolicy, params_tree) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(policy.mesh, s), param_specs(policy, params_tree)
    )


# --------------------------------------------------------------------------
# batch / cache specs
# --------------------------------------------------------------------------
def batch_specs(policy: ShardingPolicy, batch_tree, kind: str) -> Any:
    def spec(path, x):
        names = _path_names(path)
        leaf = names[-1]
        if leaf == "pos":
            return P()
        dp = policy._fit(policy.batch_axes, x.shape[0])
        if leaf in ("tokens", "token"):
            return P(dp, None)
        if leaf in ("audio", "image_embeds"):
            return P(dp, None, None)
        return P(*([None] * x.ndim))

    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def cache_specs_tree(policy: ShardingPolicy, cache_tree, cfg: ModelConfig) -> Any:
    """Decode caches.  KV: (layers..., B, S, K, dh) -> seq sharded on model.
    SSM states: heads sharded on model.  Cross-KV: source-seq sharded."""
    tp = policy.tp

    def spec(path, x):
        names = _path_names(path)
        leaf = names[-1]
        lead = x.ndim - 4  # stacked layer/unit dims before (B, S, K, dh)
        if leaf in ("k", "v", "xk", "xv"):
            dp = policy._fit(policy.batch_axes, x.shape[lead])
            seq_ax = (
                policy._fit(tp, x.shape[-3]) if policy.seq_shard_cache else None
            )
            kv_ax = None if seq_ax else policy._fit(tp, x.shape[-2])
            return P(*([None] * lead + [dp, seq_ax, kv_ax, None]))
        if leaf == "ssm":  # (..., B, H, P, N)
            dp = policy._fit(policy.batch_axes, x.shape[x.ndim - 4])
            return P(*([None] * (x.ndim - 4) + [dp, policy._fit(tp, x.shape[-3]), None, None]))
        if leaf == "conv":  # (..., B, w, conv_dim)
            dp = policy._fit(policy.batch_axes, x.shape[x.ndim - 3])
            return P(*([None] * (x.ndim - 3) + [dp, None, policy._fit(tp, x.shape[-1])]))
        if leaf == "x0":
            dp = policy._fit(policy.batch_axes, x.shape[x.ndim - 3])
            return P(*([None] * (x.ndim - 3) + [dp, None, None]))
        return P(*([None] * x.ndim))

    return jax.tree_util.tree_map_with_path(spec, cache_tree)
