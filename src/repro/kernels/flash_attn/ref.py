"""Pure-jnp oracle for the flash_attn Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attn_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True
) -> jax.Array:
    """q: (B, Sq, H, dh); k/v: (B, Sk, K, dh), H % K == 0 -> (B, Sq, H, dh).

    f32 softmax over f32 logits — the same numerics contract the kernel
    implements with online (streaming) softmax.
    """
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    rep = H // K
    qf = q.astype(jnp.float32) / jnp.sqrt(dh)
    logits = jnp.einsum(
        "bqkrd,bskd->bkrqs", qf.reshape(B, Sq, K, rep, dh), k.astype(jnp.float32)
    )
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(k.shape[1])[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkrqs,bskd->bqkrd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, dh).astype(q.dtype)
