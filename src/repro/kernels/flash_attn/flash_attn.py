"""Pallas TPU kernel: flash attention forward (online softmax), GQA-aware.

The TPU-target resolution of the SSPerf HC1/HC2 finding that XLA:CPU (and
to a lesser degree XLA:TPU) materializes the softmax chain: here the
(block_q, block_k) logit tile, its exp, and the PV partial products all
live in VMEM; HBM sees only Q/K/V reads and one O write.

Grid: (batch*q_heads, num_q_blocks, num_k_blocks) — the kv axis is the
innermost (sequential on TPU), so the online-softmax state (m, l, acc)
persists in VMEM scratch across kv steps of one (head, q-block).
Causal blocks entirely above the diagonal are skipped with pl.when.
MXU alignment: block_q/block_k multiples of 128, d_head padded by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, causal: bool, block_q: int, block_k: int, nk: int, sm_scale: float,
):
    i = pl.program_id(1)  # q block
    j = pl.program_id(2)  # kv block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * block_q
    k_start = j * block_k
    # skip fully-masked blocks (strictly above the causal diagonal)
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(k_start <= q_start + block_q - 1 if causal else True)
    def _body():
        q = q_ref[0].astype(jnp.float32) * sm_scale  # (bq, dh)
        k = k_ref[0].astype(jnp.float32)  # (bk, dh)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T  # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, _NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _emit():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attn_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """q: (BH, Sq, dh); k/v: (BK, Sk, dh) with BH = B*H, BK = B*K.
    Head grouping (GQA) is encoded in the k/v index maps: q head h reads
    kv head h // rep.  Shapes must be pre-padded to block multiples.
    """
    BH, Sq, dh = q.shape
    BK, Sk, _ = k.shape
    rep = BH // BK
    nq = Sq // block_q
    nk = Sk // block_k
    sm_scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(
        _flash_kernel, causal=causal, block_q=block_q, block_k=block_k,
        nk=nk, sm_scale=sm_scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, j: (h // rep, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, j: (h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
