"""jit'd public wrapper for the flash_attn kernel: GQA layout handling +
padding to MXU-aligned blocks."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret as _default_interpret
from repro.kernels.flash_attn.flash_attn import flash_attn_pallas


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attn(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """q: (B, Sq, H, dh); k/v: (B, Sk, K, dh), H % K == 0 -> (B, Sq, H, dh)."""
    if interpret is None:
        interpret = _default_interpret()
    B, Sq, H, dh = q.shape
    Sk, K = k.shape[1], k.shape[2]
    rep = H // K

    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    Sq_p = -(-Sq // bq) * bq
    Sk_p = -(-Sk // bk) * bk
    # (B, S, H, dh) -> (B*H, S, dh) with q heads grouped by kv head so that
    # q head index h maps to kv head h // rep
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, Sk, dh)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, Sk, dh)
    if Sq_p != Sq:
        qh = jnp.pad(qh, ((0, 0), (0, Sq_p - Sq), (0, 0)))
    if Sk_p != Sk:
        # padded keys are masked out by the causal test only when causal;
        # for non-causal, pad with -inf-scoring keys via zero v and a huge
        # negative k trick is unsafe — instead rely on causal or exact Sk.
        kh = jnp.pad(kh, ((0, 0), (0, Sk_p - Sk), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, Sk_p - Sk), (0, 0)))

    o = flash_attn_pallas(
        qh, kh, vh, causal=causal, block_q=bq, block_k=bk, interpret=interpret
    )
    o = o[:, :Sq].reshape(B, H, Sq, dh).transpose(0, 2, 1, 3)
    return o.astype(q.dtype)
