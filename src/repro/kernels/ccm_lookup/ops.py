"""jit'd public wrapper for the ccm_lookup kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels import default_interpret as _default_interpret
from repro.kernels.ccm_lookup.ccm_lookup import ccm_lookup_pallas


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_t", "interpret")
)
def ccm_lookup(
    idx: jax.Array,
    w: jax.Array,
    Y_fut: jax.Array,
    block_b: int = 32,
    block_t: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched simplex lookup: pred[b, t] = sum_k w[t,k] * Y_fut[b, idx[t,k]].

    idx/w: (Lq, k) one library table; Y_fut: (B, Lp) targets sharing it.
    """
    if interpret is None:
        interpret = _default_interpret()
    return ccm_lookup_pallas(
        idx, w, Y_fut, block_b=block_b, block_t=block_t, interpret=interpret
    )
