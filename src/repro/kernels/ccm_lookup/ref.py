"""Pure-jnp oracle for the ccm_lookup Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ccm_lookup_ref(
    idx: jax.Array, w: jax.Array, Y_fut: jax.Array
) -> jax.Array:
    """pred[b, t] = sum_k w[t, k] * Y_fut[b, idx[t, k]].

    idx, w: (Lq, k) — one library kNN table; Y_fut: (B, Lp) — a batch of
    target-series future values sharing that table (same optimal E).
    Returns (B, Lq).
    """
    g = Y_fut[:, idx]  # (B, Lq, k)
    return jnp.einsum("tk,btk->bt", w, g)
