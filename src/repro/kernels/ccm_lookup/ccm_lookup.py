"""Pallas TPU kernel: batched CCM lookup (paper Alg. 5).

The paper identifies lookup as the next bottleneck at large N (SSIV-B3,
Fig. 8a): it is a random-gather, memory-bandwidth-bound kernel.  TPU
adaptation (DESIGN.md SS2): batch *many target series* that share one
library table (same optimal E) through a single pass, so each (Lq, k)
index block is loaded once from HBM and reused across block_b targets —
raising arithmetic intensity by block_b versus the paper's one-target-at-
a-time CPU kernel.

Grid: (target blocks, time blocks).  Per program VMEM:
  Y block (block_b, Lp) + idx/w blocks (block_t, k) + out (block_b, block_t)
  ~ 1.1 MB for block_b=32, Lp=8528.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def ccm_lookup_kernel(idx_ref, w_ref, y_ref, out_ref):
    idxb = idx_ref[...]  # (BT, k)
    wb = w_ref[...]  # (BT, k)
    y = y_ref[...]  # (BB, Lp)
    BT, k = idxb.shape
    g = jnp.take(y, idxb.reshape(-1), axis=1)  # (BB, BT*k) vector gather
    g = g.reshape(y.shape[0], BT, k)
    out_ref[...] = jnp.einsum(
        "tk,btk->bt", wb, g, preferred_element_type=jnp.float32
    )


def ccm_lookup_pallas(
    idx: jax.Array,
    w: jax.Array,
    Y_fut: jax.Array,
    block_b: int = 32,
    block_t: int = 256,
    interpret: bool = True,
) -> jax.Array:
    Lq, k = idx.shape
    B, Lp = Y_fut.shape
    Lq_pad = pl.cdiv(Lq, block_t) * block_t
    B_pad = pl.cdiv(B, block_b) * block_b
    idx_p = jnp.pad(idx, ((0, Lq_pad - Lq), (0, 0)))
    w_p = jnp.pad(w, ((0, Lq_pad - Lq), (0, 0)))
    Y_p = jnp.pad(Y_fut, ((0, B_pad - B), (0, 0)))

    out = pl.pallas_call(
        ccm_lookup_kernel,
        grid=(B_pad // block_b, Lq_pad // block_t),
        in_specs=[
            pl.BlockSpec((block_t, k), lambda b, t: (t, 0)),
            pl.BlockSpec((block_t, k), lambda b, t: (t, 0)),
            pl.BlockSpec((block_b, Lp), lambda b, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_t), lambda b, t: (b, t)),
        out_shape=jax.ShapeDtypeStruct((B_pad, Lq_pad), jnp.float32),
        interpret=interpret,
    )(idx_p, w_p, Y_p)
    return out[:B, :Lq]
