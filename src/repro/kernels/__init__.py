# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared helpers for the Pallas kernel wrappers."""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Pallas TPU kernels run natively on TPU; everywhere else (CPU CI,
    this container) they are validated in interpret mode.  One definition
    shared by every kernels/*/ops.py wrapper and the engine layer."""
    return jax.default_backend() != "tpu"
