"""Pallas TPU kernels: cumulative multi-E pairwise distances + fused top-k.

The paper's hot spot (97% of cppEDM runtime) re-architected for TPU
(DESIGN.md SS2/SS8).  Two selection layouts:

SLAB (``knn_topk_kernel``, small libraries): one pass over query
row-blocks; the (block_q, Lc_pad) distance slab lives in VMEM and is
*accumulated* across embedding dimensions E = 1..E_max (cumulative
recurrence) instead of rebuilt per E.  Per-program VMEM grows with Lc
(~4.6 MB at BQ=128, Lc=8528, E_max=20), capping library length at a few
thousand frames.

STREAMING (``knn_topk_stream_kernel``, DESIGN.md SS8): the grid gains a
second, minor-most CANDIDATE-TILE dimension.  Each program accumulates a
(block_q, tile_c) distance tile on-chip from the lag slices and merges it
into a running (E_max, block_q, k) top-k carried in VMEM scratch across
tiles, so per-program VMEM is O(E_max*tile_c + block_q*tile_c +
E_max*block_q*k) — INDEPENDENT of Lc (``stream_block_shapes`` is the
pure shape function the CI guard asserts on): arbitrary library lengths
fit a 16 MB VMEM budget.

Shared selection machinery: top-k is a fused k-pass masked argmin on the
VPU (k = E+1 <= 21); TPU has no radix-sort analogue, and k-pass selection
is O(k*width) vector work per row versus O(width log width) for a sort.
Candidate columns are padded to the lane boundary and masked with _BIG.
Tie rule: argmin picks the first minimum position, which in both layouts
resolves equal distances to the LOWEST candidate index — the lax.top_k
rule — so slab, streaming, and the jnp builders agree bit-for-bit
(see knn_topk_stream_kernel's merge-order note).

Ragged queries: wrappers split the query axis into full ``block_q``
blocks plus one 8-row-aligned tail block (``_query_splits``), so a ragged
Lq pays O(8) padded rows of selection work instead of a whole extra
block.

``dist_dtype`` (EDMConfig.dist_dtype): the distance ACCUMULATOR runs in
this dtype (bfloat16 halves the tile/slab working set); merge keys and
output distances are always float32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# THE shared pinned-rounding accumulate (maximum(sq, 0) FMA guard): one
# definition for the jnp builders, the kernels, and the ref oracle — the
# exact float sequence the cross-layout bit-identity contract rests on.
from repro.core.knn import _acc_sq

_BIG = 3.0e38  # finite +inf stand-in (avoids inf-inf NaNs)
_IMAX = 2147483647  # python literal: a jnp scalar here would be captured
# by pallas kernel traces as a constant, which pallas_call rejects.


def _query_splits(Lq: int, block_q: int) -> list[tuple[int, int, int]]:
    """Query-axis work plan: [(row0, rows, block)] — full ``block_q``
    blocks plus one 8-row-aligned tail block for the ragged remainder
    (sublane granularity), so padded tail rows cost at most 7 rows of
    k-pass VPU work instead of a whole extra block."""
    main = (Lq // block_q) * block_q
    splits = []
    if main:
        splits.append((0, main, block_q))
    rem = Lq - main
    if rem:
        splits.append((main, rem, min(block_q, max(8, -(-rem // 8) * 8))))
    return splits


def _over_query_splits(Vq, block_q, call_split):
    """Shared wrapper scaffold for both layouts: run ``call_split(Vq_p,
    row0, rows_pad, bq)`` -> (idx, dist) over the _query_splits plan
    (padding each split to a block multiple) and stitch the per-split
    results back along the query axis."""
    Lq = Vq.shape[1]
    outs = []
    for row0, rows, bq in _query_splits(Lq, block_q):
        rows_pad = pl.cdiv(rows, bq) * bq
        Vq_p = jnp.pad(
            Vq[:, row0 : row0 + rows], ((0, 0), (0, rows_pad - rows))
        )
        idx, dist = call_split(Vq_p, row0, rows_pad, bq)
        outs.append((idx[:, :rows], dist[:, :rows]))
    if len(outs) == 1:
        return outs[0]
    return (
        jnp.concatenate([o[0] for o in outs], axis=1),
        jnp.concatenate([o[1] for o in outs], axis=1),
    )


def _kpass_select(md, mi, k, width):
    """Fused k-pass masked-argmin top-k over a (rows, width) buffer.

    md: f32 merge keys; mi: i32 candidate ids per column.  Selected
    positions are knocked out with +inf (strictly above the _BIG mask
    value, so an already-taken position can never shadow a real masked
    candidate).  Returns (ids, dists) each (rows, k), sorted ascending
    with ties resolved to the earliest buffer position.
    """
    rows = md.shape[0]
    pos = jax.lax.broadcasted_iota(jnp.int32, (rows, width), 1)

    def body(kk, carry):
        md_cur, idxs, dists = carry
        m = jnp.min(md_cur, axis=1)
        am = jnp.argmin(md_cur, axis=1).astype(jnp.int32)
        hit = pos == am[:, None]
        sel = jnp.min(jnp.where(hit, mi, jnp.full((), _IMAX, jnp.int32)), axis=1)
        idxs = jax.lax.dynamic_update_index_in_dim(idxs, sel, kk, axis=1)
        dists = jax.lax.dynamic_update_index_in_dim(dists, m, kk, axis=1)
        md_cur = jnp.where(hit, jnp.float32(jnp.inf), md_cur)
        return md_cur, idxs, dists

    _, idxs, dists = jax.lax.fori_loop(
        0,
        k,
        body,
        (
            md,
            jnp.zeros((rows, k), jnp.int32),
            jnp.zeros((rows, k), jnp.float32),
        ),
    )
    return idxs, dists


# ------------------------------------------------------------------ slab
def knn_topk_kernel(
    vq_ref,
    vc_ref,
    idx_ref,
    dist_ref,
    *,
    E_max: int,
    k: int,
    Lc: int,
    block_q: int,
    exclude_self: bool,
    row0: int = 0,
    dist_dtype=jnp.float32,
):
    Lc_pad = vc_ref.shape[1]
    qi = pl.program_id(0)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (block_q, Lc_pad), 1)
    invalid = col_ids >= Lc
    if exclude_self:
        row_ids = row0 + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, Lc_pad), 0
        )
        invalid = invalid | (col_ids == row_ids)

    D = jnp.zeros((block_q, Lc_pad), dist_dtype)
    for e in range(E_max):  # static unroll: E_max <= 20
        D = _acc_sq(D, vq_ref[e, :], vc_ref[e, :], dist_dtype)
        Dm = jnp.where(invalid, _BIG, D.astype(jnp.float32))
        idxs, dists = _kpass_select(Dm, col_ids, k, Lc_pad)
        idx_ref[e] = idxs
        dist_ref[e] = dists


def knn_topk_pallas(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    block_q: int = 128,
    interpret: bool = True,
    dist_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Raw pallas_call wrapper; padding/unpadding handled by ops.knn_topk."""
    E_max = Vq.shape[0]
    Lc = Vc.shape[1]
    Lc_pad = pl.cdiv(Lc, 128) * 128
    Vc_p = jnp.pad(Vc, ((0, 0), (0, Lc_pad - Lc)))

    def call_split(Vq_p, row0, rows_pad, bq):
        kernel = functools.partial(
            knn_topk_kernel,
            E_max=E_max,
            k=k,
            Lc=Lc,
            block_q=bq,
            exclude_self=exclude_self,
            row0=row0,
            dist_dtype=dist_dtype,
        )
        return pl.pallas_call(
            kernel,
            grid=(rows_pad // bq,),
            in_specs=[
                pl.BlockSpec((E_max, bq), lambda i: (0, i)),
                pl.BlockSpec((E_max, Lc_pad), lambda i: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((E_max, bq, k), lambda i: (0, i, 0)),
                pl.BlockSpec((E_max, bq, k), lambda i: (0, i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((E_max, rows_pad, k), jnp.int32),
                jax.ShapeDtypeStruct((E_max, rows_pad, k), jnp.float32),
            ],
            interpret=interpret,
        )(Vq_p, Vc_p)

    return _over_query_splits(Vq, block_q, call_split)


# ------------------------------------------------------------- streaming
def stream_block_shapes(
    E_max: int, k: int, block_q: int, tile_c: int
) -> dict[str, tuple[int, ...]]:
    """Per-program block/scratch shapes of the streaming kernel.

    A PURE function of (E_max, k, block_q, tile_c): the library length Lc
    appears nowhere — it only scales the GRID — which is the flat-VMEM
    scaling guarantee the CI guard test asserts (tests/test_knn_streaming).
    ``knn_topk_stream_pallas`` builds its BlockSpecs and scratch from this
    dict, so the guard constrains the real kernel, not a copy.
    """
    return {
        "vq": (E_max, block_q),
        "vc_tile": (E_max, tile_c),
        "out": (E_max, block_q, k),
        "scratch_idx": (E_max, block_q, k),
        "scratch_dist": (E_max, block_q, k),
        "merge": (block_q, k + tile_c),
    }


def stream_vmem_bytes(
    E_max: int, k: int, block_q: int, tile_c: int, dist_dtype=jnp.float32
) -> int:
    """VMEM budget estimate for one streaming program (DESIGN.md SS8):
    blocks + scratch + the distance tile (dist_dtype) + the f32/i32 merge
    buffers.  Independent of Lc."""
    s = stream_block_shapes(E_max, k, block_q, tile_c)
    n = lambda shp: functools.reduce(lambda a, b: a * b, shp, 1)
    it = jnp.dtype(dist_dtype).itemsize
    return (
        4 * (n(s["vq"]) + n(s["vc_tile"]))  # f32 lag blocks
        + 4 * (n(s["out"]) * 2)  # idx + dist output blocks
        + 4 * (n(s["scratch_idx"]) + n(s["scratch_dist"]))
        + it * block_q * tile_c  # distance tile accumulator
        + (4 + 4) * n(s["merge"])  # f32 keys + i32 ids
    )


def knn_topk_stream_kernel(
    vq_ref,
    vc_ref,
    idx_ref,
    dist_ref,
    idx_s,
    dist_s,
    *,
    E_max: int,
    k: int,
    Lc: int,
    block_q: int,
    tile_c: int,
    exclude_self: bool,
    row0: int = 0,
    dist_dtype=jnp.float32,
):
    """Grid (query_block, candidate_tile); candidate tiles are minor-most,
    so the running (E_max, block_q, k) top-k in VMEM scratch accumulates
    across the tiles of one query block and is flushed to the output block
    on the last tile.

    Merge order = [running k | tile columns ascending]: running entries
    hold globally-smaller candidate ids (earlier tiles) in tie-stable
    order, so the first-minimum-position argmin resolves equal distances
    to the lowest candidate id — exactly the slab kernel / lax.top_k tie
    rule, which is what makes streaming bit-identical to slab.  Scratch
    is seeded with +inf sentinels: strictly worse than every real
    candidate (masked ones carry the finite _BIG), so a sentinel can only
    surface in the degenerate k > Lc case the wrappers reject.
    """
    qi = pl.program_id(0)
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        idx_s[...] = jnp.zeros(idx_s.shape, jnp.int32)
        dist_s[...] = jnp.full(dist_s.shape, jnp.inf, jnp.float32)

    base = ci * tile_c
    col_ids = base + jax.lax.broadcasted_iota(jnp.int32, (block_q, tile_c), 1)
    invalid = col_ids >= Lc
    if exclude_self:
        row_ids = row0 + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, tile_c), 0
        )
        invalid = invalid | (col_ids == row_ids)

    D = jnp.zeros((block_q, tile_c), dist_dtype)
    for e in range(E_max):  # static unroll: E_max <= 20
        D = _acc_sq(D, vq_ref[e, :], vc_ref[e, :], dist_dtype)
        Dm = jnp.where(invalid, _BIG, D.astype(jnp.float32))
        md = jnp.concatenate([dist_s[e], Dm], axis=1)
        mi = jnp.concatenate([idx_s[e], col_ids], axis=1)
        idxs, dists = _kpass_select(md, mi, k, k + tile_c)
        idx_s[e] = idxs
        dist_s[e] = dists

    @pl.when(ci == pl.num_programs(1) - 1)
    def _flush():
        idx_ref[...] = idx_s[...]
        dist_ref[...] = dist_s[...]


def knn_topk_stream_pallas(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    block_q: int = 128,
    tile_c: int = 512,
    interpret: bool = True,
    dist_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Raw streaming pallas_call wrapper (padding via ops.knn_topk_streaming).

    VMEM per program is stream_vmem_bytes(...) — flat in Lc — so library
    length is bounded by HBM, not by the 16 MB VMEM budget.
    """
    E_max = Vq.shape[0]
    Lc = Vc.shape[1]
    if k > Lc:
        raise ValueError(f"k={k} exceeds candidate count Lc={Lc}")
    tile_c = max(8, min(tile_c, pl.cdiv(Lc, 8) * 8))
    n_c = pl.cdiv(Lc, tile_c)
    Vc_p = jnp.pad(Vc, ((0, 0), (0, n_c * tile_c - Lc)))

    def call_split(Vq_p, row0, rows_pad, bq):
        shapes = stream_block_shapes(E_max, k, bq, tile_c)
        kernel = functools.partial(
            knn_topk_stream_kernel,
            E_max=E_max,
            k=k,
            Lc=Lc,
            block_q=bq,
            tile_c=tile_c,
            exclude_self=exclude_self,
            row0=row0,
            dist_dtype=dist_dtype,
        )
        return pl.pallas_call(
            kernel,
            grid=(rows_pad // bq, n_c),
            in_specs=[
                pl.BlockSpec(shapes["vq"], lambda i, j: (0, i)),
                pl.BlockSpec(shapes["vc_tile"], lambda i, j: (0, j)),
            ],
            out_specs=[
                pl.BlockSpec(shapes["out"], lambda i, j: (0, i, 0)),
                pl.BlockSpec(shapes["out"], lambda i, j: (0, i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((E_max, rows_pad, k), jnp.int32),
                jax.ShapeDtypeStruct((E_max, rows_pad, k), jnp.float32),
            ],
            scratch_shapes=[
                pltpu.VMEM(shapes["scratch_idx"], jnp.int32),
                pltpu.VMEM(shapes["scratch_dist"], jnp.float32),
            ],
            interpret=interpret,
        )(Vq_p, Vc_p)

    return _over_query_splits(Vq, block_q, call_split)
