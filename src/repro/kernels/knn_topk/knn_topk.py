"""Pallas TPU kernel: cumulative multi-E pairwise distances + fused top-k.

The paper's hot spot (97% of cppEDM runtime) re-architected for TPU
(DESIGN.md SS2):

  * one pass over query row-blocks; the (block_q, Lc) distance slab lives in
    VMEM and is *accumulated* across embedding dimensions E = 1..E_max
    (cumulative recurrence) instead of rebuilt per E;
  * top-k is a fused k-pass masked argmin on the VPU (k = E+1 <= 21); TPU has
    no radix-sort analogue, and k-pass selection is O(k*Lc) vector work per
    row versus O(Lc log Lc) for a sort;
  * candidate columns are padded to the 128-lane boundary and masked with
    +inf so the MXU/VPU tiles stay aligned.

Grid: one program per query row-block.  Per-program VMEM:
  Vq block (E_max, BQ) + Vc (E_max, Lc_pad) + slab (BQ, Lc_pad)
  ~ 4.6 MB for BQ=128, Lc=8528, E_max=20 — fits v5e's 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 3.0e38  # finite +inf stand-in (avoids inf-inf NaNs)


def knn_topk_kernel(
    vq_ref,
    vc_ref,
    idx_ref,
    dist_ref,
    *,
    E_max: int,
    k: int,
    Lc: int,
    block_q: int,
    exclude_self: bool,
):
    Lc_pad = vc_ref.shape[1]
    qi = pl.program_id(0)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (block_q, Lc_pad), 1)
    invalid = col_ids >= Lc
    if exclude_self:
        row_ids = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, Lc_pad), 0
        )
        invalid = invalid | (col_ids == row_ids)

    D = jnp.zeros((block_q, Lc_pad), jnp.float32)
    for e in range(E_max):  # static unroll: E_max <= 20
        vq = vq_ref[e, :]
        vc = vc_ref[e, :]
        D = D + jnp.square(vq[:, None] - vc[None, :])
        Dm = jnp.where(invalid, _BIG, D)

        def body(kk, carry):
            Dm_cur, idxs, dists = carry
            m = jnp.min(Dm_cur, axis=1)
            am = jnp.argmin(Dm_cur, axis=1).astype(jnp.int32)
            idxs = jax.lax.dynamic_update_index_in_dim(idxs, am, kk, axis=1)
            dists = jax.lax.dynamic_update_index_in_dim(dists, m, kk, axis=1)
            Dm_cur = jnp.where(col_ids == am[:, None], _BIG, Dm_cur)
            return Dm_cur, idxs, dists

        _, idxs, dists = jax.lax.fori_loop(
            0,
            k,
            body,
            (
                Dm,
                jnp.zeros((block_q, k), jnp.int32),
                jnp.zeros((block_q, k), jnp.float32),
            ),
        )
        idx_ref[e] = idxs
        dist_ref[e] = dists


def knn_topk_pallas(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    block_q: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Raw pallas_call wrapper; padding/unpadding handled by ops.knn_topk."""
    E_max, Lq = Vq.shape
    Lc = Vc.shape[1]
    Lq_pad = pl.cdiv(Lq, block_q) * block_q
    Lc_pad = pl.cdiv(Lc, 128) * 128
    Vq_p = jnp.pad(Vq, ((0, 0), (0, Lq_pad - Lq)))
    Vc_p = jnp.pad(Vc, ((0, 0), (0, Lc_pad - Lc)))

    kernel = functools.partial(
        knn_topk_kernel,
        E_max=E_max,
        k=k,
        Lc=Lc,
        block_q=block_q,
        exclude_self=exclude_self,
    )
    idx, dist = pl.pallas_call(
        kernel,
        grid=(Lq_pad // block_q,),
        in_specs=[
            pl.BlockSpec((E_max, block_q), lambda i: (0, i)),
            pl.BlockSpec((E_max, Lc_pad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((E_max, block_q, k), lambda i: (0, i, 0)),
            pl.BlockSpec((E_max, block_q, k), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E_max, Lq_pad, k), jnp.int32),
            jax.ShapeDtypeStruct((E_max, Lq_pad, k), jnp.float32),
        ],
        interpret=interpret,
    )(Vq_p, Vc_p)
    return idx[:, :Lq], dist[:, :Lq]
