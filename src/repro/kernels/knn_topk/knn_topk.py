"""Pallas TPU kernels: cumulative multi-E pairwise distances + fused top-k.

The paper's hot spot (97% of cppEDM runtime) re-architected for TPU
(DESIGN.md SS2/SS8).  ONE selection layout — STREAMING:

``knn_topk_stream_kernel``: the grid has a minor-most CANDIDATE-TILE
dimension.  Each program accumulates a (block_q, tile_c) distance tile
on-chip from the lag slices, partial-sorts the tile to its own top-k
with the k-pass selector, and folds it into a running SORTED
(E_max, block_q, k) top-k carried in VMEM scratch across tiles via the
shared bitonic partial merge network (core/knn.merge_topk_sorted) —
O(k log k) per merge, independent of tile width.  Per-program VMEM is
O(E_max*tile_c + block_q*tile_c + E_max*block_q*k) — INDEPENDENT of Lc
(``stream_block_shapes`` is the pure shape function the CI guard asserts
on): arbitrary library lengths fit a 16 MB VMEM budget, and a tile
covering the whole library degenerates to one direct selection, so small
libraries pay nothing for the tiling.  (The historical dense
distance-matrix kernel is gone; ``benchmarks/run.py knn`` keeps a local
copy as the A/B reference.)

``knn_topk_prefix_kernel``: the same running merge with candidate tiles
CLIPPED at library-size boundaries (DESIGN.md SS9): candidates are
pre-gathered into sweep order (applying the optional ``col_ids``
permutation), each clipped segment padded to ``tile_c`` with masked
id -1 columns, and the running carry is emitted to the per-size output
slot at every boundary tile — the one-sweep prefix-snapshot tables of
the CCM convergence diagnostic, in-kernel, replacing the per-size
rebuild fallback the Pallas engines used to inherit.

Shared selection machinery: the per-tile top-k is a fused k-pass masked
argmin on the VPU (k = E+1 <= 21); TPU has no radix-sort analogue, and
k-pass selection is O(k*width) vector work per row versus
O(width log width) for a sort.  Candidate columns are padded to the lane
boundary and masked with _BIG.  Tie rule: argmin picks the first minimum
position, and the merge network's (distance, rank) key keeps running
entries ahead of tile entries — equal distances always resolve to the
earliest sweep position (the lowest candidate id in natural order),
exactly the lax.top_k rule, so the kernels and the jnp builders agree
bit-for-bit.

Ragged queries: wrappers split the query axis into full ``block_q``
blocks plus one 8-row-aligned tail block (``_query_splits``), so a ragged
Lq pays O(8) padded rows of selection work instead of a whole extra
block.

``dist_dtype`` (EDMConfig.dist_dtype): the distance ACCUMULATOR runs in
this dtype (bfloat16 halves the tile working set); merge keys and output
distances are always float32.
"""
from __future__ import annotations

import bisect
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# THE shared pinned-rounding accumulate (maximum(sq, 0) FMA guard) and THE
# shared partial merge network: one definition each for the jnp builders,
# the kernels, and the ref oracle — the exact float/compare sequences the
# cross-layout bit-identity contract rests on.
from repro.core.knn import _next_pow2, _acc_sq, merge_topk_sorted

_BIG = 3.0e38  # finite +inf stand-in (avoids inf-inf NaNs)
_IMAX = 2147483647  # python literal: a jnp scalar here would be captured
# by pallas kernel traces as a constant, which pallas_call rejects.


def _query_splits(Lq: int, block_q: int) -> list[tuple[int, int, int]]:
    """Query-axis work plan: [(row0, rows, block)] — full ``block_q``
    blocks plus one 8-row-aligned tail block for the ragged remainder
    (sublane granularity), so padded tail rows cost at most 7 rows of
    k-pass VPU work instead of a whole extra block."""
    main = (Lq // block_q) * block_q
    splits = []
    if main:
        splits.append((0, main, block_q))
    rem = Lq - main
    if rem:
        splits.append((main, rem, min(block_q, max(8, -(-rem // 8) * 8))))
    return splits


def _over_query_splits(Vq, block_q, call_split, q_axis: int = 1):
    """Shared wrapper scaffold: run ``call_split(Vq_p, row0, rows_pad,
    bq)`` -> (idx, dist) over the _query_splits plan (padding each split
    to a block multiple) and stitch the per-split results back along the
    query axis (``q_axis`` of the OUTPUT arrays)."""
    Lq = Vq.shape[1]
    take = (slice(None),) * q_axis
    outs = []
    for row0, rows, bq in _query_splits(Lq, block_q):
        rows_pad = pl.cdiv(rows, bq) * bq
        Vq_p = jnp.pad(
            Vq[:, row0 : row0 + rows], ((0, 0), (0, rows_pad - rows))
        )
        idx, dist = call_split(Vq_p, row0, rows_pad, bq)
        outs.append((idx[take + (slice(0, rows),)],
                     dist[take + (slice(0, rows),)]))
    if len(outs) == 1:
        return outs[0]
    return (
        jnp.concatenate([o[0] for o in outs], axis=q_axis),
        jnp.concatenate([o[1] for o in outs], axis=q_axis),
    )


def _kpass_select(md, mi, k, width):
    """Fused k-pass masked-argmin top-k over a (rows, width) buffer.

    md: f32 merge keys; mi: i32 candidate ids per column, OR a scalar
    BASE when the ids are affine in the column position (id = base +
    column, the stream kernel's natural-order tiles) — the affine form
    skips the full-width id-extraction gather (``base + argmin`` is a
    per-row scalar add), about a fifth of the per-pass VPU work.
    Selected positions are knocked out with +inf (strictly above the
    _BIG mask value, so an already-taken position can never shadow a
    real masked candidate).  Returns (ids, dists) each (rows, k), sorted
    ascending with ties resolved to the earliest buffer position —
    identical for both id forms (argmin picks exactly one position, so
    the gathered id IS base + argmin).
    """
    rows = md.shape[0]
    pos = jax.lax.broadcasted_iota(jnp.int32, (rows, width), 1)
    affine = jnp.ndim(mi) == 0

    def body(kk, carry):
        md_cur, idxs, dists = carry
        m = jnp.min(md_cur, axis=1)
        am = jnp.argmin(md_cur, axis=1).astype(jnp.int32)
        hit = pos == am[:, None]
        if affine:
            sel = mi + am
        else:
            sel = jnp.min(
                jnp.where(hit, mi, jnp.full((), _IMAX, jnp.int32)), axis=1
            )
        idxs = jax.lax.dynamic_update_index_in_dim(idxs, sel, kk, axis=1)
        dists = jax.lax.dynamic_update_index_in_dim(dists, m, kk, axis=1)
        md_cur = jnp.where(hit, jnp.float32(jnp.inf), md_cur)
        return md_cur, idxs, dists

    _, idxs, dists = jax.lax.fori_loop(
        0,
        k,
        body,
        (
            md,
            jnp.zeros((rows, k), jnp.int32),
            jnp.zeros((rows, k), jnp.float32),
        ),
    )
    return idxs, dists


# ------------------------------------------------------------- streaming
def stream_block_shapes(
    E_max: int, k: int, block_q: int, tile_c: int
) -> dict[str, tuple[int, ...]]:
    """Per-program block/scratch shapes of the streaming kernel.

    A PURE function of (E_max, k, block_q, tile_c): the library length Lc
    appears nowhere — it only scales the GRID — which is the flat-VMEM
    scaling guarantee the CI guard test asserts (tests/test_knn_streaming).
    ``knn_topk_stream_pallas`` builds its BlockSpecs and scratch from this
    dict, so the guard constrains the real kernel, not a copy.

    ``tile_ids``/``tile_topk``/``merge`` are kernel-internal working
    arrays (the candidate-id lanes, the tile's own partial top-k, and the
    DOUBLED (2 * next_pow2(k)) merge-network buffers), tracked here so
    ``stream_vmem_bytes`` models the true peak.
    """
    return {
        "vq": (E_max, block_q),
        "vc_tile": (E_max, tile_c),
        "out": (E_max, block_q, k),
        "scratch_idx": (E_max, block_q, k),
        "scratch_dist": (E_max, block_q, k),
        "tile_ids": (block_q, tile_c),
        "tile_topk": (block_q, k),
        "merge": (block_q, 2 * _next_pow2(k)),
    }


def stream_vmem_bytes(
    E_max: int, k: int, block_q: int, tile_c: int, dist_dtype=jnp.float32
) -> int:
    """VMEM budget estimate for one streaming program (DESIGN.md SS8):
    blocks + scratch + the distance tile (dist_dtype) + the candidate-id
    lanes + the tile partial top-k + the merge network's doubled
    (dist f32, id i32, rank i32) working triples — the top-k scratch
    doubling the pre-merge-network model used to omit.  Independent of
    Lc."""
    s = stream_block_shapes(E_max, k, block_q, tile_c)
    n = lambda shp: functools.reduce(lambda a, b: a * b, shp, 1)
    it = jnp.dtype(dist_dtype).itemsize
    return (
        4 * (n(s["vq"]) + n(s["vc_tile"]))  # f32 lag blocks
        + 4 * (n(s["out"]) * 2)  # idx + dist output blocks
        + 4 * (n(s["scratch_idx"]) + n(s["scratch_dist"]))
        + it * block_q * tile_c  # distance tile accumulator
        + 4 * n(s["tile_ids"])  # i32 candidate-id lanes
        + (4 + 4) * n(s["tile_topk"])  # tile partial top-k (id + dist)
        + (4 + 4 + 4) * n(s["merge"])  # merge network (dist, id, rank)
    )


def knn_topk_stream_kernel(
    vq_ref,
    vc_ref,
    idx_ref,
    dist_ref,
    idx_s=None,
    dist_s=None,
    *,
    E_max: int,
    k: int,
    Lc: int,
    block_q: int,
    tile_c: int,
    exclude_self: bool,
    row0: int = 0,
    dist_dtype=jnp.float32,
    single_tile: bool = False,
):
    """Grid (query_block, candidate_tile); candidate tiles are minor-most,
    so the running (E_max, block_q, k) top-k in VMEM scratch accumulates
    across the tiles of one query block and is flushed to the output block
    on the last tile.

    The running scratch is kept SORTED by (distance, arrival) as an
    invariant: each tile is partial-sorted to its own top-k with the
    k-pass selector (O(k*tile_c) VPU work); the FIRST tile's top-k seeds
    the scratch directly (a merge against sentinels is an identity — and
    with ``single_tile`` statically true, the whole scratch/merge/flush
    machinery drops out of the program: the one-tile grid IS a direct
    dense selection, the small-library fast case the calibrator
    exploits); every later tile folds in with the O(k log k) merge
    network — running entries (globally earlier sweep positions, i.e.
    smaller candidate ids) win ties via the network's rank key, so equal
    distances resolve to the lowest candidate id, exactly the lax.top_k
    rule: bit-identical to the jnp builders and the dense oracle.
    """
    qi = pl.program_id(0)
    ci = pl.program_id(1)

    base = ci * tile_c
    col_ids = base + jax.lax.broadcasted_iota(jnp.int32, (block_q, tile_c), 1)
    invalid = col_ids >= Lc
    if exclude_self:
        row_ids = row0 + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, tile_c), 0
        )
        invalid = invalid | (col_ids == row_ids)

    def _restore_inf(d):
        # Masked candidates carry the finite _BIG inside the selection
        # (the k-pass knockout needs +inf strictly above the mask value);
        # the dense oracle reports them as +inf, so restore inf on the
        # way out — only reachable in the degenerate k == Lc case where
        # a masked self is selected.
        return jnp.where(d >= _BIG, jnp.float32(jnp.inf), d)

    D = jnp.zeros((block_q, tile_c), dist_dtype)
    t_is, t_ds = [], []
    for e in range(E_max):  # static unroll: E_max <= 20
        D = _acc_sq(D, vq_ref[e, :], vc_ref[e, :], dist_dtype)
        Dm = jnp.where(invalid, _BIG, D.astype(jnp.float32))
        t_i, t_d = _kpass_select(Dm, base, k, tile_c)  # affine ids
        if single_tile:
            idx_ref[e] = t_i
            dist_ref[e] = _restore_inf(t_d)
        else:
            t_is.append(t_i)
            t_ds.append(t_d)

    if not single_tile:
        # One batched (E_max, block_q, k) seed/fold per tile — the merge
        # network broadcasts over leading dims, so folding every E at
        # once costs one network instead of E_max of them.
        T_i, T_d = jnp.stack(t_is), jnp.stack(t_ds)

        @pl.when(ci == 0)
        def _seed():
            idx_s[...] = T_i
            dist_s[...] = T_d

        @pl.when(ci != 0)
        def _fold():
            m_i, m_d = merge_topk_sorted(idx_s[...], dist_s[...], T_i, T_d, k)
            idx_s[...] = m_i
            dist_s[...] = m_d

        @pl.when(ci == pl.num_programs(1) - 1)
        def _flush():
            idx_ref[...] = idx_s[...]
            dist_ref[...] = _restore_inf(dist_s[...])


def knn_topk_stream_pallas(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    block_q: int = 128,
    tile_c: int = 512,
    interpret: bool = True,
    dist_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Raw streaming pallas_call wrapper (padding via ops.knn_topk_streaming).

    VMEM per program is stream_vmem_bytes(...) — flat in Lc — so library
    length is bounded by HBM, not by the 16 MB VMEM budget.  tile_c is
    clamped up to an 8-aligned width >= k (the per-tile partial sort
    needs k real columns available) and down to the padded library width
    (a tile covering Lc is one direct selection — the small-library fast
    case the calibrator exploits).
    """
    E_max = Vq.shape[0]
    Lc = Vc.shape[1]
    if k > Lc:
        raise ValueError(f"k={k} exceeds candidate count Lc={Lc}")
    tile_c = max(-(-k // 8) * 8, min(tile_c, pl.cdiv(Lc, 8) * 8))
    n_c = pl.cdiv(Lc, tile_c)
    # Balance tile widths under the cap (same tile count, 8-aligned
    # ceil(Lc / n_c) width) so the grid pays O(8 * n_c) padded columns
    # instead of a whole ragged tail tile.
    tile_c = max(-(-k // 8) * 8, pl.cdiv(pl.cdiv(Lc, n_c), 8) * 8)
    Vc_p = jnp.pad(Vc, ((0, 0), (0, n_c * tile_c - Lc)))

    def call_split(Vq_p, row0, rows_pad, bq):
        shapes = stream_block_shapes(E_max, k, bq, tile_c)
        kernel = functools.partial(
            knn_topk_stream_kernel,
            E_max=E_max,
            k=k,
            Lc=Lc,
            block_q=bq,
            tile_c=tile_c,
            exclude_self=exclude_self,
            row0=row0,
            dist_dtype=dist_dtype,
            single_tile=n_c == 1,
        )
        # one-tile grids select directly into the outputs: no running
        # top-k scratch to allocate or flush
        scratch = [] if n_c == 1 else [
            pltpu.VMEM(shapes["scratch_idx"], jnp.int32),
            pltpu.VMEM(shapes["scratch_dist"], jnp.float32),
        ]
        return pl.pallas_call(
            kernel,
            grid=(rows_pad // bq, n_c),
            in_specs=[
                pl.BlockSpec(shapes["vq"], lambda i, j: (0, i)),
                pl.BlockSpec(shapes["vc_tile"], lambda i, j: (0, j)),
            ],
            out_specs=[
                pl.BlockSpec(shapes["out"], lambda i, j: (0, i, 0)),
                pl.BlockSpec(shapes["out"], lambda i, j: (0, i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((E_max, rows_pad, k), jnp.int32),
                jax.ShapeDtypeStruct((E_max, rows_pad, k), jnp.float32),
            ],
            scratch_shapes=scratch,
            interpret=interpret,
        )(Vq_p, Vc_p)

    return _over_query_splits(Vq, block_q, call_split)


# ------------------------------------------- prefix snapshots (DESIGN SS9)
def prefix_block_shapes(
    E_hi: int, nb: int, k: int, block_q: int, tile_c: int
) -> dict[str, tuple[int, ...]]:
    """Per-program block/scratch shapes of the prefix-snapshot kernel —
    like ``stream_block_shapes``, a pure function of the static tile
    parameters: neither the library length nor the NUMBER of library
    sizes appears (the size count S only scales the output allocation
    and the grid's boundary-tile count), so prefix snapshots inherit the
    flat-VMEM guarantee."""
    return {
        "vq": (E_hi, block_q),
        "vc_tile": (E_hi, tile_c),
        "ids": (1, tile_c),
        "out": (1, nb, block_q, k),
        "scratch_idx": (nb, block_q, k),
        "scratch_dist": (nb, block_q, k),
        "tile_topk": (block_q, k),
        "merge": (block_q, 2 * _next_pow2(k)),
    }


def knn_topk_prefix_kernel(
    slot_ref,  # scalar-prefetch (n_tiles,) snapshot-slot table; consumed
    # by the output index_map, unused in the body.
    vq_ref,
    vc_ref,
    ids_ref,
    idx_ref,
    dist_ref,
    idx_s,
    dist_s,
    *,
    buckets: tuple[int, ...],
    k: int,
    block_q: int,
    tile_c: int,
    exclude_self: bool,
    row0: int = 0,
    dist_dtype=jnp.float32,
):
    """In-kernel prefix snapshots: the streaming running merge over
    candidate tiles pre-clipped at library-size boundaries.

    Candidates arrive pre-gathered in sweep order (the ``col_ids``
    permutation already applied by the wrapper); ``ids_ref`` carries each
    lane's ORIGINAL candidate id, -1 on the padding that fills clipped
    segments up to ``tile_c`` (masked to _BIG like out-of-range columns,
    so padding never enters a table — every prefix holds >= k real
    candidates by the wrapper's validation).  Selection runs only at the
    ``buckets`` dimensions into an (nb, block_q, k) sorted running
    scratch.  Every program writes the carry to its snapshot slot's
    output block; consecutive tiles of one slot revisit the same block
    (one VMEM-resident write), and the LAST writer is the tile ending
    exactly at the slot's library-size boundary — so each emitted slot
    holds the prefix table, bit-identical to the one-sweep jnp builder
    and the per-size rebuild oracle.
    """
    qi = pl.program_id(0)
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        idx_s[...] = jnp.zeros(idx_s.shape, jnp.int32)
        dist_s[...] = jnp.full(dist_s.shape, jnp.inf, jnp.float32)

    ids = jnp.broadcast_to(ids_ref[...], (block_q, tile_c))
    invalid = ids < 0
    if exclude_self:
        row_ids = row0 + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, tile_c), 0
        )
        invalid = invalid | (ids == row_ids)

    want = set(buckets)
    D = jnp.zeros((block_q, tile_c), dist_dtype)
    t_is, t_ds = [], []
    for e in range(buckets[-1]):  # static unroll: E <= 20
        D = _acc_sq(D, vq_ref[e, :], vc_ref[e, :], dist_dtype)
        if e + 1 not in want:
            continue
        Dm = jnp.where(invalid, _BIG, D.astype(jnp.float32))
        t_i, t_d = _kpass_select(Dm, ids, k, tile_c)
        t_is.append(t_i)
        t_ds.append(t_d)
    # One batched (nb, block_q, k) fold per tile (see the stream kernel):
    # slot si merges with bucket si's tile selection; the first tile's
    # merge against the inf-seeded scratch is an identity.
    T_i, T_d = jnp.stack(t_is), jnp.stack(t_ds)
    m_i, m_d = merge_topk_sorted(idx_s[...], dist_s[...], T_i, T_d, k)
    idx_s[...] = m_i
    dist_s[...] = m_d

    idx_ref[0] = idx_s[...]
    # Restore +inf on masked-selected entries (see the stream kernel's
    # flush) so the carry matches the jnp builders bit-for-bit even in
    # the degenerate k == prefix-size case.
    d = dist_s[...]
    dist_ref[0] = jnp.where(d >= _BIG, jnp.float32(jnp.inf), d)


def knn_topk_prefix_pallas(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    buckets: tuple[int, ...],
    lib_sizes: tuple[int, ...],
    block_q: int = 128,
    tile_c: int = 512,
    interpret: bool = True,
    dist_dtype=jnp.float32,
    col_ids: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Raw prefix-snapshot pallas_call wrapper (DESIGN.md SS9).

    Returns (idx, sq_dists), each (S, len(buckets), Lq, k) — the same
    contract (and bit-identical output) as
    core/knn.knn_tables_prefix_streaming / _rebuild.

    The ragged clipped tiles of ``_prefix_tile_bounds`` (the SAME bounds
    the jnp one-sweep builder uses) are made uniform for the Pallas grid
    by a static gather plan: position j of padded tile t maps to sweep
    position bounds[t].start + j (through the optional ``col_ids``
    permutation) or to a masked -1 lane.  Each tile's snapshot SLOT (the
    library size whose boundary closes the tile's segment) rides in as a
    scalar-prefetch vector the output index_map indexes (index maps may
    not capture array constants), so no dynamic stores are needed.
    """
    from repro.core import knn as core_knn

    E_rows, Lq = Vq.shape
    Lc = Vc.shape[1]
    core_knn._check_prefix_args(
        Lq, Lc, k, exclude_self, buckets, lib_sizes, E_rows, col_ids
    )
    E_hi = buckets[-1]
    nb = len(buckets)
    S = len(lib_sizes)
    need = k + 1 if exclude_self else k
    tile_c = -(-max(tile_c, need) // 8) * 8
    bounds = core_knn._prefix_tile_bounds(lib_sizes, tile_c)
    n_tiles = len(bounds)

    pos = np.zeros((n_tiles, tile_c), np.int32)
    valid = np.zeros((n_tiles, tile_c), bool)
    slots = np.zeros((n_tiles,), np.int32)
    for t, (start, stop) in enumerate(bounds):
        w = stop - start
        pos[t, :w] = np.arange(start, stop, dtype=np.int32)
        valid[t, :w] = True
        slots[t] = bisect.bisect_left(lib_sizes, stop)
    posj = jnp.asarray(pos)
    validj = jnp.asarray(valid)
    if col_ids is None:
        ids_val = posj
    else:
        ids_val = jnp.take(col_ids.astype(jnp.int32), posj)
    ids = jnp.where(validj, ids_val, -1)
    gather = jnp.where(validj, ids_val, 0).reshape(-1)
    Vc_g = jnp.take(Vc[:E_hi], gather, axis=1)  # (E_hi, n_tiles * tile_c)
    slot_arr = jnp.asarray(slots)

    def call_split(Vq_p, row0, rows_pad, bq):
        shapes = prefix_block_shapes(E_hi, nb, k, bq, tile_c)
        kernel = functools.partial(
            knn_topk_prefix_kernel,
            buckets=tuple(buckets),
            k=k,
            block_q=bq,
            tile_c=tile_c,
            exclude_self=exclude_self,
            row0=row0,
            dist_dtype=dist_dtype,
        )
        out_spec = pl.BlockSpec(
            shapes["out"], lambda i, j, slots: (slots[j], 0, i, 0)
        )
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(rows_pad // bq, n_tiles),
                in_specs=[
                    pl.BlockSpec(shapes["vq"], lambda i, j, slots: (0, i)),
                    pl.BlockSpec(
                        shapes["vc_tile"], lambda i, j, slots: (0, j)
                    ),
                    pl.BlockSpec(shapes["ids"], lambda i, j, slots: (j, 0)),
                ],
                out_specs=[out_spec, out_spec],
                scratch_shapes=[
                    pltpu.VMEM(shapes["scratch_idx"], jnp.int32),
                    pltpu.VMEM(shapes["scratch_dist"], jnp.float32),
                ],
            ),
            out_shape=[
                jax.ShapeDtypeStruct((S, nb, rows_pad, k), jnp.int32),
                jax.ShapeDtypeStruct((S, nb, rows_pad, k), jnp.float32),
            ],
            interpret=interpret,
        )(slot_arr, Vq_p, Vc_g, ids)

    return _over_query_splits(Vq[:E_hi], block_q, call_split, q_axis=2)
