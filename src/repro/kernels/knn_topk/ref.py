"""Pure-jnp oracle for the knn_topk Pallas kernel.

Semantics: for every embedding dimension E in 1..E_max, the k nearest
candidate points of every query point under the cumulative delay-embedding
squared distance.  Accumulation is termwise-sequential over lags — the same
fp order the kernel uses — so oracle and kernel agree to tie-breaking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def knn_topk_ref(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
) -> tuple[jax.Array, jax.Array]:
    """Vq: (E_max, Lq), Vc: (E_max, Lc) -> idx, sqd each (E_max, Lq, k)."""
    E_max, Lq = Vq.shape
    Lc = Vc.shape[1]
    self_mask = (
        jnp.eye(Lq, Lc, dtype=bool)
        if exclude_self
        else jnp.zeros((Lq, Lc), bool)
    )

    from repro.core.knn import _acc_sq

    def step(D, vs):
        vq, vc = vs
        # Same pinned square-then-add rounding (FMA guard) as the kernels
        # and the core builders — one shared definition.
        D = _acc_sq(D, vq, vc, jnp.float32)
        Dm = jnp.where(self_mask, jnp.inf, D)
        neg_d, idx = jax.lax.top_k(-Dm, k)
        return D, (idx.astype(jnp.int32), -neg_d)

    _, (indices, sq_dists) = jax.lax.scan(
        step, jnp.zeros((Lq, Lc), jnp.float32), (Vq, Vc)
    )
    return indices, sq_dists


def knn_topk_stream_ref(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    tile_c: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the STREAMING kernel: the core candidate-tiled builder
    (core/knn.py), which carries the same running sorted-merge in a
    lax.scan and is itself bit-identical to the dense lax.top_k oracle
    (:func:`knn_topk_ref`) — so the streaming kernel is checked against
    an independently-tiled implementation, not a copy of its own
    merge."""
    from repro.core import knn

    return knn.knn_tables_all_E_streaming(
        Vq, Vc, k, exclude_self=exclude_self, tile_c=tile_c
    )
