"""jit'd public wrappers for the knn_topk streaming kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret as _default_interpret
from repro.kernels.knn_topk.knn_topk import (
    knn_topk_prefix_pallas,
    knn_topk_stream_pallas,
)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "exclude_self", "block_q", "tile_c", "dist_dtype", "interpret"
    ),
)
def knn_topk_streaming(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool = False,
    block_q: int = 128,
    tile_c: int = 512,
    dist_dtype: str = "float32",
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Multi-E kNN tables, STREAMING layout (DESIGN.md SS8).

    Vq: (E_max, Lq) query lag matrix, Vc: (E_max, Lc) candidates.
    Returns (idx, sq_dists) each (E_max, Lq, k): for every embedding
    dimension E=e+1, the k nearest candidates under the dimension-E
    delay-embedding distance.  The grid streams candidate tiles of width
    ``tile_c`` through a running sorted VMEM top-k (partial merge
    network), so per-program VMEM is independent of the library length
    (see knn_topk.stream_vmem_bytes) and arbitrary Lc fits the chip.
    dist_dtype: distance-accumulator dtype (EDMConfig.dist_dtype;
    bfloat16 halves the tile working set, merge keys stay float32).
    Bit-identical to the dense jnp oracle (ref.knn_topk_ref).
    """
    if exclude_self and Vq.shape != Vc.shape:
        raise ValueError("exclude_self requires query set == candidate set")
    if interpret is None:
        interpret = _default_interpret()
    return knn_topk_stream_pallas(
        Vq, Vc, k, exclude_self, block_q=block_q, tile_c=tile_c,
        interpret=interpret, dist_dtype=jnp.dtype(dist_dtype),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "exclude_self", "buckets", "lib_sizes", "block_q", "tile_c",
        "dist_dtype", "interpret",
    ),
)
def knn_topk_prefix(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    buckets: tuple[int, ...],
    lib_sizes: tuple[int, ...],
    block_q: int = 128,
    tile_c: int = 512,
    dist_dtype: str = "float32",
    interpret: bool | None = None,
    col_ids: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """In-kernel prefix-snapshot kNN tables (DESIGN.md SS9).

    Returns (idx, sq_dists), each (len(lib_sizes), len(buckets), Lq, k):
    for every library prefix size Ls (candidate sweep positions [0, Ls),
    optionally routed through the ``col_ids`` permutation) and every
    bucket dimension E, the k nearest candidates.  Candidate tiles are
    clipped at library-size boundaries and the running carry emitted at
    each boundary — ONE sweep over the largest library, bit-identical to
    core/knn.knn_tables_prefix_streaming and the per-size rebuild oracle.
    """
    if exclude_self and Vq.shape != Vc.shape:
        raise ValueError("exclude_self requires query set == candidate set")
    if interpret is None:
        interpret = _default_interpret()
    return knn_topk_prefix_pallas(
        Vq, Vc, k, exclude_self, tuple(buckets), tuple(lib_sizes),
        block_q=block_q, tile_c=tile_c, interpret=interpret,
        dist_dtype=jnp.dtype(dist_dtype), col_ids=col_ids,
    )
