"""jit'd public wrappers for the knn_topk kernels (slab + streaming)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret as _default_interpret
from repro.kernels.knn_topk.knn_topk import (
    knn_topk_pallas,
    knn_topk_stream_pallas,
)


@functools.partial(
    jax.jit,
    static_argnames=("k", "exclude_self", "block_q", "dist_dtype", "interpret"),
)
def knn_topk(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool = False,
    block_q: int = 128,
    dist_dtype: str = "float32",
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Multi-E kNN tables, SLAB layout (VMEM-resident (block_q, Lc) slab).

    Vq: (E_max, Lq) query lag matrix, Vc: (E_max, Lc) candidates.
    Returns (idx, sq_dists) each (E_max, Lq, k): for every embedding
    dimension E=e+1, the k nearest candidates under the dimension-E
    delay-embedding distance.  dist_dtype: distance-accumulator dtype
    (EDMConfig.dist_dtype; bfloat16 halves the slab working set, merge
    keys stay float32).
    """
    if exclude_self and Vq.shape != Vc.shape:
        raise ValueError("exclude_self requires query set == candidate set")
    if interpret is None:
        interpret = _default_interpret()
    return knn_topk_pallas(
        Vq, Vc, k, exclude_self, block_q=block_q, interpret=interpret,
        dist_dtype=jnp.dtype(dist_dtype),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "exclude_self", "block_q", "tile_c", "dist_dtype", "interpret"
    ),
)
def knn_topk_streaming(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool = False,
    block_q: int = 128,
    tile_c: int = 512,
    dist_dtype: str = "float32",
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Multi-E kNN tables, STREAMING layout (DESIGN.md SS8).

    Same contract and bit-identical output to :func:`knn_topk`, but the
    grid streams candidate tiles of width ``tile_c`` through a running
    VMEM top-k, so per-program VMEM is independent of the library length
    (see knn_topk.stream_vmem_bytes) and arbitrary Lc fits the chip.
    """
    if exclude_self and Vq.shape != Vc.shape:
        raise ValueError("exclude_self requires query set == candidate set")
    if interpret is None:
        interpret = _default_interpret()
    return knn_topk_stream_pallas(
        Vq, Vc, k, exclude_self, block_q=block_q, tile_c=tile_c,
        interpret=interpret, dist_dtype=jnp.dtype(dist_dtype),
    )
