"""jit'd public wrapper for the knn_topk kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels import default_interpret as _default_interpret
from repro.kernels.knn_topk.knn_topk import knn_topk_pallas


@functools.partial(
    jax.jit, static_argnames=("k", "exclude_self", "block_q", "interpret")
)
def knn_topk(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool = False,
    block_q: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Multi-E kNN tables.

    Vq: (E_max, Lq) query lag matrix, Vc: (E_max, Lc) candidates.
    Returns (idx, sq_dists) each (E_max, Lq, k): for every embedding
    dimension E=e+1, the k nearest candidates under the dimension-E
    delay-embedding distance.
    """
    if exclude_self and Vq.shape != Vc.shape:
        raise ValueError("exclude_self requires query set == candidate set")
    if interpret is None:
        interpret = _default_interpret()
    return knn_topk_pallas(
        Vq, Vc, k, exclude_self, block_q=block_q, interpret=interpret
    )
