"""LR schedules: cosine, WSD (warmup-stable-decay, MiniCPM arXiv:2404.06395),
constant-with-warmup.  Pure functions of the step counter."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str, lr: float, warmup: int, total: int):
    warmup = max(1, warmup)

    def cosine(step):
        w = jnp.minimum(step / warmup, 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        return lr * w * 0.5 * (1.0 + jnp.cos(jnp.pi * t))

    def wsd(step):
        w = jnp.minimum(step / warmup, 1.0)
        decay_start = int(0.9 * total)  # final 10%: exponential-ish decay
        t = jnp.clip((step - decay_start) / jnp.maximum(total - decay_start, 1), 0.0, 1.0)
        return lr * w * jnp.where(step < decay_start, 1.0, 0.5 ** (10.0 * t))

    def constant(step):
        return lr * jnp.minimum(step / warmup, 1.0)

    return {"cosine": cosine, "wsd": wsd, "constant": constant}[kind]
