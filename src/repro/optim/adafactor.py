"""Adafactor (Shazeer & Stern 2018): factored second moment for >=2D params
(row+col accumulators instead of a full moment tensor) — the optimizer of
choice for the 100B+ MoE archs where AdamW moments would not fit HBM."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(p) -> bool:
    return p.ndim >= 2


def init(params):
    def per_param(p):
        if _factored(p):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # row accum
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "acc": jax.tree.map(per_param, params),
        "count": jnp.zeros((), jnp.int32),
    }


def update(grads, state, params, lr, decay=0.8, eps=1e-30, clip_thresh=1.0, weight_decay=0.0):
    count = state["count"] + 1
    beta = 1.0 - count.astype(jnp.float32) ** (-decay)

    def upd(g, acc, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if _factored(p):
            vr = beta * acc["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * acc["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            new_acc = {"vr": vr, "vc": vc}
        else:
            vhat = beta * acc["v"] + (1 - beta) * g2
            new_acc = {"v": vhat}
        u = gf / jnp.sqrt(jnp.maximum(vhat, eps))
        # update clipping (RMS threshold)
        rms = jnp.sqrt(jnp.mean(u * u))
        u = u / jnp.maximum(1.0, rms / clip_thresh)
        step = lr * u
        if weight_decay > 0.0 and p.ndim >= 2:
            step = step + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype), new_acc

    out = jax.tree_util.tree_map(
        upd, grads, state["acc"], params,
        is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x),
    )
    is_pair = lambda x: isinstance(x, tuple)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
    new_acc = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
    return new_params, {"acc": new_acc, "count": count}
