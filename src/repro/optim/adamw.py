"""AdamW with decoupled weight decay and global-norm gradient clipping.

Moments live in a configurable dtype (fp32 default; bf16 for the very
large archs so optimizer state stays within HBM — see sharding policy)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(params, moment_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def update(grads, state, params, lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01):
    count = state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        step = lr * (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        if weight_decay > 0.0 and p.ndim >= 2:  # no decay on norms/biases
            step = step + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}
