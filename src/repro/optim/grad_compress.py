"""int8 gradient compression with error feedback (1-bit-Adam family,
arXiv:1811.03617 / 2102.02888 adapted to int8): an opt-in distributed-
optimization trick for the data-parallel all-reduce.

Under pure DP in shard_map, each worker quantizes its local gradient to
int8 with a per-tensor scale, all-reduces the int8 payload (8x less ICI
traffic — on the wire it rides psum as int32 partial sums, which real
deployments replace with an int8 ring via ppermute), dequantizes, and
keeps the quantization residual in an error-feedback buffer added to the
next step's gradient — preserving convergence (tested in
tests/test_train_loop.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_residual(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (int8 payload, scale, new error-feedback buffer)."""
    gf = g.astype(jnp.float32) + err
    q, scale = quantize(gf)
    new_err = gf - dequantize(q, scale)
    return q, scale, new_err


def compressed_psum(g: jax.Array, err: jax.Array, axis_names) -> tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce of one gradient tensor (inside
    shard_map over `axis_names`).  Returns (mean gradient f32, new err).

    Workers first agree on a SHARED scale (scalar pmax — negligible
    traffic); int32 partial sums of the int8 payloads are then exactly
    decodable, so the only error is local quantization, which the error-
    feedback buffer re-injects next step."""
    gf = g.astype(jnp.float32) + err
    gmax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_names)
    scale = gmax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_names)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
    return total.astype(jnp.float32) * scale / n, new_err


def init_error_buffers(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
