"""Streaming significance driver: rho maps -> validated causal graphs.

Runs the two statistical stages of DESIGN.md SS9 over the same
(row-chunk x col-tile) decomposition as phase 2, sharing its meshes,
ChunkStreamer, and TileWriter store:

  * CONVERGENCE — per row chunk, ONE prefix-snapshot table build yields
    bucketed kNN tables for every library size (nested random prefixes
    of the seeded subsampling permutation); per column tile the
    rho-vs-library-size curves reduce on device to the drho and
    monotonic-trend maps.
  * SURROGATE NULLS — per row chunk the full-library tables are rebuilt
    once (exactly phase 2's tables, so the null matches the observed
    statistic); per column tile every target contributes m surrogate
    futures batched along the target axis, and the per-pair empirical
    p-value (1 + #{null >= obs}) / (m + 1) is computed on device.
  * FDR + ASSEMBLY — empirical p-values take only m+1 distinct values,
    so the Benjamini–Hochberg threshold is computed EXACTLY from
    streamed per-value counts (no sort, no dense p array), and the
    significance-masked edge list is assembled row-streamed from the
    (memmapped) maps.

With ``out_dir`` set, blocks stream through TileWriters into the new
store artifacts ``rho_conv/`` (drho; trend.npy rides in the same dir),
``pvals/``, and ``edges/`` — no dense (N, N) host allocation beyond the
existing memmap assembly, and killed runs RESUME at the first chunk any
artifact is missing.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import ccm
from repro.core.pipeline import (
    _flat,
    _pad_rows,
    default_mesh,
    make_ccm_tables_fn_bucketed,
)
from repro.core.types import EDMConfig
from repro.data import store
from repro.data.store import TileWriter
from repro.inference import convergence, significance, surrogates
from repro.inference.types import SignificanceConfig, SignificanceResult
from repro.runtime.stream import ChunkStreamer


# ------------------------------------------------- shard_map'd chunk/tile fns
def make_conv_tables_fn(mesh, cfg: EDMConfig, plan, lib_sizes):
    """(chunk, L) sharded + subsampling permutation repl -> prefix tables
    (idx, w) each (chunk, S, nb, Lp, k) sharded on rows."""
    axes = _flat(mesh)
    tspec = P(axes, None, None, None, None)

    def local(rows, col_ids):
        return convergence.conv_block_tables(rows, cfg, plan, lib_sizes, col_ids)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axes, None), P(None)),
            out_specs=(tspec, tspec),
            check_rep=False,
        )
    )


def make_conv_tile_fn(mesh, cfg: EDMConfig):
    """seg_plan -> tile fn (memoized like make_ccm_tile_fn_bucketed):
    (prefix tables sharded; fut_tile repl) -> stacked (2, chunk, t)
    [drho; trend] sharded on rows."""
    axes = _flat(mesh)
    tspec = P(axes, None, None, None, None)

    @functools.lru_cache(maxsize=None)
    def for_plan(seg_plan):
        def local(idx, w, fut_tile):
            drho, trend = convergence.conv_block_tile(
                idx, w, fut_tile, cfg, seg_plan
            )
            return jnp.stack([drho, trend])

        return jax.jit(
            shard_map(
                local,
                mesh=mesh,
                in_specs=(tspec, tspec, P(None, None)),
                out_specs=P(None, axes, None),
                check_rep=False,
            )
        )

    return for_plan


def make_null_tile_fn(mesh, cfg: EDMConfig, m: int):
    """seg_plan -> tile fn: (full-library tables sharded; surrogate
    futures repl; observed rho block sharded) -> pvals (chunk, t)."""
    axes = _flat(mesh)
    tspec = P(axes, None, None, None)

    @functools.lru_cache(maxsize=None)
    def for_plan(seg_plan):
        seg_plan_m = tuple((b, cnt * m) for b, cnt in seg_plan)

        def local(idx, w, fut_surr, rho_obs):
            return significance.null_block_pvals(
                idx, w, fut_surr, rho_obs, cfg, seg_plan_m, m
            )

        return jax.jit(
            shard_map(
                local,
                mesh=mesh,
                in_specs=(tspec, tspec, P(None, None), P(axes, None)),
                out_specs=P(axes, None),
                check_rep=False,
            )
        )

    return for_plan


# ------------------------------------------------------------------- driver
def _writer(out_dir, name: str, N: int, order) -> TileWriter:
    w = TileWriter(f"{out_dir}/{name}", N)
    w.ensure_col_order(order)
    return w


def _check_resume_config(out_dir, sig: SignificanceConfig) -> None:
    """Pin the null-model parameters of a store to its first run.

    Coverage is the only thing the resume path inspects, so without this
    guard a rerun with different surrogates/seed/lib_sizes would silently
    reuse blocks computed under the OLD parameters (and stamp the new
    ones into meta.json).  alpha is deliberately NOT pinned: it only
    enters the BH pass and edge mask, which are recomputed every run.
    """
    import json
    import pathlib

    f = pathlib.Path(out_dir) / "significance.json"
    want = {
        "lib_sizes": list(sig.lib_sizes),
        "n_surrogates": sig.n_surrogates,
        "surrogate": sig.surrogate,
        "seed": sig.seed,
    }
    if f.exists():
        have = json.loads(f.read_text())
        if have != want:
            raise ValueError(
                f"resume config mismatch in {out_dir}: store was written "
                f"with {have} but this run asks for {want}; use a fresh "
                "--out dir (only --fdr may change across resumes)"
            )
        return
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(json.dumps(want))


def run_significance(
    ts: np.ndarray,
    optE: np.ndarray,
    rho: np.ndarray,
    cfg: EDMConfig,
    sig: SignificanceConfig,
    mesh=None,
    out_dir: Optional[str] = None,
    progress: bool = False,
) -> SignificanceResult:
    """Validate a causal map: convergence statistics, surrogate p-values,
    and the BH-FDR significance-masked edge list.

    ts: (N, L) series; optE: (N,) phase-1 optimal embeddings; rho: the
    (N, N) observed causal map (memmap fine — read O(chunk x N) at a
    time).  Stages run per sig.lib_sizes / sig.n_surrogates; with
    ``out_dir`` every artifact streams through a TileWriter (resumable)
    and the returned maps are disk-backed memmaps.
    """
    if mesh is None:
        mesh = default_mesh()
    N, L = ts.shape
    Lp = cfg.n_points(L)
    do_conv = bool(sig.lib_sizes)
    do_null = sig.n_surrogates > 0
    if not (do_conv or do_null):
        return SignificanceResult(None, None, None, None)
    if do_conv and sig.lib_sizes[-1] > Lp:
        raise ValueError(
            f"lib_sizes[-1]={sig.lib_sizes[-1]} exceeds the {Lp} embeddable "
            f"library points of length-{L} series (E_max={cfg.E_max}, "
            f"tau={cfg.tau}, Tp={cfg.Tp})"
        )
    m = sig.n_surrogates
    chunk = mesh.size * cfg.lib_block
    T = cfg.target_tile or N

    optE = np.asarray(optE, np.int32)
    plan, order = ccm.make_bucket_plan(optE)
    tile_plans = ccm.make_tile_plans(plan, T)
    ts_fut = np.asarray(ccm.all_futures(jnp.asarray(ts), cfg))

    key = jax.random.PRNGKey(sig.seed)
    perm_key, surr_key = jax.random.split(key)
    col_ids = convergence.subsample_permutation(perm_key, Lp)

    conv_tables_fn = conv_tile_for = full_tables_fn = null_tile_for = None
    if do_conv:
        conv_tables_fn = make_conv_tables_fn(mesh, cfg, plan, sig.lib_sizes)
        conv_tile_for = make_conv_tile_fn(mesh, cfg)
    if do_null:
        full_tables_fn = make_ccm_tables_fn_bucketed(mesh, cfg, plan)
        null_tile_for = make_null_tile_fn(mesh, cfg, m)

    # ---- outputs: streaming writers or (small-N) dense host maps -------
    if out_dir is not None:
        _check_resume_config(out_dir, sig)
        conv_w = _writer(out_dir, "rho_conv", N, order) if do_conv else None
        trend_w = _writer(out_dir, "rho_trend", N, order) if do_conv else None
        pv_w = _writer(out_dir, "pvals", N, order) if do_null else None
        writers = [w for w in (conv_w, trend_w, pv_w) if w is not None]
        cov = writers[0].covered()
        for w in writers[1:]:
            cov &= w.covered()
        plan_chunks = writers[0].chunk_plan(chunk, covered=cov)
        drho_map = trend_map = pv_map = None
    else:
        conv_w = trend_w = pv_w = None
        drho_map = np.zeros((N, N), np.float32) if do_conv else None
        trend_map = np.zeros((N, N), np.float32) if do_conv else None
        pv_map = np.ones((N, N), np.float32) if do_null else None
        plan_chunks = [(r, min(chunk, N - r)) for r in range(0, N, chunk)]

    # Streaming BH inputs: empirical p-values take the m+1 discrete values
    # j/(m+1), so per-value counts (diagonal excluded) determine the BH
    # threshold exactly — no dense p array, no sort (DESIGN.md SS9).
    p_counts = np.zeros(m + 1, np.int64)

    def drain(tag, block):
        kind, row0, c0, valid = tag
        cols = order[c0 : c0 + block.shape[-1]]
        last = c0 + block.shape[-1] >= N
        if kind == "conv":
            drho_b, trend_b = block[0][:valid], block[1][:valid]
            if conv_w is not None:
                conv_w.write_tile(row0, c0, drho_b, commit=last)
                trend_w.write_tile(row0, c0, trend_b, commit=last)
            else:
                drho_map[row0 : row0 + valid, cols] = drho_b
                trend_map[row0 : row0 + valid, cols] = trend_b
        else:
            pv_b = block[:valid]
            offdiag = cols[None, :] != (row0 + np.arange(valid))[:, None]
            p_counts[:] += np.bincount(
                np.rint(pv_b[offdiag] * (m + 1)).astype(np.int64) - 1,
                minlength=m + 1,
            )
            if pv_w is not None:
                pv_w.write_tile(row0, c0, pv_b, commit=last)
            else:
                pv_map[row0 : row0 + valid, cols] = pv_b
        # One line per row chunk: the pval drain when the null stage runs
        # (it lands last), else the conv drain.
        if progress and last and (kind == "pval" or not do_null):
            print(f"significance rows {row0}..{row0 + valid} / {N}")

    resumed_rows = N - sum(v for _, v in plan_chunks)
    with ChunkStreamer(drain, depth=cfg.stream_depth) as streamer:
        for row0, valid in plan_chunks:
            rows = _pad_rows(ts[row0 : row0 + chunk], chunk)
            rows_j = jnp.asarray(rows)
            rho_chunk = np.asarray(rho[row0 : row0 + valid]) if do_null else None
            if do_conv:
                cidx, cw = conv_tables_fn(rows_j, col_ids)
            if do_null:
                fidx, fw = full_tables_fn(rows_j)
            for c0, seg_plan in tile_plans:
                c1 = min(c0 + T, N)
                orig = order[c0:c1]
                if do_conv:
                    fut_tile = jnp.asarray(ts_fut[orig])
                    streamer.submit(
                        ("conv", row0, c0, valid),
                        conv_tile_for(seg_plan)(cidx, cw, fut_tile),
                    )
                if do_null:
                    # Regenerated per (chunk, tile) like _phase2_tiled's
                    # fut_tile upload: keeping every tile's (t*m, Lp)
                    # surrogate batch resident would defeat the tiling at
                    # scale, and the per-tile FFT is dominated by the m x
                    # lookup work the tile triggers anyway.
                    fut_surr = surrogates.surrogate_futures(
                        surr_key, jnp.asarray(ts[orig]),
                        jnp.asarray(orig.astype(np.int32)),
                        n=m, kind=sig.surrogate, cfg=cfg,
                    )
                    rho_obs = jnp.asarray(
                        _pad_rows(rho_chunk[:, orig], chunk)
                    )
                    streamer.submit(
                        ("pval", row0, c0, valid),
                        null_tile_for(seg_plan)(fidx, fw, fut_surr, rho_obs),
                    )

    # ---- assembly ------------------------------------------------------
    meta_common = {
        "lib_sizes": list(sig.lib_sizes),
        "n_surrogates": m,
        "surrogate": sig.surrogate,
        "seed": sig.seed,
    }
    if conv_w is not None:
        conv_w.commit()
        trend_w.commit()
        drho_map = conv_w.assemble(mmap_path=conv_w.dir / "data.npy")
        trend_map = trend_w.assemble(mmap_path=trend_w.dir / "data.npy")
        store.save_meta(
            conv_w.dir, drho_map.shape, drho_map.dtype,
            {**meta_common, "stat": "delta_rho", "trend": "../rho_trend"},
        )
        store.save_meta(
            trend_w.dir, trend_map.shape, trend_map.dtype,
            {**meta_common, "stat": "monotonic_trend"},
        )

    p_threshold, edges = 0.0, None
    n_tests = int(p_counts.sum())
    if do_null:
        if pv_w is not None:
            pv_w.commit()
            pv_map = pv_w.assemble(mmap_path=pv_w.dir / "data.npy")
        if resumed_rows:
            # Chunks already durable from a prior run never re-drained, so
            # their p-value counts are recovered from the assembled map.
            n_tests, p_counts = _recount_pvals(pv_map, m)
        p_threshold, _ = significance.bh_threshold_discrete(
            p_counts, m, sig.alpha
        )
        # p-values in the map are float32 of j/(m+1); cut at the MIDPOINT
        # between discrete levels so the threshold level itself is always
        # included regardless of f32-vs-f64 rounding of the quotient.
        p_cut = p_threshold + 0.5 / (m + 1) if p_threshold > 0 else 0.0
        edges = significance.assemble_edges(
            pv_map, rho, drho_map, trend_map, p_cut
        )
        if pv_w is not None:
            store.save_meta(
                pv_w.dir, pv_map.shape, pv_map.dtype,
                {**meta_common, "alpha": sig.alpha,
                 "p_threshold": p_threshold, "n_tests": n_tests},
            )
            edir = pv_w.dir.parent / "edges"
            edir.mkdir(parents=True, exist_ok=True)
            np.save(edir / "data.npy", edges)
            store.save_meta(
                edir, edges.shape, edges.dtype.str,
                {**meta_common, "alpha": sig.alpha,
                 "p_threshold": p_threshold, "n_tests": n_tests,
                 "n_edges": int(edges.shape[0]),
                 "fields": list(edges.dtype.names)},
            )
        if progress:
            print(
                f"BH-FDR alpha={sig.alpha}: p* = {p_threshold:.4g} over "
                f"{n_tests} tests -> {0 if edges is None else len(edges)} edges"
            )

    return SignificanceResult(
        drho=drho_map, trend=trend_map, pvals=pv_map, edges=edges,
        p_threshold=p_threshold, n_tests=n_tests,
    )


def _recount_pvals(pv_map: np.ndarray, m: int) -> tuple[int, np.ndarray]:
    """Row-streamed per-value p counts (diagonal excluded) from a
    (memmapped) p-value map — the resume path of the discrete BH pass."""
    N = pv_map.shape[0]
    counts = np.zeros(m + 1, np.int64)
    for i in range(N):
        row = np.asarray(pv_map[i])
        idx = np.rint(np.delete(row, i) * (m + 1)).astype(np.int64) - 1
        counts += np.bincount(idx, minlength=m + 1)
    return int(counts.sum()), counts
