"""Streaming significance driver: rho maps -> validated causal graphs.

Runs the two statistical stages of DESIGN.md SS9 over the same
(row-chunk x col-tile) decomposition as phase 2, sharing its meshes,
ChunkStreamer, and TileWriter store:

  * CONVERGENCE — per row chunk, ONE prefix-snapshot table build yields
    bucketed kNN tables for every library size (nested random prefixes
    of the seeded subsampling permutation); per column tile the
    rho-vs-library-size curves reduce on device to the drho and
    monotonic-trend maps.
  * SURROGATE NULLS — per row chunk the full-library tables are rebuilt
    once (exactly phase 2's tables, so the null matches the observed
    statistic); per column tile every target contributes m surrogate
    futures batched along the target axis, and the per-pair empirical
    p-value (1 + #{null >= obs}) / (m + 1) is computed on device.
  * FDR + ASSEMBLY — empirical p-values take only m+1 distinct values,
    so the Benjamini–Hochberg threshold is computed EXACTLY from
    streamed per-value counts (no sort, no dense p array), and the
    significance-masked edge list is assembled row-streamed from the
    (memmapped) maps.

With ``out_dir`` set, blocks stream through TileWriters into the new
store artifacts ``rho_conv/`` (drho; trend.npy rides in the same dir),
``pvals/``, and ``edges/`` — no dense (N, N) host allocation beyond the
existing memmap assembly, and killed runs RESUME at the first chunk any
artifact is missing.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import ccm
from repro.core.pipeline import (
    _flat,
    _pad_rows,
    default_mesh,
    make_ccm_tables_fn_bucketed,
)
from repro.core.types import EDMConfig
from repro.data import store
from repro.data.store import TileWriter
from repro.inference import convergence, significance, surrogates
from repro.inference.types import SignificanceConfig, SignificanceResult
from repro.runtime import history, telemetry
from repro.runtime.stream import ChunkStreamer


# ------------------------------------------------- shard_map'd chunk/tile fns
def make_conv_tables_fn(mesh, cfg: EDMConfig, plan, lib_sizes):
    """(chunk, L) sharded + subsampling permutation repl -> prefix tables
    (idx, w) each (chunk, S, nb, Lp, k) sharded on rows."""
    axes = _flat(mesh)
    tspec = P(axes, None, None, None, None)

    def local(rows, col_ids):
        return convergence.conv_block_tables(rows, cfg, plan, lib_sizes, col_ids)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axes, None), P(None)),
            out_specs=(tspec, tspec),
            check_rep=False,
        )
    )


def make_conv_tile_fn(mesh, cfg: EDMConfig):
    """seg_plan -> tile fn (memoized like make_ccm_tile_fn_bucketed):
    (prefix tables sharded; fut_tile repl) -> stacked (2, chunk, t)
    [drho; trend] sharded on rows."""
    axes = _flat(mesh)
    tspec = P(axes, None, None, None, None)

    @functools.lru_cache(maxsize=None)
    def for_plan(seg_plan):
        def local(idx, w, fut_tile):
            drho, trend = convergence.conv_block_tile(
                idx, w, fut_tile, cfg, seg_plan
            )
            return jnp.stack([drho, trend])

        return jax.jit(
            shard_map(
                local,
                mesh=mesh,
                in_specs=(tspec, tspec, P(None, None)),
                out_specs=P(None, axes, None),
                check_rep=False,
            )
        )

    return for_plan


def make_null_tile_fn(mesh, cfg: EDMConfig, m: int):
    """seg_plan -> tile fn: (full-library tables sharded; surrogate
    futures repl; observed rho block sharded) -> pvals (chunk, t)."""
    axes = _flat(mesh)
    tspec = P(axes, None, None, None)

    @functools.lru_cache(maxsize=None)
    def for_plan(seg_plan):
        seg_plan_m = tuple((b, cnt * m) for b, cnt in seg_plan)

        def local(idx, w, fut_surr, rho_obs):
            return significance.null_block_pvals(
                idx, w, fut_surr, rho_obs, cfg, seg_plan_m, m
            )

        return jax.jit(
            shard_map(
                local,
                mesh=mesh,
                in_specs=(tspec, tspec, P(None, None), P(axes, None)),
                out_specs=P(axes, None),
                check_rep=False,
            )
        )

    return for_plan


# ----------------------------------------------------- chunk-level compute
class SignificanceChunkRunner:
    """Compiled per-chunk significance compute — convergence tables and
    tile reductions, surrogate-null batches — decoupled from chunk
    PLANNING and finalization, so a fleet worker (DESIGN.md SS10) can
    run exactly the row chunks it claims from the work queue while the
    single-process driver runs them all.

    Everything that must agree across workers for bit-identity is
    derived here from shared inputs only: the bucket plan and column
    order from phase-1 optE, the subsampling permutation and surrogate
    keys from sig.seed (per-target fold_in — independent of chunk/tile
    geometry).  ``run`` then computes any subset of row chunks and
    drains blocks through the caller's sink.
    """

    def __init__(self, ts: np.ndarray, optE: np.ndarray, cfg: EDMConfig,
                 sig: SignificanceConfig, mesh=None):
        if mesh is None:
            mesh = default_mesh()
        self.mesh, self.cfg, self.sig = mesh, cfg, sig
        N, L = ts.shape
        self.N = N
        Lp = cfg.n_points(L)
        self.do_conv = bool(sig.lib_sizes)
        self.do_null = sig.n_surrogates > 0
        if self.do_conv and sig.lib_sizes[-1] > Lp:
            raise ValueError(
                f"lib_sizes[-1]={sig.lib_sizes[-1]} exceeds the {Lp} "
                f"embeddable library points of length-{L} series "
                f"(E_max={cfg.E_max}, tau={cfg.tau}, Tp={cfg.Tp})"
            )
        self.m = sig.n_surrogates
        self.chunk = mesh.size * cfg.lib_block
        self.T = cfg.target_tile or N
        self.ts = ts
        optE = np.asarray(optE, np.int32)
        self.plan, self.order = ccm.make_bucket_plan(optE)
        self.tile_plans = ccm.make_tile_plans(self.plan, self.T)
        self.ts_fut = np.asarray(ccm.all_futures(jnp.asarray(ts), cfg))

        key = jax.random.PRNGKey(sig.seed)
        perm_key, self.surr_key = jax.random.split(key)
        self.col_ids = convergence.subsample_permutation(perm_key, Lp)

        self.conv_tables_fn = self.conv_tile_for = None
        self.full_tables_fn = self.null_tile_for = None
        if self.do_conv:
            self.conv_tables_fn = make_conv_tables_fn(
                mesh, cfg, self.plan, sig.lib_sizes
            )
            self.conv_tile_for = make_conv_tile_fn(mesh, cfg)
        if self.do_null:
            self.full_tables_fn = make_ccm_tables_fn_bucketed(
                mesh, cfg, self.plan
            )
            self.null_tile_for = make_null_tile_fn(mesh, cfg, self.m)

    def run(self, plan_chunks, rho, drain, on_chunk=None) -> None:
        """Compute the given (row0, valid) chunks, draining ("conv"|
        "pval", row0, c0, valid)-tagged blocks in submission order.

        rho: the observed causal map (memmap fine; only read when the
        null stage is active).  on_chunk(row0) fires before each chunk's
        dispatch — fleet workers renew their unit lease there.
        """
        N, T, m, sig, cfg = self.N, self.T, self.m, self.sig, self.cfg
        order, ts, ts_fut = self.order, self.ts, self.ts_fut
        cache0 = telemetry.compile_cache_entries()
        with ChunkStreamer(drain, depth=cfg.stream_depth,
                           stage="sig") as streamer:
            for row0, valid in plan_chunks:
                if on_chunk is not None:
                    on_chunk(row0)
                with telemetry.span(
                    "sig", "chunk", row0=row0, rows=valid,
                    chunk_rows=self.chunk, tile=T,
                    conv=self.do_conv, null=self.do_null,
                ):
                    with telemetry.span("sig", "device_put", row0=row0):
                        rows = _pad_rows(
                            ts[row0 : row0 + self.chunk], self.chunk
                        )
                        rows_j = jnp.asarray(rows)
                    rho_chunk = (
                        np.asarray(rho[row0 : row0 + valid])
                        if self.do_null else None
                    )
                    if self.do_conv:
                        cidx, cw = self.conv_tables_fn(rows_j, self.col_ids)
                    if self.do_null:
                        fidx, fw = self.full_tables_fn(rows_j)
                    for c0, seg_plan in self.tile_plans:
                        c1 = min(c0 + T, N)
                        orig = order[c0:c1]
                        if self.do_conv:
                            fut_tile = jnp.asarray(ts_fut[orig])
                            streamer.submit(
                                ("conv", row0, c0, valid),
                                self.conv_tile_for(seg_plan)(
                                    cidx, cw, fut_tile
                                ),
                            )
                        if self.do_null:
                            # Regenerated per (chunk, tile) like
                            # _phase2_tiled's fut_tile upload: keeping every
                            # tile's (t*m, Lp) surrogate batch resident would
                            # defeat the tiling at scale, and the per-tile
                            # FFT is dominated by the m x lookup work the
                            # tile triggers anyway.
                            fut_surr = surrogates.surrogate_futures(
                                self.surr_key, jnp.asarray(ts[orig]),
                                jnp.asarray(orig.astype(np.int32)),
                                n=m, kind=sig.surrogate, cfg=cfg,
                            )
                            rho_obs = jnp.asarray(
                                _pad_rows(rho_chunk[:, orig], self.chunk)
                            )
                            streamer.submit(
                                ("pval", row0, c0, valid),
                                self.null_tile_for(seg_plan)(
                                    fidx, fw, fut_surr, rho_obs
                                ),
                            )
        telemetry.emit_compile_cache("sig", cache0)


# ------------------------------------------------------------------- driver
def _writer(
    out_dir, name: str, N: int, order, writer_id: str | None = None
) -> TileWriter:
    w = TileWriter(f"{out_dir}/{name}", N, writer_id=writer_id, stage="sig")
    w.ensure_col_order(order)
    return w


def make_store_drain(N: int, conv_w, trend_w, pv_w):
    """Tile-store sink for :meth:`SignificanceChunkRunner.run` blocks —
    the ONE place that knows the block routing (conv stacks [drho;
    trend], pval is flat) and the per-chunk commit-batching policy.
    Shared by the in-process driver and fleet workers so the on-disk
    layout can never diverge between them (W=1 ≡ W=4 byte-identity)."""

    def drain(tag, block):
        kind, row0, c0, valid = tag
        last = c0 + block.shape[-1] >= N
        if kind == "conv":
            conv_w.write_tile(row0, c0, block[0][:valid], commit=last)
            trend_w.write_tile(row0, c0, block[1][:valid], commit=last)
        else:
            pv_w.write_tile(row0, c0, block[:valid], commit=last)

    return drain


def _check_resume_config(out_dir, sig: SignificanceConfig) -> None:
    """Pin the null-model parameters of a store to its first run.

    Coverage is the only thing the resume path inspects, so without this
    guard a rerun with different surrogates/seed/lib_sizes would silently
    reuse blocks computed under the OLD parameters (and stamp the new
    ones into meta.json).  alpha is deliberately NOT pinned: it only
    enters the BH pass and edge mask, which are recomputed every run.
    """
    import json
    import pathlib

    f = pathlib.Path(out_dir) / "significance.json"
    want = {
        "lib_sizes": list(sig.lib_sizes),
        "n_surrogates": sig.n_surrogates,
        "surrogate": sig.surrogate,
        "seed": sig.seed,
    }
    if f.exists():
        have = json.loads(f.read_text())
        if have != want:
            raise ValueError(
                f"resume config mismatch in {out_dir}: store was written "
                f"with {have} but this run asks for {want}; use a fresh "
                "--out dir (only --fdr may change across resumes)"
            )
        return
    f.parent.mkdir(parents=True, exist_ok=True)
    # Atomic + idempotent: concurrent fleet workers write identical bytes.
    store.atomic_write_text(f, json.dumps(want))


def run_significance(
    ts: np.ndarray,
    optE: np.ndarray,
    rho: np.ndarray,
    cfg: EDMConfig,
    sig: SignificanceConfig,
    mesh=None,
    out_dir: Optional[str] = None,
    progress: bool = False,
) -> SignificanceResult:
    """Validate a causal map: convergence statistics, surrogate p-values,
    and the BH-FDR significance-masked edge list.

    ts: (N, L) series; optE: (N,) phase-1 optimal embeddings; rho: the
    (N, N) observed causal map (memmap fine — read O(chunk x N) at a
    time).  Stages run per sig.lib_sizes / sig.n_surrogates; with
    ``out_dir`` every artifact streams through a TileWriter (resumable)
    and the returned maps are disk-backed memmaps.
    """
    if not (sig.lib_sizes or sig.n_surrogates > 0):
        return SignificanceResult(None, None, None, None)
    runner = SignificanceChunkRunner(ts, optE, cfg, sig, mesh)
    N = runner.N
    do_conv, do_null = runner.do_conv, runner.do_null
    m, chunk, order = runner.m, runner.chunk, runner.order

    # ---- outputs: streaming writers or (small-N) dense host maps -------
    if out_dir is not None:
        from repro.runtime import integrity

        # Same stamp-or-verify as run_causal_inference: sig params are
        # pinned separately below, the fingerprint pins (data, cfg).
        integrity.stamp_fingerprint(
            out_dir, integrity.fingerprint_of(np.asarray(ts, np.float32), cfg)
        )
        _check_resume_config(out_dir, sig)
        conv_w = _writer(out_dir, "rho_conv", N, order) if do_conv else None
        trend_w = _writer(out_dir, "rho_trend", N, order) if do_conv else None
        pv_w = _writer(out_dir, "pvals", N, order) if do_null else None
        writers = [w for w in (conv_w, trend_w, pv_w) if w is not None]
        cov = writers[0].covered()
        for w in writers[1:]:
            cov &= w.covered()
        plan_chunks = writers[0].chunk_plan(chunk, covered=cov)
        drho_map = trend_map = pv_map = None
    else:
        conv_w = trend_w = pv_w = None
        drho_map = np.zeros((N, N), np.float32) if do_conv else None
        trend_map = np.zeros((N, N), np.float32) if do_conv else None
        pv_map = np.ones((N, N), np.float32) if do_null else None
        plan_chunks = [(r, min(chunk, N - r)) for r in range(0, N, chunk)]

    # Streaming BH inputs: empirical p-values take the m+1 discrete values
    # j/(m+1), so per-value counts (diagonal excluded) determine the BH
    # threshold exactly — no dense p array, no sort (DESIGN.md SS9).
    p_counts = np.zeros(m + 1, np.int64)

    store_drain = (
        make_store_drain(N, conv_w, trend_w, pv_w) if out_dir is not None
        else None
    )

    def drain(tag, block):
        kind, row0, c0, valid = tag
        cols = order[c0 : c0 + block.shape[-1]]
        last = c0 + block.shape[-1] >= N
        if kind == "pval":
            pv_b = block[:valid]
            offdiag = cols[None, :] != (row0 + np.arange(valid))[:, None]
            p_counts[:] += np.bincount(
                np.rint(pv_b[offdiag] * (m + 1)).astype(np.int64) - 1,
                minlength=m + 1,
            )
        if store_drain is not None:
            store_drain(tag, block)
        elif kind == "conv":
            drho_map[row0 : row0 + valid, cols] = block[0][:valid]
            trend_map[row0 : row0 + valid, cols] = block[1][:valid]
        else:
            pv_map[row0 : row0 + valid, cols] = block[:valid]
        # One line per row chunk: the pval drain when the null stage runs
        # (it lands last), else the conv drain.
        if progress and last and (kind == "pval" or not do_null):
            print(f"significance rows {row0}..{row0 + valid} / {N}")

    resumed_rows = N - sum(v for _, v in plan_chunks)
    runner.run(plan_chunks, rho, drain)

    # ---- assembly ------------------------------------------------------
    if out_dir is not None:
        for w in writers:
            w.commit()
        # Chunks already durable from a prior run never re-drained, so
        # their p-value counts are recovered from the assembled map
        # (p_counts=None -> recount inside the finalizer).
        result = _finalize_store(
            cfg, sig, rho, conv_w=conv_w, trend_w=trend_w, pv_w=pv_w,
            p_counts=None if resumed_rows else p_counts, progress=progress,
        )
        # Run finished: append its summary to the run history (no-op
        # when telemetry is off and EDM_HISTORY unset; DESIGN.md SS13).
        history.record_run(out_dir)
        return result

    p_threshold, edges = 0.0, None
    n_tests = int(p_counts.sum())
    if do_null:
        p_threshold, p_cut = _bh_cut(p_counts, m, sig.alpha)
        edges = significance.assemble_edges(
            pv_map, rho, drho_map, trend_map, p_cut
        )
        if progress:
            print(
                f"BH-FDR alpha={sig.alpha}: p* = {p_threshold:.4g} over "
                f"{n_tests} tests -> {0 if edges is None else len(edges)} edges"
            )

    return SignificanceResult(
        drho=drho_map, trend=trend_map, pvals=pv_map, edges=edges,
        p_threshold=p_threshold, n_tests=n_tests,
    )


def _bh_cut(p_counts: np.ndarray, m: int, alpha: float) -> tuple[float, float]:
    """(p_threshold, edge cut).  p-values in the map are float32 of
    j/(m+1); the cut sits at the MIDPOINT between discrete levels so the
    threshold level itself is always included regardless of f32-vs-f64
    rounding of the quotient."""
    p_threshold, _ = significance.bh_threshold_discrete(p_counts, m, alpha)
    p_cut = p_threshold + 0.5 / (m + 1) if p_threshold > 0 else 0.0
    return p_threshold, p_cut


def _finalize_store(
    cfg: EDMConfig,
    sig: SignificanceConfig,
    rho: np.ndarray,
    *,
    conv_w: Optional[TileWriter],
    trend_w: Optional[TileWriter],
    pv_w: Optional[TileWriter],
    p_counts: Optional[np.ndarray] = None,
    progress: bool = False,
) -> SignificanceResult:
    """Assembly + exact discrete BH + edge list over store artifacts.

    Idempotent, and runnable by a process that computed NONE of the
    chunks (the fleet's ``finalize`` unit): with ``p_counts=None`` the
    per-value histogram is recovered by row-streaming the assembled
    p map — the recount-on-resume path, now also the recount-on-
    distributed-completion path (workers' streamed counts only ever
    cover their own chunks, so a fleet always recounts).
    """
    with telemetry.span("finalize", "store"):
        return _finalize_store_inner(
            cfg, sig, rho, conv_w=conv_w, trend_w=trend_w, pv_w=pv_w,
            p_counts=p_counts, progress=progress,
        )


def _finalize_store_inner(
    cfg: EDMConfig,
    sig: SignificanceConfig,
    rho: np.ndarray,
    *,
    conv_w: Optional[TileWriter],
    trend_w: Optional[TileWriter],
    pv_w: Optional[TileWriter],
    p_counts: Optional[np.ndarray] = None,
    progress: bool = False,
) -> SignificanceResult:
    m = sig.n_surrogates
    meta_common = {
        "lib_sizes": list(sig.lib_sizes),
        "n_surrogates": m,
        "surrogate": sig.surrogate,
        "seed": sig.seed,
    }
    drho_map = trend_map = pv_map = None
    if conv_w is not None:
        drho_map = conv_w.assemble(mmap_path=conv_w.dir / "data.npy")
        trend_map = trend_w.assemble(mmap_path=trend_w.dir / "data.npy")
        store.save_meta(
            conv_w.dir, drho_map.shape, drho_map.dtype,
            {**meta_common, "stat": "delta_rho", "trend": "../rho_trend"},
        )
        store.save_meta(
            trend_w.dir, trend_map.shape, trend_map.dtype,
            {**meta_common, "stat": "monotonic_trend"},
        )

    p_threshold, edges, n_tests = 0.0, None, 0
    if pv_w is not None:
        pv_map = pv_w.assemble(mmap_path=pv_w.dir / "data.npy")
        if p_counts is None:
            n_tests, p_counts = _recount_pvals(pv_map, m)
        else:
            n_tests = int(p_counts.sum())
        p_threshold, p_cut = _bh_cut(p_counts, m, sig.alpha)
        edges = significance.assemble_edges(
            pv_map, rho, drho_map, trend_map, p_cut
        )
        sig_meta = {**meta_common, "alpha": sig.alpha,
                    "p_threshold": p_threshold, "n_tests": n_tests}
        store.save_meta(pv_w.dir, pv_map.shape, pv_map.dtype, sig_meta)
        edir = pv_w.dir.parent / "edges"
        edir.mkdir(parents=True, exist_ok=True)
        store.save_npy_checksummed(edir / "data.npy", edges, fault="edges")
        store.save_meta(
            edir, edges.shape, edges.dtype.str,
            {**sig_meta, "n_edges": int(edges.shape[0]),
             "fields": list(edges.dtype.names)},
        )
        if progress:
            print(
                f"BH-FDR alpha={sig.alpha}: p* = {p_threshold:.4g} over "
                f"{n_tests} tests -> {len(edges)} edges"
            )

    return SignificanceResult(
        drho=drho_map, trend=trend_map, pvals=pv_map, edges=edges,
        p_threshold=p_threshold, n_tests=n_tests,
    )


def finalize_significance(
    out_dir: str,
    rho: np.ndarray,
    cfg: EDMConfig,
    sig: SignificanceConfig,
    progress: bool = False,
) -> SignificanceResult:
    """The fleet's ``finalize`` work unit (DESIGN.md SS10): assemble the
    (multi-writer) significance store, recount the p-value histogram,
    and write the BH-FDR edge list — by whichever worker claims the
    unit, none of whose own chunks need be among the blocks.  Idempotent
    (a finalizer crash just reruns it); raises if any artifact's
    coverage is still incomplete."""
    N = rho.shape[0]
    do_conv = bool(sig.lib_sizes)
    do_null = sig.n_surrogates > 0
    conv_w = TileWriter(f"{out_dir}/rho_conv", N) if do_conv else None
    trend_w = TileWriter(f"{out_dir}/rho_trend", N) if do_conv else None
    pv_w = TileWriter(f"{out_dir}/pvals", N) if do_null else None
    for w in (conv_w, trend_w, pv_w):
        if w is not None and not w.covered().all():
            raise ValueError(
                f"{w.dir} is incomplete ({int((~w.covered()).sum())} rows "
                "uncovered): finalize ran before every sig unit was done"
            )
    result = _finalize_store(
        cfg, sig, rho, conv_w=conv_w, trend_w=trend_w, pv_w=pv_w,
        p_counts=None, progress=progress,
    )
    # The finalize claimer is the run's single history writer: one
    # summary record per finished run, replaced (not duplicated) when an
    # elastic resume or heal re-finalizes (DESIGN.md SS13).
    history.record_run(out_dir)
    return result


def _recount_pvals(pv_map: np.ndarray, m: int) -> tuple[int, np.ndarray]:
    """Row-streamed per-value p counts (diagonal excluded) from a
    (memmapped) p-value map — the resume path of the discrete BH pass."""
    N = pv_map.shape[0]
    counts = np.zeros(m + 1, np.int64)
    for i in range(N):
        row = np.asarray(pv_map[i])
        idx = np.rint(np.delete(row, i) * (m + 1)).astype(np.int64) - 1
        counts += np.bincount(idx, minlength=m + 1)
    return int(counts.sum()), counts
