"""Batched CCM convergence diagnostic (DESIGN.md SS9).

CCM only evidences causation when cross-map skill CONVERGES — rho grows
with library size (paper SSII-B) — but the per-pair subsampling loop the
seed carried rebuilt a full kNN table per (pair, size): O(S) full sweeps
per pair, unusable beyond a handful of pairs.  This module batches the
diagnostic with the same machinery as phase 2:

  * per library row, ONE prefix-snapshot table build
    (`Engine.knn_tables_prefix`) yields tables for every library size in
    a single candidate sweep — libraries are nested prefixes of a seeded
    random permutation of the library points;
  * per size, the rho row comes from the existing bucketed `ccm_lookup`
    path (tables for the distinct-optE bucket set, targets grouped per
    bucket), so curves for ALL N targets of a row cost S lookups;
  * the (S,) curve per pair is reduced on device to two statistics:
    drho = rho_max - rho_min and a Kendall-style monotonic-trend score.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engines
from repro.core import ccm, embedding, knn
from repro.core.stats import pearson, simplex_weights
from repro.core.types import EDMConfig


def subsample_permutation(key: jax.Array, Lp: int) -> jax.Array:
    """The seeded library-subsampling permutation (one per run): prefixes
    of it are the nested random libraries of every convergence build."""
    return jax.random.permutation(key, Lp).astype(jnp.int32)


def convergence_stats(curves: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Reduce rho-vs-library-size curves to (drho, trend).

    curves: (S, ...) rho at each library size.  drho = rho_max - rho_min
    (the paper-style convergence magnitude); trend = the Kendall-style
    monotonic-trend score mean_{s<t} sign(rho_t - rho_s) in [-1, 1]
    (+1 = strictly increasing with library size — the causal signature;
    ~0 = flat/noise; -1 = strictly decreasing).
    """
    S = curves.shape[0]
    drho = jnp.max(curves, axis=0) - jnp.min(curves, axis=0)
    i, j = np.triu_indices(S, 1)
    trend = jnp.mean(jnp.sign(curves[j] - curves[i]), axis=0)
    return drho, trend


def conv_row_tables(
    x: jax.Array,
    cfg: EDMConfig,
    plan: ccm.BucketPlan,
    lib_sizes: tuple[int, ...],
    col_ids: jax.Array | None,
) -> tuple[jax.Array, jax.Array]:
    """Prefix-snapshot tables + simplex weights for ONE library series.

    Returns (idx, w), each (S, len(buckets), Lp, k): slice s is the
    bucketed table set of the size-lib_sizes[s] nested library, directly
    consumable by `ccm.ccm_row_lookup_bucketed` per size.
    """
    eng = engines.get_engine(cfg.engine)
    Lp = cfg.n_points(x.shape[0])
    kb = ccm._bucket_k(cfg, plan)
    ccm._check_k(kb, Lp, cfg, "conv_row_tables")
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    idx, sqd = eng.knn_tables_prefix(
        V, V, kb, buckets=plan.buckets, lib_sizes=lib_sizes,
        exclude_self=cfg.exclude_self, cfg=cfg, col_ids=col_ids,
    )
    # tables_with_weights_bucketed broadcasts over the leading S axis.
    return knn.tables_with_weights_bucketed(idx, sqd, plan.buckets)


def conv_block_tables(
    lib_block: jax.Array,
    cfg: EDMConfig,
    plan: ccm.BucketPlan,
    lib_sizes: tuple[int, ...],
    col_ids: jax.Array | None,
):
    """(B, L) -> (idx, w) each (B, S, len(buckets), Lp, k)."""
    return jax.vmap(
        lambda x: conv_row_tables(x, cfg, plan, lib_sizes, col_ids)
    )(lib_block)


def conv_block_tile(
    idx: jax.Array,
    w: jax.Array,
    fut_tile: jax.Array,
    cfg: EDMConfig,
    seg_plan: tuple[tuple[int, int], ...],
) -> tuple[jax.Array, jax.Array]:
    """(drho, trend) of one (row-chunk x col-tile) block.

    idx/w: (B, S, nb, Lp, k) prefix tables; fut_tile: (t, Lp)
    bucket-sorted target futures.  Returns (drho, trend), each (B, t);
    the (S, t) curves per row never leave the device.
    """
    S = idx.shape[1]

    def per_row(i_r, w_r):
        curves = jnp.stack(
            [
                ccm.ccm_row_lookup_bucketed(i_r[s], w_r[s], fut_tile, cfg, seg_plan)
                for s in range(S)
            ]
        )
        return convergence_stats(curves)

    drho, trend = jax.vmap(per_row)(idx, w)
    return drho, trend


def ccm_convergence_pair(
    x: jax.Array,
    y: jax.Array,
    E: int,
    lib_sizes: tuple[int, ...],
    cfg: EDMConfig,
    key: jax.Array,
) -> jax.Array:
    """Convergence curve of ONE pair through the batched prefix path.

    Cross-maps y from x's manifold at embedding dimension E over nested
    random libraries (prefixes of the key-seeded permutation).  Returns
    rho (S,).  This is the engine behind the deprecated
    `repro.core.ccm.ccm_convergence` wrapper.
    """
    eng = engines.get_engine(cfg.engine)
    Lp = cfg.n_points(x.shape[0])
    perm = subsample_permutation(key, Lp)
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    y_fut = embedding.future_values(y, cfg.E_max, cfg.tau, cfg.Tp, Lp)
    idx, sqd = eng.knn_tables_prefix(
        V, V, E + 1, buckets=(E,), lib_sizes=tuple(lib_sizes),
        exclude_self=cfg.exclude_self, cfg=cfg, col_ids=perm,
    )
    w = simplex_weights(sqd, E + 1)
    preds = jax.vmap(lambda i, ww: knn.simplex_forecast(i[0], ww[0], y_fut))(
        idx, w
    )
    return pearson(y_fut[None, :], preds)
