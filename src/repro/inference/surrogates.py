"""Surrogate null models, batched along a surrogate axis (DESIGN.md SS9).

Two generators, both (key, (L,) series, n) -> (n, L) surrogates:

  * random_shuffle  — i.i.d. permutations: preserves the amplitude
    distribution only.  The strictest null (destroys ALL temporal
    structure), appropriate when any dynamics at all should count as
    signal.
  * phase_randomized — FFT phase randomization: preserves the power
    spectrum (hence the full linear autocorrelation) while destroying
    nonlinear/state-dependent structure.  The standard CCM null: a
    linear-stochastic twin of the target that no manifold can
    cross-map, so surviving skill evidences nonlinear coupling.

`surrogate_futures` is the batched entry the significance pipeline
consumes: per-target keys are derived by fold_in on the GLOBAL series
id, so the null draw for a pair is independent of chunk/tile geometry
and reproducible from the single run seed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import embedding


def random_shuffle(key: jax.Array, x: jax.Array, n: int) -> jax.Array:
    """(L,) -> (n, L) independent random permutations of x."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: jax.random.permutation(k, x))(keys)


def phase_randomized(key: jax.Array, x: jax.Array, n: int) -> jax.Array:
    """(L,) -> (n, L) FFT phase-randomized surrogates of x.

    Every surrogate has BIT-the-same rfft magnitudes as x (the power
    spectrum is preserved exactly up to the irfft round trip): magnitudes
    are kept, phases of the strictly-positive-frequency bins are
    replaced by i.i.d. uniform draws.  The DC bin — and, for even L, the
    Nyquist bin — must stay real for the inverse transform to be a real
    series, so those bins keep their ORIGINAL complex value (a random
    sign flip would change the mean / alternating component).
    """
    L = x.shape[-1]
    X = jnp.fft.rfft(x)
    nf = X.shape[-1]
    keep = jnp.zeros((nf,), bool).at[0].set(True)
    if L % 2 == 0:
        keep = keep.at[nf - 1].set(True)
    keys = jax.random.split(key, n)
    phases = jax.vmap(
        lambda k: jax.random.uniform(
            k, (nf,), minval=0.0, maxval=2.0 * jnp.pi
        )
    )(keys)
    Xs = jnp.where(
        keep[None, :],
        X[None, :],
        jnp.abs(X)[None, :] * jnp.exp(1j * phases),
    )
    return jnp.fft.irfft(Xs, n=L).astype(x.dtype)


_GENERATORS = {"shuffle": random_shuffle, "phase": phase_randomized}


@functools.partial(jax.jit, static_argnames=("n", "kind", "cfg"))
def surrogate_futures(
    key: jax.Array,
    ts_rows: jax.Array,
    series_ids: jax.Array,
    n: int,
    kind: str,
    cfg,
) -> jax.Array:
    """Null-model target futures for a tile of series.

    ts_rows: (t, L) raw target series; series_ids: (t,) GLOBAL series
    ids (the fold_in salt).  Returns (t * n, Lp) future-value rows —
    target 0's n surrogates first, then target 1's, ... — i.e. exactly
    the layout of a bucket-sorted column tile whose every segment count
    is scaled by n, so the batch streams through the same
    ccm_lookup path as the real targets (DESIGN.md SS9).
    """
    gen = _GENERATORS[kind]
    L = ts_rows.shape[-1]
    Lp = cfg.n_points(L)

    def per_series(x, sid):
        surr = gen(jax.random.fold_in(key, sid), x, n)  # (n, L)
        return jax.vmap(
            lambda s: embedding.future_values(s, cfg.E_max, cfg.tau, cfg.Tp, Lp)
        )(surr)

    fut = jax.vmap(per_series)(ts_rows, series_ids)  # (t, n, Lp)
    return fut.reshape(-1, Lp)
