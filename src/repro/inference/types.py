"""Configuration and result types of the causal-significance subsystem
(DESIGN.md SS9)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

SURROGATE_KINDS = ("phase", "shuffle")


@dataclasses.dataclass(frozen=True)
class SignificanceConfig:
    """One significance pass over a causal map (DESIGN.md SS9).

    Attributes:
      lib_sizes: ascending library sizes of the convergence diagnostic —
        nested prefixes of a seeded random permutation of the library
        points.  Empty = skip the convergence stage.
      n_surrogates: null-model surrogates per target series.  0 = skip
        the surrogate/p-value stage.
      alpha: Benjamini–Hochberg FDR level for the edge mask.
      surrogate: null model — "phase" (FFT phase-randomized: preserves
        the power spectrum / linear autocorrelation, destroys nonlinear
        coupling) or "shuffle" (random permutation: preserves only the
        amplitude distribution).
      seed: single root seed; one jax.random key derived from it drives
        BOTH the convergence subsampling permutation and every surrogate
        draw (per-target fold_in, so results are independent of chunk or
        tile geometry).
    """

    lib_sizes: tuple[int, ...] = ()
    n_surrogates: int = 20
    alpha: float = 0.05
    surrogate: str = "phase"
    seed: int = 0

    def __post_init__(self):
        if list(self.lib_sizes) != sorted(set(self.lib_sizes)):
            raise ValueError(
                f"lib_sizes must be ascending and distinct: {self.lib_sizes}"
            )
        if self.n_surrogates < 0:
            raise ValueError("n_surrogates must be >= 0")
        if not (0.0 < self.alpha <= 1.0):
            raise ValueError(f"alpha={self.alpha} must be in (0, 1]")
        if self.surrogate not in SURROGATE_KINDS:
            raise ValueError(
                f"surrogate={self.surrogate!r}; known: {SURROGATE_KINDS}"
            )


#: dtype of one row of the persisted edge list (edges/data.npy): src
#: CCM-causes dst (src = target/column axis, dst = library/row axis of
#: the rho map — rho[dst, src] is the cross-map skill backing the edge).
EDGE_DTYPE = np.dtype(
    [
        ("src", np.int32),
        ("dst", np.int32),
        ("rho", np.float32),
        ("drho", np.float32),
        ("trend", np.float32),
        ("pval", np.float32),
    ]
)


@dataclasses.dataclass
class SignificanceResult:
    """Output of :func:`repro.inference.pipeline.run_significance`.

    drho/trend are the convergence statistic maps (rho_max - rho_min and
    the Kendall-style monotonic-trend score of the rho-vs-library-size
    curve); pvals the per-pair surrogate p-values; edges the
    FDR-surviving edge list (EDGE_DTYPE).  Maps may be disk-backed
    memmaps when an output store was used; entries are None when the
    corresponding stage was skipped.
    """

    drho: Optional[np.ndarray]
    trend: Optional[np.ndarray]
    pvals: Optional[np.ndarray]
    edges: Optional[np.ndarray]
    p_threshold: float = 0.0
    n_tests: int = 0
