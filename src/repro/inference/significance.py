"""Surrogate p-values, BH-FDR control, and causal-edge assembly
(DESIGN.md SS9).

At whole-brain scale a raw-rho threshold drowns in multiple comparisons
(N^2 - N simultaneous tests); large-scale network inference needs
surrogate null distributions with FDR-corrected testing (Novelli et al.
2019).  The pipeline here: per-pair empirical p-values against the
surrogate null, one Benjamini–Hochberg pass across the whole map, and a
significance-masked edge list as the persisted causal graph.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ccm
from repro.core.types import EDMConfig
from repro.inference.types import EDGE_DTYPE


def null_block_pvals(
    idx: jax.Array,
    w: jax.Array,
    fut_surr: jax.Array,
    rho_obs: jax.Array,
    cfg: EDMConfig,
    seg_plan_m: tuple[tuple[int, int], ...],
    m: int,
) -> jax.Array:
    """Per-pair surrogate p-values of one (row-chunk x col-tile) block.

    idx/w: (B, nb, Lp, k) FULL-library bucketed tables (the same tables
    phase 2 used, so the null matches the observed statistic exactly);
    fut_surr: (t*m, Lp) surrogate futures in surrogate_futures layout;
    rho_obs: (B, t) observed rho block; seg_plan_m: the tile's bucket
    seg_plan with every count scaled by m.  Returns pvals (B, t) with the
    standard +1 correction: p = (1 + #{null >= obs}) / (m + 1), so the
    smallest attainable p is 1/(m+1) — never an impossible zero.
    """
    null = jax.vmap(
        lambda i_r, w_r: ccm.ccm_row_lookup_bucketed(
            i_r, w_r, fut_surr, cfg, seg_plan_m
        )
    )(idx, w)  # (B, t*m)
    null = null.reshape(null.shape[0], -1, m)
    exceed = jnp.sum(null >= rho_obs[..., None], axis=-1)
    return (1.0 + exceed) / (m + 1.0)


# ------------------------------------------------------------------ BH-FDR
def bh_threshold(pvals: np.ndarray, alpha: float) -> tuple[float, int]:
    """Benjamini–Hochberg rejection threshold over a flat p-value array.

    Returns (p_star, n_tests): reject every p <= p_star, where p_star is
    the largest p_(i) with p_(i) <= alpha * i / n (0.0 when nothing
    passes — then p <= 0.0 rejects nothing, as empirical p-values are
    strictly positive).
    """
    p = np.sort(np.asarray(pvals, np.float64).ravel())
    n = p.size
    if n == 0:
        return 0.0, 0
    crit = alpha * np.arange(1, n + 1) / n
    ok = np.nonzero(p <= crit)[0]
    return (float(p[ok[-1]]), n) if ok.size else (0.0, n)


def bh_threshold_discrete(
    counts: np.ndarray, m: int, alpha: float
) -> tuple[float, int]:
    """BH threshold from per-value COUNTS of discrete empirical p-values.

    Surrogate p-values take only the m+1 values j/(m+1), j = 1..m+1, so
    ``counts[j-1] = #{p == j/(m+1)}`` determines the BH pass exactly: a
    tied run of value v is accepted iff v <= alpha * rank_max(v) / n
    (the most favourable rank of the run decides, as in the sorted
    scan), and the threshold is the largest accepted value.  Identical
    to :func:`bh_threshold` on the expanded array — asserted in tests —
    but streamable in O(m) memory with no sort: the whole-map FDR pass
    never materializes a dense p array (DESIGN.md SS9).
    """
    counts = np.asarray(counts, np.int64)
    if counts.shape != (m + 1,):
        raise ValueError(f"counts must have shape ({m + 1},): {counts.shape}")
    n = int(counts.sum())
    if n == 0:
        return 0.0, 0
    ranks = np.cumsum(counts)  # max rank of each tied value run
    values = np.arange(1, m + 2) / (m + 1.0)
    ok = np.nonzero((counts > 0) & (values <= alpha * ranks / n))[0]
    return (float(values[ok[-1]]), n) if ok.size else (0.0, n)


def bh_adjust(pvals: np.ndarray) -> np.ndarray:
    """BH-adjusted p-values (q-values), same shape as the input.

    q_(i) = min_{j >= i} p_(j) * n / j — the smallest FDR level at which
    p_(i) would be rejected.  Matches
    scipy.stats.false_discovery_control(method="bh") (the test oracle).
    """
    p = np.asarray(pvals, np.float64)
    flat = p.ravel()
    n = flat.size
    order = np.argsort(flat)
    scaled = flat[order] * n / np.arange(1, n + 1)
    q_sorted = np.minimum.accumulate(scaled[::-1])[::-1]
    q = np.empty(n, np.float64)
    q[order] = np.minimum(q_sorted, 1.0)
    return q.reshape(p.shape)


# ------------------------------------------------------------ edge assembly
def assemble_edges(
    pvals: np.ndarray,
    rho: np.ndarray,
    drho: np.ndarray | None,
    trend: np.ndarray | None,
    p_threshold: float,
) -> np.ndarray:
    """Significance-masked causal edge list (EDGE_DTYPE, sorted by pval).

    Row-streamed over the (possibly memmapped) maps — no dense boolean
    mask or second map copy; the diagonal (self-edges) is never tested.
    rho[i, j] high means j CCM-causes i, so an edge is (src=j, dst=i).
    """
    N = pvals.shape[0]
    parts = []
    for i in range(N):
        p_row = np.asarray(pvals[i])
        sig = p_row <= p_threshold
        sig[i] = False
        (js,) = np.nonzero(sig)
        if js.size == 0:
            continue
        e = np.empty(js.size, EDGE_DTYPE)
        e["src"] = js
        e["dst"] = i
        e["rho"] = np.asarray(rho[i])[js]
        e["drho"] = np.asarray(drho[i])[js] if drho is not None else 0.0
        e["trend"] = np.asarray(trend[i])[js] if trend is not None else 0.0
        e["pval"] = p_row[js]
        parts.append(e)
    if not parts:
        return np.empty(0, EDGE_DTYPE)
    edges = np.concatenate(parts)
    return edges[np.argsort(edges["pval"], kind="stable")]
