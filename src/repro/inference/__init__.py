"""Causal significance subsystem (DESIGN.md SS9): turns raw rho maps
into statistically validated causal graphs — one-sweep convergence CCM
over prefix-snapshot kNN tables, batched surrogate null models, and
FDR-controlled significance masking, scaled with the phase-2 machinery.
"""
from repro.inference.convergence import (
    ccm_convergence_pair,
    convergence_stats,
    subsample_permutation,
)
from repro.inference.pipeline import (
    SignificanceChunkRunner,
    finalize_significance,
    run_significance,
)
from repro.inference.significance import (
    assemble_edges,
    bh_adjust,
    bh_threshold,
    bh_threshold_discrete,
)
from repro.inference.surrogates import (
    phase_randomized,
    random_shuffle,
    surrogate_futures,
)
from repro.inference.types import (
    EDGE_DTYPE,
    SignificanceConfig,
    SignificanceResult,
)

__all__ = [
    "EDGE_DTYPE",
    "SignificanceChunkRunner",
    "SignificanceConfig",
    "SignificanceResult",
    "assemble_edges",
    "finalize_significance",
    "bh_adjust",
    "bh_threshold",
    "bh_threshold_discrete",
    "ccm_convergence_pair",
    "convergence_stats",
    "phase_randomized",
    "random_shuffle",
    "run_significance",
    "subsample_permutation",
    "surrogate_futures",
]
