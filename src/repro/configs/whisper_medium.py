"""whisper-medium [audio] — arXiv:2212.04356 (unverified).

24 encoder + 24 decoder layers, d=1024, 16 heads, LayerNorm, GELU,
learned positions; conv audio frontend is a stub (input_specs supplies
1500 precomputed frame embeddings).  Vocab 51,865 is padded to 51,968
(multiple of 256) for TP divisibility — DESIGN.md SS6.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio",
        n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, d_ff=4096, vocab_size=51865,
        qkv_bias=True, norm="layernorm", pos="learned", mlp_act="gelu",
        n_frontend_tokens=1500,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke", family="audio",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=512,
        qkv_bias=True, norm="layernorm", pos="learned", mlp_act="gelu",
        n_frontend_tokens=16, dtype="float32", vocab_pad_multiple=8,
    )
