"""qwen2-1.5b [dense] — arXiv:2407.10671 (hf-verified).

GQA 12H/2KV with QKV bias, d_head=128 (> d_model/n_heads: Qwen2 uses
fixed 128 head dim)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab_size=151936, d_head=128,
        qkv_bias=True, rope_theta=1.0e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, d_head=16, qkv_bias=True,
        dtype="float32", vocab_pad_multiple=8,
    )
