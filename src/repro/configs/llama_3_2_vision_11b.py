"""llama-3.2-vision-11b [vlm] — hf:meta-llama/Llama-3.2-11B-Vision (unverified).

40 decoder layers, 8 of them gated cross-attention over image patch
embeddings (period 5); vision frontend is a stub (input_specs supplies
precomputed patch embeddings, 1601 tokens for 560px/14 + CLS).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=128256, rope_theta=5.0e5,
        cross_attn_period=5, n_frontend_tokens=1601,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b-smoke", family="vlm",
        n_layers=10, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, cross_attn_period=5,
        n_frontend_tokens=8, dtype="float32", vocab_pad_multiple=8,
    )
