"""grok-1-314b [moe] — hf:xai-org/grok-1 (unverified).

64L, d=6144, 48H/8KV GQA, 8 experts top-2, d_ff=32768 per expert,
GELU experts."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=32768, vocab_size=131072, d_head=128, mlp_act="gelu",
        n_experts=8, experts_per_tok=2,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, d_head=16, mlp_act="gelu",
        n_experts=4, experts_per_tok=2, moe_group_size=64,
        dtype="float32", vocab_pad_multiple=8,
    )
