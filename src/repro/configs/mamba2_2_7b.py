"""mamba2-2.7b [ssm] — arXiv:2405.21060 (unverified).

Attention-free SSD: 64 Mamba2 layers, d=2560, d_state=128, head_dim 64
(d_inner 5120 -> 80 SSD heads).  O(1) decode state => runs long_500k."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50280, d_head=1,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        pos="none",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=512, d_head=1,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
        pos="none", dtype="float32", vocab_pad_multiple=8,
    )
