"""zamba2-7b [hybrid] — arXiv:2411.15242 (unverified).

81 layers tiling the unit (mamba2, mamba2, shared-attention): 54 Mamba2
blocks + 27 applications of ONE shared attention+MLP block reading
concat(h, h0), with per-application LoRA adapters on q/k/v.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab_size=32000, d_head=112,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        hybrid_pattern=("m", "m", "a"), lora_rank=64,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke", family="hybrid",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, d_head=16,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
        hybrid_pattern=("m", "m", "a"), lora_rank=4,
        dtype="float32", vocab_pad_multiple=8,
    )
