"""minicpm-2b [dense] — arXiv:2404.06395 (hf-verified).

Llama-like, 36H full MHA (kv=36), tied embeddings, WSD schedule
(schedule lives in TrainConfig; arch itself is llama-like)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
        d_ff=5760, vocab_size=122753, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, tie_embeddings=True,
        dtype="float32", vocab_pad_multiple=8,
    )
