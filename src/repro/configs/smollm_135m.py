"""smollm-135m [dense] — hf:HuggingFaceTB/SmolLM-135M (hf-verified).

Llama-arch small: 30L, d=576, 9H/3KV, tied embeddings.  Also the
~100M-class model used by examples/train_lm.py end-to-end driver."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", family="dense",
        n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
        d_ff=1536, vocab_size=49152, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m-smoke", family="dense",
        n_layers=2, d_model=48, n_heads=3, n_kv_heads=3,
        d_ff=128, vocab_size=512, tie_embeddings=True,
        dtype="float32", vocab_pad_multiple=8,
    )
