"""qwen2.5-3b [dense] — hf:Qwen/Qwen2.5-3B (hf-verified).

GQA 16H/2KV with QKV bias, d_head=128."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family="dense",
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
        d_ff=11008, vocab_size=151936, d_head=128,
        qkv_bias=True, rope_theta=1.0e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, d_head=16, qkv_bias=True,
        dtype="float32", vocab_pad_multiple=8,
    )
