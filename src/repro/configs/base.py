"""Model / run configuration schema shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None
    # attention
    qkv_bias: bool = False
    rope_theta: float = 1.0e4
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    pos: str = "rope"  # rope | learned | none
    mlp_act: str = "swiglu"  # swiglu | gelu
    # moe
    n_experts: int = 0
    experts_per_tok: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024
    # ssm (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    # hybrid (zamba2): repeating unit of n_layers, e.g. ("m","m","a") —
    # "a" is the SHARED attention block (one param set + per-use LoRA)
    hybrid_pattern: Tuple[str, ...] = ()
    lora_rank: int = 0
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    n_frontend_tokens: int = 0  # audio frames / image patches (stub frontend)
    # vlm: a cross-attention block replaces every k-th decoder layer
    cross_attn_period: int = 0
    # misc
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    tie_embeddings: bool = False
    # scan-over-layers (compile-time friendly).  False unrolls the layer
    # loop — used by the dry-run's per-layer cost extrapolation, since XLA
    # cost_analysis counts while-loop bodies once (launch/dryrun.py).
    scan_layers: bool = True
    # attention implementation: "xla" (materialized S^2 logits) or
    # "chunked" (flash-style online-softmax over KV blocks; see SSPerf)
    attn_impl: str = "xla"
    attn_chunk: int = 1024
    # serving prefill emits only the last position's logits (the next-token
    # distribution) instead of (B, S, V) — SSPerf hillclimb knob
    prefill_last_only: bool = False
    # sequence-parallel attention: shard the query-sequence dim over the
    # model axis inside attention (16x less attention compute/memory per chip
    # for archs whose head count does not divide the axis) — SSPerf knob
    attn_seq_shard: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_enc_dec(self) -> bool:
        return self.n_enc_layers > 0


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) column of the assigned grid."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPE_CELLS = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)


def shape_cell(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"  # adamw | adafactor
    moment_dtype: str = "float32"  # bfloat16 halves AdamW moment memory
    lr: float = 3e-4
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    schedule: str = "cosine"  # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 1000
    remat: bool = True
    microbatch: int = 0  # >0: gradient accumulation micro-batch size
    grad_compression: bool = False  # int8 + error feedback all-reduce
    moe_aux_weight: float = 0.01
    seed: int = 0
