"""EDM dataset configurations — the paper's three zebrafish recordings
(Table I) plus synthetic scaling stand-ins for the dummy datasets of
SSIV-B3."""
import dataclasses

from repro.core.types import EDMConfig


@dataclasses.dataclass(frozen=True)
class EDMDatasetConfig:
    name: str
    n_time_steps: int  # L
    n_time_series: int  # N (active neurons)
    edm: EDMConfig = EDMConfig()


FISH1_NORMO = EDMDatasetConfig("Fish1_Normo", 1450, 53053)
SUBJECT6 = EDMDatasetConfig("Subject6", 3780, 92538)
SUBJECT11 = EDMDatasetConfig("Subject11", 8528, 101729)

DATASETS = {d.name.lower(): d for d in (FISH1_NORMO, SUBJECT6, SUBJECT11)}
