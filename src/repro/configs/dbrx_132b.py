"""dbrx-132b [moe] — hf:databricks/dbrx-base (unverified).

40L, d=6144, 48H/8KV GQA, 16 experts top-4 fine-grained (d_ff=10752
per expert)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab_size=100352, d_head=128, rope_theta=5.0e5,
        n_experts=16, experts_per_tok=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, d_head=16,
        n_experts=4, experts_per_tok=2, moe_group_size=64,
        dtype="float32", vocab_pad_multiple=8,
    )
