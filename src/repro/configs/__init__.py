"""Architecture registry: --arch <id> -> ModelConfig, plus input specs.

Every assigned architecture is a selectable config; smoke variants are
reduced same-family configs for CPU tests.  input_specs() returns
ShapeDtypeStruct stand-ins (no allocation) for the dry-run.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import (
    SHAPE_CELLS,
    ModelConfig,
    ShapeCell,
    TrainConfig,
    shape_cell,
)

ARCHS = (
    "llama-3.2-vision-11b",
    "zamba2-7b",
    "whisper-medium",
    "qwen2-1.5b",
    "minicpm-2b",
    "smollm-135m",
    "qwen2.5-3b",
    "mamba2-2.7b",
    "dbrx-132b",
    "grok-1-314b",
)

_MODULES = {
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "zamba2-7b": "zamba2_7b",
    "whisper-medium": "whisper_medium",
    "qwen2-1.5b": "qwen2_1_5b",
    "minicpm-2b": "minicpm_2b",
    "smollm-135m": "smollm_135m",
    "qwen2.5-3b": "qwen2_5_3b",
    "mamba2-2.7b": "mamba2_2_7b",
    "dbrx-132b": "dbrx_132b",
    "grok-1-314b": "grok_1_314b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke_config() if smoke else mod.config()


def list_archs() -> tuple[str, ...]:
    return ARCHS


# --------------------------------------------------------------------------
# shape-grid applicability (DESIGN.md SS5)
# --------------------------------------------------------------------------
def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """long_500k needs sub-quadratic context state: ssm/hybrid only."""
    if cell.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "pure full-attention arch: no sub-quadratic path at 512k"
    return True, ""


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: the full batch; decode: one new token + positions
    (the KV/state cache is a separate lowering argument, see launch.dryrun).
    """
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if cell.kind in ("train", "prefill"):
        batch = {"tokens": sds((B, S), i32)}
        if cfg.family == "audio":
            batch["audio"] = sds((B, cfg.n_frontend_tokens, cfg.d_model), act)
        if cfg.family == "vlm":
            batch["image_embeds"] = sds((B, cfg.n_frontend_tokens, cfg.d_model), act)
        return batch
    return {"token": sds((B, 1), i32), "pos": sds((), i32)}


def cache_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStructs of the decode cache (via eval_shape: no alloc)."""
    from repro.models import transformer

    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, cell.global_batch, cell.seq_len)
    )
