"""Asynchronous, sharded, elastic checkpointing.

Design (DESIGN.md SS6; replaces the paper's BeeOND burst-buffer pattern):
  * each checkpoint is a directory `step_<n>/` of one .npy per pytree leaf
    (flat key = path joined with '.'), written with large sequential writes
    — never the small-random-write pattern the paper found pathological on
    GPFS;
  * writes happen on a background thread (training continues; `wait()`
    joins before the next save or at exit);
  * commits are atomic: write to `tmp_step_<n>/`, fsync, rename — a crash
    mid-save never corrupts the latest checkpoint;
  * restore is ELASTIC: leaves are loaded as host arrays and re-placed with
    whatever sharding the *current* mesh prescribes, so a run checkpointed
    on 512 chips resumes on 256 (or 8 CPU devices in tests) unchanged;
  * keep_last garbage-collects old steps;
  * on real multi-host pods each process writes only its addressable shards
    (`process_index` suffix); this container is single-process, so the
    degenerate path writes full arrays.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "."


def _key_str(p) -> str:
    for attr in ("key", "name", "idx"):  # DictKey / GetAttrKey / SequenceKey
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_SEP.join(_key_str(p) for p in path)] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, keep_last: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False) -> None:
        self.wait()  # at most one in-flight save
        # snapshot to host before handing to the writer thread
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        treedef = jax.tree_util.tree_structure(tree)

        def _write():
            tmp = self.dir / f"tmp_step_{step:08d}"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for key, arr in host.items():
                np.save(tmp / (key + ".npy"), arr)
            (tmp / "manifest.json").write_text(
                json.dumps(
                    {
                        "step": step,
                        "keys": sorted(host.keys()),
                        "treedef": str(treedef),
                    }
                )
            )
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if (p / "manifest.json").exists()
        )

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of `like`; re-shard elastically when
        `shardings` (a matching pytree of jax.sharding.Sharding) is given."""
        d = self.dir / f"step_{step:08d}"
        flat_like = _flatten(like)
        loaded = {k: np.load(d / (k + ".npy")) for k in flat_like}
        leaves = [loaded[k] for k in flat_like]
        treedef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        else:
            tree = jax.tree.map(
                lambda a, l: jax.device_put(
                    a.astype(l.dtype) if hasattr(l, "dtype") else a
                ),
                tree,
                like,
            )
        return tree

    def restore_latest(self, like, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like, shardings)
