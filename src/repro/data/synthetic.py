"""Synthetic nonlinear dynamical systems with known causal structure.

Used for (a) validating that CCM recovers ground-truth causality
(Sugihara et al. 2012 coupled logistic maps) and (b) generating
zebrafish-brain-scale dummy datasets for benchmarks, mirroring the
paper's dummy-dataset scaling studies (Figs. 6-9).
"""
from __future__ import annotations

import numpy as np


def coupled_logistic(
    L: int,
    beta_xy: float = 0.02,
    beta_yx: float = 0.1,
    rx: float = 3.8,
    ry: float = 3.5,
    seed: int = 0,
    transient: int = 300,
) -> tuple[np.ndarray, np.ndarray]:
    """Two coupled logistic maps (Sugihara 2012, Science).

    beta_yx is the effect of x on y (x drives y); beta_xy the reverse.
    Returns (x, y) float32 arrays of length L.
    """
    rng = np.random.default_rng(seed)
    x, y = rng.uniform(0.2, 0.6, size=2)
    xs = np.empty(L + transient, np.float64)
    ys = np.empty(L + transient, np.float64)
    for t in range(L + transient):
        x, y = (
            x * (rx - rx * x - beta_xy * y),
            y * (ry - ry * y - beta_yx * x),
        )
        xs[t], ys[t] = x, y
    return xs[transient:].astype(np.float32), ys[transient:].astype(np.float32)


def logistic_network(
    N: int,
    L: int,
    density: float = 0.05,
    strength: float = 0.08,
    r_range: tuple[float, float] = (3.6, 3.9),
    seed: int = 0,
    transient: int = 200,
) -> tuple[np.ndarray, np.ndarray]:
    """Sparse directed network of coupled logistic maps — a miniature
    'brain' with known ground-truth adjacency.

    Returns (ts (N, L) float32, adj (N, N) bool) where adj[src, dst] means
    src drives dst.
    """
    rng = np.random.default_rng(seed)
    adj = rng.uniform(size=(N, N)) < density
    np.fill_diagonal(adj, False)
    B = np.where(adj, strength, 0.0) / max(1.0, density * N / 4.0)
    r = rng.uniform(*r_range, size=N)
    x = rng.uniform(0.2, 0.6, size=N)
    ts = np.empty((L + transient, N), np.float64)
    for t in range(L + transient):
        drive = B.T @ x  # drive[dst] = sum_src B[src,dst] x[src]
        x = np.clip(x * (r - r * x - drive), 1e-6, 1.0)
        ts[t] = x
    out = ts[transient:].T.astype(np.float32)  # (N, L)
    return out, adj


def dummy_brain(N: int, L: int, seed: int = 0) -> np.ndarray:
    """Fast dummy dataset for scaling benchmarks (paper SSIV-B3): smoothed
    noise with per-series autocorrelation, standardized."""
    rng = np.random.default_rng(seed)
    ts = rng.standard_normal((N, L)).astype(np.float32)
    # AR(1)-style smoothing gives realistic neighbour structure.
    alpha = 0.8
    for t in range(1, L):
        ts[:, t] = alpha * ts[:, t - 1] + (1 - alpha) * ts[:, t]
    ts -= ts.mean(axis=1, keepdims=True)
    ts /= ts.std(axis=1, keepdims=True) + 1e-6
    return ts
