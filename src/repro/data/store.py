"""'zarr-lite' memmap store — the HDF5 replacement (h5py unavailable offline).

Layout: <name>/meta.json + <name>/data.npy (memmap-able).  Mirrors the
paper's I/O design points: parallel read of the input dataset, and causal-
map output written as large sequential ROW-BLOCK shards (never the
small-random-write pattern that bottlenecked GPFS, SSIII-C)."""
from __future__ import annotations

import json
import pathlib

import numpy as np


def save_dataset(path: str | pathlib.Path, ts: np.ndarray, meta: dict | None = None):
    p = pathlib.Path(path)
    p.mkdir(parents=True, exist_ok=True)
    np.save(p / "data.npy", ts)
    (p / "meta.json").write_text(
        json.dumps({"shape": list(ts.shape), "dtype": str(ts.dtype), **(meta or {})})
    )


def load_dataset(path: str | pathlib.Path, mmap: bool = True) -> np.ndarray:
    p = pathlib.Path(path)
    return np.load(p / "data.npy", mmap_mode="r" if mmap else None)


class RowBlockWriter:
    """Streamed causal-map output: one .npy per completed row block + a
    {row0: nrows} manifest — the resume unit of the EDM pipeline.  Coverage
    is tracked per ROW, so a restart with a different worker count (elastic:
    different chunk size) resumes exactly where any prior mesh left off."""

    def __init__(self, path: str | pathlib.Path, N: int):
        self.dir = pathlib.Path(path)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.N = N
        self.manifest = self.dir / "blocks.json"
        self.done: dict[str, int] = (
            json.loads(self.manifest.read_text()) if self.manifest.exists() else {}
        )

    def covered(self) -> np.ndarray:
        cov = np.zeros(self.N, bool)
        for row0_s, n in self.done.items():
            row0 = int(row0_s)
            cov[row0 : row0 + n] = True
        return cov

    def next_uncovered(self, start: int = 0) -> int | None:
        cov = self.covered()
        idx = np.nonzero(~cov[start:])[0]
        return int(idx[0]) + start if idx.size else None

    def chunk_plan(self, chunk: int) -> list[tuple[int, int]]:
        """Ordered (row0, nrows) work list for a resume at chunk granularity.

        Mirrors the pipeline's elastic-resume walk: each chunk starts at the
        first uncovered row at-or-after the previous chunk's end, and spans
        min(chunk, N - row0) rows.  Computed up-front so the streaming loop
        can keep multiple chunks in flight without re-reading coverage
        (this process is the only writer; see runtime/stream.py).
        """
        plan: list[tuple[int, int]] = []
        row0 = 0
        while row0 < self.N:
            nxt = self.next_uncovered(row0)
            if nxt is None:
                break
            valid = min(chunk, self.N - nxt)
            plan.append((nxt, valid))
            row0 = nxt + valid
        return plan

    def write_block(self, row0: int, rho_rows: np.ndarray):
        rho_rows = rho_rows[: max(0, self.N - row0)]
        np.save(self.dir / f"rows_{row0:08d}.npy", rho_rows)
        self.done[str(row0)] = int(rho_rows.shape[0])
        tmp = self.manifest.with_suffix(".tmp")
        tmp.write_text(json.dumps(self.done))
        tmp.rename(self.manifest)

    def assemble(self) -> np.ndarray:
        """Gather all blocks into the (N, N) causal map (small N only)."""
        rho = np.zeros((self.N, self.N), np.float32)
        for row0_s in self.done:
            row0 = int(row0_s)
            rows = np.load(self.dir / f"rows_{row0:08d}.npy")
            rho[row0 : row0 + rows.shape[0]] = rows[:, : self.N]
        return rho
