"""'zarr-lite' memmap store — the HDF5 replacement (h5py unavailable offline).

Layout: <name>/meta.json + <name>/data.npy (memmap-able).  Mirrors the
paper's I/O design points: parallel read of the input dataset, and causal-
map output written as large sequential BLOCK shards (never the
small-random-write pattern that bottlenecked GPFS, SSIII-C)."""
from __future__ import annotations

import errno
import json
import os
import pathlib
import time

import numpy as np

# telemetry imports NOTHING from the store at module scope (its JSONL
# sink borrows atomic_write_text lazily inside flush), so this edge is
# acyclic: the store emits write/fsync spans, the sink persists them
# with the store's own durability primitive.  integrity's checksum /
# fingerprint primitives are pure (its fsck half imports the store
# lazily), and faultpoints imports only telemetry — both acyclic too.
from repro.runtime import faultpoints, telemetry
from repro.runtime.integrity import (
    Crc32,
    IntegrityError,
    checksum_file,
    manifest_with_crc,
    read_manifest_shard,
    write_sidecar,
)

#: errnos where retrying the SAME write is pointless (the medium, not
#: the attempt, is broken) — the work queue poisons the unit immediately
#: instead of burning its retry budget (see workqueue._fatal_oserror).
FATAL_WRITE_ERRNOS = (errno.ENOSPC, errno.EDQUOT, errno.EROFS)


def _fsync_dir(path: pathlib.Path) -> None:
    """Best-effort directory fsync after a rename (durability of the
    rename itself; no-op where unsupported)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _unique_tmp(path: pathlib.Path) -> pathlib.Path:
    """Collision-free temp sibling (pid alone is not enough: threads of
    one process may write the same target concurrently)."""
    return path.parent / f"{path.name}.tmp-{os.getpid()}-{os.urandom(4).hex()}"


def _classify_write_error(e: OSError, path: pathlib.Path,
                          tmp: pathlib.Path) -> OSError:
    """Failed-write cleanup + classification: unlink the temp (a dead
    half-written temp must not linger as .tmp residue on a FULL disk of
    all places), and rewrap disk-exhaustion errnos with a clear message
    so the fleet can poison the unit instead of retrying it."""
    try:
        tmp.unlink()
    except OSError:
        pass
    if e.errno in FATAL_WRITE_ERRNOS:
        return OSError(e.errno, f"out of space at {path} "
                                f"({os.strerror(e.errno)})")
    return e


def atomic_write_text(
    path: str | pathlib.Path, text: str, fault: str | None = None
) -> None:
    """write-temp + fsync + os.replace: a writer killed at any point
    leaves the old file or the new file, never a torn mix.  The ONE
    durability primitive of the store AND the work queue (workqueue.py
    imports it) — keep fixes here, not in copies.

    ``fault`` names this write's fault-point prefix (faultpoints.py):
    ``<fault>_pre_rename`` fires in the temp-durable-but-invisible
    window the atomicity claim is really about."""
    path = pathlib.Path(path)
    tmp = _unique_tmp(path)
    try:
        with open(tmp, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
    except OSError as e:
        raise _classify_write_error(e, path, tmp) from e
    if fault is not None:
        faultpoints.fire(f"{fault}_pre_rename")
    os.replace(tmp, path)
    _fsync_dir(path.parent)


def atomic_save_npy(
    path: pathlib.Path, arr: np.ndarray, fault: str | None = None
) -> dict:
    """Atomic np.save — the shared-store write primitive: concurrent
    duplicate writers (lease-steal races) replace each other with
    identical bytes instead of interleaving.  Returns write stats
    ({bytes, fsync_s, crc32}) so instrumented callers (TileWriter) can
    record the content checksum without a second pass — the crc is
    accumulated WHILE np.save streams through the temp file.

    ``fault`` arms ``<fault>_pre_fsync`` / ``<fault>_pre_rename``
    (e.g. fault="tile" -> the ISSUE's ``tile_pre_rename`` point)."""
    tmp = _unique_tmp(path)
    try:
        with open(tmp, "wb") as f:
            tee = Crc32(f)
            np.save(tee, arr)
            f.flush()
            if fault is not None:
                faultpoints.fire(f"{fault}_pre_fsync")
            t0 = time.perf_counter()
            os.fsync(f.fileno())
            fsync_s = time.perf_counter() - t0
    except OSError as e:
        raise _classify_write_error(e, path, tmp) from e
    if fault is not None:
        faultpoints.fire(f"{fault}_pre_rename")
    os.replace(tmp, path)
    _fsync_dir(path.parent)
    return {"bytes": int(arr.nbytes), "fsync_s": fsync_s, "crc32": tee.hex}


def save_npy_checksummed(
    path: pathlib.Path, arr: np.ndarray, fault: str | None = None
) -> dict:
    """atomic_save_npy + ``<path>.crc32`` sidecar, for standalone .npy
    artifacts with no manifest to carry their checksum (dataset,
    col_order, phase-1 outputs, edges).  Sidecar lands AFTER the data —
    a crash between the two leaves a verifiable-later gap ("unverified"
    in fsck), never a false mismatch, because rewrites are idempotent."""
    stats = atomic_save_npy(path, arr, fault=fault)
    write_sidecar(path, stats["crc32"])
    return stats


def save_meta(
    path: str | pathlib.Path, shape, dtype, meta: dict | None = None
) -> None:
    """Write just the zarr-lite meta.json (for data produced elsewhere,
    e.g. a causal map assembled straight into <name>/data.npy)."""
    p = pathlib.Path(path)
    p.mkdir(parents=True, exist_ok=True)
    atomic_write_text(
        p / "meta.json",
        json.dumps({"shape": list(shape), "dtype": str(dtype), **(meta or {})}),
    )


def save_dataset(path: str | pathlib.Path, ts: np.ndarray, meta: dict | None = None):
    p = pathlib.Path(path)
    p.mkdir(parents=True, exist_ok=True)
    # Atomic: a driver killed mid-save must not leave a torn data.npy
    # that a later existence check (fleet resume) would trust.
    save_npy_checksummed(p / "data.npy", ts, fault="dataset")
    save_meta(p, ts.shape, ts.dtype, meta)


def load_dataset(path: str | pathlib.Path, mmap: bool = True) -> np.ndarray:
    p = pathlib.Path(path)
    return np.load(p / "data.npy", mmap_mode="r" if mmap else None)


def _union_covers(intervals: list[tuple[int, int]], width: int) -> bool:
    """True when the union of [a, b) intervals covers [0, width)."""
    reach = 0
    for a, b in sorted(intervals):
        if a > reach:
            return False
        reach = max(reach, b)
        if reach >= width:
            return True
    return reach >= width


class TileWriter:
    """Streamed causal-map output in (row-chunk x col-tile) blocks + a 2D
    manifest — the resume unit of the EDM pipeline (DESIGN.md SS7).

    Each completed block is one sequential .npy write (the BeeOND
    large-sequential-write design point, paper SSIII-C); the manifest maps
    ``"row0"`` (legacy full-width row block) or ``"row0,col0"`` (tile) to
    its extent.  Coverage is tracked per ROW — a row counts as covered
    only when its tiles union to the full column width — so a restart
    with a different worker count OR tile width (elastic: different chunk
    and tile geometry) resumes exactly where any prior mesh left off.

    ``col_order``: the bucketed tiled pipeline writes tiles in the
    bucket-SORTED column order; the permutation is persisted next to the
    blocks (col_order.npy), verified on resume, and undone at
    :meth:`assemble` time.  Full-width row blocks are always written in
    natural column order (the pipeline unsorts before writing).

    ``writer_id``: multi-process fleets (DESIGN.md SS10) give each
    worker its own id; the worker then commits its manifest entries to a
    private shard ``blocks.<id>.json`` — no cross-process manifest lock
    is ever needed, because no two processes write the same file.  Every
    writer (and plain readers, writer_id=None) LOADS the union of all
    shards, so coverage, chunk_plan, and assemble always see every
    durable block regardless of who wrote it.  All writes (tiles, blocks,
    manifests, col_order) are write-temp + fsync + os.replace, so a
    worker SIGKILLed mid-write can never corrupt shared resume state —
    and duplicate computation of a unit (lease-steal race) replaces
    tiles with identical bytes instead of interleaving.
    """

    def __init__(
        self,
        path: str | pathlib.Path,
        N: int,
        M: int | None = None,
        writer_id: str | None = None,
        stage: str = "store",
    ):
        self.dir = pathlib.Path(path)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.N = N
        self.M = N if M is None else M
        # Telemetry label only (never touches bytes): which pipeline
        # stage this writer's tiles belong to ("phase2", "sig", …).
        self.stage = stage
        if writer_id is not None and not writer_id.isidentifier():
            raise ValueError(f"writer_id={writer_id!r} must be identifier-like")
        self.writer_id = writer_id
        self.manifest = self.dir / (
            "blocks.json" if writer_id is None else f"blocks.{writer_id}.json"
        )
        # _own: entries THIS writer commits (its manifest shard's content);
        # done: the merged all-shards view used for coverage and assembly.
        # A torn/corrupt own shard degrades to {} — its tiles resurface
        # as uncovered and are recomputed (fsck reports it eagerly).
        self._own: dict[str, object] = (
            read_manifest_shard(self.manifest) or {}
            if self.manifest.exists() else {}
        )
        self.done: dict[str, object] = {}
        self.refresh()
        co = self.dir / "col_order.npy"
        self._col_order: np.ndarray | None = np.load(co) if co.exists() else None

    def _manifest_shards(self):
        """blocks.json plus every blocks.<writer>.json (skip .tmp residue
        of a killed writer — only fully-replaced manifests count)."""
        for p in sorted(self.dir.glob("blocks*.json")):
            if p.suffix == ".json":
                yield p

    def refresh(self) -> "TileWriter":
        """Re-merge every manifest shard from disk (fleet workers call
        this to observe blocks other processes committed); uncommitted
        in-memory entries of THIS writer are kept."""
        merged: dict[str, object] = {}
        for p in self._manifest_shards():
            parsed = read_manifest_shard(p)
            if parsed is None:
                # a shard torn by a foreign non-atomic writer (or failing
                # its __crc__ self-checksum): ignore — its tiles resurface
                # as uncovered and are recomputed; fsck reports it eagerly
                continue
            merged.update(parsed)
        merged.update(self._own)
        self.done = merged
        return self

    # ------------------------------------------------------------ coverage
    def _blocks(self):
        """Yield (tiled, row0, col0, nrows, ncols, crc|None) per manifest
        entry.  Entry formats (all readable forever): tiles ``[nr, nc]``
        (legacy) or ``[nr, nc, crc]``; full-width row blocks ``nrows``
        (legacy) or ``[nrows, crc]``."""
        for key, val in self.done.items():
            if "," in key:
                row0, col0 = (int(s) for s in key.split(","))
                nr, nc = int(val[0]), int(val[1])
                crc = val[2] if len(val) > 2 else None
                yield True, row0, col0, nr, nc, crc
            else:
                if isinstance(val, list):
                    nr, crc = int(val[0]), val[1]
                else:
                    nr, crc = int(val), None
                yield False, int(key), 0, nr, self.M, crc

    def covered(self) -> np.ndarray:
        """(N,) bool: rows whose tiles union to the full column width.

        Cost is O(#manifest entries) in the common case: tiles are grouped
        by their (row0, nrows) span and each span's column intervals are
        merged ONCE for all its rows.  Only rows under spans that do NOT
        resolve on their own (mixed tile geometries from an elastic resume
        with a different chunk/tile size) fall back to a precise per-row
        interval union — bounded by the crash/overlap region, never
        O(N x tiles)."""
        cov = np.zeros(self.N, bool)
        spans: dict[tuple[int, int], list[tuple[int, int]]] = {}
        for _tiled, row0, col0, nr, nc, _crc in self._blocks():
            if col0 == 0 and nc >= self.M:  # full-width fast path
                cov[row0 : row0 + nr] = True
            else:
                spans.setdefault((row0, nr), []).append((col0, col0 + nc))
        unresolved = []
        for (row0, nr), ivals in spans.items():
            if _union_covers(ivals, self.M):
                cov[row0 : row0 + nr] = True
            else:
                unresolved.append((row0, nr, ivals))
        if unresolved:
            per_row: dict[int, list[tuple[int, int]]] = {}
            for row0, nr, ivals in unresolved:
                for r in range(row0, min(row0 + nr, self.N)):
                    if not cov[r]:
                        per_row.setdefault(r, []).extend(ivals)
            for r, ivals in per_row.items():
                if _union_covers(ivals, self.M):
                    cov[r] = True
        return cov

    def next_uncovered(self, start: int = 0) -> int | None:
        cov = self.covered()
        idx = np.nonzero(~cov[start:])[0]
        return int(idx[0]) + start if idx.size else None

    def chunk_plan(
        self, chunk: int, covered: np.ndarray | None = None
    ) -> list[tuple[int, int]]:
        """Ordered (row0, nrows) work list for a resume at chunk granularity.

        Each maximal RUN of uncovered rows is split into at-most-``chunk``
        spans, so fragmented coverage (elastic resume after a mesh-size
        change can leave covered islands mid-range) is skipped rather than
        recomputed: resume work is proportional to what is actually
        missing.  Computed up-front so the streaming loop can keep
        multiple chunks in flight without re-reading coverage (this
        process is the only writer; see runtime/stream.py).

        ``covered``: optional (N,) bool overriding this writer's own
        coverage — drivers emitting several artifacts in lockstep (the
        significance pipeline's rho_conv/pvals writers) pass the AND of
        all their coverages so a crash mid-chunk recomputes the chunk
        for every artifact.
        """
        if covered is None:
            covered = self.covered()
        uncovered = np.nonzero(~np.asarray(covered))[0]
        if uncovered.size == 0:
            return []
        run_starts = np.nonzero(np.diff(uncovered) > 1)[0] + 1
        plan: list[tuple[int, int]] = []
        for run in np.split(uncovered, run_starts):
            s, e = int(run[0]), int(run[-1]) + 1
            for row0 in range(s, e, chunk):
                plan.append((row0, min(chunk, e - row0)))
        return plan

    # ------------------------------------------------------------- writing
    def _commit(self) -> None:
        # Only THIS writer's entries go to its shard; merged `done` stays
        # a read-side view (rewriting it here would cross-duplicate other
        # workers' entries into this shard).
        with telemetry.span(self.stage, "manifest_commit",
                            entries=len(self._own)):
            atomic_write_text(self.manifest, manifest_with_crc(self._own),
                              fault="manifest")

    def ensure_col_order(self, order: np.ndarray | None) -> None:
        """Declare (and persist) the on-disk column permutation for tile
        writes; raises if it conflicts with a prior run's layout."""
        want = np.arange(self.M) if order is None else np.asarray(order)
        f = self.dir / "col_order.npy"
        if f.exists():
            existing = np.load(f)
            if not np.array_equal(existing, want):
                raise ValueError(
                    f"resume column-order mismatch in {self.dir}: the store "
                    "was written under a different target permutation "
                    "(different optE/bucketing?); use a fresh --out dir"
                )
            self._col_order = None if order is None else existing
            return
        if order is None:
            return  # natural order needs no marker
        # Full-width row blocks are always natural order (compatible with
        # any tile permutation); only pre-existing TILES pin the layout.
        has_tiles = any("," in k for k in self.done)
        if has_tiles and not np.array_equal(want, np.arange(self.M)):
            raise ValueError(
                f"store {self.dir} already holds natural-order tiles; "
                "cannot add column-permuted tiles (use a fresh --out dir)"
            )
        # Atomic replace: concurrent fleet workers race this benignly —
        # both derive the same permutation from the shared phase-1 optE,
        # so whoever lands second replaces identical bytes.
        save_npy_checksummed(f, want, fault="col_order")
        self._col_order = want

    def write_block(self, row0: int, rho_rows: np.ndarray):
        """Full-width row block (legacy single-tile path)."""
        rho_rows = rho_rows[: max(0, self.N - row0)]
        with telemetry.span(self.stage, "write_block", row0=row0) as t:
            stats = atomic_save_npy(self.dir / f"rows_{row0:08d}.npy",
                                    rho_rows, fault="tile")
            t.update(stats)
        entry = [int(rho_rows.shape[0]), stats["crc32"]]
        self.done[str(row0)] = self._own[str(row0)] = entry
        self._commit()

    def write_tile(self, row0: int, col0: int, block: np.ndarray,
                   commit: bool = True):
        """One (row-chunk x col-tile) block; columns are on-disk order
        (i.e. already permuted by col_order when one is declared).

        commit=False defers the manifest rewrite — callers emitting many
        tiles per row chunk (the 2D pipeline) batch it to one
        :meth:`commit` per chunk, keeping manifest I/O O(chunks) instead
        of O(tiles).  Deferring is always safe: an uncommitted tile is
        merely recomputed on resume (the .npy itself is durable before
        the manifest ever mentions it)."""
        block = block[: max(0, self.N - row0), : max(0, self.M - col0)]
        with telemetry.span(self.stage, "write_tile", row0=row0,
                            col0=col0) as t:
            stats = atomic_save_npy(
                self.dir / f"tile_{row0:08d}_{col0:08d}.npy", block,
                fault="tile",
            )
            t.update(stats)
        entry = [int(block.shape[0]), int(block.shape[1]), stats["crc32"]]
        self.done[f"{row0},{col0}"] = self._own[f"{row0},{col0}"] = entry
        if commit:
            self._commit()

    def commit(self) -> None:
        """Flush deferred write_tile manifest entries (atomic rewrite)."""
        self._commit()

    # ------------------------------------------------------------ assembly
    def assemble(self, mmap_path: str | pathlib.Path | None = None) -> np.ndarray:
        """Gather all blocks into the (N, M) causal map, undoing col_order.

        mmap_path=None allocates a dense host array (small N only);
        otherwise the map is assembled straight into a .npy memmap at that
        path — peak host memory stays O(block), the paper-scale path.

        This is the store's lazy READ-side integrity check: every block
        with a recorded checksum is verified against its bytes before it
        enters the map (a bit-rotted or truncated tile raises
        IntegrityError instead of silently poisoning downstream
        significance).  The assembled memmap gets its own .crc32 sidecar
        so fsck can verify the end product too.
        """
        if mmap_path is None:
            rho = np.zeros((self.N, self.M), np.float32)
        else:
            p = pathlib.Path(mmap_path)
            p.parent.mkdir(parents=True, exist_ok=True)
            rho = np.lib.format.open_memmap(
                p, mode="w+", dtype=np.float32, shape=(self.N, self.M)
            )
        colmap = self._col_order
        for tiled, row0, col0, _nr, _nc, crc in self._blocks():
            f = (self.dir / f"tile_{row0:08d}_{col0:08d}.npy" if tiled
                 else self.dir / f"rows_{row0:08d}.npy")
            if crc is not None and checksum_file(f) != crc:
                raise IntegrityError(
                    f"{f}: content does not match the manifest checksum "
                    f"{crc} — the store is corrupt; run "
                    "`edm_fleet fsck --heal` and rerun to recompute it"
                )
            block = np.load(f)
            if not tiled:
                block = block[:, : self.M]
            nr, nc = block.shape
            if tiled and colmap is not None:
                rho[row0 : row0 + nr, colmap[col0 : col0 + nc]] = block
            else:
                rho[row0 : row0 + nr, col0 : col0 + nc] = block
        if mmap_path is not None:
            rho.flush()
            write_sidecar(p, checksum_file(p))
        return rho


class RowBlockWriter(TileWriter):
    """Back-compat name: the full-width row-block writer is the one-tile
    special case of :class:`TileWriter`."""
