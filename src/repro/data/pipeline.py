"""Input pipeline: deterministic synthetic token streams with host-side
prefetch, sharded across data-parallel workers.

Production shape: each process generates/loads only its slice of the global
batch (process_index-keyed), a background thread keeps `prefetch` batches
ready on device, and batch content is a pure function of (seed, step) so a
restarted/elastic run replays the identical stream.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


class TokenStream:
    """Deterministic synthetic LM batches: batch(step) = f(seed, step)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 extra_specs: Optional[dict] = None):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.extra_specs = extra_specs or {}

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        out = {
            "tokens": rng.integers(
                0, self.vocab, size=(self.batch, self.seq), dtype=np.int32
            )
        }
        for name, (shape, dtype) in self.extra_specs.items():
            out[name] = (0.1 * rng.standard_normal(size=shape)).astype(dtype)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch + device_put with the given shardings."""

    def __init__(self, stream, shardings=None, prefetch: int = 2, n_steps: Optional[int] = None):
        self.stream = stream
        self.shardings = shardings
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self.n_steps = n_steps
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        for i, batch in enumerate(self.stream):
            if self._stop.is_set() or (self.n_steps is not None and i >= self.n_steps):
                break
            if self.shardings is not None:
                batch = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), batch, self.shardings
                )
            else:
                batch = jax.tree.map(jax.device_put, batch)
            self.q.put(batch)
        self.q.put(None)

    def __iter__(self):
        while True:
            item = self.q.get()
            if item is None:
                break
            yield item

    def stop(self):
        self._stop.set()
