"""EDM core: the paper's contribution (simplex projection + improved CCM)."""
from repro.core.types import CausalMap, EDMConfig
from repro.core.embedding import delay_embed, future_values, lag_matrix
from repro.core.knn import (
    knn_table_single_E,
    knn_tables_all_E,
    simplex_forecast,
    tables_with_weights,
)
from repro.core.simplex import simplex_batch, simplex_series
from repro.core.ccm import (
    all_futures,
    ccm_block,
    ccm_convergence,
    ccm_library_row,
    ccm_matrix,
)
from repro.core.baseline import ccm_naive, ccm_pair_naive
from repro.core.stats import pearson, simplex_weights

__all__ = [
    "CausalMap",
    "EDMConfig",
    "delay_embed",
    "future_values",
    "lag_matrix",
    "knn_table_single_E",
    "knn_tables_all_E",
    "simplex_forecast",
    "tables_with_weights",
    "simplex_batch",
    "simplex_series",
    "all_futures",
    "ccm_block",
    "ccm_convergence",
    "ccm_library_row",
    "ccm_matrix",
    "ccm_naive",
    "ccm_pair_naive",
    "pearson",
    "simplex_weights",
]
