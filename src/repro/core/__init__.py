"""EDM core: the paper's contribution (simplex projection + improved CCM)."""
from repro.core.types import CausalMap, EDMConfig
from repro.core.embedding import delay_embed, future_values, lag_matrix
from repro.core.knn import (
    knn_table_single_E,
    knn_tables_all_E,
    knn_tables_bucketed,
    simplex_forecast,
    tables_with_weights,
    tables_with_weights_bucketed,
)
from repro.core.simplex import simplex_batch, simplex_series
from repro.core.ccm import (
    BucketPlan,
    all_futures,
    ccm_block,
    ccm_block_bucketed,
    ccm_convergence,
    ccm_library_row,
    ccm_library_row_bucketed,
    ccm_matrix,
    make_bucket_plan,
)
from repro.core.baseline import ccm_naive, ccm_pair_naive
from repro.core.stats import pearson, simplex_weights

__all__ = [
    "BucketPlan",
    "CausalMap",
    "EDMConfig",
    "delay_embed",
    "future_values",
    "lag_matrix",
    "knn_table_single_E",
    "knn_tables_all_E",
    "knn_tables_bucketed",
    "make_bucket_plan",
    "simplex_forecast",
    "tables_with_weights",
    "tables_with_weights_bucketed",
    "simplex_batch",
    "simplex_series",
    "all_futures",
    "ccm_block",
    "ccm_block_bucketed",
    "ccm_convergence",
    "ccm_library_row",
    "ccm_library_row_bucketed",
    "ccm_matrix",
    "ccm_naive",
    "ccm_pair_naive",
    "pearson",
    "simplex_weights",
]
