"""kNN table construction — the paper's hot spot (97% of cppEDM runtime).

Two paths:
  * pure-jnp (this file): cumulative-E scan + lax.top_k.  Oracle + CPU path.
  * Pallas (kernels/knn_topk): same math tiled for MXU/VMEM.  TPU path.

The cumulative-E recurrence (DESIGN.md SS2) builds the squared-distance
matrix for every embedding dimension E in one O(Lq*Lc) sweep per E:

    D_E(t, s) = D_{E-1}(t, s) + (V[E-1, t] - V[E-1, s])^2

where V = lag_matrix(x).  mpEDM recomputes each D_E from scratch
(O(Lq*Lc*E) each, O(Lq*Lc*E_max^2) total); the recurrence is an E_max/2 x
algorithmic saving on table construction, with identical results.

SELECTION is always STREAMING (DESIGN.md SS8): scan over candidate tiles
of width ``tile_c``, partial-sort each tile to its own top-k, and fold it
into a running sorted (Lq, k) table with the :func:`merge_topk_sorted`
comparator network — no O(Lq*Lc) array is ever built, the working set is
flat in Lc, and a tile covering the whole library degenerates to a single
direct selection, so small libraries pay nothing for the tiling.  The
historical dense distance-matrix layout survives only as the test/bench
oracle (:func:`knn_tables_dense`); ``calibrate_knn_tile`` replaces its
auto-threshold routing with a pure tile-width calibration
(EDMConfig.knn_tile_c = 0).

Bit-identity contract: streaming selection == ``lax.top_k`` over the full
candidate row (values AND tie order) for every k <= Lc and any tile
partition — see merge_topk_sorted / _knn_tables_streaming.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding
from repro.core.stats import simplex_weights

# A numpy (not jnp) scalar: a module-scope device array would initialize
# the jax backend at import time, before runtime/platform.py can latch
# platform / XLA flags (DESIGN.md SS14).  jnp.where promotes it exactly
# like the old jnp.float32 constant.
INF = np.float32(np.inf)

# Ceiling of the per-program streaming working set the tile calibration
# aims for: the 16 MB TPU VMEM size.  Wide tiles are the lever that
# amortizes per-tile selection+merge dispatch overhead (measured: tile
# 8192 beats 4096 by ~15% at Lc >= 16k); the KNN_TILE_MAX cap below is
# what keeps the per-program footprint (~10 MB at the paper shape, see
# stream_vmem_bytes) inside VMEM with double-buffer headroom.
KNN_TILE_BUDGET_BYTES = 16 * 2**20
# Lane-aligned bounds for calibrated candidate tiles: narrower than 128
# wastes VPU lanes, wider than 8192 exceeds the VMEM budget at paper
# shapes before it buys any more merge amortization.
KNN_TILE_MIN, KNN_TILE_MAX = 128, 8192
# Host (pure-jnp) streaming profile: the working set targets the CPU
# last-level cache, not VMEM, and XLA:CPU's top_k carries a ~1.5 ms
# fixed cost PER CALL (measured at 128 rows; two 8192-wide calls lose to
# one 16384-wide call), so the host path calibrates against a wider
# budget and cap — paper-scale libraries (L <= 16384) become a single
# direct-selection tile on the reference engine.
KNN_TILE_BUDGET_BYTES_HOST = 32 * 2**20
KNN_TILE_MAX_HOST = 16384


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def streaming_bytes(
    Lq: int, k: int, tile_c: int, n_sel: int, dist_dtype=jnp.float32
) -> int:
    """Peak distance-working-set bytes of the streaming selection path:
    one (Lq, tile_c) tile (dist_dtype accumulator + i32 candidate ids),
    the tile's own (Lq, k) partial top-k, the DOUBLED (Lq, 2*K) merge
    -network buffers (dist f32 + id i32 + rank i32, K = next pow2 >= k),
    and the (n_sel, Lq, k) running tables.  Independent of Lc — the
    streaming scaling guarantee (DESIGN.md SS8)."""
    it = jnp.dtype(dist_dtype).itemsize
    K = _next_pow2(k)
    tile = Lq * tile_c * (it + 4)  # dist accumulator + i32 ids
    tile_topk = Lq * k * (4 + 4)  # per-tile partial sort output
    merge = Lq * 2 * K * (4 + 4 + 4)  # network: f32 dist + i32 id + i32 rank
    carry = n_sel * Lq * k * (4 + 4)
    return tile + tile_topk + merge + carry


@functools.lru_cache(maxsize=None)
def calibrate_knn_tile(
    Lc: int,
    E_max: int = 20,
    k: int = 21,
    block_q: int = 128,
    dist_dtype: str = "float32",
    budget_bytes: int = KNN_TILE_BUDGET_BYTES,
    tile_max: int = KNN_TILE_MAX,
) -> int:
    """One-shot candidate-tile-width calibration (EDMConfig.knn_tile_c=0).

    Streaming with a tile covering the whole library IS the direct dense
    selection (one tile, no merges), so the widest tile that fits the
    working-set budget is optimal at every Lc: small libraries get the
    single-tile fast case, large ones the flat-memory scan.  Picks the
    largest power-of-two width in [KNN_TILE_MIN, KNN_TILE_MAX] not
    exceeding ``budget_bytes`` under the :func:`streaming_bytes` model
    (evaluated at one ``block_q`` query block — the Pallas per-program
    shape), stopping early once the tile covers Lc.  Pure shape
    arithmetic: no timing runs, stable across processes, cacheable.
    """
    if Lc < 1:
        raise ValueError(f"Lc={Lc} must be positive")
    tile = KNN_TILE_MIN
    while tile < Lc and tile < tile_max:
        nxt = tile * 2
        if streaming_bytes(block_q, k, nxt, E_max, dist_dtype) > budget_bytes:
            break
        tile = nxt
    return tile


def resolve_stream_tile(Lc: int, cfg, profile: str = "vmem") -> int:
    """EDMConfig.knn_tile_c semantics, shared by every engine: > 0 forces
    that candidate-tile width, 0 auto-calibrates via
    :func:`calibrate_knn_tile`.  -1 — the deleted dense distance-matrix
    route — raises instead of silently selecting a layout that no longer
    exists (EDMConfig construction already rejects it; this guards
    config-like ducks).

    ``profile`` picks the calibration budget for knn_tile_c=0: "vmem"
    (default, safe on every backend) models the 16 MB Pallas per-program
    footprint; "host" models the CPU cache for pure-jnp call sites,
    allowing the wider tiles that amortize XLA:CPU's per-top_k-call
    cost."""
    if cfg.knn_tile_c > 0:
        return cfg.knn_tile_c
    if cfg.knn_tile_c < 0:
        raise ValueError(
            "knn_tile_c=-1 (the removed dense distance-matrix selection "
            "path) is deprecated: selection is always streaming; use 0 "
            "(auto-calibrated tile width) or a positive tile width"
        )
    budget, tile_max = (
        (KNN_TILE_BUDGET_BYTES_HOST, KNN_TILE_MAX_HOST)
        if profile == "host"
        else (KNN_TILE_BUDGET_BYTES, KNN_TILE_MAX)
    )
    tile = calibrate_knn_tile(
        Lc, E_max=cfg.E_max, k=cfg.k_max, dist_dtype=cfg.dist_dtype,
        budget_bytes=budget, tile_max=tile_max,
    )
    _emit_calibration(Lc, tile, profile, cfg)
    return tile


# Calibration results are pure shape arithmetic — emit each distinct
# (Lc, tile, profile) once per process, not once per chunk.
_calibration_seen: set = set()


def _emit_calibration(Lc: int, tile: int, profile: str, cfg) -> None:
    from repro.runtime import telemetry  # lazy: knn is a leaf module

    if not telemetry.enabled():
        return
    key = (Lc, tile, profile)
    if key in _calibration_seen:
        return
    _calibration_seen.add(key)
    telemetry.counter(
        "engine", "knn_tile", float(tile), Lc=Lc, profile=profile,
        working_set_bytes=streaming_bytes(128, cfg.k_max, tile, cfg.E_max,
                                          cfg.dist_dtype),
    )


def merge_topk_sorted(run_i, run_d, new_i, new_d, k: int):
    """Bitonic partial merge network for two sorted top-k lists.

    run_i/run_d: (..., k) running top-k, ascending by (distance, arrival
    order); new_i/new_d: (..., m <= k) incoming tile top-k, ascending in
    its own arrival order.  Returns (idx, dist), each (..., k): the top-k
    of the union, ascending, ties resolved running-before-new and
    earlier-position-first within each list — exactly the
    ``lax.top_k(concat([running, tile]))`` rule of the old merge, but as
    a fixed O(k log k) comparator network instead of an O((k + tile)
    log(k + tile))-class selection over the whole buffer.

    Mechanics: pad both lists to K = next_pow2(k) with (+inf, id 2^31-1)
    sentinels, attach explicit arrival ranks (running 0..K-1, new
    K..2K-1) so the comparator key (distance, rank) is a strict total
    order, lay out [running | reverse(new)] — ascending then descending,
    i.e. bitonic — and run the log2(2K) halving compare-exchange stages.
    (dist, id, rank) triples travel together through every exchange, so
    the output order is deterministic and partition-independent; padding
    sentinels order strictly after every real entry and can only surface
    in the k > (real candidates) cases the builders reject.  Runs
    unchanged inside the Pallas kernels (pure jnp ops on the VPU) and in
    the jnp builders — one definition for the whole bit-identity
    contract.
    """
    K = _next_pow2(k)

    def _pad(i, d, rank0):
        w = d.shape[-1]
        pad = K - w
        if pad:
            shp = d.shape[:-1] + (pad,)
            d = jnp.concatenate(
                [d, jnp.full(shp, jnp.inf, jnp.float32)], axis=-1
            )
            i = jnp.concatenate(
                [i, jnp.full(shp, 2147483647, jnp.int32)], axis=-1
            )
        pos = jax.lax.broadcasted_iota(jnp.int32, d.shape, d.ndim - 1)
        r = rank0 + pos
        if pad:
            # Padding sentinels rank after EVERY real entry of BOTH lists
            # (2K offset), not just after their own list: a real entry can
            # legitimately carry dist=+inf (masked self / shard-padding
            # column, k == Lc), and the (dist, rank) key must still order
            # it before synthetic padding — the shard-merge tree (SS14)
            # feeds such lists; interior sentinels ranking between the two
            # lists would beat the new list's genuine +inf entries and
            # break the lax.top_k (distance, id) tie contract.
            r = jnp.where(pos >= w, r + 2 * K, r)
        return i, d, r

    ai, ad, ar = _pad(run_i, run_d, 0)
    bi, bd, br = _pad(new_i, new_d, K)
    d = jnp.concatenate([ad, bd[..., ::-1]], axis=-1)
    i = jnp.concatenate([ai, bi[..., ::-1]], axis=-1)
    r = jnp.concatenate([ar, br[..., ::-1]], axis=-1)
    lead = d.shape[:-1]
    s = K
    while s >= 1:
        shape = lead + (K // s, 2, s)
        dv = d.reshape(shape)
        d_lo, d_hi = dv[..., 0, :], dv[..., 1, :]
        iv = i.reshape(shape)
        i_lo, i_hi = iv[..., 0, :], iv[..., 1, :]
        rv = r.reshape(shape)
        r_lo, r_hi = rv[..., 0, :], rv[..., 1, :]
        sw = (d_lo > d_hi) | ((d_lo == d_hi) & (r_lo > r_hi))

        def _apply(lo, hi, sw=sw, shape=shape, lead=lead):
            return jnp.stack(
                [jnp.where(sw, hi, lo), jnp.where(sw, lo, hi)], axis=-2
            ).reshape(lead + (2 * K,))

        d = _apply(d_lo, d_hi)
        i = _apply(i_lo, i_hi)
        r = _apply(r_lo, r_hi)
        s //= 2
    return i[..., :k], d[..., :k]

# Trace-time instrumentation: total (Lq, k) table rows selected by the
# builders below, keyed by builder kind.  jit caches traces, so tests that
# assert on these counters must use fresh shapes/configs (or call the
# builders un-jitted); see tests/test_engine.py.
TABLE_ROWS_BUILT = {"all_E": 0, "bucketed": 0}


def reset_table_counters() -> None:
    for k in TABLE_ROWS_BUILT:
        TABLE_ROWS_BUILT[k] = 0


def _acc_sq(D: jax.Array, vq: jax.Array, vc: jax.Array, dist_dtype) -> jax.Array:
    """One cumulative-E distance update with PINNED square-then-add rounding.

    LLVM contracts ``D + (vq - vc)**2`` into an FMA inside some XLA:CPU
    fusions but not others (scan body vs unrolled, dense vs tile shapes),
    shifting results by 1 ulp and breaking the dense==streaming
    bit-identity contract (DESIGN.md SS8).  The ``maximum(sq, 0)`` guard —
    numerically exact, squares are non-negative — sits between the
    multiply and the add, so no context can contract them; every
    cumulative builder (dense oracle, bucketed, streaming, single-E)
    therefore runs the identical square-then-add float sequence.  ``optimization_barrier`` does NOT
    work here: it is dropped before the fusion/codegen stage that decides
    contraction, and ``abs`` is folded by the algebraic simplifier.
    """
    sq = jnp.square(vq[:, None] - vc[None, :]).astype(dist_dtype)
    return D + jnp.maximum(sq, jnp.zeros((), dist_dtype))


def knn_tables_dense(
    Vq: jax.Array,
    Vc: jax.Array,
    k_max: int,
    exclude_self: bool,
    impl: str = "scan",
    dist_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """DENSE ORACLE: kNN tables for every embedding dimension 1..E_max by
    materializing the full (Lq, Lc) distance matrix and lax.top_k-ing it
    per E.  No engine routes here any more — selection is always
    streaming — but this builder is the independent oracle the streaming
    bit-identity tests and the benchmark historical-reference column
    compare against, and the knn_impl A/B surface.

    Vq: (E_max, Lq) query lag matrix; Vc: (E_max, Lc) candidate lag matrix.
    Returns (indices, sq_dists), each (E_max, Lq, k_max); row e holds the
    k_max nearest candidates under the dimension-(e+1) embedding distance.
    exclude_self requires Vq and Vc to be the same point set (CCM tables).

    impl (SSPerf hillclimb #3 knobs):
      scan    — cumulative-E lax.scan over lag increments (baseline);
      unroll  — same recurrence, python loop: XLA fuses the D update with
                the following top_k read, cutting D HBM round-trips;
      rebuild — per-E from-scratch matmul-form distances (O(L^2 E) each):
                more MXU FLOPs, ~1/3 less D traffic — for compute-starved,
                memory-bound cells.
    dist_dtype: bfloat16 halves D traffic at ~1e-2 relative distance error
                (neighbour sets may differ on near-ties; opt-in).
    """
    E_max, Lq = Vq.shape
    Lc = Vc.shape[1]
    if exclude_self and Lq != Lc:
        raise ValueError("exclude_self requires query set == candidate set")
    if impl.startswith("blocked"):
        # fall back to fully-unrolled when the block size doesn't divide E_max
        g = int(impl.split(":")[1]) if ":" in impl else 4
        if E_max % g != 0:
            impl = "unroll"
    TABLE_ROWS_BUILT["all_E"] += E_max
    self_mask = (
        jnp.eye(Lq, dtype=bool) if exclude_self else jnp.zeros((Lq, Lc), bool)
    )

    def select(D):
        Dm = jnp.where(self_mask, INF, D.astype(jnp.float32))
        neg_d, idx = jax.lax.top_k(-Dm, k_max)
        return idx.astype(jnp.int32), -neg_d

    if impl == "rebuild":
        outs = [
            select(_matmul_sq_dists(Vq[:E], Vc[:E]).astype(dist_dtype))
            for E in range(1, E_max + 1)
        ]
        indices = jnp.stack([o[0] for o in outs])
        sq_dists = jnp.stack([o[1] for o in outs])
        return indices, sq_dists

    def step(D, vs):
        vq, vc = vs
        D = _acc_sq(D, vq, vc, dist_dtype)
        return D, select(D)

    D0 = jnp.zeros((Lq, Lc), dist_dtype)
    if impl == "unroll":
        outs = []
        D = D0
        for e in range(E_max):
            D, out = step(D, (Vq[e], Vc[e]))
            outs.append(out)
        indices = jnp.stack([o[0] for o in outs])
        sq_dists = jnp.stack([o[1] for o in outs])
        return indices, sq_dists
    if impl.startswith("blocked"):
        # scan over E-blocks of g unrolled steps: D-matrix HBM round-trips
        # drop ~g-fold (XLA fuses within a block) while only ~g distance
        # matrices stay live — the peak-vs-traffic frontier knob (HC3 #5).
        def block_step(D, vs_blk):
            vq_b, vc_b = vs_blk  # (g, Lq), (g, Lc)
            outs = []
            for e in range(g):
                D, out = step(D, (vq_b[e], vc_b[e]))
                outs.append(out)
            idx = jnp.stack([o[0] for o in outs])
            d = jnp.stack([o[1] for o in outs])
            return D, (idx, d)

        nb = E_max // g
        _, (indices, sq_dists) = jax.lax.scan(
            block_step,
            D0,
            (Vq.reshape(nb, g, Lq), Vc.reshape(nb, g, Lc)),
        )
        return indices.reshape(E_max, Lq, -1), sq_dists.reshape(E_max, Lq, -1)
    _, (indices, sq_dists) = jax.lax.scan(step, D0, (Vq, Vc))
    return indices, sq_dists


def knn_tables_bucketed_dense(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    buckets: tuple[int, ...],
    impl: str = "unroll",
    dist_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """DENSE ORACLE, bucketed: tables only for the dimensions in
    ``buckets`` via the full (Lq, Lc) distance matrix.  Test/bench oracle
    only — every engine builds bucketed tables with the streaming merge
    network (:func:`knn_tables_bucketed_streaming`).

    Phase-2 CCM never reads a table row whose E is absent from optE, so
    building just the distinct-optE bucket set (DESIGN.md SS3) cuts both
    the top-k work and the stacked-table footprint by len(buckets)/E_max.
    The distance accumulation still sweeps e = 1..max(buckets) (the prefix
    recurrence needs every lag), but the O(Lq*Lc*k)-ish selection — the
    dominant term at paper k — runs only at bucket dimensions, and lags
    above max(buckets) are never touched.

    buckets: static ascending tuple of distinct E values (1-based).
    impl: "rebuild" builds each bucket's distances from scratch in matmul
    form (the knn_tables_dense "rebuild" numerics: near-ties may order
    differently); every other value uses the unrolled cumulative
    recurrence, whose sparse selection makes the scan/blocked sweep
    shapings moot.  Returns (idx, sq_dists), each (len(buckets), Lq, k);
    row b holds the table for embedding dimension buckets[b].  Cumulative
    numerics are bit-identical to the matching rows of the cumulative
    knn_tables_dense variants (same termwise-sequential accumulation
    order).
    """
    if not buckets or list(buckets) != sorted(set(buckets)):
        raise ValueError(f"buckets must be ascending and distinct: {buckets}")
    E_max, Lq = Vq.shape
    Lc = Vc.shape[1]
    if buckets[-1] > E_max:
        raise ValueError(f"bucket E {buckets[-1]} exceeds lag rows {E_max}")
    if exclude_self and Lq != Lc:
        raise ValueError("exclude_self requires query set == candidate set")
    TABLE_ROWS_BUILT["bucketed"] += len(buckets)
    self_mask = (
        jnp.eye(Lq, dtype=bool) if exclude_self else jnp.zeros((Lq, Lc), bool)
    )

    def select(D):
        Dm = jnp.where(self_mask, INF, D.astype(jnp.float32))
        neg_d, idx = jax.lax.top_k(-Dm, k)
        return idx.astype(jnp.int32), -neg_d

    if impl == "rebuild":
        outs = [
            select(_matmul_sq_dists(Vq[:E], Vc[:E]).astype(dist_dtype))
            for E in buckets
        ]
    else:
        want = set(buckets)
        outs = []
        D = jnp.zeros((Lq, Lc), dist_dtype)
        for e in range(buckets[-1]):
            D = _acc_sq(D, Vq[e], Vc[e], dist_dtype)
            if e + 1 in want:
                outs.append(select(D))
    indices = jnp.stack([o[0] for o in outs])
    sq_dists = jnp.stack([o[1] for o in outs])
    return indices, sq_dists


# ------------------------------------------- streaming candidate-tiled path
def _knn_tables_streaming(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    tile_c: int,
    select_Es: tuple[int, ...],
    dist_dtype,
    col_offset=0,
    col_hi=None,
) -> tuple[jax.Array, jax.Array]:
    """Candidate-tiled kNN selection: no (Lq, Lc) distance matrix, ever.

    Scans candidate tiles of width ``tile_c``; within each tile the
    cumulative-E recurrence accumulates a (Lq, tile_c) distance block, and
    at every E in ``select_Es`` the tile is partial-sorted to its own
    top-k (lax.top_k over tile_c columns) and folded into the running
    sorted (Lq, k) table with the :func:`merge_topk_sorted` comparator
    network — O(k log k) per merge, independent of tile width.  The peak
    distance working set is O(Lq * tile_c) + the (n_sel, Lq, k) carry —
    independent of Lc (DESIGN.md SS8).

    BIT-IDENTITY with ``lax.top_k`` over the full candidate row — and
    hence with the CUMULATIVE dense-oracle impls (scan/unroll/blocked,
    NOT the matmul-form ``rebuild`` A/B shape, whose near-tie ordering
    already differs from them) — values AND tie order, argument:
    per-element distances accumulate lag terms in the same sequential
    order, so they are bit-equal to the dense oracle's; lax.top_k breaks
    value ties by lowest position; the running list is kept sorted by
    (distance, arrival), tile entries excluded from a tile's own top-k
    can never reach the union top-k, and the merge network's rank key
    orders running entries (globally earlier candidates, by induction —
    the first tile is selected directly with no synthetic carry) before
    tile entries and tile entries by ascending position — so equal
    distances always resolve to the lowest candidate id, exactly the
    lax.top_k rule.  Holds for every k <= Lc, including all-tied
    (dead/duplicate-neuron) rows, and for ANY tile partition.

    ``col_offset``/``col_hi`` (library sharding, DESIGN.md SS8): candidate
    column j of Vc is GLOBAL candidate ``col_offset + j``; columns at or
    beyond ``col_hi`` (default col_offset + Lc) are padding and masked to
    +inf.  ``exclude_self`` masks global column == query row.  Both may be
    traced scalars, so per-shard builds jit/shard_map with one trace.
    """
    if not select_Es or list(select_Es) != sorted(set(select_Es)):
        raise ValueError(f"select_Es must be ascending, distinct: {select_Es}")
    E_hi = select_Es[-1]
    E_rows, Lq = Vq.shape
    Lc = Vc.shape[1]
    if E_hi > E_rows:
        raise ValueError(f"selection E {E_hi} exceeds lag rows {E_rows}")
    if k > Lc:
        raise ValueError(f"k={k} exceeds candidate count Lc={Lc}")
    # First tile selects directly (no synthetic carry entries), so it must
    # be at least k wide; clamping also avoids over-padding tiny libraries.
    tile_c = max(k, min(tile_c, Lc))
    n_tiles = -(-Lc // tile_c)
    # Balance tile widths under the calibrated cap: the same number of
    # tiles, each ceil(Lc / n_tiles) wide, so the sweep pays at most
    # n_tiles - 1 padded columns instead of a whole ragged tail tile
    # (Lc=16000 under an 8192 cap -> 2 x 8000, zero padding).
    tile_c = max(k, -(-Lc // n_tiles))
    Vq = Vq[:E_hi]
    Vc = jnp.pad(Vc[:E_hi], ((0, 0), (0, n_tiles * tile_c - Lc)))
    tiles = Vc.reshape(E_hi, n_tiles, tile_c).transpose(1, 0, 2)
    starts = jnp.arange(n_tiles, dtype=jnp.int32) * tile_c
    if col_hi is None:
        col_hi = col_offset + Lc
    want = set(select_Es)
    row_ids = jnp.arange(Lq, dtype=jnp.int32)[:, None]

    def tile_tables(run, vc_t, start):
        cols = col_offset + start + jnp.arange(tile_c, dtype=jnp.int32)[None, :]
        invalid = jnp.broadcast_to(cols >= col_hi, (Lq, tile_c))
        if exclude_self:
            invalid = invalid | (cols == row_ids)
        D = jnp.zeros((Lq, tile_c), dist_dtype)
        out_i, out_d = [], []
        for e in range(E_hi):
            D = _acc_sq(D, Vq[e], vc_t[e], dist_dtype)
            if e + 1 not in want:
                continue
            # Partial-sort the tile to its own top-k (sorted by distance,
            # then position; mask and negate in one pass — -inf marks
            # invalid columns, equivalent to +inf before negation).
            neg_d, pos = jax.lax.top_k(
                jnp.where(invalid, -INF, -D.astype(jnp.float32)), k
            )
            # tile ids are affine in position (col_offset + start + j for
            # every column, valid or masked), so the id gather is an add
            out_i.append((pos + start + col_offset).astype(jnp.int32))
            out_d.append(-neg_d)
        t_i, t_d = jnp.stack(out_i), jnp.stack(out_d)
        if run is None:
            return t_i, t_d
        # ONE comparator-network merge batched over every selected E —
        # same O(k log k) exchanges per row, 1/n_sel the op dispatches —
        # folding the tile top-ks into the sorted running lists; never a
        # (k + tile_c) buffer.
        return merge_topk_sorted(run[0], run[1], t_i, t_d, k)

    carry = tile_tables(None, tiles[0], starts[0])
    if n_tiles == 1:
        return carry

    def step(run, xs):
        return tile_tables(run, xs[0], xs[1]), None

    (idx, dist), _ = jax.lax.scan(step, carry, (tiles[1:], starts[1:]))
    return idx, dist


def knn_tables_all_E_streaming(
    Vq: jax.Array,
    Vc: jax.Array,
    k_max: int,
    exclude_self: bool,
    tile_c: int,
    dist_dtype=jnp.float32,
    col_offset=0,
    col_hi=None,
) -> tuple[jax.Array, jax.Array]:
    """All-E streaming tables — identical (idx, sq_dists) to the dense
    oracle :func:`knn_tables_dense` (cumulative impls), (E_max, Lq, k_max)
    each, built without ever materializing the (Lq, Lc) distance matrix
    (DESIGN.md SS8).  THE engine selection path for phase 1 / unbucketed
    phase 2."""
    E_max, Lq = Vq.shape
    unsharded = col_hi is None and isinstance(col_offset, int) and col_offset == 0
    if exclude_self and unsharded and Lq != Vc.shape[1]:
        raise ValueError("exclude_self requires query set == candidate set")
    TABLE_ROWS_BUILT["all_E"] += E_max
    return _knn_tables_streaming(
        Vq, Vc, k_max, exclude_self, tile_c,
        tuple(range(1, E_max + 1)), dist_dtype, col_offset, col_hi,
    )


def knn_tables_bucketed_streaming(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    buckets: tuple[int, ...],
    tile_c: int,
    dist_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Bucketed streaming tables — identical (len(buckets), Lq, k) tables
    to the dense oracle :func:`knn_tables_bucketed_dense`; the per-tile
    distance accumulation still sweeps e = 1..max(buckets) but selection
    (and the carry) exists only at bucket dimensions.  THE engine
    selection path for bucketed phase 2."""
    if not buckets or list(buckets) != sorted(set(buckets)):
        raise ValueError(f"buckets must be ascending and distinct: {buckets}")
    if exclude_self and Vq.shape[1] != Vc.shape[1]:
        raise ValueError("exclude_self requires query set == candidate set")
    TABLE_ROWS_BUILT["bucketed"] += len(buckets)
    return _knn_tables_streaming(
        Vq, Vc, k, exclude_self, tile_c, tuple(buckets), dist_dtype
    )


# --------------------------------------- prefix-snapshot path (DESIGN SS9)
def _check_prefix_args(
    Lq: int, Lc: int, k: int, exclude_self: bool,
    buckets: tuple[int, ...], lib_sizes: tuple[int, ...], E_rows: int,
    col_ids,
) -> None:
    if not buckets or list(buckets) != sorted(set(buckets)):
        raise ValueError(f"buckets must be ascending and distinct: {buckets}")
    if buckets[-1] > E_rows:
        raise ValueError(f"bucket E {buckets[-1]} exceeds lag rows {E_rows}")
    if not lib_sizes or list(lib_sizes) != sorted(set(lib_sizes)):
        raise ValueError(
            f"lib_sizes must be ascending and distinct: {lib_sizes}"
        )
    if lib_sizes[-1] > Lc:
        raise ValueError(
            f"lib_sizes[-1]={lib_sizes[-1]} exceeds candidate count Lc={Lc}"
        )
    # Every query row must find k REAL neighbours inside the smallest
    # library; with self-exclusion one prefix column may be the query
    # itself, so one extra candidate is required.
    need = k + 1 if exclude_self else k
    if lib_sizes[0] < need:
        raise ValueError(
            f"lib_sizes[0]={lib_sizes[0]} too small for k={k} neighbours"
            + (" with self-exclusion" if exclude_self else "")
            + "; raise the smallest library size or shrink k"
        )
    if exclude_self and col_ids is None and Lq != Lc:
        raise ValueError("exclude_self requires query set == candidate set")


def _prefix_tile_bounds(
    lib_sizes: tuple[int, ...], tile_c: int
) -> list[tuple[int, int]]:
    """Candidate-tile [start, stop) spans covering [0, lib_sizes[-1]) that
    never CROSS a library-size boundary, so the running carry after the
    tile ending at each boundary IS that prefix's table."""
    bounds = []
    lo = 0
    for hi in lib_sizes:
        for s in range(lo, hi, tile_c):
            bounds.append((s, min(s + tile_c, hi)))
        lo = hi
    return bounds


def knn_tables_prefix_streaming(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    buckets: tuple[int, ...],
    lib_sizes: tuple[int, ...],
    tile_c: int,
    dist_dtype=jnp.float32,
    col_ids: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """ONE-sweep prefix-snapshot kNN tables (DESIGN.md SS9).

    Returns (idx, sq_dists), each (S, len(buckets), Lq, k) where
    S = len(lib_sizes): slice s holds, for every bucket dimension, the
    top-k table restricted to candidate COLUMNS [0, lib_sizes[s]) — the
    nested library prefixes of the CCM convergence diagnostic — built in
    a single candidate sweep by snapshotting the streaming running carry
    at each prefix boundary (vs S full per-size rebuilds).

    Tiles are the streaming merge of SS8 with boundaries clipped so no
    tile crosses a prefix edge; the carry after the tile ending at
    lib_sizes[s] is exactly the table a from-scratch build over the first
    lib_sizes[s] columns produces (same per-element accumulation order,
    same lowest-position tie rule), so snapshots are BIT-IDENTICAL to
    independently built per-size tables (:func:`knn_tables_prefix_rebuild`).

    ``col_ids``: optional (Lc,) int32 candidate PERMUTATION: position j
    of the sweep order holds candidate COLUMN col_ids[j] of Vc, so the
    size-Ls library is the random subset {col_ids[0], ..., col_ids[Ls-1]}
    — the seeded nested subsampling of the convergence diagnostic.  The
    builder gathers the permuted columns tile by tile; emitted indices
    are ORIGINAL candidate ids, directly usable against unpermuted
    target futures, and ``exclude_self`` masks col_ids[j] == query row.
    None = natural order (ids = positions).
    """
    E_rows, Lq = Vq.shape
    Lc = Vc.shape[1]
    _check_prefix_args(
        Lq, Lc, k, exclude_self, buckets, lib_sizes, E_rows, col_ids
    )
    E_hi = buckets[-1]
    # The first tile selects directly (no carry), so it must be at least k
    # wide; its width is min(tile_c, lib_sizes[0]) and lib_sizes[0] >= k is
    # validated above, hence clamping tile_c up to k suffices.  tile_c is
    # deliberately NOT clamped down to lib_sizes[0]: segments between
    # boundaries should stay whole (one merge per snapshot gap) whenever
    # they fit a tile — splitting them only adds merge overhead.
    tile_c = max(k + 1 if exclude_self else k, tile_c)
    want = set(buckets)
    Vq = Vq[:E_hi]
    row_ids = jnp.arange(Lq, dtype=jnp.int32)[:, None]
    boundary = set(lib_sizes)

    run_i = run_d = None
    snaps_i, snaps_d = [], []
    for start, stop in _prefix_tile_bounds(lib_sizes, tile_c):
        width = stop - start
        if col_ids is None:
            vc_t = jax.lax.slice(Vc, (0, start), (E_hi, stop))
            ids = start + jnp.arange(width, dtype=jnp.int32)
        else:
            ids = jax.lax.slice_in_dim(col_ids, start, stop).astype(jnp.int32)
            vc_t = jnp.take(Vc[:E_hi], ids, axis=1)
        ids_b = jnp.broadcast_to(ids[None, :], (Lq, width))
        invalid = (ids_b == row_ids) if exclude_self else None
        D = jnp.zeros((Lq, width), dist_dtype)
        dms = []
        for e in range(E_hi):
            D = _acc_sq(D, Vq[e], vc_t[e], dist_dtype)
            if e + 1 not in want:
                continue
            Dm = D.astype(jnp.float32)
            if invalid is not None:
                Dm = jnp.where(invalid, INF, Dm)
            dms.append(Dm)
        # ONE batched tile partial-sort + merge network per tile across
        # all bucket dimensions (top_k and the comparator network batch
        # over leading axes) — bit-identical to per-bucket merges but
        # with len(buckets) x fewer host-visible ops, which is what
        # keeps the per-tile constant below a from-scratch rebuild's.
        # Clipped boundary tiles can be narrower than k: the tile's own
        # top-k is then just its full sorted width, padded to k with
        # +inf sentinels inside merge_topk_sorted.
        Dsel = jnp.stack(dms)  # (nb, Lq, width)
        ids_nb = jnp.broadcast_to(ids_b, Dsel.shape)
        neg_d, pos = jax.lax.top_k(-Dsel, min(k, width))
        t_i = jnp.take_along_axis(ids_nb, pos, axis=-1)
        t_d = -neg_d
        if run_i is None:
            run_i, run_d = t_i, t_d  # first tile is >= k wide (validated)
        else:
            run_i, run_d = merge_topk_sorted(run_i, run_d, t_i, t_d, k)
        if stop in boundary:
            snaps_i.append(run_i)
            snaps_d.append(run_d)
    return jnp.stack(snaps_i), jnp.stack(snaps_d)


def knn_tables_prefix_rebuild(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    buckets: tuple[int, ...],
    lib_sizes: tuple[int, ...],
    tile_c: int,
    dist_dtype=jnp.float32,
    col_ids: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Old-style per-size convergence tables: S INDEPENDENT sweeps, one per
    library size (what every path did before the prefix-snapshot builder).

    Same contract and bit-identical output to
    :func:`knn_tables_prefix_streaming`; kept as the engine base-class
    fallback and the A/B baseline of ``benchmarks/run.py significance``.
    """
    _check_prefix_args(  # validate the FULL size tuple, not just each Ls
        Vq.shape[1], Vc.shape[1], k, exclude_self, buckets, lib_sizes,
        Vq.shape[0], col_ids,
    )
    outs = [
        knn_tables_prefix_streaming(
            Vq, Vc, k, exclude_self, buckets, (Ls,), tile_c, dist_dtype,
            col_ids,
        )
        for Ls in lib_sizes
    ]
    return (
        jnp.concatenate([o[0] for o in outs]),
        jnp.concatenate([o[1] for o in outs]),
    )


def merge_topk_tree(idx_parts, dist_parts, k: int):
    """Device-side tree reduction of per-candidate-shard top-k tables to
    the global top-k (DESIGN.md SS14) — the jnp replacement for the host
    :func:`merge_shard_tables` oracle.

    idx_parts / dist_parts: sequences of (..., Lq, k_s) shard tables in
    ASCENDING ``col_offset`` order, indices GLOBAL candidate ids.  Folds
    contiguous pairs through :func:`merge_topk_sorted` (the PR-6 bitonic
    partial merge network), so the whole reduction is O(log S) merge
    levels of fixed comparator networks — no sorts, no host round-trip.

    Tie rule (proof sketch, expanded in DESIGN.md SS14): the network
    resolves distance ties running-before-new; pairs are always
    contiguous ascending shard blocks, and every id in a left block is
    strictly smaller than every id in a right block, so
    running-before-new IS the (distance, id) lexicographic key of
    lax.top_k / :func:`merge_shard_tables` — bit-for-bit, ties included.
    Each level merges to width ``min(k, w_a + w_b)`` rather than k so no
    +inf/id-2^31-1 padding sentinel is ever introduced: a sentinel
    carries an arrival rank, not a global id, and could otherwise
    outrank a later shard's genuine masked entry in the k == Lc
    exclude-self edge case.
    """
    parts = list(zip(list(idx_parts), list(dist_parts)))
    if not parts:
        raise ValueError("merge_topk_tree needs at least one shard table")
    while len(parts) > 1:
        nxt = []
        for a in range(0, len(parts) - 1, 2):
            (ia, da), (ib, db) = parts[a], parts[a + 1]
            kk = min(k, ia.shape[-1] + ib.shape[-1])
            nxt.append(merge_topk_sorted(ia, da, ib, db, kk))
        if len(parts) % 2:
            nxt.append(parts[-1])
        parts = nxt
    idx, dist = parts[0]
    return idx[..., :k], dist[..., :k]


def merge_topk_collective(idx, dist, k: int, axis_name: str):
    """Collective shard-table merge INSIDE a shard_map (DESIGN.md SS14).

    idx / dist: this device's (..., Lq, k_s) candidate-shard top-k table
    (global ids via ``col_offset``), where device i along ``axis_name``
    holds the i-th contiguous candidate shard.  Returns the GLOBAL
    (..., Lq, k) top-k, replicated on every device — the paper-scale
    all-reduce that keeps the reduction on the interconnect instead of
    funnelling every shard through the host.

    Power-of-two axis: a ppermute butterfly — round r exchanges tables
    with partner ``i XOR 2^r``, each device keeps the merged top-k of
    its aligned 2^(r+1)-shard block, log2(W) rounds total, per-round
    traffic one table.  The XOR partner of an aligned block is always
    the adjacent block of the same size, so run/new assignment by block
    side preserves the ascending-contiguous invariant that makes
    running-before-new equal the (distance, id) tie rule (see
    :func:`merge_topk_tree`).  Other axis sizes: one all_gather + the
    same contiguous tree fold on every device.
    """
    W = jax.lax.psum(1, axis_name)
    if W == 1:
        return idx[..., :k], dist[..., :k]
    if W & (W - 1) == 0:
        me = jax.lax.axis_index(axis_name)
        step = 1
        while step < W:
            perm = [(i, i ^ step) for i in range(W)]
            oi = jax.lax.ppermute(idx, axis_name, perm)
            od = jax.lax.ppermute(dist, axis_name, perm)
            left = (me & step) == 0
            kk = min(k, idx.shape[-1] + oi.shape[-1])
            idx, dist = merge_topk_sorted(
                jnp.where(left, idx, oi),
                jnp.where(left, dist, od),
                jnp.where(left, oi, idx),
                jnp.where(left, od, dist),
                kk,
            )
            step *= 2
        return idx[..., :k], dist[..., :k]
    gi = jax.lax.all_gather(idx, axis_name)
    gd = jax.lax.all_gather(dist, axis_name)
    return merge_topk_tree(list(gi), list(gd), k)


def merge_shard_tables(
    idx_parts, dist_parts, k: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side reduction of per-candidate-shard top-k tables to the
    global top-k (DESIGN.md SS8/SS14).

    .. deprecated:: PR 10
        The pipeline now merges on-device (:func:`merge_topk_tree` /
        :func:`merge_topk_collective`); this np.lexsort path is kept as
        the ORACLE the device collective is bit-checked against (and for
        host-only tooling/tests).  New code should not call it on the
        hot path.

    idx_parts / dist_parts: sequences of (..., Lq, k_s) tables whose
    indices are GLOBAL candidate ids (each shard selected over its own
    candidate slice via ``col_offset``).  The merge key is
    (distance ascending, id ascending) — exactly lax.top_k's tie rule —
    so merging shard tables reproduces the unsharded streaming table
    bit-for-bit whenever k <= the global candidate count.
    """
    idx = np.concatenate([np.asarray(p) for p in idx_parts], axis=-1)
    dist = np.concatenate([np.asarray(p) for p in dist_parts], axis=-1)
    if k is None:
        k = min(np.asarray(p).shape[-1] for p in idx_parts)
    order = np.lexsort((idx, dist))[..., :k]
    return (
        np.take_along_axis(idx, order, axis=-1),
        np.take_along_axis(dist, order, axis=-1),
    )


def _matmul_sq_dists(dq: jax.Array, dc: jax.Array) -> jax.Array:
    """|q - c|^2 = |q|^2 + |c|^2 - 2 q.c — the MXU form."""
    D = (
        jnp.sum(dq * dq, axis=0)[:, None]
        + jnp.sum(dc * dc, axis=0)[None, :]
        - 2.0 * (dq.T @ dc)
    )
    return jnp.maximum(D, 0.0)


def knn_table_single_E(
    Vq: jax.Array,
    Vc: jax.Array,
    E: int,
    k: int,
    exclude_self: bool,
    *,
    matmul_form: bool = False,
    candidate_mask: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Single-E kNN table, computed from scratch (cppEDM / Alg. 3 semantics).

    Used by the naive baseline and as an oracle for the Pallas kernel.

    matmul_form=False accumulates lag terms sequentially — bit-identical to
    the cumulative scan in knn_tables_dense, so naive vs improved equivalence
    tests are exact.  matmul_form=True uses |q|^2 + |c|^2 - 2 q.c, the
    MXU-friendly form the Pallas kernel implements.
    candidate_mask: optional (Lc,) bool — library subsampling for the CCM
    convergence diagnostic; excluded candidates get +inf distance.
    """
    dq = Vq[:E]  # (E, Lq)
    dc = Vc[:E]
    if matmul_form:
        D = _matmul_sq_dists(dq, dc)
    else:
        D = jnp.zeros((Vq.shape[1], Vc.shape[1]), jnp.float32)
        for e in range(E):  # sequential, same fp order as the scan
            D = _acc_sq(D, dq[e], dc[e], jnp.float32)
    if exclude_self:
        D = jnp.where(jnp.eye(Vq.shape[1], dtype=bool), INF, D)
    if candidate_mask is not None:
        D = jnp.where(candidate_mask[None, :], D, INF)
    neg_d, idx = jax.lax.top_k(-D, k)
    return idx.astype(jnp.int32), -neg_d


def tables_with_weights(
    indices: jax.Array, sq_dists: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Convert stacked per-E tables to (indices, normalized weights).

    For table e (embedding dimension E = e+1), only the first E+1 neighbours
    carry weight; the padding lets all E share one array shape.
    """
    E_max = indices.shape[0]
    k_valid = jnp.arange(1, E_max + 1)[:, None, None] + 1  # (E_max, 1, 1)
    w = simplex_weights(sq_dists, k_valid)
    return indices, w


def tables_with_weights_bucketed(
    indices: jax.Array, sq_dists: jax.Array, buckets: tuple[int, ...]
) -> tuple[jax.Array, jax.Array]:
    """tables_with_weights for a bucketed table stack (DESIGN.md SS3).

    Row b is the table for embedding dimension buckets[b], so its valid
    neighbour count is buckets[b] + 1 (instead of the dense row index + 2).
    """
    k_valid = jnp.asarray(buckets, jnp.int32)[:, None, None] + 1
    return indices, simplex_weights(sq_dists, k_valid)


def simplex_forecast(idx: jax.Array, w: jax.Array, fut_c: jax.Array) -> jax.Array:
    """lookup (paper Alg. 5): weighted average of candidate futures.

    idx, w: (..., Lq, k); fut_c: (Lc,) candidate future values.
    Returns predictions (..., Lq).
    """
    return jnp.sum(w * fut_c[idx], axis=-1)
