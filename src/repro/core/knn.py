"""kNN table construction — the paper's hot spot (97% of cppEDM runtime).

Two paths:
  * pure-jnp (this file): cumulative-E scan + lax.top_k.  Oracle + CPU path.
  * Pallas (kernels/knn_topk): same math tiled for MXU/VMEM.  TPU path.

The cumulative-E recurrence (DESIGN.md SS2) builds the squared-distance
matrix for every embedding dimension E in one O(Lq*Lc) sweep per E:

    D_E(t, s) = D_{E-1}(t, s) + (V[E-1, t] - V[E-1, s])^2

where V = lag_matrix(x).  mpEDM recomputes each D_E from scratch
(O(Lq*Lc*E) each, O(Lq*Lc*E_max^2) total); the recurrence is an E_max/2 x
algorithmic saving on table construction, with identical results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import embedding
from repro.core.stats import simplex_weights

INF = jnp.float32(jnp.inf)

# Trace-time instrumentation: total (Lq, k) table rows selected by the
# builders below, keyed by builder kind.  jit caches traces, so tests that
# assert on these counters must use fresh shapes/configs (or call the
# builders un-jitted); see tests/test_engine.py.
TABLE_ROWS_BUILT = {"all_E": 0, "bucketed": 0}


def reset_table_counters() -> None:
    for k in TABLE_ROWS_BUILT:
        TABLE_ROWS_BUILT[k] = 0


def knn_tables_all_E(
    Vq: jax.Array,
    Vc: jax.Array,
    k_max: int,
    exclude_self: bool,
    impl: str = "scan",
    dist_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """kNN tables for every embedding dimension 1..E_max in one pass.

    Vq: (E_max, Lq) query lag matrix; Vc: (E_max, Lc) candidate lag matrix.
    Returns (indices, sq_dists), each (E_max, Lq, k_max); row e holds the
    k_max nearest candidates under the dimension-(e+1) embedding distance.
    exclude_self requires Vq and Vc to be the same point set (CCM tables).

    impl (SSPerf hillclimb #3 knobs):
      scan    — cumulative-E lax.scan over lag increments (baseline);
      unroll  — same recurrence, python loop: XLA fuses the D update with
                the following top_k read, cutting D-slab HBM round-trips;
      rebuild — per-E from-scratch matmul-form distances (O(L^2 E) each):
                more MXU FLOPs, ~1/3 less D traffic — for compute-starved,
                memory-bound cells.
    dist_dtype: bfloat16 halves D traffic at ~1e-2 relative distance error
                (neighbour sets may differ on near-ties; opt-in).
    """
    E_max, Lq = Vq.shape
    Lc = Vc.shape[1]
    if exclude_self and Lq != Lc:
        raise ValueError("exclude_self requires query set == candidate set")
    if impl.startswith("blocked"):
        # fall back to fully-unrolled when the block size doesn't divide E_max
        g = int(impl.split(":")[1]) if ":" in impl else 4
        if E_max % g != 0:
            impl = "unroll"
    TABLE_ROWS_BUILT["all_E"] += E_max
    self_mask = (
        jnp.eye(Lq, dtype=bool) if exclude_self else jnp.zeros((Lq, Lc), bool)
    )

    def select(D):
        Dm = jnp.where(self_mask, INF, D.astype(jnp.float32))
        neg_d, idx = jax.lax.top_k(-Dm, k_max)
        return idx.astype(jnp.int32), -neg_d

    if impl == "rebuild":
        outs = [
            select(_matmul_sq_dists(Vq[:E], Vc[:E]).astype(dist_dtype))
            for E in range(1, E_max + 1)
        ]
        indices = jnp.stack([o[0] for o in outs])
        sq_dists = jnp.stack([o[1] for o in outs])
        return indices, sq_dists

    def step(D, vs):
        vq, vc = vs
        D = D + jnp.square(vq[:, None] - vc[None, :]).astype(dist_dtype)
        return D, select(D)

    D0 = jnp.zeros((Lq, Lc), dist_dtype)
    if impl == "unroll":
        outs = []
        D = D0
        for e in range(E_max):
            D, out = step(D, (Vq[e], Vc[e]))
            outs.append(out)
        indices = jnp.stack([o[0] for o in outs])
        sq_dists = jnp.stack([o[1] for o in outs])
        return indices, sq_dists
    if impl.startswith("blocked"):
        # scan over E-blocks of g unrolled steps: D-slab HBM round-trips
        # drop ~g-fold (XLA fuses within a block) while only ~g slabs stay
        # live — the peak-vs-traffic frontier knob (SSPerf HC3 #5).
        def block_step(D, vs_blk):
            vq_b, vc_b = vs_blk  # (g, Lq), (g, Lc)
            outs = []
            for e in range(g):
                D, out = step(D, (vq_b[e], vc_b[e]))
                outs.append(out)
            idx = jnp.stack([o[0] for o in outs])
            d = jnp.stack([o[1] for o in outs])
            return D, (idx, d)

        nb = E_max // g
        _, (indices, sq_dists) = jax.lax.scan(
            block_step,
            D0,
            (Vq.reshape(nb, g, Lq), Vc.reshape(nb, g, Lc)),
        )
        return indices.reshape(E_max, Lq, -1), sq_dists.reshape(E_max, Lq, -1)
    _, (indices, sq_dists) = jax.lax.scan(step, D0, (Vq, Vc))
    return indices, sq_dists


def knn_tables_bucketed(
    Vq: jax.Array,
    Vc: jax.Array,
    k: int,
    exclude_self: bool,
    buckets: tuple[int, ...],
    impl: str = "unroll",
    dist_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """kNN tables only for the embedding dimensions in ``buckets``.

    Phase-2 CCM never reads a table row whose E is absent from optE, so
    building just the distinct-optE bucket set (DESIGN.md SS3) cuts both
    the top-k work and the stacked-table footprint by len(buckets)/E_max.
    The distance accumulation still sweeps e = 1..max(buckets) (the prefix
    recurrence needs every lag), but the O(Lq*Lc*k)-ish selection — the
    dominant term at paper k — runs only at bucket dimensions, and lags
    above max(buckets) are never touched.

    buckets: static ascending tuple of distinct E values (1-based).
    impl: "rebuild" builds each bucket's distances from scratch in matmul
    form (the knn_tables_all_E "rebuild" numerics: near-ties may order
    differently); every other value uses the unrolled cumulative
    recurrence, whose sparse selection makes the scan/blocked sweep
    shapings moot.  Returns (idx, sq_dists), each (len(buckets), Lq, k);
    row b holds the table for embedding dimension buckets[b].  Cumulative
    numerics are bit-identical to the matching rows of the cumulative
    knn_tables_all_E variants (same termwise-sequential accumulation
    order).
    """
    if not buckets or list(buckets) != sorted(set(buckets)):
        raise ValueError(f"buckets must be ascending and distinct: {buckets}")
    E_max, Lq = Vq.shape
    Lc = Vc.shape[1]
    if buckets[-1] > E_max:
        raise ValueError(f"bucket E {buckets[-1]} exceeds lag rows {E_max}")
    if exclude_self and Lq != Lc:
        raise ValueError("exclude_self requires query set == candidate set")
    TABLE_ROWS_BUILT["bucketed"] += len(buckets)
    self_mask = (
        jnp.eye(Lq, dtype=bool) if exclude_self else jnp.zeros((Lq, Lc), bool)
    )

    def select(D):
        Dm = jnp.where(self_mask, INF, D.astype(jnp.float32))
        neg_d, idx = jax.lax.top_k(-Dm, k)
        return idx.astype(jnp.int32), -neg_d

    if impl == "rebuild":
        outs = [
            select(_matmul_sq_dists(Vq[:E], Vc[:E]).astype(dist_dtype))
            for E in buckets
        ]
    else:
        want = set(buckets)
        outs = []
        D = jnp.zeros((Lq, Lc), dist_dtype)
        for e in range(buckets[-1]):
            D = D + jnp.square(Vq[e][:, None] - Vc[e][None, :]).astype(dist_dtype)
            if e + 1 in want:
                outs.append(select(D))
    indices = jnp.stack([o[0] for o in outs])
    sq_dists = jnp.stack([o[1] for o in outs])
    return indices, sq_dists


def _matmul_sq_dists(dq: jax.Array, dc: jax.Array) -> jax.Array:
    """|q - c|^2 = |q|^2 + |c|^2 - 2 q.c — the MXU form."""
    D = (
        jnp.sum(dq * dq, axis=0)[:, None]
        + jnp.sum(dc * dc, axis=0)[None, :]
        - 2.0 * (dq.T @ dc)
    )
    return jnp.maximum(D, 0.0)


def knn_table_single_E(
    Vq: jax.Array,
    Vc: jax.Array,
    E: int,
    k: int,
    exclude_self: bool,
    *,
    matmul_form: bool = False,
    candidate_mask: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Single-E kNN table, computed from scratch (cppEDM / Alg. 3 semantics).

    Used by the naive baseline and as an oracle for the Pallas kernel.

    matmul_form=False accumulates lag terms sequentially — bit-identical to
    the cumulative scan in knn_tables_all_E, so naive vs improved equivalence
    tests are exact.  matmul_form=True uses |q|^2 + |c|^2 - 2 q.c, the
    MXU-friendly form the Pallas kernel implements.
    candidate_mask: optional (Lc,) bool — library subsampling for the CCM
    convergence diagnostic; excluded candidates get +inf distance.
    """
    dq = Vq[:E]  # (E, Lq)
    dc = Vc[:E]
    if matmul_form:
        D = (
            jnp.sum(dq * dq, axis=0)[:, None]
            + jnp.sum(dc * dc, axis=0)[None, :]
            - 2.0 * (dq.T @ dc)
        )
        D = jnp.maximum(D, 0.0)
    else:
        D = jnp.zeros((Vq.shape[1], Vc.shape[1]), jnp.float32)
        for e in range(E):  # sequential, same fp order as the scan
            D = D + jnp.square(dq[e][:, None] - dc[e][None, :])
    if exclude_self:
        D = jnp.where(jnp.eye(Vq.shape[1], dtype=bool), INF, D)
    if candidate_mask is not None:
        D = jnp.where(candidate_mask[None, :], D, INF)
    neg_d, idx = jax.lax.top_k(-D, k)
    return idx.astype(jnp.int32), -neg_d


def tables_with_weights(
    indices: jax.Array, sq_dists: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Convert stacked per-E tables to (indices, normalized weights).

    For table e (embedding dimension E = e+1), only the first E+1 neighbours
    carry weight; the padding lets all E share one array shape.
    """
    E_max = indices.shape[0]
    k_valid = jnp.arange(1, E_max + 1)[:, None, None] + 1  # (E_max, 1, 1)
    w = simplex_weights(sq_dists, k_valid)
    return indices, w


def tables_with_weights_bucketed(
    indices: jax.Array, sq_dists: jax.Array, buckets: tuple[int, ...]
) -> tuple[jax.Array, jax.Array]:
    """tables_with_weights for a bucketed table stack (DESIGN.md SS3).

    Row b is the table for embedding dimension buckets[b], so its valid
    neighbour count is buckets[b] + 1 (instead of the dense row index + 2).
    """
    k_valid = jnp.asarray(buckets, jnp.int32)[:, None, None] + 1
    return indices, simplex_weights(sq_dists, k_valid)


def simplex_forecast(idx: jax.Array, w: jax.Array, fut_c: jax.Array) -> jax.Array:
    """lookup (paper Alg. 5): weighted average of candidate futures.

    idx, w: (..., Lq, k); fut_c: (Lc,) candidate future values.
    Returns predictions (..., Lq).
    """
    return jnp.sum(w * fut_c[idx], axis=-1)
