"""Delay embedding (Takens) utilities.

All embeddings are *aligned on present time*: for a length-L series and a
maximum embedding dimension E_max, point index ``t`` refers to present time
``p(t) = t + (E_max - 1) * tau`` regardless of the actual embedding dimension
E <= E_max in use.  Dimension-E coordinates of point t are

    ( x[p(t)], x[p(t) - tau], ..., x[p(t) - (E-1) tau] )

This costs (E_max - E)*tau unusable points at the series head (negligible:
19 steps for E_max=20, tau=1 vs L >= 1450) and buys two things:

  * every E shares one point indexing -> kNN tables for all E stack into a
    single dense [E_max, Lp, k_max] array, and
  * the squared distance obeys the prefix recurrence
        D_E = D_{E-1} + outer_sq_diff(lag_{E-1})
    so all E_max tables cost O(L^2 E_max) instead of O(L^2 E_max^2)
    (beyond-paper optimization; DESIGN.md SS2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lag_matrix(x: jax.Array, E_max: int, tau: int, Lp: int) -> jax.Array:
    """Return V[k, t] = x[p(t) - k*tau] for k in [0, E_max), t in [0, Lp).

    V[:E] are exactly the dimension-E coordinates of every point.
    """
    offset = (E_max - 1) * tau
    idx = offset + jnp.arange(Lp)[None, :] - tau * jnp.arange(E_max)[:, None]
    return x[idx]


def delay_embed(x: jax.Array, E: int, tau: int, Tp: int = 0) -> jax.Array:
    """Classic standalone delay embedding: rows are points, columns lags.

    Point t has coordinates (x[t+(E-1)tau], ..., x[t]) — i.e. present time
    t + (E-1)tau.  Used by the oracle tests; the pipeline uses lag_matrix.
    """
    Lp = x.shape[0] - (E - 1) * tau - Tp
    idx = (E - 1) * tau + jnp.arange(Lp)[:, None] - tau * jnp.arange(E)[None, :]
    return x[idx]


def future_values(x: jax.Array, E_max: int, tau: int, Tp: int, Lp: int) -> jax.Array:
    """fut[t] = x[p(t) + Tp]: the value a simplex forecast of point t targets."""
    offset = (E_max - 1) * tau
    return x[offset + Tp + jnp.arange(Lp)]
