"""Phase 2 — Convergent Cross Mapping, mpEDM improved algorithm (paper Alg. 2).

Key idea reproduced from the paper: the kNN table depends only on the
*library* series, so per library series i we precompute tables once and
reuse them across all N targets — O(N L^2 E^2 + N^2 L E) vs cppEDM's
O(N^2 L^2 E).  Two table layouts (DESIGN.md SS3):

  * all-E      — tables for every E in 1..E_max (the paper's shape);
  * bucketed   — tables only for the DISTINCT optE values present, with
    targets grouped by bucket so every lookup batch shares one table
    (contiguous gathers; the layout kernels/ccm_lookup is built for).

Both produce the same causal map; the bucketed layout is the default in
EDMConfig and cuts table top-k work and footprint by len(buckets)/E_max.

rho[i, j] = pearson(ts_j_future, cross_map_prediction) — the skill of
predicting series j from library i's reconstructed manifold; high skill
means j CCM-causes i (paper SSII-B).

Phase 2 is additionally TILEABLE along the target (column) axis
(DESIGN.md SS7): table construction (`ccm_row_tables*` / the
`ccm_block_tables*` wrappers) is split from the lookup
(`ccm_row_lookup*` / `ccm_block_tile*`) so one kNN table set per
library chunk is reused across every column tile — tables depend only
on the library series, so tiling never rebuilds them — and only the
live (tile, Lp) slice of the target futures needs to be resident.
`cfg.target_tile = 0` keeps the single-tile path; both produce
bit-identical causal maps.

All device compute routes through the execution engine named by
cfg.engine (repro.engine; DESIGN.md SS5).  Table construction inside the
engines is candidate-tiled STREAMING selection (cfg.knn_tile_c = forced
or auto-calibrated tile width, DESIGN.md SS8) — every tile width yields
bit-identical tables, so every CCM path here is oblivious to the choice;
the flat-in-Lc working set is what keeps per-device table construction
inside the VMEM/HBM budget at paper-scale library lengths.  For
libraries too long for one device, pipeline.knn_tables_library_sharded
shards the CANDIDATE axis and reduces per-shard tables host-side.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engines
from repro.core import embedding, knn
from repro.core.stats import pearson
from repro.core.types import EDMConfig


# ---------------------------------------------------------------- bucketing
@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static phase-2 grouping of targets by optimal embedding dimension.

    buckets: ascending distinct E values present in optE;
    counts[b]: number of targets whose optE == buckets[b].
    Hashable -> usable as a static jit argument.
    """

    buckets: tuple[int, ...]
    counts: tuple[int, ...]

    @property
    def offsets(self) -> tuple[int, ...]:
        """Start offset of each bucket's segment in the sorted target order."""
        out, off = [], 0
        for c in self.counts:
            out.append(off)
            off += c
        return tuple(out)

    @property
    def n_targets(self) -> int:
        return sum(self.counts)


def make_bucket_plan(optE: np.ndarray) -> tuple[BucketPlan, np.ndarray]:
    """Group targets by optE.

    Returns (plan, order) where ``order`` (a host ndarray) permutes targets
    into bucket-sorted layout: targets order[offsets[b]:offsets[b]+counts[b]]
    all share embedding dimension buckets[b].  The sort is stable so
    within-bucket target order is the original one.
    """
    optE = np.asarray(optE)
    values, counts = np.unique(optE, return_counts=True)
    plan = BucketPlan(
        buckets=tuple(int(v) for v in values),
        counts=tuple(int(c) for c in counts),
    )
    order = np.argsort(optE, kind="stable")
    return plan, order


def _check_k(k: int, Lp: int, cfg: EDMConfig, where: str) -> None:
    """Fail with a diagnosable message instead of crashing inside lax.top_k
    when the requested neighbour-table width exceeds the library points."""
    if k < 1:
        raise ValueError(f"{where}: neighbour count k={k} must be >= 1")
    if k > Lp:
        raise ValueError(
            f"{where}: k={k} neighbours requested but only Lp={Lp} library "
            f"points are embeddable (series too short for E_max={cfg.E_max}, "
            f"tau={cfg.tau}, Tp={cfg.Tp}; shrink E_max/k_override or use a "
            "longer series)"
        )


def _bucket_k(cfg: EDMConfig, plan: BucketPlan) -> int:
    """Neighbour-table width for the bucketed layout.

    ``k_override`` is honoured when SET (None = unset; 0 is rejected by
    EDMConfig) — the old ``cfg.k_override or ...`` idiom silently dropped
    an explicit 0 into the default path.
    """
    return plan.buckets[-1] + 1 if cfg.k_override is None else cfg.k_override


def ccm_row_tables(x: jax.Array, cfg: EDMConfig) -> tuple[jax.Array, jax.Array]:
    """kNN tables + simplex weights for ONE library series, all-E layout.

    x: (L,).  Returns (idx, w), each (E_max, Lp, k_max).  Tables depend
    only on the library series, so callers reuse them across every target
    tile of a chunk (DESIGN.md SS7).  The engine streams candidate tiles
    of the width resolved from cfg.knn_tile_c (DESIGN.md SS8) —
    identical tables at any width.
    """
    eng = engines.get_engine(cfg.engine)
    Lp = cfg.n_points(x.shape[0])
    _check_k(cfg.k_max, Lp, cfg, "ccm_row_tables")
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    idx, sqd = eng.knn_tables(V, V, cfg.k_max, exclude_self=cfg.exclude_self, cfg=cfg)
    return knn.tables_with_weights(idx, sqd)


def ccm_row_tables_bucketed(
    x: jax.Array, cfg: EDMConfig, plan: BucketPlan
) -> tuple[jax.Array, jax.Array]:
    """kNN tables + weights for ONE library series, bucketed layout.

    Returns (idx, w), each (len(plan.buckets), Lp, k).
    """
    eng = engines.get_engine(cfg.engine)
    Lp = cfg.n_points(x.shape[0])
    kb = _bucket_k(cfg, plan)
    _check_k(kb, Lp, cfg, "ccm_row_tables_bucketed")
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    idx, sqd = eng.knn_tables_bucketed(
        V, V, kb, buckets=plan.buckets, exclude_self=cfg.exclude_self, cfg=cfg
    )
    return knn.tables_with_weights_bucketed(idx, sqd, plan.buckets)


def ccm_row_lookup(
    idx: jax.Array, w: jax.Array, ts_fut: jax.Array, e_idx: jax.Array,
    cfg: EDMConfig,
) -> jax.Array:
    """rho of a batch of targets against one library's all-E tables.

    idx/w: (E_max, Lp, k) tables from :func:`ccm_row_tables`; ts_fut:
    (n, Lp) target futures; e_idx: (n,) TABLE INDEX per target (optE - 1).
    Targets are processed in blocks of cfg.target_block (lax.map) so the
    (block, Lp) prediction buffer stays bounded at brain scale (N ~ 1e5).
    """
    eng = engines.get_engine(cfg.engine)
    n = ts_fut.shape[0]

    def per_target(y_fut: jax.Array, e: jax.Array) -> jax.Array:
        # Cross mapping: library neighbours, *target* futures (paper line 10).
        pred = eng.simplex_forecast(idx[e], w[e], y_fut)
        return pearson(y_fut, pred)

    tb = min(cfg.target_block, n)
    if n % tb != 0:  # pad targets to a block multiple
        pad = tb - n % tb
        ts_fut = jnp.pad(ts_fut, ((0, pad), (0, 0)))
        e_idx = jnp.pad(e_idx, (0, pad))
    blocks = (
        ts_fut.reshape(-1, tb, ts_fut.shape[1]),
        e_idx.reshape(-1, tb),
    )
    rho = jax.lax.map(
        lambda be: jax.vmap(per_target)(be[0], be[1]), blocks
    ).reshape(-1)
    return rho[:n]


def ccm_library_row(
    x: jax.Array, ts_fut: jax.Array, optE: jax.Array, cfg: EDMConfig
) -> jax.Array:
    """Cross-map every target from one library series (all-E table layout).

    x: (L,) library series.  ts_fut: (N, Lp) future values of every target
    (precomputed once per run).  optE: (N,) optimal E per target.
    Returns rho row (N,).
    """
    idx, w = ccm_row_tables(x, cfg)
    return ccm_row_lookup(idx, w, ts_fut, optE - 1, cfg)


def _rho_for_table(eng, idx, w, seg, cfg: EDMConfig) -> jax.Array:
    """rho of every target in one bucket segment against one table.

    idx/w: (Lq, k) the bucket's table; seg: (n, Lp) bucket-sorted target
    futures.  The batched lookup makes the gather contiguous: all n targets
    stream through the SAME index table (the kernels/ccm_lookup access
    pattern) instead of per-target table rows.
    """
    n = seg.shape[0]
    tb = min(cfg.target_block, n)
    if n <= tb:
        return pearson(seg, eng.ccm_lookup(idx, w, seg))
    if n % tb != 0:  # pad to a block multiple; padded rows sliced off below
        seg = jnp.pad(seg, ((0, tb - n % tb), (0, 0)))
    blocks = seg.reshape(-1, tb, seg.shape[1])
    rho = jax.lax.map(
        lambda s: pearson(s, eng.ccm_lookup(idx, w, s)), blocks
    ).reshape(-1)
    return rho[:n]


def ccm_row_lookup_bucketed(
    idx: jax.Array, w: jax.Array, fut_tile: jax.Array, cfg: EDMConfig,
    seg_plan: tuple[tuple[int, int], ...],
) -> jax.Array:
    """rho of one bucket-sorted target tile against one library's tables.

    idx/w: (len(buckets), Lp, k) tables from :func:`ccm_row_tables_bucketed`;
    fut_tile: (t, Lp) a contiguous slice of the bucket-sorted target
    futures; seg_plan: static ((table_row, count), ...) describing how the
    tile decomposes into bucket segments (counts sum to t).  Each segment
    streams through its ONE shared table via the batched ccm_lookup — the
    contiguous access pattern the kernels are built for — exactly as in
    the untiled path, so tiled and untiled rho are bit-identical.
    """
    eng = engines.get_engine(cfg.engine)
    segs, off = [], 0
    for b, cnt in seg_plan:
        seg = jax.lax.slice_in_dim(fut_tile, off, off + cnt)
        segs.append(_rho_for_table(eng, idx[b], w[b], seg, cfg))
        off += cnt
    if fut_tile.shape[0] != off:
        raise ValueError(
            f"seg_plan covers {off} targets but tile has {fut_tile.shape[0]}"
        )
    return segs[0] if len(segs) == 1 else jnp.concatenate(segs)


def ccm_library_row_bucketed(
    x: jax.Array, ts_fut_sorted: jax.Array, cfg: EDMConfig, plan: BucketPlan
) -> jax.Array:
    """Cross-map every target from one library series, bucketed layout.

    ts_fut_sorted: (N, Lp) target futures permuted into plan order (see
    make_bucket_plan).  Returns the rho row (N,) in SORTED target order;
    the caller owns the inverse permutation.
    """
    idx, w = ccm_row_tables_bucketed(x, cfg, plan)
    seg_plan = tuple(enumerate(plan.counts))
    return ccm_row_lookup_bucketed(idx, w, ts_fut_sorted, cfg, seg_plan)


def make_tile_plans(
    plan: BucketPlan, tile: int
) -> list[tuple[int, tuple[tuple[int, int], ...]]]:
    """Static column-tile decomposition of the bucket-sorted target axis.

    Returns [(col0, seg_plan), ...] covering sorted columns [0, N) in
    tiles of ``tile`` (the last may be short); seg_plan is the
    ((table_row, count), ...) intersection of the tile with the bucket
    segments, consumable by :func:`ccm_row_lookup_bucketed`.  Distinct
    seg_plan values are few — interior tiles of a bucket share one — so
    jit recompilation stays bounded at ~2 x len(buckets) regardless of
    the number of tiles.
    """
    if tile < 1:
        raise ValueError(f"tile must be >= 1, got {tile}")
    N = plan.n_targets
    plans: list[tuple[int, tuple[tuple[int, int], ...]]] = []
    for c0 in range(0, N, tile):
        c1 = min(c0 + tile, N)
        segs = []
        for b, (off, cnt) in enumerate(zip(plan.offsets, plan.counts)):
            lo, hi = max(off, c0), min(off + cnt, c1)
            if hi > lo:
                segs.append((b, hi - lo))
        plans.append((c0, tuple(segs)))
    return plans


@functools.partial(jax.jit, static_argnames=("cfg",))
def ccm_block(
    lib_block: jax.Array, ts_fut: jax.Array, optE: jax.Array, cfg: EDMConfig
) -> jax.Array:
    """rho rows for a block of library series: (B, L) -> (B, N)."""
    return jax.vmap(lambda x: ccm_library_row(x, ts_fut, optE, cfg))(lib_block)


@functools.partial(jax.jit, static_argnames=("cfg", "plan"))
def ccm_block_bucketed(
    lib_block: jax.Array, ts_fut_sorted: jax.Array, cfg: EDMConfig, plan: BucketPlan
) -> jax.Array:
    """Bucketed rho rows: (B, L) -> (B, N), columns in plan-sorted order."""
    return jax.vmap(
        lambda x: ccm_library_row_bucketed(x, ts_fut_sorted, cfg, plan)
    )(lib_block)


# ------------------------------------------------- tiled phase 2 (DESIGN SS7)
def _block_tables(lib_block: jax.Array, cfg: EDMConfig):
    return jax.vmap(lambda x: ccm_row_tables(x, cfg))(lib_block)


def _block_tables_bucketed(lib_block: jax.Array, cfg: EDMConfig, plan: BucketPlan):
    return jax.vmap(lambda x: ccm_row_tables_bucketed(x, cfg, plan))(lib_block)


def _block_tile(idx, w, fut_tile, e_idx, cfg: EDMConfig):
    return jax.vmap(lambda i, ww: ccm_row_lookup(i, ww, fut_tile, e_idx, cfg))(idx, w)


def _block_tile_bucketed(idx, w, fut_tile, cfg: EDMConfig, seg_plan):
    return jax.vmap(
        lambda i, ww: ccm_row_lookup_bucketed(i, ww, fut_tile, cfg, seg_plan)
    )(idx, w)


@functools.partial(jax.jit, static_argnames=("cfg",))
def ccm_block_tables(lib_block: jax.Array, cfg: EDMConfig):
    """All-E tables for a block of library series: (B, L) ->
    (idx, w) each (B, E_max, Lp, k).  Built ONCE per row chunk and reused
    by every :func:`ccm_block_tile` call of that chunk."""
    return _block_tables(lib_block, cfg)


@functools.partial(jax.jit, static_argnames=("cfg", "plan"))
def ccm_block_tables_bucketed(lib_block: jax.Array, cfg: EDMConfig, plan: BucketPlan):
    """Bucketed tables for a block: (B, L) -> (idx, w) each
    (B, len(buckets), Lp, k)."""
    return _block_tables_bucketed(lib_block, cfg, plan)


@functools.partial(jax.jit, static_argnames=("cfg",))
def ccm_block_tile(idx, w, fut_tile, e_idx, cfg: EDMConfig):
    """One (row-chunk x col-tile) rho block, all-E layout: tables (B, ...)
    + fut_tile (t, Lp) + e_idx (t,) -> rho (B, t)."""
    return _block_tile(idx, w, fut_tile, e_idx, cfg)


@functools.partial(jax.jit, static_argnames=("cfg", "seg_plan"))
def ccm_block_tile_bucketed(idx, w, fut_tile, cfg: EDMConfig, seg_plan):
    """One (row-chunk x col-tile) rho block, bucketed layout; columns in
    plan-sorted order, seg_plan from :func:`make_tile_plans`."""
    return _block_tile_bucketed(idx, w, fut_tile, cfg, seg_plan)


@functools.partial(jax.jit, static_argnames=("cfg",))
def all_futures(ts: jax.Array, cfg: EDMConfig) -> jax.Array:
    """(N, L) -> (N, Lp) future-value arrays used as cross-map targets."""
    N, L = ts.shape
    Lp = cfg.n_points(L)
    return jax.vmap(
        lambda x: embedding.future_values(x, cfg.E_max, cfg.tau, cfg.Tp, Lp)
    )(ts)


def ccm_matrix(ts: jax.Array, optE: jax.Array, cfg: EDMConfig) -> jax.Array:
    """Full (N, N) causal map on one device (small problems / tests).

    Dispatches on cfg.bucketed and cfg.target_tile; every combination
    returns an identical map (the bucket permutation is undone on the
    columns before returning, tiles are reassembled in column order).
    """
    ts_fut = all_futures(ts, cfg)
    if cfg.target_tile:
        return _ccm_matrix_tiled(ts, ts_fut, optE, cfg)
    if not cfg.bucketed:
        return ccm_block(ts, ts_fut, optE, cfg)
    plan, order = make_bucket_plan(np.asarray(optE))
    order_j = jnp.asarray(order)
    rho_sorted = ccm_block_bucketed(ts, ts_fut[order_j], cfg, plan)
    inv = jnp.asarray(np.argsort(order))
    return rho_sorted[:, inv]


def _ccm_matrix_tiled(
    ts: jax.Array, ts_fut: jax.Array, optE: jax.Array, cfg: EDMConfig
) -> jax.Array:
    """Single-device tiled phase 2: tables once, targets in column tiles."""
    N = ts.shape[0]
    T = cfg.target_tile
    optE_np = np.asarray(optE)
    if not cfg.bucketed:
        idx, w = ccm_block_tables(ts, cfg)
        e_idx = jnp.asarray(optE_np.astype(np.int32) - 1)
        cols = [
            ccm_block_tile(
                idx, w,
                jax.lax.slice_in_dim(ts_fut, c0, min(c0 + T, N)),
                jax.lax.slice_in_dim(e_idx, c0, min(c0 + T, N)),
                cfg,
            )
            for c0 in range(0, N, T)
        ]
        return jnp.concatenate(cols, axis=1)
    plan, order = make_bucket_plan(optE_np)
    idx, w = ccm_block_tables_bucketed(ts, cfg, plan)
    ts_fut_sorted = ts_fut[jnp.asarray(order)]
    cols = [
        ccm_block_tile_bucketed(
            idx, w,
            jax.lax.slice_in_dim(ts_fut_sorted, c0, min(c0 + T, N)),
            cfg, seg_plan,
        )
        for c0, seg_plan in make_tile_plans(plan, T)
    ]
    rho_sorted = jnp.concatenate(cols, axis=1)
    inv = jnp.asarray(np.argsort(order))
    return rho_sorted[:, inv]


def ccm_convergence(
    x: jax.Array,
    y: jax.Array,
    E: int,
    lib_sizes: tuple[int, ...],
    cfg: EDMConfig,
    key: jax.Array,
) -> jax.Array:
    """DEPRECATED: convergence diagnostic, kept as a thin same-signature
    wrapper over the batched prefix-snapshot path
    (:func:`repro.inference.convergence.ccm_convergence_pair`).

    The old body rebuilt a full kNN table per library size (S full
    sweeps per pair); the new path snapshots ONE candidate sweep at each
    prefix boundary (DESIGN.md SS9).  Libraries are now NESTED random
    subsamples — prefixes of the key-seeded permutation — instead of
    independent per-size draws, so per-size rho values differ from the
    old implementation while the convergence behaviour (rho increasing
    with library size under true causation) is unchanged.
    """
    import warnings

    warnings.warn(
        "ccm_convergence is deprecated; use "
        "repro.inference.convergence.ccm_convergence_pair (per-pair) or "
        "repro.inference.run_significance (whole-map) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.inference.convergence import ccm_convergence_pair

    return ccm_convergence_pair(x, y, E, tuple(lib_sizes), cfg, key)
