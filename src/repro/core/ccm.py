"""Phase 2 — Convergent Cross Mapping, mpEDM improved algorithm (paper Alg. 2).

Key idea reproduced from the paper: the kNN table depends only on the
*library* series, so per library series i we precompute tables once and
reuse them across all N targets — O(N L^2 E^2 + N^2 L E) vs cppEDM's
O(N^2 L^2 E).  Two table layouts (DESIGN.md SS3):

  * all-E      — tables for every E in 1..E_max (the paper's shape);
  * bucketed   — tables only for the DISTINCT optE values present, with
    targets grouped by bucket so every lookup batch shares one table
    (contiguous gathers; the layout kernels/ccm_lookup is built for).

Both produce the same causal map; the bucketed layout is the default in
EDMConfig and cuts table top-k work and footprint by len(buckets)/E_max.

rho[i, j] = pearson(ts_j_future, cross_map_prediction) — the skill of
predicting series j from library i's reconstructed manifold; high skill
means j CCM-causes i (paper SSII-B).

All device compute routes through the execution engine named by
cfg.engine (repro.engine; DESIGN.md SS5).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engines
from repro.core import embedding, knn
from repro.core.stats import pearson, simplex_weights
from repro.core.types import EDMConfig


# ---------------------------------------------------------------- bucketing
@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static phase-2 grouping of targets by optimal embedding dimension.

    buckets: ascending distinct E values present in optE;
    counts[b]: number of targets whose optE == buckets[b].
    Hashable -> usable as a static jit argument.
    """

    buckets: tuple[int, ...]
    counts: tuple[int, ...]

    @property
    def offsets(self) -> tuple[int, ...]:
        """Start offset of each bucket's segment in the sorted target order."""
        out, off = [], 0
        for c in self.counts:
            out.append(off)
            off += c
        return tuple(out)

    @property
    def n_targets(self) -> int:
        return sum(self.counts)


def make_bucket_plan(optE: np.ndarray) -> tuple[BucketPlan, np.ndarray]:
    """Group targets by optE.

    Returns (plan, order) where ``order`` (a host ndarray) permutes targets
    into bucket-sorted layout: targets order[offsets[b]:offsets[b]+counts[b]]
    all share embedding dimension buckets[b].  The sort is stable so
    within-bucket target order is the original one.
    """
    optE = np.asarray(optE)
    values, counts = np.unique(optE, return_counts=True)
    plan = BucketPlan(
        buckets=tuple(int(v) for v in values),
        counts=tuple(int(c) for c in counts),
    )
    order = np.argsort(optE, kind="stable")
    return plan, order


def ccm_library_row(
    x: jax.Array, ts_fut: jax.Array, optE: jax.Array, cfg: EDMConfig
) -> jax.Array:
    """Cross-map every target from one library series (all-E table layout).

    x: (L,) library series.  ts_fut: (N, Lp) future values of every target
    (precomputed once per run).  optE: (N,) optimal E per target.
    Returns rho row (N,).

    Targets are processed in blocks of cfg.target_block (lax.map) so the
    (block, Lp) prediction buffer stays bounded at brain scale (N ~ 1e5).
    """
    eng = engines.get_engine(cfg.engine)
    L = x.shape[0]
    Lp = cfg.n_points(L)
    N = ts_fut.shape[0]
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    idx, sqd = eng.knn_tables(V, V, cfg.k_max, exclude_self=cfg.exclude_self, cfg=cfg)
    idx, w = knn.tables_with_weights(idx, sqd)

    def per_target(y_fut: jax.Array, e: jax.Array) -> jax.Array:
        # Cross mapping: library neighbours, *target* futures (paper line 10);
        # e is the TABLE INDEX (optE - 1).
        pred = eng.simplex_forecast(idx[e], w[e], y_fut)
        return pearson(y_fut, pred)

    tb = min(cfg.target_block, N)
    e_idx = optE - 1  # table row for embedding dimension E
    if N % tb != 0:  # pad targets to a block multiple
        pad = tb - N % tb
        ts_fut = jnp.pad(ts_fut, ((0, pad), (0, 0)))
        e_idx = jnp.pad(e_idx, (0, pad))
    blocks = (
        ts_fut.reshape(-1, tb, ts_fut.shape[1]),
        e_idx.reshape(-1, tb),
    )
    rho = jax.lax.map(
        lambda be: jax.vmap(per_target)(be[0], be[1]), blocks
    ).reshape(-1)
    return rho[:N]


def _rho_for_table(eng, idx, w, seg, cfg: EDMConfig) -> jax.Array:
    """rho of every target in one bucket segment against one table.

    idx/w: (Lq, k) the bucket's table; seg: (n, Lp) bucket-sorted target
    futures.  The batched lookup makes the gather contiguous: all n targets
    stream through the SAME index table (the kernels/ccm_lookup access
    pattern) instead of per-target table rows.
    """
    n = seg.shape[0]
    tb = min(cfg.target_block, n)
    if n <= tb:
        return pearson(seg, eng.ccm_lookup(idx, w, seg))
    if n % tb != 0:  # pad to a block multiple; padded rows sliced off below
        seg = jnp.pad(seg, ((0, tb - n % tb), (0, 0)))
    blocks = seg.reshape(-1, tb, seg.shape[1])
    rho = jax.lax.map(
        lambda s: pearson(s, eng.ccm_lookup(idx, w, s)), blocks
    ).reshape(-1)
    return rho[:n]


def ccm_library_row_bucketed(
    x: jax.Array, ts_fut_sorted: jax.Array, cfg: EDMConfig, plan: BucketPlan
) -> jax.Array:
    """Cross-map every target from one library series, bucketed layout.

    ts_fut_sorted: (N, Lp) target futures permuted into plan order (see
    make_bucket_plan).  Returns the rho row (N,) in SORTED target order;
    the caller owns the inverse permutation.
    """
    eng = engines.get_engine(cfg.engine)
    L = x.shape[0]
    Lp = cfg.n_points(L)
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    kb = cfg.k_override or plan.buckets[-1] + 1
    idx, sqd = eng.knn_tables_bucketed(
        V, V, kb, buckets=plan.buckets, exclude_self=cfg.exclude_self, cfg=cfg
    )
    idx, w = knn.tables_with_weights_bucketed(idx, sqd, plan.buckets)

    segs = []
    for b, (off, cnt) in enumerate(zip(plan.offsets, plan.counts)):
        seg = jax.lax.slice_in_dim(ts_fut_sorted, off, off + cnt)
        segs.append(_rho_for_table(eng, idx[b], w[b], seg, cfg))
    return jnp.concatenate(segs)


@functools.partial(jax.jit, static_argnames=("cfg",))
def ccm_block(
    lib_block: jax.Array, ts_fut: jax.Array, optE: jax.Array, cfg: EDMConfig
) -> jax.Array:
    """rho rows for a block of library series: (B, L) -> (B, N)."""
    return jax.vmap(lambda x: ccm_library_row(x, ts_fut, optE, cfg))(lib_block)


@functools.partial(jax.jit, static_argnames=("cfg", "plan"))
def ccm_block_bucketed(
    lib_block: jax.Array, ts_fut_sorted: jax.Array, cfg: EDMConfig, plan: BucketPlan
) -> jax.Array:
    """Bucketed rho rows: (B, L) -> (B, N), columns in plan-sorted order."""
    return jax.vmap(
        lambda x: ccm_library_row_bucketed(x, ts_fut_sorted, cfg, plan)
    )(lib_block)


@functools.partial(jax.jit, static_argnames=("cfg",))
def all_futures(ts: jax.Array, cfg: EDMConfig) -> jax.Array:
    """(N, L) -> (N, Lp) future-value arrays used as cross-map targets."""
    N, L = ts.shape
    Lp = cfg.n_points(L)
    return jax.vmap(
        lambda x: embedding.future_values(x, cfg.E_max, cfg.tau, cfg.Tp, Lp)
    )(ts)


def ccm_matrix(ts: jax.Array, optE: jax.Array, cfg: EDMConfig) -> jax.Array:
    """Full (N, N) causal map on one device (small problems / tests).

    Dispatches on cfg.bucketed; both layouts return identical maps (the
    bucket permutation is undone on the columns before returning).
    """
    ts_fut = all_futures(ts, cfg)
    if not cfg.bucketed:
        return ccm_block(ts, ts_fut, optE, cfg)
    plan, order = make_bucket_plan(np.asarray(optE))
    order_j = jnp.asarray(order)
    rho_sorted = ccm_block_bucketed(ts, ts_fut[order_j], cfg, plan)
    inv = jnp.asarray(np.argsort(order))
    return rho_sorted[:, inv]


def ccm_convergence(
    x: jax.Array,
    y: jax.Array,
    E: int,
    lib_sizes: tuple[int, ...],
    cfg: EDMConfig,
    key: jax.Array,
) -> jax.Array:
    """Convergence diagnostic (the subsampling test the paper's hot path
    skips, SSIII-A): rho of cross-mapping y from x at increasing library
    sizes.  True causation shows rho increasing with library size.
    """
    L = x.shape[0]
    Lp = cfg.n_points(L)
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    y_fut = embedding.future_values(y, cfg.E_max, cfg.tau, cfg.Tp, Lp)
    rhos = []
    for i, Ls in enumerate(lib_sizes):
        sub = jax.random.choice(
            jax.random.fold_in(key, i), Lp, shape=(Ls,), replace=False
        )
        member = jnp.zeros((Lp,), bool).at[sub].set(True)
        idx, sqd = knn.knn_table_single_E(
            V, V, E, cfg.k_max, exclude_self=cfg.exclude_self,
            candidate_mask=member,
        )
        w = simplex_weights(sqd, E + 1)
        pred = knn.simplex_forecast(idx, w, y_fut)
        rhos.append(pearson(y_fut, pred))
    return jnp.stack(rhos)
