"""Phase 2 — Convergent Cross Mapping, mpEDM improved algorithm (paper Alg. 2).

Key idea reproduced from the paper: the kNN table depends only on the
*library* series, so per library series i we precompute tables for every
E in 1..E_max once (cumulative scan, see core/knn.py) and reuse them across
all N targets — O(N L^2 E^2 + N^2 L E) vs cppEDM's O(N^2 L^2 E).

rho[i, j] = pearson(ts_j_future, cross_map_prediction) — the skill of
predicting series j from library i's reconstructed manifold; high skill
means j CCM-causes i (paper SSII-B).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import embedding, knn
from repro.core.stats import pearson, simplex_weights
from repro.core.types import EDMConfig


def ccm_library_row(
    x: jax.Array, ts_fut: jax.Array, optE: jax.Array, cfg: EDMConfig
) -> jax.Array:
    """Cross-map every target from one library series.

    x: (L,) library series.  ts_fut: (N, Lp) future values of every target
    (precomputed once per run).  optE: (N,) optimal E per target.
    Returns rho row (N,).

    Targets are processed in blocks of cfg.target_block (lax.map) so the
    (block, Lp) prediction buffer stays bounded at brain scale (N ~ 1e5).
    """
    L = x.shape[0]
    Lp = cfg.n_points(L)
    N = ts_fut.shape[0]
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    if cfg.use_kernels:
        from repro.kernels.knn_topk.ops import knn_topk

        idx, sqd = knn_topk(V, V, cfg.k_max, exclude_self=cfg.exclude_self)
    else:
        idx, sqd = knn.knn_tables_all_E(
            V, V, cfg.k_max, exclude_self=cfg.exclude_self,
            impl=cfg.knn_impl, dist_dtype=jnp.dtype(cfg.dist_dtype),
        )
    idx, w = knn.tables_with_weights(idx, sqd)

    def per_target(y_fut: jax.Array, e: jax.Array) -> jax.Array:
        # Cross mapping: library neighbours, *target* futures (paper line 10);
        # e is the TABLE INDEX (optE - 1).
        pred = knn.simplex_forecast(idx[e], w[e], y_fut)
        return pearson(y_fut, pred)

    tb = min(cfg.target_block, N)
    e_idx = optE - 1  # table row for embedding dimension E
    if N % tb != 0:  # pad targets to a block multiple
        pad = tb - N % tb
        ts_fut = jnp.pad(ts_fut, ((0, pad), (0, 0)))
        e_idx = jnp.pad(e_idx, (0, pad))
    blocks = (
        ts_fut.reshape(-1, tb, ts_fut.shape[1]),
        e_idx.reshape(-1, tb),
    )
    rho = jax.lax.map(
        lambda be: jax.vmap(per_target)(be[0], be[1]), blocks
    ).reshape(-1)
    return rho[:N]


@functools.partial(jax.jit, static_argnames=("cfg",))
def ccm_block(
    lib_block: jax.Array, ts_fut: jax.Array, optE: jax.Array, cfg: EDMConfig
) -> jax.Array:
    """rho rows for a block of library series: (B, L) -> (B, N)."""
    return jax.vmap(lambda x: ccm_library_row(x, ts_fut, optE, cfg))(lib_block)


@functools.partial(jax.jit, static_argnames=("cfg",))
def all_futures(ts: jax.Array, cfg: EDMConfig) -> jax.Array:
    """(N, L) -> (N, Lp) future-value arrays used as cross-map targets."""
    N, L = ts.shape
    Lp = cfg.n_points(L)
    return jax.vmap(
        lambda x: embedding.future_values(x, cfg.E_max, cfg.tau, cfg.Tp, Lp)
    )(ts)


def ccm_matrix(ts: jax.Array, optE: jax.Array, cfg: EDMConfig) -> jax.Array:
    """Full (N, N) causal map on one device (small problems / tests)."""
    ts_fut = all_futures(ts, cfg)
    return ccm_block(ts, ts_fut, optE, cfg)


def ccm_convergence(
    x: jax.Array,
    y: jax.Array,
    E: int,
    lib_sizes: tuple[int, ...],
    cfg: EDMConfig,
    key: jax.Array,
) -> jax.Array:
    """Convergence diagnostic (the subsampling test the paper's hot path
    skips, SSIII-A): rho of cross-mapping y from x at increasing library
    sizes.  True causation shows rho increasing with library size.
    """
    L = x.shape[0]
    Lp = cfg.n_points(L)
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    y_fut = embedding.future_values(y, cfg.E_max, cfg.tau, cfg.Tp, Lp)
    rhos = []
    for i, Ls in enumerate(lib_sizes):
        sub = jax.random.choice(
            jax.random.fold_in(key, i), Lp, shape=(Ls,), replace=False
        )
        member = jnp.zeros((Lp,), bool).at[sub].set(True)
        idx, sqd = knn.knn_table_single_E(
            V, V, E, cfg.k_max, exclude_self=cfg.exclude_self,
            candidate_mask=member,
        )
        w = simplex_weights(sqd, E + 1)
        pred = knn.simplex_forecast(idx, w, y_fut)
        rhos.append(pearson(y_fut, pred))
    return jnp.stack(rhos)
