"""Small numerical helpers shared across the EDM core (simplex weight
semantics under masked +inf distances: DESIGN.md SS4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-8


def pearson(a: jax.Array, b: jax.Array) -> jax.Array:
    """Pearson correlation along the last axis; 0 when either side is
    degenerate (cppEDM reports 0 skill for degenerate predictions).

    Degenerate covers BOTH zero variance — constant (dead-neuron) series,
    where num/den would be 0/0 = NaN — and non-finite moments (a float32
    variance overflow turns den into inf and num/den into inf/inf = NaN).
    Significance masking and the assembled causal/p-value maps therefore
    always see finite rho.  The norm product is computed as
    sqrt(sum a^2) * sqrt(sum b^2) so it only overflows when a single
    norm does, not when the product of variances does.
    """
    a = a - jnp.mean(a, axis=-1, keepdims=True)
    b = b - jnp.mean(b, axis=-1, keepdims=True)
    num = jnp.sum(a * b, axis=-1)
    den = jnp.sqrt(jnp.sum(a * a, axis=-1)) * jnp.sqrt(jnp.sum(b * b, axis=-1))
    good = (den > _EPS) & jnp.isfinite(den) & jnp.isfinite(num)
    return jnp.where(good, num / jnp.where(good, den, 1.0), 0.0)


def simplex_weights(sq_dists: jax.Array, k_valid: jax.Array | int) -> jax.Array:
    """Exponential simplex weights from *squared* neighbour distances.

    w_j = exp(-d_j / d_1) over the k_valid nearest neighbours, row-normalized
    (cppEDM convention: scale by the distance to the nearest neighbour).

    When d_1 == 0 (duplicate points, silent/dead neurons, constant series)
    the ratio d_j / d_1 degenerates: the eps-clamped exponential underflows
    to a delta on neighbour 1 even when several neighbours are exactly
    tied at distance 0.  cppEDM handles this by weighting the TIED
    neighbours uniformly and dropping the rest (the exp(-d/d_1) limit as
    d_1 -> 0); we reproduce that branch so degenerate series yield finite
    weights (and downstream pearson sees no NaN/Inf) instead of an
    arbitrary winner among exact ties.  For d_1 > 0 — however small — the
    TRUE ratio is used: it is scale-invariant, so low-amplitude series
    are weighted exactly like their rescaled counterparts (no
    absolute-eps cliff).

    sq_dists: (..., k_max) sorted ascending.  k_valid: number of neighbours
    actually used (E+1); entries beyond it get weight 0 so every embedding
    dimension can share one padded table shape.
    """
    k_max = sq_dists.shape[-1]
    d = jnp.sqrt(jnp.maximum(sq_dists, 0.0))
    # Masked entries may be +inf (self-exclusion with tiny candidate sets);
    # they fall out via exp(-inf) = 0, but keep d1 finite.
    d1 = jnp.where(jnp.isfinite(d[..., :1]), d[..., :1], 0.0)
    w = jnp.exp(-d / jnp.where(d1 > 0, d1, 1.0))
    w = jnp.where(jnp.isfinite(w), w, 0.0)
    # d1 == 0: uniform over the neighbours tied at zero distance (the
    # exact limit above), not the underflowed delta.
    w = jnp.where(d1 > 0, w, (d <= 0).astype(w.dtype))
    kmask = jnp.arange(k_max) < k_valid
    w = w * kmask
    return w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), _EPS)
