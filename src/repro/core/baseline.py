"""cppEDM-style naive CCM (paper Alg. 1) — the baseline mpEDM improves
on, and the comparison point of the cumulative-E recurrence
(DESIGN.md SS2).

Per (library i, target j) pair the kNN table is rebuilt from scratch at
E = optE[j]: O(N^2 L^2 E).  Kept (a) to validate that the improved
algorithm is output-identical, and (b) as the measured baseline for the
paper's speedup claim (benchmarks/table2_speedup.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import embedding, knn
from repro.core.stats import pearson, simplex_weights
from repro.core.types import EDMConfig


@functools.partial(jax.jit, static_argnames=("E", "cfg"))
def ccm_pair_naive(
    x: jax.Array, y_fut: jax.Array, E: int, cfg: EDMConfig
) -> jax.Array:
    """One cross mapping, full table rebuild (Alg. 1 lines 14-17)."""
    L = x.shape[0]
    Lp = cfg.n_points(L)
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    idx, sqd = knn.knn_table_single_E(
        V, V, E, E + 1, exclude_self=cfg.exclude_self
    )
    w = simplex_weights(sqd, E + 1)
    pred = knn.simplex_forecast(idx, w, y_fut)
    return pearson(y_fut, pred)


def ccm_naive(ts: jax.Array, optE: jax.Array, cfg: EDMConfig) -> jax.Array:
    """Full (N, N) causal map, redundant per-pair tables (test scale only)."""
    import numpy as np

    from repro.core.ccm import all_futures

    N = ts.shape[0]
    ts_fut = all_futures(ts, cfg)
    optE_np = np.asarray(optE)
    rho = np.zeros((N, N), np.float32)
    for i in range(N):
        for j in range(N):
            rho[i, j] = ccm_pair_naive(ts[i], ts_fut[j], int(optE_np[j]), cfg)
    return jnp.asarray(rho)
