"""Phase 1 — simplex projection: find the optimal embedding dimension per
series (paper Alg. 1 lines 1-11; aligned indexing DESIGN.md SS2,
exclusion semantics DESIGN.md SS4).

Library = first half of the series, target = second half; for each
E in 1..E_max forecast every target point from its E+1 nearest library
neighbours, score with Pearson rho, and keep the argmax E.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import engine as engines
from repro.core import embedding, knn
from repro.core.stats import pearson
from repro.core.types import EDMConfig


def simplex_series(x: jax.Array, cfg: EDMConfig) -> tuple[jax.Array, jax.Array]:
    """Simplex projection of one series.

    Returns (rhos (E_max,), optE scalar int32 in [1, E_max]).
    """
    eng = engines.get_engine(cfg.engine)
    L = x.shape[0]
    Lp = cfg.n_points(L)
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    fut = embedding.future_values(x, cfg.E_max, cfg.tau, cfg.Tp, Lp)
    Lh = Lp // 2
    Vc, Vq = V[:, :Lh], V[:, Lh:]
    idx, sqd = eng.knn_tables(Vq, Vc, cfg.k_max, exclude_self=False, cfg=cfg)
    idx, w = knn.tables_with_weights(idx, sqd)
    preds = eng.simplex_forecast(idx, w, fut[:Lh])  # (E_max, Lq)
    rhos = pearson(jnp.broadcast_to(fut[Lh:], preds.shape), preds)
    optE = jnp.argmax(rhos).astype(jnp.int32) + 1
    return rhos, optE


@functools.partial(jax.jit, static_argnames=("cfg",))
def simplex_batch(ts: jax.Array, cfg: EDMConfig) -> tuple[jax.Array, jax.Array]:
    """vmapped phase 1 over a (N, L) dataset -> (rhos (N, E_max), optE (N,))."""
    return jax.vmap(lambda x: simplex_series(x, cfg))(ts)
