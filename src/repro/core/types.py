"""Shared EDM configuration and result types (notation DESIGN.md SS1;
every knob names the design section that owns it)."""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional


@dataclasses.dataclass(frozen=True)
class EDMConfig:
    """Configuration of one causal-inference run (paper §III).

    Attributes:
      E_max: maximum embedding dimension swept in simplex projection
        (paper uses <= 20 in practice).
      tau: delay-embedding lag.
      Tp: prediction horizon in time steps (paper: one step ahead).
      exclude_self: mask the zero-distance self neighbour when library ==
        target (cppEDM exclusionRadius semantics; see DESIGN.md SS4).
      lib_block: number of library series processed per device per chunk in
        the distributed CCM phase (granularity of progress checkpoints).
      engine: execution-engine registry key (repro.engine) that owns kNN
        tables, simplex forecast, and CCM lookup: "reference" (pure jnp),
        "pallas-interpret", "pallas-compiled", or any registered backend
        (DESIGN.md SS5).
      bucketed: run phase-2 CCM with optE-bucketed tables — build kNN
        tables only for the distinct optE values present and group targets
        by bucket for contiguous lookups (DESIGN.md SS3).  Output matches
        the all-E path; disable only for A/B benchmarks.
      stream_depth: CCM row chunks in flight in the pipeline's streaming
        loop.  2 = double buffering (chunk i+1 dispatched while chunk i's
        device->host copy and row-block write drain); 1 = the fully
        synchronous legacy behaviour.
      target_tile: phase-2 COLUMN tile width (DESIGN.md SS7).  0 (default)
        keeps the single-tile path: the full (N, Lp) ts_fut is replicated
        per device and rho rows span all N columns.  > 0 splits phase 2
        into a second tiling dimension: kNN tables are built ONCE per row
        chunk, then targets stream through in column tiles of this width —
        only the live (tile, Lp) slice is resident per device and rho is
        emitted as (row-chunk x col-tile) blocks.  Phase 2 then allocates
        nothing that scales beyond the O(N L) inputs (ts and ts_fut stay
        host-resident): its own working set is O(chunk x tile) on the
        host (no (N, N) map when streaming to a store) and
        O(lib_block x buckets x Lp x k + tile x Lp) per device (no
        (N, Lp) replication).
      knn_tile_c: streaming kNN candidate-tile width (DESIGN.md SS8).
        Selection is ALWAYS streaming: candidate tiles folded through the
        running sorted top-k via the partial merge network.  0 (default)
        = one-shot calibration (knn.calibrate_knn_tile: widest
        power-of-two tile under the VMEM budget — a tile covering the
        whole library degenerates to one direct selection, so small
        libraries lose nothing).  > 0 = force that tile width.  -1 (the
        removed dense distance-matrix selection path) raises a
        deprecation error.  The distance working set is
        O(Lq x (tile + k log k)) — independent of the library length —
        and every tile width is bit-identical to the dense lax.top_k
        oracle (values and tie order) on every engine.
      use_kernels: DEPRECATED alias — True selects engine="pallas-compiled"
        (the old kernel routing), False engine="reference".
    """

    E_max: int = 20
    tau: int = 1
    Tp: int = 1
    exclude_self: bool = True
    lib_block: int = 8
    target_block: int = 2048
    engine: str = "reference"
    bucketed: bool = True
    stream_depth: int = 2
    target_tile: int = 0
    use_kernels: Optional[bool] = None
    # Accumulation variant of the DENSE ORACLE builders (knn_tables_dense
    # and friends — the lax.top_k A/B reference used by tests and
    # benchmarks; no engine routes through them):
    #   rebuild    — per-E matmul-form rebuild (the PAPER-FAITHFUL shape:
    #                mpEDM recomputes each E's kNN from scratch)
    #   scan       — cumulative-E lax.scan (beyond-paper; cost_analysis
    #                cannot see scan bodies, so dry-runs avoid it)
    #   unroll     — cumulative-E python loop (XLA fuses consecutive updates)
    #   blocked:g  — scan over blocks of g unrolled steps (DEFAULT; falls
    #                back to unroll when E_max %% g != 0)
    knn_impl: str = "blocked:4"
    dist_dtype: str = "float32"  # bfloat16 halves distance-tile HBM traffic
    knn_tile_c: int = 0  # 0 auto-calibrated; >0 forced streaming tile width
    # k_override: pins the neighbour-table width independent of E_max —
    # used by the dry-run's reduced-E cost compiles so per-E bodies carry
    # the PRODUCTION top-k cost (k tracks E_max otherwise).  None = unset
    # (k tracks E_max / the bucket set); 0 is rejected so "unset" can never
    # be confused with a (meaningless) zero-neighbour table.
    k_override: Optional[int] = None

    def __post_init__(self):
        if self.use_kernels is not None:
            warnings.warn(
                "EDMConfig.use_kernels is deprecated; pass "
                "engine='pallas-compiled' (True) or engine='reference' "
                "(False) instead",
                DeprecationWarning,
                stacklevel=3,
            )
            want = "pallas-compiled" if self.use_kernels else "reference"
            if self.engine not in ("reference", want):
                raise ValueError(
                    f"conflicting config: use_kernels={self.use_kernels} "
                    f"implies engine={want!r} but engine={self.engine!r} "
                    "was passed; drop use_kernels"
                )
            object.__setattr__(self, "engine", want)
            # Normalize so the shimmed config equals (and shares jit cache
            # entries with) the equivalent engine=... config, and so
            # dataclasses.replace(cfg, engine=...) is not overridden again.
            object.__setattr__(self, "use_kernels", None)
        if self.stream_depth < 1:
            raise ValueError("stream_depth must be >= 1")
        if self.target_tile < 0:
            raise ValueError("target_tile must be >= 0 (0 = untiled)")
        if self.knn_tile_c == -1:
            raise ValueError(
                "knn_tile_c=-1 (the removed dense distance-matrix "
                "selection path) is deprecated: selection is always "
                "streaming; pass 0 (auto-calibrated tile width) or a "
                "positive tile width"
            )
        if self.knn_tile_c < 0:
            raise ValueError(
                f"knn_tile_c={self.knn_tile_c} is invalid: 0 = "
                "auto-calibrated tile width, > 0 = forced tile width"
            )
        if self.k_override is not None and self.k_override < 1:
            raise ValueError(
                f"k_override={self.k_override} is invalid: pass None (unset; "
                "k tracks E_max / the bucket set) or a positive table width"
            )

    @property
    def k_max(self) -> int:
        # Simplex uses E+1 neighbours for embedding dimension E.
        return self.k_override if self.k_override is not None else self.E_max + 1

    def n_points(self, L: int) -> int:
        """Number of embeddable query/candidate points for a length-L series.

        All embedding dimensions share the aligned 'present-time' indexing
        (offset (E_max-1)*tau) so that tables for every E have one shape and
        the cumulative-distance recurrence applies (DESIGN.md SS2).
        """
        return L - (self.E_max - 1) * self.tau - self.Tp


@dataclasses.dataclass
class CausalMap:
    """Output of the pipeline: rho[i, j] = skill of cross-mapping target j
    from library i's reconstructed manifold (j "CCM-causes" i when high)."""

    rho: "jax.Array | numpy.ndarray"  # (N, N) float32
    optE: "jax.Array | numpy.ndarray"  # (N,) int32
    simplex_rho: Optional["jax.Array | numpy.ndarray"] = None  # (N, E_max)
