"""Shared EDM configuration and result types."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class EDMConfig:
    """Configuration of one causal-inference run (paper §III).

    Attributes:
      E_max: maximum embedding dimension swept in simplex projection
        (paper uses <= 20 in practice).
      tau: delay-embedding lag.
      Tp: prediction horizon in time steps (paper: one step ahead).
      exclude_self: mask the zero-distance self neighbour when library ==
        target (cppEDM exclusionRadius semantics; see DESIGN.md SS4).
      lib_block: number of library series processed per device per chunk in
        the distributed CCM phase (granularity of progress checkpoints).
      use_kernels: route kNN/lookup through the Pallas kernels (interpret
        mode on CPU) instead of the pure-jnp reference path.
    """

    E_max: int = 20
    tau: int = 1
    Tp: int = 1
    exclude_self: bool = True
    lib_block: int = 8
    target_block: int = 2048
    use_kernels: bool = False
    # kNN table construction variants (SSPerf hillclimb #3):
    #   rebuild    — per-E matmul-form rebuild (the PAPER-FAITHFUL shape:
    #                mpEDM recomputes each E's kNN from scratch)
    #   scan       — cumulative-E lax.scan (beyond-paper; cost_analysis
    #                cannot see scan bodies, so dry-runs avoid it)
    #   unroll     — cumulative-E python loop (XLA fuses consecutive updates)
    #   blocked:g  — scan over blocks of g unrolled steps: the peak-memory /
    #                HBM-traffic frontier (DEFAULT; falls back to unroll
    #                when E_max %% g != 0)
    knn_impl: str = "blocked:4"
    dist_dtype: str = "float32"  # bfloat16 halves D-slab HBM traffic
    # k_override: pins the neighbour-table width independent of E_max —
    # used by the dry-run's reduced-E cost compiles so per-E bodies carry
    # the PRODUCTION top-k cost (k tracks E_max otherwise).
    k_override: int = 0

    @property
    def k_max(self) -> int:
        # Simplex uses E+1 neighbours for embedding dimension E.
        return self.k_override or self.E_max + 1

    def n_points(self, L: int) -> int:
        """Number of embeddable query/candidate points for a length-L series.

        All embedding dimensions share the aligned 'present-time' indexing
        (offset (E_max-1)*tau) so that tables for every E have one shape and
        the cumulative-distance recurrence applies (DESIGN.md SS2).
        """
        return L - (self.E_max - 1) * self.tau - self.Tp


@dataclasses.dataclass
class CausalMap:
    """Output of the pipeline: rho[i, j] = skill of cross-mapping target j
    from library i's reconstructed manifold (j "CCM-causes" i when high)."""

    rho: "jax.Array | numpy.ndarray"  # (N, N) float32
    optE: "jax.Array | numpy.ndarray"  # (N,) int32
    simplex_rho: Optional["jax.Array | numpy.ndarray"] = None  # (N, E_max)
