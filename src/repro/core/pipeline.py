"""Distributed causal-inference pipeline — the paper's system layer on TPU.

Replaces the MPI master-worker (paper SSIII-C) with SPMD shard_map over
library-series blocks on the FLAT device grid (pod x data x model treated
as one worker axis, matching the paper's 512 flat workers):

  phase 1 (simplex projection): series sharded across workers, optE
    gathered to host (N int32 — the paper's single broadcast);
  phase 2 (CCM): double-buffered loop over row CHUNKS (chunk = workers x
    lib_block); each chunk is one jit'd shard_map call with zero internal
    collectives.  With cfg.bucketed (default) targets are grouped by
    distinct optE so each chunk builds kNN tables only for the bucket set
    (DESIGN.md SS3).  Completed chunks stream through a ChunkStreamer
    (runtime/stream.py): chunk i+1's host->device transfer and dispatch
    are queued while chunk i's device->host copy and RowBlockWriter write
    (sequential block writes — the BeeOND design point) drain, so the
    streaming store is off the critical path.  The writer doubles as the
    RESUME manifest.

Fault tolerance: kill the process at any point; rerun resumes at the first
uncovered row, on any mesh size (elastic — coverage is tracked per row).
Self-scheduling is unnecessary: after the mpEDM algorithmic improvement all
per-series tasks cost the same FLOPs (DESIGN.md SS2), so static balanced
decomposition is optimal.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import ccm, simplex
from repro.core.types import CausalMap, EDMConfig
from repro.data.store import RowBlockWriter
from repro.runtime.stream import ChunkStreamer


def _flat(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def make_simplex_fn(mesh, cfg: EDMConfig):
    """(chunk, L) sharded on rows -> (rhos (chunk, E_max), optE (chunk,))."""
    axes = _flat(mesh)

    def local(ts_rows):
        return simplex.simplex_batch(ts_rows, cfg)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axes, None),),
            out_specs=(P(axes, None), P(axes)),
            check_rep=False,
        )
    )


def make_ccm_chunk_fn(mesh, cfg: EDMConfig):
    """(lib_rows (chunk, L) sharded, ts_fut (N, Lp) repl, optE (N,) repl)
    -> rho rows (chunk, N) sharded.  No collectives inside."""
    axes = _flat(mesh)

    def local(lib_rows, ts_fut, optE):
        return ccm.ccm_block(lib_rows, ts_fut, optE, cfg)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axes, None), P(None, None), P(None)),
            out_specs=P(axes, None),
            check_rep=False,
        )
    )


def make_ccm_chunk_fn_bucketed(mesh, cfg: EDMConfig, plan: "ccm.BucketPlan"):
    """Bucketed variant: (lib_rows sharded, ts_fut_sorted repl) -> rho rows
    (chunk, N) sharded, columns in plan-sorted target order."""
    axes = _flat(mesh)

    def local(lib_rows, ts_fut_sorted):
        return ccm.ccm_block_bucketed(lib_rows, ts_fut_sorted, cfg, plan)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axes, None), P(None, None)),
            out_specs=P(axes, None),
            check_rep=False,
        )
    )


def _pad_rows(a: np.ndarray, rows: int) -> np.ndarray:
    if a.shape[0] == rows:
        return a
    pad = np.zeros((rows - a.shape[0],) + a.shape[1:], a.dtype)
    return np.concatenate([a, pad], axis=0)


def run_causal_inference(
    ts: np.ndarray,
    cfg: EDMConfig,
    mesh=None,
    out_dir: Optional[str] = None,
    progress: bool = False,
) -> CausalMap:
    """Full pipeline on the given mesh (defaults to all local devices)."""
    if mesh is None:
        n = len(jax.devices())
        mesh = jax.make_mesh((n,), ("workers",))
    n_workers = mesh.size
    N, L = ts.shape
    chunk = n_workers * cfg.lib_block

    # ---- phase 1: simplex projection -> optE --------------------------
    simplex_fn = make_simplex_fn(mesh, cfg)
    rhos_parts, optE_parts = [], []
    for row0 in range(0, N, chunk):
        rows = _pad_rows(ts[row0 : row0 + chunk], chunk)
        rhos_c, optE_c = simplex_fn(jnp.asarray(rows))
        rhos_parts.append(np.asarray(rhos_c))
        optE_parts.append(np.asarray(optE_c))
    simplex_rhos = np.concatenate(rhos_parts)[:N]
    optE = np.concatenate(optE_parts)[:N].astype(np.int32)

    # ---- phase 2: all-to-all CCM, double-buffered chunk stream ---------
    ts_fut = np.asarray(ccm.all_futures(jnp.asarray(ts), cfg))
    writer = RowBlockWriter(out_dir, N) if out_dir else None
    rho = np.zeros((N, N), np.float32)

    if cfg.bucketed:
        plan, order = ccm.make_bucket_plan(optE)
        inv = np.argsort(order)
        chunk_fn = make_ccm_chunk_fn_bucketed(mesh, cfg, plan)
        ts_fut_j = jnp.asarray(ts_fut[order])
        dispatch = lambda rows: chunk_fn(jnp.asarray(rows), ts_fut_j)
        unsort = lambda rho_rows: rho_rows[:, inv]
    else:
        chunk_fn = make_ccm_chunk_fn(mesh, cfg)
        ts_fut_j = jnp.asarray(ts_fut)
        optE_j = jnp.asarray(optE)
        dispatch = lambda rows: chunk_fn(jnp.asarray(rows), ts_fut_j, optE_j)
        unsort = lambda rho_rows: rho_rows

    if writer is not None:
        chunk_plan = writer.chunk_plan(chunk)
    else:
        chunk_plan = [(r, min(chunk, N - r)) for r in range(0, N, chunk)]

    def drain(tag, rho_rows):
        row0, valid = tag
        rows_np = unsort(rho_rows)[:valid]
        rho[row0 : row0 + valid] = rows_np
        if writer is not None:
            writer.write_block(row0, rows_np)
        if progress:
            print(f"ccm rows {row0}..{row0 + valid} / {N}")

    with ChunkStreamer(drain, depth=cfg.stream_depth) as streamer:
        for row0, valid in chunk_plan:
            rows = _pad_rows(ts[row0 : row0 + chunk], chunk)
            streamer.submit((row0, valid), dispatch(rows))

    if writer is not None:
        rho = writer.assemble()
    return CausalMap(rho=rho, optE=optE, simplex_rho=simplex_rhos)
