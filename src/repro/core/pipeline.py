"""Distributed causal-inference pipeline — the paper's system layer on TPU.

Replaces the MPI master-worker (paper SSIII-C) with SPMD shard_map over
library-series blocks on the FLAT device grid (pod x data x model treated
as one worker axis, matching the paper's 512 flat workers):

  phase 1 (simplex projection): series sharded across workers, optE
    gathered to host (N int32 — the paper's single broadcast);
  phase 2 (CCM): double-buffered loop over row CHUNKS (chunk = workers x
    lib_block); each chunk is one or more jit'd shard_map calls with zero
    internal collectives.  With cfg.bucketed (default) targets are
    grouped by distinct optE so each chunk builds kNN tables only for
    the bucket set (DESIGN.md SS3).  With cfg.target_tile > 0 phase 2
    gains a SECOND tiling dimension (DESIGN.md SS7): kNN tables are
    built once per chunk (they depend only on the library rows) and the
    targets stream through in column tiles — only the live (tile, Lp)
    slice of ts_fut is resident per device, killing the full (N, Lp)
    replication, and rho is emitted as (row-chunk x col-tile) blocks.
    Completed blocks stream through a ChunkStreamer (runtime/stream.py):
    the next dispatch is queued while older blocks' device->host copies
    and TileWriter writes (sequential block writes — the BeeOND design
    point) drain, so the streaming store is off the critical path.  The
    writer doubles as the RESUME manifest; when it is active no dense
    (N, N) host array is ever allocated — the causal map is assembled
    into a memmap, so phase 2's own host working set is O(chunk x tile)
    on top of the O(N x L) inputs (ts, ts_fut) it reads.

Fault tolerance: kill the process at any point; rerun resumes at the first
uncovered row, on any mesh size (elastic — coverage is tracked per row).
Self-scheduling is unnecessary: after the mpEDM algorithmic improvement all
per-series tasks cost the same FLOPs (DESIGN.md SS2), so static balanced
decomposition is optimal.
"""
from __future__ import annotations

import functools
from time import perf_counter as _perf
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import ccm, knn, simplex
from repro.core.types import CausalMap, EDMConfig
from repro.data.store import TileWriter
from repro.runtime import telemetry
from repro.runtime.stream import ChunkStreamer


def _flat(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def default_mesh():
    """The FLAT all-local-devices worker mesh every driver defaults to
    (paper's 512 flat workers); shared by phase 1/2 and the significance
    subsystem so one process always decomposes work the same way."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("workers",))


def make_simplex_fn(mesh, cfg: EDMConfig):
    """(chunk, L) sharded on rows -> (rhos (chunk, E_max), optE (chunk,))."""
    axes = _flat(mesh)

    def local(ts_rows):
        return simplex.simplex_batch(ts_rows, cfg)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axes, None),),
            out_specs=(P(axes, None), P(axes)),
            check_rep=False,
        )
    )


def make_ccm_chunk_fn(mesh, cfg: EDMConfig):
    """(lib_rows (chunk, L) sharded, ts_fut (N, Lp) repl, optE (N,) repl)
    -> rho rows (chunk, N) sharded.  No collectives inside."""
    axes = _flat(mesh)

    def local(lib_rows, ts_fut, optE):
        return ccm.ccm_block(lib_rows, ts_fut, optE, cfg)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axes, None), P(None, None), P(None)),
            out_specs=P(axes, None),
            check_rep=False,
        )
    )


def make_ccm_chunk_fn_bucketed(mesh, cfg: EDMConfig, plan: "ccm.BucketPlan"):
    """Bucketed variant: (lib_rows sharded, ts_fut_sorted repl) -> rho rows
    (chunk, N) sharded, columns in plan-sorted target order."""
    axes = _flat(mesh)

    def local(lib_rows, ts_fut_sorted):
        return ccm.ccm_block_bucketed(lib_rows, ts_fut_sorted, cfg, plan)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axes, None), P(None, None)),
            out_specs=P(axes, None),
            check_rep=False,
        )
    )


# --------------------------------------------- tiled phase 2 (DESIGN.md SS7)
def make_ccm_tables_fn(mesh, cfg: EDMConfig):
    """(chunk, L) sharded -> (idx, w) tables sharded on rows, all-E layout.
    Called once per row chunk; the tables stay on device and feed every
    column tile of that chunk."""
    axes = _flat(mesh)
    tspec = P(axes, None, None, None)
    return jax.jit(
        shard_map(
            lambda rows: ccm._block_tables(rows, cfg),
            mesh=mesh,
            in_specs=(P(axes, None),),
            out_specs=(tspec, tspec),
            check_rep=False,
        )
    )


def make_ccm_tables_fn_bucketed(mesh, cfg: EDMConfig, plan: "ccm.BucketPlan"):
    """Bucketed tables variant: (chunk, L) sharded -> (idx, w) sharded."""
    axes = _flat(mesh)
    tspec = P(axes, None, None, None)
    return jax.jit(
        shard_map(
            lambda rows: ccm._block_tables_bucketed(rows, cfg, plan),
            mesh=mesh,
            in_specs=(P(axes, None),),
            out_specs=(tspec, tspec),
            check_rep=False,
        )
    )


def make_ccm_tile_fn(mesh, cfg: EDMConfig):
    """(idx, w sharded; fut_tile (t, Lp) repl; e_idx (t,) repl) -> rho
    (chunk, t) sharded.  Only the LIVE tile is replicated — O(tile x Lp)
    per device instead of the old O(N x Lp) ts_fut replication."""
    axes = _flat(mesh)
    tspec = P(axes, None, None, None)

    def local(idx, w, fut_tile, e_idx):
        return ccm._block_tile(idx, w, fut_tile, e_idx, cfg)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(tspec, tspec, P(None, None), P(None)),
            out_specs=P(axes, None),
            check_rep=False,
        )
    )


def make_ccm_tile_fn_bucketed(mesh, cfg: EDMConfig):
    """Returns seg_plan -> tile fn (memoized: distinct seg_plans are few —
    interior tiles of a bucket share one; see ccm.make_tile_plans)."""
    axes = _flat(mesh)
    tspec = P(axes, None, None, None)

    @functools.lru_cache(maxsize=None)
    def for_plan(seg_plan):
        def local(idx, w, fut_tile):
            return ccm._block_tile_bucketed(idx, w, fut_tile, cfg, seg_plan)

        return jax.jit(
            shard_map(
                local,
                mesh=mesh,
                in_specs=(tspec, tspec, P(None, None)),
                out_specs=P(axes, None),
                check_rep=False,
            )
        )

    return for_plan


# ---------------------------------- library-sharded kNN (DESIGN SS8, SS14)
def make_knn_shard_fn(mesh, cfg: EDMConfig, k: int, exclude_self: bool,
                      tile_c: int):
    """(Vq repl, Vc cols sharded, [lo, hi) bounds sharded) -> per-shard
    top-k tables stacked on a leading shard axis, (W, E_max, Lq, k) each.

    Every device runs the STREAMING builder over its own candidate shard
    with global column ids (``col_offset``/``col_hi``), so per-device
    memory is O(E_max x Lc/W + Lq x (k + tile)) and no device ever sees
    the full candidate axis — the paper-style multi-node library building
    block.  Zero collectives — this is the PER-SHARD half used by tests
    and the host-merge oracle; the production path is
    :func:`make_knn_shard_merge_fn`, which adds the on-device collective
    reduction (DESIGN.md SS14).
    """
    axes = _flat(mesh)

    def local(Vq, Vc_shard, bounds):
        idx, d = knn.knn_tables_all_E_streaming(
            Vq, Vc_shard, k, exclude_self=exclude_self, tile_c=tile_c,
            dist_dtype=jnp.dtype(cfg.dist_dtype),
            col_offset=bounds[0, 0], col_hi=bounds[0, 1],
        )
        return idx[None], d[None]

    tspec = P(axes, None, None, None)
    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(None, None), P(None, axes), P(axes, None)),
            out_specs=(tspec, tspec),
            check_rep=False,
        )
    )


def make_knn_shard_merge_fn(mesh, cfg: EDMConfig, k: int, k_s: int,
                            exclude_self: bool, tile_c: int):
    """(Vq repl, Vc cols sharded, [lo, hi) bounds sharded) -> GLOBAL
    (E_max, Lq, k) top-k tables, replicated — per-shard streaming build
    followed by :func:`repro.core.knn.merge_topk_collective` (DESIGN.md
    SS14), all inside one shard_map so the reduction runs on the device
    interconnect (ppermute butterfly / all_gather tree) and the tables
    never round-trip through the host.
    """
    axes = _flat(mesh)

    def local(Vq, Vc_shard, bounds):
        idx, d = knn.knn_tables_all_E_streaming(
            Vq, Vc_shard, k_s, exclude_self=exclude_self, tile_c=tile_c,
            dist_dtype=jnp.dtype(cfg.dist_dtype),
            col_offset=bounds[0, 0], col_hi=bounds[0, 1],
        )
        return knn.merge_topk_collective(idx, d, k, axes[0])

    rspec = P(None, None, None)
    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(None, None), P(None, axes), P(axes, None)),
            out_specs=(rspec, rspec),
            check_rep=False,
        )
    )


def _shard_bounds(Lc: int, W: int) -> tuple[int, np.ndarray]:
    """Contiguous candidate-shard geometry: (slab width, (W, 2) [lo, hi))."""
    shard = -(-Lc // W)
    lo = np.arange(W, dtype=np.int32) * shard
    return shard, np.stack([lo, np.minimum(lo + shard, Lc)], axis=1)


def knn_tables_library_sharded(
    Vq, Vc, k: int, cfg: EDMConfig, *, exclude_self: bool, mesh=None
) -> tuple[jax.Array, jax.Array]:
    """kNN tables with the CANDIDATE (library) axis sharded across devices.

    Each device selects top-k over its candidate shard (streaming
    builders, global column ids), then the shard tables are reduced
    ON-DEVICE by the collective bitonic merge (DESIGN.md SS14) whose
    (distance, id) tie rule matches lax.top_k — the result is
    bit-identical to the single-device streaming table whenever k <= Lc.
    Returns DEVICE (idx, sq_dists), each (E_max, Lq, k), replicated
    across the mesh: callers feeding downstream device code (CCM
    lookups, weights) pay no host round-trip; host consumers can
    np.asarray at their own boundary.
    """
    if mesh is None:
        mesh = default_mesh()
    W = mesh.size
    Lc = Vc.shape[1]
    if k > Lc:
        raise ValueError(f"k={k} exceeds candidate count Lc={Lc}")
    shard, bounds = _shard_bounds(Lc, W)
    Vc_p = jnp.pad(jnp.asarray(Vc), ((0, 0), (0, shard * W - Lc)))
    tile_c = knn.resolve_stream_tile(shard, cfg, profile="host")
    # A shard narrower than k still contributes all its candidates; the
    # global top-k can draw at most min(k, shard) entries from one shard.
    k_s = min(k, shard)
    fn = make_knn_shard_merge_fn(mesh, cfg, k, k_s, exclude_self, tile_c)
    return fn(jnp.asarray(Vq), Vc_p, jnp.asarray(bounds))


def knn_tables_library_sharded_sim(
    Vq, Vc, k: int, cfg: EDMConfig, *, exclude_self: bool, shards: int
) -> tuple[jax.Array, jax.Array]:
    """SIMULATED library sharding on however few devices are present:
    builds the ``shards`` per-shard streaming tables sequentially (same
    ``col_offset`` geometry as the real mesh path) and reduces them with
    the device-side tree merge (DESIGN.md SS14).  Exercises the exact
    collective merge arithmetic — bit-identical to both the unsharded
    table and the real multi-device path — so scaling benchmarks and CI
    can sweep shard counts beyond the local device count.
    """
    Lc = Vc.shape[1]
    if k > Lc:
        raise ValueError(f"k={k} exceeds candidate count Lc={Lc}")
    shard, bounds = _shard_bounds(Lc, shards)
    Vc_p = jnp.pad(jnp.asarray(Vc), ((0, 0), (0, shard * shards - Lc)))
    tile_c = knn.resolve_stream_tile(shard, cfg, profile="host")
    k_s = min(k, shard)
    idx_parts, d_parts = [], []
    for s in range(shards):
        lo, hi = int(bounds[s, 0]), int(bounds[s, 1])
        idx, d = knn.knn_tables_all_E_streaming(
            jnp.asarray(Vq), Vc_p[:, s * shard : (s + 1) * shard], k_s,
            exclude_self=exclude_self, tile_c=tile_c,
            dist_dtype=jnp.dtype(cfg.dist_dtype),
            col_offset=lo, col_hi=hi,
        )
        idx_parts.append(idx)
        d_parts.append(d)
    return knn.merge_topk_tree(idx_parts, d_parts, k)


def _pad_rows(a: np.ndarray, rows: int) -> np.ndarray:
    if a.shape[0] == rows:
        return a
    pad = np.zeros((rows - a.shape[0],) + a.shape[1:], a.dtype)
    return np.concatenate([a, pad], axis=0)


def _phase2_untiled(
    ts, ts_fut, optE, cfg, mesh, chunk, chunk_plan, writer, rho, progress,
    on_chunk=None,
):
    """Legacy single-tile phase 2: full-width (chunk, N) row blocks."""
    N = ts.shape[0]
    if cfg.bucketed:
        plan, order = ccm.make_bucket_plan(optE)
        inv = np.argsort(order)
        chunk_fn = make_ccm_chunk_fn_bucketed(mesh, cfg, plan)
        ts_fut_j = jnp.asarray(ts_fut[order])
        dispatch = lambda rows: chunk_fn(jnp.asarray(rows), ts_fut_j)
        unsort = lambda rho_rows: rho_rows[:, inv]
    else:
        chunk_fn = make_ccm_chunk_fn(mesh, cfg)
        ts_fut_j = jnp.asarray(ts_fut)
        optE_j = jnp.asarray(optE)
        dispatch = lambda rows: chunk_fn(jnp.asarray(rows), ts_fut_j, optE_j)
        unsort = lambda rho_rows: rho_rows

    def drain(tag, rho_rows):
        row0, valid = tag
        rows_np = unsort(rho_rows)[:valid]
        if writer is not None:
            writer.write_block(row0, rows_np)
        else:
            rho[row0 : row0 + valid] = rows_np
        if progress:
            print(f"ccm rows {row0}..{row0 + valid} / {N}")

    with ChunkStreamer(drain, depth=cfg.stream_depth,
                       stage="phase2") as streamer:
        for row0, valid in chunk_plan:
            if on_chunk is not None:
                on_chunk(row0)
            with telemetry.span("phase2", "chunk", row0=row0,
                                rows=valid, tiled=False) as t:
                with telemetry.span("phase2", "device_put", row0=row0):
                    rows = jnp.asarray(_pad_rows(ts[row0 : row0 + chunk], chunk))
                dev = dispatch(rows)
                t["chunk_rows"] = chunk
            streamer.submit((row0, valid), dev)


def _phase2_tiled(
    ts, ts_fut, optE, cfg, mesh, chunk, chunk_plan, writer, rho, progress,
    on_chunk=None,
):
    """2D (row-chunk x col-tile) phase 2: tables once per chunk, targets in
    column tiles of cfg.target_tile, blocks streamed with
    (row0, col0, valid) tags."""
    N = ts.shape[0]
    T = cfg.target_tile
    if cfg.bucketed:
        plan, order = ccm.make_bucket_plan(optE)
        tables_fn = make_ccm_tables_fn_bucketed(mesh, cfg, plan)
        tile_fn_for = make_ccm_tile_fn_bucketed(mesh, cfg)
        tile_plans = ccm.make_tile_plans(plan, T)
        if writer is not None:
            writer.ensure_col_order(order)
    else:
        order = None
        tables_fn = make_ccm_tables_fn(mesh, cfg)
        tile_fn = make_ccm_tile_fn(mesh, cfg)
        tile_plans = [(c0, None) for c0 in range(0, N, T)]
        e_idx_host = optE.astype(np.int32) - 1
        if writer is not None:
            writer.ensure_col_order(None)

    def drain(tag, block):
        row0, col0, valid = tag
        blk = block[:valid]
        last_tile = col0 + blk.shape[1] >= N
        if writer is not None:
            # On-disk (col_order) layout.  The manifest commit is batched
            # to once per row chunk (drains are ordered, so when the last
            # tile lands every earlier tile of the chunk is durable).
            writer.write_tile(row0, col0, blk, commit=last_tile)
        elif order is not None:
            rho[row0 : row0 + valid][:, order[col0 : col0 + blk.shape[1]]] = blk
        else:
            rho[row0 : row0 + valid, col0 : col0 + blk.shape[1]] = blk
        if progress and last_tile:
            print(f"ccm rows {row0}..{row0 + valid} / {N} (tiles of {T})")

    with ChunkStreamer(drain, depth=cfg.stream_depth,
                       stage="phase2") as streamer:
        for row0, valid in chunk_plan:
            if on_chunk is not None:
                on_chunk(row0)
            with telemetry.span("phase2", "chunk", row0=row0, rows=valid,
                                tiled=True, tile=T,
                                n_tiles=len(tile_plans)) as t:
                with telemetry.span("phase2", "device_put", row0=row0):
                    rows = jnp.asarray(_pad_rows(ts[row0 : row0 + chunk], chunk))
                idx, w = tables_fn(rows)  # once per chunk
                for c0, seg_plan in tile_plans:
                    c1 = min(c0 + T, N)
                    # per-tile slice only — a gather through `order` in the
                    # bucketed layout, so NO second (N, Lp) sorted host copy
                    fut_tile = jnp.asarray(
                        ts_fut[order[c0:c1]] if order is not None else ts_fut[c0:c1]
                    )
                    if seg_plan is not None:
                        block = tile_fn_for(seg_plan)(idx, w, fut_tile)
                    else:
                        block = tile_fn(
                            idx, w, fut_tile, jnp.asarray(e_idx_host[c0:c1])
                        )
                    streamer.submit((row0, c0, valid), block)
                t["chunk_rows"] = chunk
    if writer is not None:
        writer.commit()  # defensive: deferred entries are never left behind


def run_phase1(
    ts: np.ndarray, cfg: EDMConfig, mesh=None, on_chunk=None
) -> tuple[np.ndarray, np.ndarray]:
    """Phase 1 (simplex projection) alone: (simplex_rhos (N, E_max),
    optE (N,) int32).  Fleet workers call this under the ``phase1`` work
    unit; the result is the one whole-run broadcast the paper's design
    allows (SSIII-C), persisted to the shared store for every other
    worker to load.  on_chunk(row0) fires before each chunk dispatch —
    fleet workers renew their unit lease there (the whole-run phase-1
    unit can outlive a TTL on cold compile caches)."""
    if mesh is None:
        mesh = default_mesh()
    N = ts.shape[0]
    chunk = mesh.size * cfg.lib_block
    simplex_fn = make_simplex_fn(mesh, cfg)
    rhos_parts, optE_parts = [], []
    cache0 = telemetry.compile_cache_entries()
    for row0 in range(0, N, chunk):
        if on_chunk is not None:
            on_chunk(row0)
        with telemetry.span("phase1", "chunk", row0=row0,
                            chunk_rows=chunk) as t:
            with telemetry.span("phase1", "device_put", row0=row0):
                rows = jnp.asarray(_pad_rows(ts[row0 : row0 + chunk], chunk))
            rhos_c, optE_c = simplex_fn(rows)
            t0 = _perf()
            rhos_parts.append(np.asarray(rhos_c))
            optE_parts.append(np.asarray(optE_c))
            t["gather_s"] = _perf() - t0
    telemetry.emit_compile_cache("phase1", cache0)
    simplex_rhos = np.concatenate(rhos_parts)[:N]
    optE = np.concatenate(optE_parts)[:N].astype(np.int32)
    return simplex_rhos, optE


def run_phase2_chunks(
    ts: np.ndarray,
    ts_fut: np.ndarray,
    optE: np.ndarray,
    cfg: EDMConfig,
    mesh,
    chunk_plan: list[tuple[int, int]],
    writer: Optional[TileWriter] = None,
    rho: Optional[np.ndarray] = None,
    progress: bool = False,
    on_chunk=None,
) -> None:
    """Phase 2 over an EXPLICIT (row0, nrows) chunk plan — the claimable
    compute unit of the work queue (DESIGN.md SS10).

    Values are geometry-independent (kNN tables are per library row,
    targets per column), so any partition of the rows across calls —
    or across worker processes writing through writer_id-sharded
    TileWriters — produces bit-identical blocks.  ``writer`` streams
    blocks to the store; with ``rho`` they land in a host map instead.
    ``on_chunk(row0)`` fires before each chunk dispatch — fleet workers
    renew their unit lease there, same contract as :func:`run_phase1`.
    """
    chunk = mesh.size * cfg.lib_block
    phase2 = _phase2_tiled if cfg.target_tile else _phase2_untiled
    cache0 = telemetry.compile_cache_entries()
    phase2(ts, ts_fut, optE, cfg, mesh, chunk, chunk_plan, writer, rho,
           progress, on_chunk=on_chunk)
    telemetry.emit_compile_cache("phase2", cache0)


def run_causal_inference(
    ts: np.ndarray,
    cfg: EDMConfig,
    mesh=None,
    out_dir: Optional[str] = None,
    progress: bool = False,
) -> CausalMap:
    """Full pipeline on the given mesh (defaults to all local devices).

    With ``out_dir`` set, phase-2 blocks stream to a :class:`TileWriter`
    and the returned causal map is a disk-backed memmap
    (<out_dir>/causal_map/data.npy) — no dense (N, N) host array is
    allocated at any point.  The store is fingerprint-stamped on first
    write and checked on every resume: tiles computed from different
    data or a different config can never silently mix (DESIGN.md SS12).
    """
    if mesh is None:
        mesh = default_mesh()
    N, L = ts.shape
    chunk = mesh.size * cfg.lib_block

    if out_dir is not None:
        from repro.runtime import integrity

        integrity.stamp_fingerprint(
            out_dir, integrity.fingerprint_of(np.asarray(ts, np.float32), cfg)
        )

    # ---- phase 1: simplex projection -> optE --------------------------
    simplex_rhos, optE = run_phase1(ts, cfg, mesh)

    # ---- phase 2: all-to-all CCM, streamed (row-chunk x col-tile) ------
    ts_fut = np.asarray(ccm.all_futures(jnp.asarray(ts), cfg))
    writer = TileWriter(out_dir, N) if out_dir else None
    # The dense host map exists ONLY when there is no streaming store;
    # with --out the blocks go straight to disk (O(chunk x tile) host).
    rho = None if writer is not None else np.zeros((N, N), np.float32)

    if writer is not None:
        chunk_plan = writer.chunk_plan(chunk)
    else:
        chunk_plan = [(r, min(chunk, N - r)) for r in range(0, N, chunk)]

    run_phase2_chunks(
        ts, ts_fut, optE, cfg, mesh, chunk_plan, writer, rho, progress
    )

    if writer is not None:
        with telemetry.span("assemble", "causal_map", N=N):
            rho = writer.assemble(
                mmap_path=writer.dir / "causal_map" / "data.npy"
            )
        # In-process finalize path: record the run summary into the
        # history store (no-op with telemetry off and EDM_HISTORY unset;
        # a later significance finalize REPLACES it — same run identity).
        from repro.runtime import history

        history.record_run(out_dir)
    return CausalMap(rho=rho, optE=optE, simplex_rho=simplex_rhos)
