"""EDM extensions — the paper's stated future work (SSV: "EDM algorithms
other than simplex projection and CCM will be implemented in mpEDM");
they ride on the phase-1/2 machinery of DESIGN.md SS2.

  * S-Map (Sugihara 1994): locally-weighted linear forecasting; the theta
    sweep separates linear (theta=0) from state-dependent nonlinear
    dynamics, and rho(theta) rising above rho(0) is the classic
    nonlinearity test.
  * Time-delayed CCM (Ye et al. 2015, paper ref [8]): cross-map skill as a
    function of prediction lag; the argmax lag's SIGN distinguishes true
    causal direction (negative optimal lag) from synchrony artifacts —
    "the adjacency in the network is determined by time delay cross
    mapping" (paper SSII-A).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import embedding, knn
from repro.core.stats import pearson
from repro.core.types import EDMConfig


@functools.partial(jax.jit, static_argnames=("E", "cfg"))
def smap_series(x: jax.Array, theta: jax.Array, E: int, cfg: EDMConfig) -> jax.Array:
    """S-Map forecast skill of one series at locality theta.

    Solves, per target point, the distance-weighted least squares
    y = [1, coords] @ b with weights exp(-theta * d / d_mean), library =
    first half, target = second half.  Returns Pearson rho.
    """
    L = x.shape[0]
    Lp = cfg.n_points(L)
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)  # (E_max, Lp)
    fut = embedding.future_values(x, cfg.E_max, cfg.tau, cfg.Tp, Lp)
    Lh = Lp // 2
    lib, tgt = V[:E, :Lh].T, V[:E, Lh:].T  # (Lh, E), (Lt, E)
    fut_lib, fut_tgt = fut[:Lh], fut[Lh:]

    d = jnp.sqrt(
        jnp.maximum(
            jnp.sum(jnp.square(tgt[:, None, :] - lib[None, :, :]), -1), 0.0
        )
    )  # (Lt, Lh)
    dbar = jnp.mean(d, axis=1, keepdims=True)
    w = jnp.exp(-theta * d / jnp.maximum(dbar, 1e-8))  # (Lt, Lh)

    A = jnp.concatenate([jnp.ones((Lh, 1)), lib], axis=1)  # (Lh, E+1)

    def solve_one(wi):
        Aw = A * wi[:, None]
        yw = fut_lib * wi
        # ridge-regularized normal equations (stable under tiny weights)
        G = Aw.T @ Aw + 1e-4 * jnp.eye(A.shape[1])
        b = jnp.linalg.solve(G, Aw.T @ yw)
        return b

    B = jax.vmap(solve_one)(w)  # (Lt, E+1)
    Aq = jnp.concatenate([jnp.ones((tgt.shape[0], 1)), tgt], axis=1)
    pred = jnp.sum(Aq * B, axis=1)
    return pearson(fut_tgt, pred)


def smap_theta_sweep(
    x: jax.Array, E: int, cfg: EDMConfig,
    thetas=(0.0, 0.1, 0.3, 0.75, 1.5, 3.0, 6.0),
) -> jax.Array:
    """rho(theta).  rho rising above rho(0) => state-dependent
    (nonlinear) dynamics — the S-Map nonlinearity test."""
    return jnp.stack([smap_series(x, jnp.float32(t), E, cfg) for t in thetas])


@functools.partial(jax.jit, static_argnames=("E", "cfg", "lags"))
def ccm_lagged(
    x: jax.Array, y: jax.Array, E: int, cfg: EDMConfig,
    lags: tuple[int, ...] = (-4, -3, -2, -1, 0, 1, 2, 3, 4),
) -> jax.Array:
    """Time-delayed CCM: skill of estimating y(t + lag) from M_x.

    For true y -> x causation the best lag is <= 0 (the cause precedes);
    a positive optimal lag flags synchrony/anticipatory artifacts.
    Returns rho per lag.
    """
    L = x.shape[0]
    Lp = cfg.n_points(L)
    V = embedding.lag_matrix(x, cfg.E_max, cfg.tau, Lp)
    idx, sqd = knn.knn_table_single_E(V, V, E, E + 1, exclude_self=cfg.exclude_self)
    from repro.core.stats import simplex_weights

    w = simplex_weights(sqd, E + 1)
    offset = (cfg.E_max - 1) * cfg.tau
    max_lag = max(abs(l) for l in lags)
    rhos = []
    for lag in lags:
        # y value aligned to each library point's present time + Tp + lag,
        # clipped into range; edge points masked out of the correlation
        t = offset + cfg.Tp + lag + jnp.arange(Lp)
        valid_t = jnp.clip(t, 0, L - 1)
        y_fut = y[valid_t]
        pred = knn.simplex_forecast(idx, w, y_fut)
        m = ((t >= 0) & (t < L)) & (jnp.arange(Lp) < Lp - max_lag)
        mu_a = jnp.sum(y_fut * m) / jnp.sum(m)
        mu_b = jnp.sum(pred * m) / jnp.sum(m)
        a, b = (y_fut - mu_a) * m, (pred - mu_b) * m
        rho = jnp.sum(a * b) / jnp.maximum(
            jnp.sqrt(jnp.sum(a * a) * jnp.sum(b * b)), 1e-8
        )
        rhos.append(rho)
    return jnp.stack(rhos)
